"""Multi-pod dry-run walkthrough: lower + compile ONE cell against the
production meshes and print the memory/cost/roofline summary.

  PYTHONPATH=src python examples/dryrun_multipod.py \
      [--arch qwen1.5-110b] [--shape train_4k] [--mesh both]

(The full 80-cell sweep is ``bash benchmarks/run_dryrun.sh``.)
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    from repro.launch.dryrun import run_cell

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-110b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for mesh in meshes:
        rec = run_cell(args.arch, args.shape, mesh)
        print(f"\n=== {args.arch} x {args.shape} x {mesh} "
              f"({'2x16x16' if mesh == 'multi' else '16x16'}) ===")
        if rec["status"] != "ok":
            print(rec)
            continue
        rf = rec["roofline"]
        mem = rec["memory"]
        print(f"step={rec['step']} dispatch={rec['dispatch']} "
              f"compile={rec['compile_s']:.1f}s")
        print(f"per-device arg bytes: "
              f"{mem['arg_bytes_analytic_per_device']/2**30:.2f} GiB")
        print(f"roofline: compute={rf['compute_s']:.3e}s "
              f"memory={rf['memory_s']:.3e}s "
              f"collective={rf['collective_s']:.3e}s "
              f"-> bottleneck: {rf['bottleneck']}")
        print(f"useful_ratio={rf['useful_ratio']:.3f} "
              f"roofline_frac={rf['roofline_frac']:.4f}")
        cc = rec["collectives"]
        print("collective schedule:",
              {k: f"{v/2**30:.2f}GiB" for k, v in cc.items()
               if isinstance(v, float) and v > 0})


if __name__ == "__main__":
    main()
