"""Quickstart: the public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

1. pick an architecture config      (repro.configs)
2. train a smoke-scale variant      (repro.launch.train)
3. serve it with continuous batching (repro.serving)
4. schedule replicas with Jiagu     (repro.core)
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import InputShape, get_config, get_smoke_config, \
    list_archs
from repro.launch.train import train_loop
from repro.models import model as model_lib
from repro.serving.engine import Request, ServingEngine

# -- 1. configs --------------------------------------------------------------
print("assigned architectures:", ", ".join(list_archs()))
full = get_config("gemma2-2b")
print(f"gemma2-2b: {full.n_layers}L d={full.d_model} "
      f"params={full.param_count()/1e9:.2f}B")
cfg = get_smoke_config("gemma2-2b")     # laptop-scale, same block pattern

# -- 2. train a few steps ------------------------------------------------------
mesh = jax.make_mesh((1, 1), ("data", "model"))
shape = InputShape("quickstart", 128, 4, "train")
state, losses = train_loop(cfg, shape, mesh, steps=20, log_every=5)
print(f"trained 20 steps: loss {losses[0]:.2f} -> {losses[-1]:.2f}")

# -- 3. serve it ----------------------------------------------------------------
eng = ServingEngine(cfg, state["params"], slots=2, max_len=128)
eng.scale_up(2)
rng = np.random.default_rng(0)
for i in range(4):
    eng.submit(Request(rid=i, prompt=rng.integers(
        0, cfg.vocab_size, 16).astype(np.int32), max_new=8))
done = eng.drain()
print(f"served {len(done)} requests; sample completion: {done[0].tokens}")

# -- 4. Jiagu-schedule replicas ---------------------------------------------------
from repro.core import (Cluster, GroundTruth, JiaguScheduler, PerfPredictor,
                        ProfileStore, QoSStore, arch_functions,
                        generate_dataset)

specs = arch_functions()                 # one serving function per arch
gt = GroundTruth(seed=0)
store = ProfileStore(seed=0)
qos = QoSStore(store, gt)
pred = PerfPredictor(n_trees=16, max_depth=8, seed=0)
X, y = generate_dataset(specs, gt, store, qos, 800, seed=1)
pred.add_dataset(X, y)

cluster = Cluster(specs)
sched = JiaguScheduler(cluster, store, qos, pred)
fn = "serve-gemma2-2b"
sched.schedule(fn, 3, now=0.0)           # slow path: predict capacity
sched.on_tick(1.0)                       # async capacity-table update
placements = sched.schedule(fn, 2, now=2.0)   # fast path: table lookup
m = sched.metrics
print(f"scheduled 5 replicas: fast={m.fast} slow={m.slow} "
      f"mean latency {m.mean_latency_ms:.2f} ms")
