"""Jiagu end-to-end serving: the paper's control plane scheduling REAL
model replicas (smoke-scale gemma2 + mamba2), driven by a fluctuating
request trace.  Dual-staged scaling releases/revives replicas as load
moves; every completion is a real greedy decode.

``--scenario`` swaps the default sinusoidal offered load for any
registered scenario trace program (``repro.platform`` scenario
registry: correlated burst storms, migrating diurnal peaks,
heavy-tailed cold-start churn, the Azure-like sparse tail, or a
``replay`` of a real CSV dump via ``--trace-csv``), normalized to
smoke-scale request rates.

  PYTHONPATH=src python examples/serve_cluster.py [--seconds 60]
      [--scenario burst-storm]
      [--scenario replay --trace-csv tests/data/sample_trace.csv]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import model as model_lib
from repro.platform import get_scenario_builder, registered_scenarios
from repro.serving.engine import Request, ServingEngine


def offered_load(scenario: str, archs, seconds: int, seed: int = 0,
                 peak: float = 3.5, **trace_kw):
    """Per-arch Poisson-rate series from a registered scenario trace
    program.

    One global normalization (the hottest arch's hottest second offers
    ``peak`` requests) so the cross-arch load skew the scenario
    generators produce is preserved; None for the default sinusoid."""
    if scenario == "sinusoid":
        return None
    gen = get_scenario_builder(scenario)
    tr = gen(list(archs), duration_s=seconds, seed=seed,
             scale_rps={a: 1.0 for a in archs}, **trace_kw)
    hi = max(float(tr.rps[a].max()) for a in archs)
    factor = peak / hi if hi > 0 else 1.0
    return {a: tr.rps[a] * factor for a in archs}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=int, default=30)
    ap.add_argument("--release-after", type=int, default=6,
                    help="ticks of low load before releasing a replica")
    ap.add_argument("--scenario", default="sinusoid",
                    choices=["sinusoid"] + registered_scenarios(),
                    help="offered-load program (default: sinusoid)")
    ap.add_argument("--trace-csv", default=None,
                    help="CSV dump for --scenario replay "
                         "(fn,timestamp,rps rows)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    trace_kw = {}
    if args.scenario == "replay":
        if not args.trace_csv:
            ap.error("--scenario replay requires --trace-csv")
        trace_kw["path"] = args.trace_csv

    engines = {}
    for arch in ["gemma2-2b", "mamba2-2.7b"]:
        cfg = get_smoke_config(arch)
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, params, slots=2, max_len=96)
        eng.scale_up(2)
        engines[arch] = (cfg, eng)

    rng = np.random.default_rng(args.seed)
    rid = 0
    low_ticks = {a: 0 for a in engines}
    stats = {a: dict(logical=0, released=0, done=0) for a in engines}
    load = offered_load(args.scenario, list(engines), args.seconds,
                        seed=args.seed, **trace_kw)

    for t in range(args.seconds):
        for arch, (cfg, eng) in engines.items():
            if load is not None:
                lam = float(load[arch][t])
            else:
                # sinusoidal offered load, out of phase per arch
                lam = 1.5 + 1.4 * np.sin(t / 5.0
                                         + (0 if arch < "m" else 2.5))
            for _ in range(rng.poisson(max(lam, 0.05))):
                eng.submit(Request(rid=rid, prompt=rng.integers(
                    0, cfg.vocab_size, 12).astype(np.int32), max_new=4))
                rid += 1
            # dual-staged autoscaling on queue pressure
            busy = sum(i.n_active() for i in eng.instances.values())
            cap = eng.n_saturated() * eng.slots
            if eng.queue and eng.n_saturated() < len(eng.instances):
                got = eng.logical_start(1)       # <1 ms re-route
                stats[arch]["logical"] += got
                low_ticks[arch] = 0
            elif busy < cap // 2 and not eng.queue:
                low_ticks[arch] += 1
                if low_ticks[arch] >= args.release_after and \
                        eng.n_saturated() > 1:
                    eng.release(1)
                    stats[arch]["released"] += 1
                    low_ticks[arch] = 0
            else:
                low_ticks[arch] = 0
            eng.tick()
        if t % 10 == 0:
            line = " | ".join(
                f"{a}: sat={e.n_saturated()}/{len(e.instances)} "
                f"q={len(e.queue)} done={len(e.done)}"
                for a, (_c, e) in engines.items())
            print(f"t={t:3d}  {line}", flush=True)

    for arch, (cfg, eng) in engines.items():
        done = eng.drain()
        lats = [r.latency_ms for r in done]
        p90 = float(np.percentile(lats, 90)) if lats else 0.0
        s = stats[arch]
        print(f"{arch}: {len(done)} requests served, p90 {p90:.0f} ms, "
              f"{s['released']} releases, {s['logical']} logical cold "
              f"starts (0 real cold starts after warmup)")


if __name__ == "__main__":
    main()
