"""End-to-end training driver: train a ~100M-parameter gemma2-family LM
on the repo's own source code (byte-level) for a few hundred steps with
checkpointing and fault-tolerance enabled.

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]

``--tiny`` shrinks to a ~1M model for a fast demo; the default ~100M
config takes a while per step on 1 CPU core — it is the honest "train a
~100M model for a few hundred steps" driver and checkpoints every 50
steps so an interrupted run resumes (rerun the same command).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.base import InputShape, get_config
from repro.launch.train import train_loop
from repro.optim.adamw import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    args = ap.parse_args()

    base = get_config("gemma2-2b")
    if args.tiny:
        cfg = base.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                           head_dim=32, d_ff=512, vocab_size=256,
                           window=128, dtype="float32")
        batch, seq = args.batch or 8, args.seq or 256
    else:
        # ~100M-param gemma2-family model (byte vocab keeps the embedding
        # small so the budget goes to the blocks)
        cfg = base.replace(n_layers=10, d_model=768, n_heads=8,
                           n_kv_heads=4, head_dim=96, d_ff=3072,
                           vocab_size=256, window=512, dtype="float32")
        batch, seq = args.batch or 8, args.seq or 512
    n = cfg.param_count()
    print(f"model: {cfg.n_layers}L d={cfg.d_model} params={n/1e6:.1f}M")

    mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
    shape = InputShape("train_lm", seq, batch, "train")
    oc = AdamWConfig(lr=6e-4, total_steps=args.steps,
                     warmup_steps=max(args.steps // 20, 1))
    state, losses = train_loop(
        cfg, shape, mesh, steps=args.steps, ckpt_dir=args.ckpt_dir,
        resume=True, save_every=50, log_every=10, data="bytes", opt_cfg=oc)
    print(f"done. loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(ckpts in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
