"""The composable scheduling-decision pipeline (filter -> score -> bind).

Covers: stage-level behaviour (pre-decision capacity gate, filters,
scorers, picker stages), the placement-parity gate for all four
re-expressed schedulers (pipeline stack vs legacy ``schedule()`` must be
bit-identical end to end), the ``HarvestingScheduler``'s QoS-margin
release behaviour, and ``DecisionTrace`` round-tripping through the
``EventHub`` observer hooks."""
import json

import pytest

from repro.core import (CapEntry, Cluster, GroundTruth, PerfPredictor,
                        ProfileStore, QoSStore, generate_dataset,
                        scenario_world, synthetic_functions)
from repro.core.harvesting import HarvestingScheduler
from repro.core.pipeline import (Binder, BreachAwareReleasePicker,
                                 CapacityTableGate, DecisionContext,
                                 DecisionTrace, GreedyLogicalStartPicker,
                                 GreedyReleasePicker, InstanceCountScorer,
                                 NodeFilter, NodeScorer,
                                 PipelineJiaguScheduler, PreDecision,
                                 RequestedFitFilter, StaleTableFilter,
                                 TableBoundLogicalStartPicker,
                                 WarmAffinityScorer)
from repro.platform import (Observer, Platform, PlatformConfig,
                            PlatformConfigError, get_stage,
                            register_stage, registered_stages,
                            scenario_from_config, scheduler_entry)

SMALL = {
    "scenario": {"kind": "burst-storm", "n_functions": 3,
                 "duration_s": 40, "target_nodes": 6, "seed": 0},
    "prediction": {"n_train": 250, "n_trees": 6},
}

PAIRS = [("k8s", "k8s-pipeline"), ("owl", "owl-pipeline"),
         ("jiagu", "jiagu-pipeline"), ("gsight", "gsight-pipeline")]


@pytest.fixture(scope="module")
def scenario():
    return scenario_from_config(PlatformConfig.from_dict(SMALL))


def _fresh_world(scenario):
    """GroundTruth.measure draws noise from a stateful RNG, so parity
    arms must each start from identical world state."""
    return scenario_world(scenario, n_train=250, n_trees=6)


@pytest.fixture(scope="module")
def tiny():
    """Hand-built world pieces for stage-level unit tests."""
    specs = synthetic_functions(3, seed=4)
    gt = GroundTruth(seed=0)
    store = ProfileStore(seed=0)
    qos = QoSStore(store, gt)
    pred = PerfPredictor(n_trees=6, max_depth=6, seed=0)
    X, y = generate_dataset(specs, gt, store, qos, 300, seed=1)
    pred.add_dataset(X, y)
    return specs, gt, store, qos, pred


def _jiagu_pipeline(tiny) -> PipelineJiaguScheduler:
    specs, _gt, store, qos, pred = tiny
    return PipelineJiaguScheduler(Cluster(specs), store, qos, pred)


# ---------------------------------------------------------------------------
# Stage-level units
# ---------------------------------------------------------------------------


def test_stages_satisfy_protocols():
    assert isinstance(StaleTableFilter(), NodeFilter)
    assert isinstance(RequestedFitFilter(), NodeFilter)
    assert isinstance(InstanceCountScorer(), NodeScorer)
    assert isinstance(WarmAffinityScorer(), NodeScorer)
    assert isinstance(CapacityTableGate(), PreDecision)
    from repro.core.pipeline import DeployOneBinder, JiaguSlowBinder
    assert isinstance(JiaguSlowBinder(), Binder)
    assert isinstance(DeployOneBinder(), Binder)


def test_capacity_table_gate_places_from_fresh_tables(tiny):
    sched = _jiagu_pipeline(tiny)
    fn = sorted(sched.cluster.specs)[0]
    node = sched.cluster.add_node()
    node.deploy(fn, 2)
    node.table[fn] = CapEntry(capacity=5, fresh=True)
    rows_before = sched.metrics.critical_inference_rows
    ctx = DecisionContext(sched, fn, 3, 0.0,
                          DecisionTrace(sched.name, fn, 0.0, 3))
    CapacityTableGate().gate(ctx)
    # capacity 5, 2 saturated -> 3 more fit at pure table-lookup cost
    assert ctx.remaining == 0
    assert node.funcs[fn].n_sat == 5
    assert sched.metrics.fast == 1
    assert sched.metrics.critical_inference_rows == rows_before
    [binding] = ctx.trace.pre_decision
    assert (binding.node_id, binding.count) == (node.id, 3)
    assert binding.capacity == 5 and binding.room_before == 3


def test_capacity_table_gate_skips_stale_and_full(tiny):
    sched = _jiagu_pipeline(tiny)
    fn = sorted(sched.cluster.specs)[0]
    stale = sched.cluster.add_node()
    stale.deploy(fn, 1)
    stale.table[fn] = CapEntry(capacity=9, fresh=False)
    full = sched.cluster.add_node()
    full.deploy(fn, 3)
    full.table[fn] = CapEntry(capacity=3, fresh=True)
    ctx = DecisionContext(sched, fn, 2, 0.0,
                          DecisionTrace(sched.name, fn, 0.0, 2))
    CapacityTableGate().gate(ctx)
    assert ctx.remaining == 2                      # nothing placeable
    assert ctx.trace.filtered == {"stale-table": 1,
                                  "no-table-headroom": 1}


def test_scorer_orderings(tiny):
    sched = _jiagu_pipeline(tiny)
    names = sorted(sched.cluster.specs)
    fn = names[0]
    a = sched.cluster.add_node()
    a.deploy(names[1], 4)
    b = sched.cluster.add_node()
    b.deploy(fn, 1)
    ctx = DecisionContext(sched, fn, 1, 0.0, None)
    # most-packed-first
    assert InstanceCountScorer().score(ctx, a) > \
        InstanceCountScorer().score(ctx, b)
    # warm affinity outranks packing
    assert WarmAffinityScorer().score(ctx, b) > \
        WarmAffinityScorer().score(ctx, a)


def test_picker_stages_match_scheduler_capabilities(tiny):
    """BaseScheduler delegates the ReleasePicker/LogicalStartPicker
    capabilities to stage objects; Jiagu installs the table-bound
    logical-start stage."""
    sched = _jiagu_pipeline(tiny)
    assert isinstance(sched.release_stage, GreedyReleasePicker)
    assert isinstance(sched.logical_start_stage,
                      TableBoundLogicalStartPicker)
    fn = sorted(sched.cluster.specs)[0]
    light = sched.cluster.add_node()
    light.deploy(fn, 1)
    heavy = sched.cluster.add_node()
    heavy.deploy(fn, 5)
    picks = sched.pick_release_nodes(fn, 2)
    assert picks[0][0] is light                    # least-loaded first
    heavy.release(fn, 3)
    heavy.table[fn] = CapEntry(capacity=4, fresh=True)
    picks = sched.pick_logical_start_nodes(fn, 3)
    # table capacity 4, 2 saturated -> absorb only 2 of 3 cached
    assert picks == [(heavy, 2)]


def test_stage_registry_lookup_and_unknown():
    assert "greedy" in registered_stages("release")
    assert "table-bound" in registered_stages("logical-start")
    assert get_stage("release", "breach-aware") is BreachAwareReleasePicker
    assert get_stage("logical-start", "greedy") is GreedyLogicalStartPicker
    with pytest.raises(ValueError, match="unknown pipeline stage"):
        get_stage("release", "no-such-stage")
    with pytest.raises(ValueError, match="already registered"):
        register_stage("release", "greedy", GreedyReleasePicker)


# ---------------------------------------------------------------------------
# Placement parity: pipeline stacks vs legacy schedule()
# ---------------------------------------------------------------------------


def _run(scenario, name):
    plat = Platform.build(
        scenario=scenario, config={**SMALL, "scheduler": {"name": name}},
        world=_fresh_world(scenario))
    res = plat.run()
    placement = sorted(
        tuple(sorted((fn, s.n_sat, s.n_cached)
                     for fn, s in n.funcs.items()))
        for n in plat.cluster.nodes.values())
    return res, placement


@pytest.mark.parametrize("legacy_name,pipeline_name", PAIRS)
def test_pipeline_placement_parity(scenario, legacy_name, pipeline_name):
    legacy, place_l = _run(scenario, legacy_name)
    pipe, place_p = _run(scenario, pipeline_name)
    assert place_l == place_p
    assert legacy.density == pipe.density
    assert legacy.qos_violation_rate == pipe.qos_violation_rate
    assert legacy.requests == pipe.requests
    assert legacy.nodes_peak == pipe.nodes_peak
    # (sched_time_ms is measured inference wall time — identical call
    # structure but not bit-identical clock readings)
    for attr in ("decisions", "fast", "slow", "instances_placed",
                 "failed"):
        assert getattr(legacy.sched, attr) == getattr(pipe.sched, attr), \
            attr
    for attr in ("real_cold_starts", "logical_cold_starts", "releases",
                 "evictions", "migrations"):
        assert getattr(legacy.scaling, attr) == \
            getattr(pipe.scaling, attr), attr


def test_pipeline_variants_registered():
    for _legacy, name in PAIRS:
        entry = scheduler_entry(name)
        assert entry.name == name
    assert scheduler_entry("jiagu-pipeline").dual_staged_default
    assert scheduler_entry("jiagu-pipeline").needs_predictor
    assert not scheduler_entry("k8s-pipeline").needs_predictor


# ---------------------------------------------------------------------------
# DecisionTrace: emission, round trip, config toggle
# ---------------------------------------------------------------------------


class _TraceCollector(Observer):
    def __init__(self):
        self.traces = []
        self.schedules = 0

    def on_schedule(self, now, fn, placements, trace=None):
        self.schedules += 1
        self.traces.append((fn, placements, trace))


def test_decision_traces_through_eventhub(scenario):
    obs = _TraceCollector()
    plat = Platform.build(
        scenario=scenario,
        config={**SMALL, "scheduler": {"name": "jiagu-pipeline"}},
        world=_fresh_world(scenario), observers=[obs])
    plat.run()
    assert obs.schedules > 0
    traced = [t for _fn, _p, t in obs.traces if t is not None]
    assert traced, "pipeline scheduler produced no traces"
    for fn, placements, trace in obs.traces:
        assert trace is not None
        assert trace.fn == fn
        assert trace.placed == sum(p.count for p in placements)
        assert trace.requested >= trace.placed
        # every placement is explained by a gate or binder record
        explained = sum(b.count for b in trace.pre_decision) \
            + sum(b.count for b in trace.bindings)
        assert explained == trace.placed
        # round trip: to_dict must be pure JSON
        d = trace.to_dict()
        back = json.loads(json.dumps(d))
        assert back["fn"] == fn
        assert back["placed"] == trace.placed
        summary = trace.summary()
        json.dumps(summary)
        assert summary["placed"] == trace.placed


def test_legacy_schedulers_produce_no_trace(scenario):
    obs = _TraceCollector()
    plat = Platform.build(scenario=scenario, config=SMALL,
                          world=_fresh_world(scenario), observers=[obs])
    plat.run()
    assert obs.schedules > 0
    assert all(t is None for _fn, _p, t in obs.traces)


def test_decision_traces_config_toggle(scenario):
    obs = _TraceCollector()
    plat = Platform.build(
        scenario=scenario,
        config={**SMALL, "scheduler": {"name": "jiagu-pipeline"},
                "pipeline": {"decision_traces": False}},
        world=_fresh_world(scenario), observers=[obs])
    plat.run()
    assert obs.schedules > 0
    assert all(t is None for _fn, _p, t in obs.traces)


def test_pipeline_section_roundtrip_and_validation():
    cfg = PlatformConfig.from_dict({
        "pipeline": {"decision_traces": False,
                     "release_picker": "breach-aware",
                     "logical_start_picker": "greedy"}})
    d = cfg.to_dict()
    json.dumps(d)
    assert PlatformConfig.from_dict(d) == cfg
    with pytest.raises(ValueError, match="unknown pipeline stage"):
        PlatformConfig.from_dict({
            "pipeline": {"release_picker": "no-such"}}).validate()
    with pytest.raises(PlatformConfigError, match="harvest_headroom"):
        PlatformConfig.from_dict({
            "scheduler": {"harvest_headroom": 0.0}}).validate()
    with pytest.raises(PlatformConfigError, match="cooldown"):
        PlatformConfig.from_dict({
            "scheduler": {"qos_release_cooldown_s": -1.0}}).validate()


def test_picker_stage_override_from_manifest(scenario):
    plat = Platform.build(
        scenario=scenario,
        config={**SMALL, "pipeline": {"release_picker": "breach-aware"}},
        world=_fresh_world(scenario))
    assert isinstance(plat.scheduler.release_stage,
                      BreachAwareReleasePicker)


# ---------------------------------------------------------------------------
# HarvestingScheduler
# ---------------------------------------------------------------------------


def _harvesting(tiny, **kw) -> HarvestingScheduler:
    specs, _gt, store, qos, pred = tiny
    sched = HarvestingScheduler(Cluster(specs), store, qos, pred, **kw)
    sched.trace_decisions = True        # standalone: opt in explicitly
    return sched


def test_harvesting_schedules_and_traces(tiny):
    sched = _harvesting(tiny)
    fn = sorted(sched.cluster.specs)[0]
    placements = sched.schedule(fn, 4, 0.0)
    assert sum(p.count for p in placements) == 4
    trace = sched.take_trace()
    assert trace is not None and trace.placed == 4
    assert sched.metrics.slow >= 1          # capacity-solved placements


def test_harvesting_headroom_bounds_placement(tiny):
    tight = _harvesting(tiny, harvest_headroom=0.5)
    loose = _harvesting(tiny, harvest_headroom=1.0)
    fn = sorted(tight.cluster.specs)[0]
    tight.schedule(fn, 30, 0.0)
    loose.schedule(fn, 30, 0.0)
    per_node_tight = max(n.funcs[fn].n_sat
                         for n in tight.cluster.nodes.values())
    per_node_loose = max(n.funcs[fn].n_sat
                         for n in loose.cluster.nodes.values())
    assert per_node_tight < per_node_loose


def test_harvesting_qos_breach_release_and_cooldown(tiny):
    sched = _harvesting(tiny, qos_release_cooldown_s=30.0)
    fn = sorted(sched.cluster.specs)[0]
    sched.schedule(fn, 8, 0.0)
    node = max(sched.cluster.nodes.values(),
               key=lambda n: n.funcs.get(fn).n_sat if fn in n.funcs else 0)
    sat_before = node.funcs[fn].n_sat
    assert sat_before > 0

    sched.observe(node, ok=False, now=10.0)
    # released (not evicted): saturated dropped, cached grew
    assert sched.qos_breaches == 1
    assert sched.qos_released >= 1
    assert node.funcs[fn].n_sat < sat_before
    assert node.funcs[fn].n_cached >= 1
    assert sched.qos_cooldown_until(node) == 40.0

    # a second breach during cooldown extends it but releases nothing
    released = sched.qos_released
    sched.observe(node, ok=False, now=12.0)
    assert sched.qos_released == released
    assert sched.qos_cooldown_until(node) == 42.0

    # while cooling down, the pipeline refuses to re-harvest the node
    sched.schedule(fn, 2, 15.0)
    trace = sched.take_trace()
    assert "qos-cooldown" in trace.filtered
    assert all(b.node_id != node.id
               for b in trace.pre_decision + trace.bindings)
    # ... and the logical-start stage skips it too
    sched._now = 15.0
    assert all(n.id != node.id
               for n, _k in sched.pick_logical_start_nodes(fn, 1))

    # keep-alive: released instances the load never re-claimed are
    # evicted for real
    cached = node.funcs[fn].n_cached
    sched.on_tick(100.0)
    assert node.funcs.get(fn) is None or \
        node.funcs[fn].n_cached < cached


def test_harvesting_release_enters_autoscaler_ledger(tiny):
    """With an assembled control plane, QoS-breach releases go through
    Autoscaler.note_release: counted, evented, and keep-alive-evicted
    by the standard ledger instead of harvesting's private fallback."""
    from repro.core import Autoscaler, ScalingConfig
    sched = _harvesting(tiny)
    aut = Autoscaler(sched.cluster, sched, ScalingConfig())
    sched.release_ledger = aut          # what build_simulation wires
    fn = sorted(sched.cluster.specs)[0]
    sched.schedule(fn, 6, 0.0)
    node = max(sched.cluster.nodes.values(),
               key=lambda n: n.funcs[fn].n_sat)
    sched.observe(node, ok=False, now=5.0)
    assert sched.qos_released >= 1
    assert aut.metrics.releases == sched.qos_released
    assert not sched._released               # fallback deque unused
    ledgered = sum(e[2] for e in aut._ledger.q.get(fn, ()))
    assert ledgered == sched.qos_released


def test_harvesting_breach_aware_release_prefers_breached_node(tiny):
    sched = _harvesting(tiny)
    fn = sorted(sched.cluster.specs)[0]
    calm = sched.cluster.add_node()
    calm.deploy(fn, 1)                      # least-loaded, but healthy
    breached = sched.cluster.add_node()
    breached.deploy(fn, 4)
    sched._cooldown_until[breached.id] = 50.0
    picks = sched.release_stage.pick_release_nodes(fn, 2)
    assert picks[0][0] is breached


def test_harvesting_from_pure_manifest(scenario):
    entry = scheduler_entry("harvesting")
    assert entry.needs_predictor and entry.dual_staged_default
    plat = Platform.build(
        scenario=scenario,
        config={**SMALL,
                "scheduler": {"name": "harvesting",
                              "harvest_headroom": 0.85,
                              "qos_release_cooldown_s": 20.0}},
        world=_fresh_world(scenario))
    assert isinstance(plat.scheduler, HarvestingScheduler)
    assert plat.scheduler.harvest_headroom == 0.85
    assert plat.scheduler.cooldown_s == 20.0
    res = plat.run()
    assert res.ticks == 40
    assert res.sched.instances_placed > 0
