"""Per-arch smoke tests: every assigned architecture instantiates a
reduced config, runs a forward/train step (shapes + finiteness), and the
decode path is consistent with the full-sequence forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (InputShape, SHAPES, cell_is_runnable,
                                get_config, get_smoke_config, list_archs)
from repro.models import model as model_lib
from repro.models import steps as steps_lib

ARCHS = list_archs()
SMOKE_SHAPE = InputShape("smoke", 64, 2, "train")


def _params(cfg, seed=0):
    return model_lib.init_params(cfg, jax.random.PRNGKey(seed))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = _params(cfg)
    batch = steps_lib.make_train_batch(cfg, SMOKE_SHAPE)
    logits = model_lib.forward(cfg, params, batch)
    B = SMOKE_SHAPE.global_batch
    S = SMOKE_SHAPE.seq_len
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    assert logits.shape[1] == S  # frontends add+consume their own tokens
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_loss_finite_and_grads_flow(arch):
    cfg = get_smoke_config(arch)
    params = _params(cfg)
    batch = steps_lib.make_train_batch(cfg, SMOKE_SHAPE)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: steps_lib.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not get_config(a).encoder_only])
def test_prefill_decode_matches_forward(arch):
    """Teacher forcing: prefill(S0) + decode of the next tokens must match
    the full forward logits at those positions."""
    cfg = get_smoke_config(arch)
    # MoE archs: capacity-based dropping differs between the full-sequence
    # forward and the shorter prefill (per-expert capacity scales with
    # token count), so logits at kept positions diverge for reasons that
    # have nothing to do with the decode cache under test.  Lift capacity
    # so no token drops and the equivalence is exact (verified: with no
    # drops llama4 prefill matches forward to 0.0).
    if cfg.moe is not None:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    params = _params(cfg)
    B, S0, n_dec = 2, 24, 4
    S = S0 + n_dec
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(rng.standard_normal(
            (B, cfg.n_frontend_tokens, cfg.frontend_dim)), jnp.float32)
    full = model_lib.forward(cfg, params, batch).astype(jnp.float32)
    n_front = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0

    pre_batch = {k: (v[:, :S0] if k == "tokens" else v)
                 for k, v in batch.items()}
    logits0, cache = model_lib.prefill(cfg, params, pre_batch, S + n_front)
    tol = 5e-3
    np.testing.assert_allclose(
        np.asarray(logits0, np.float32),
        np.asarray(full[:, n_front + S0 - 1], np.float32),
        atol=tol, rtol=tol)

    for i in range(n_dec - 1):
        pos = jnp.full((B,), S0 + i, jnp.int32) + n_front
        lg, cache = model_lib.decode_step(cfg, params,
                                          jnp.asarray(toks[:, S0 + i]),
                                          pos, cache)
        np.testing.assert_allclose(
            np.asarray(lg, np.float32),
            np.asarray(full[:, n_front + S0 + i], np.float32),
            atol=tol, rtol=tol)


def test_full_configs_match_assignment_table():
    """Exact dims from the assignment (one assert per row)."""
    t = {
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    }
    for arch, (L, d, H, kv, dff, V) in t.items():
        cfg = get_config(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff if cfg.moe is None or arch.startswith("deepseek")
               else cfg.d_ff, cfg.vocab_size)
        if arch == "deepseek-v2-236b":
            got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                   cfg.moe.d_ff_expert, cfg.vocab_size)
        if arch == "mamba2-2.7b":
            got = (cfg.n_layers, cfg.d_model, 0, 0, 0, cfg.vocab_size)
        assert got == (L, d, H, kv, dff, V), (arch, got)
    assert get_config("deepseek-v2-236b").moe.n_experts == 160
    assert get_config("deepseek-v2-236b").moe.top_k == 6
    assert get_config("llama4-maverick-400b-a17b").moe.n_experts == 128
    assert get_config("llama4-maverick-400b-a17b").moe.top_k == 1
    assert get_config("mamba2-2.7b").ssd.d_state == 128
    assert get_config("gemma-7b").resolved_head_dim() == 256


def test_cell_skips_match_design():
    skipped = {(a, s.name) for a in ARCHS for s in SHAPES
               if not cell_is_runnable(get_config(a), s)[0]}
    want = {("hubert-xlarge", "decode_32k"), ("hubert-xlarge", "long_500k"),
            ("deepseek-v2-236b", "long_500k"), ("gemma-7b", "long_500k"),
            ("qwen1.5-110b", "long_500k"), ("internvl2-2b", "long_500k")}
    assert skipped == want


def test_param_count_analytic_vs_actual():
    """Analytic param_count matches the real init tree within ~1%."""
    for arch in ["gemma2-2b", "mamba2-2.7b", "recurrentgemma-2b",
                 "deepseek-v2-236b"]:
        cfg = get_smoke_config(arch)
        params = _params(cfg)
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.02, (
            arch, actual, analytic)


def test_moe_dispatch_methods_agree():
    """einsum (GShard), grouped gshard and sort dispatch agree on kept
    tokens."""
    cfg = get_smoke_config("deepseek-v2-236b")
    params = _params(cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32)
    outs = {}
    for d in ["einsum", "sort", "gshard:1", "gshard:2", "sortg:1",
              "sortg:4"]:
        outs[d] = np.asarray(
            model_lib.forward(cfg, params, {"tokens": toks}, dispatch=d),
            np.float32)
    np.testing.assert_allclose(outs["einsum"], outs["sort"], atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(outs["einsum"], outs["gshard:1"], atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(outs["einsum"], outs["sortg:1"], atol=2e-3,
                               rtol=2e-3)
    # grouped variants use per-group capacity: match when not binding
    np.testing.assert_allclose(outs["einsum"], outs["sortg:4"], atol=2e-2,
                               rtol=2e-2)
    # grouped capacity differs per group; agreement holds when capacity
    # is not binding (tiny batch): still require close match
    np.testing.assert_allclose(outs["einsum"], outs["gshard:2"], atol=2e-2,
                               rtol=2e-2)


def test_long_context_ring_buffer_local_attention():
    """Decode past the local window uses the ring cache correctly:
    compare against a fresh prefill of the trailing window."""
    cfg = get_smoke_config("gemma2-2b")  # local/global alternating
    params = _params(cfg)
    B, W = 1, cfg.window
    rng = np.random.default_rng(1)
    S = W * 3  # run well past the window
    toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    full = model_lib.forward(cfg, params,
                             {"tokens": jnp.asarray(toks)})
    logits0, cache = model_lib.prefill(
        cfg, params, {"tokens": jnp.asarray(toks[:, :S - 8])}, S)
    lg = logits0
    for i in range(S - 8, S):
        lg, cache = model_lib.decode_step(
            cfg, params, jnp.asarray(toks[:, i]),
            jnp.full((B,), i, jnp.int32), cache)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(full[:, -1], np.float32),
                               atol=5e-3, rtol=5e-3)
