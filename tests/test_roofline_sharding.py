"""Roofline HLO parsing and sharding-rule units (no multi-device state
needed — specs are computed against a duck-typed mesh)."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.roofline import (collective_bytes, hlo_stats,
                                   model_flops, normalize_cost_analysis,
                                   roofline_terms)
from repro.distributed.sharding import (batch_pspecs, cache_pspec_for,
                                        dp_axes, pspec_for_param)
from repro.configs.base import SHAPE_BY_NAME, get_config


# ---------------------------------------------------------------------------
# HLO parsing
# ---------------------------------------------------------------------------


def test_hlo_stats_scales_loop_bodies():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    st = hlo_stats(c.as_text())
    one = 2 * 64 * 128 * 128
    assert abs(st["flops"] / one - 7.0) < 0.01
    # XLA's own cost_analysis counts the body once — our reason to parse
    # (list in older JAX, dict in newer — normalize either way)
    xla = normalize_cost_analysis(c.cost_analysis())
    assert abs(xla["flops"] / one - 1.0) < 0.01


def test_hlo_stats_counts_dot_contraction():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((32, 100), jnp.float32)
    b = jax.ShapeDtypeStruct((100, 16), jnp.float32)
    c = jax.jit(f).lower(a, b).compile()
    st = hlo_stats(c.as_text())
    assert st["flops"] == 2 * 32 * 100 * 16


def test_collective_bytes_synthetic_hlo():
    hlo = """HloModule m

ENTRY %main.1 (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %ar = f32[16,16]{1,0} all-reduce(%p), replica_groups={}, to_apply=%add.1
  ROOT %ag = f32[32,16]{1,0} all-gather(%ar), dimensions={0}
}
"""
    coll = collective_bytes(hlo)
    assert coll["all-reduce"] == 2 * 16 * 16 * 4   # 2x convention
    assert coll["all-gather"] == 32 * 16 * 4


def test_roofline_terms_and_bottleneck():
    cost = {"flops": 197e12, "bytes accessed": 819e9 * 2}
    coll = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
            "all-to-all": 0.0, "collective-permute": 0.0}
    t = roofline_terms(cost, coll, 256)
    assert abs(t["compute_s"] - 1.0) < 1e-6
    assert abs(t["memory_s"] - 2.0) < 1e-6
    assert t["bottleneck"] == "memory"


def test_model_flops_conventions():
    cfg = get_config("gemma2-2b")
    tr = model_flops(cfg, SHAPE_BY_NAME["train_4k"])
    pf = model_flops(cfg, SHAPE_BY_NAME["prefill_32k"])
    dc = model_flops(cfg, SHAPE_BY_NAME["decode_32k"])
    n = cfg.param_count()
    assert tr == 6.0 * n * 4096 * 256
    assert pf == 2.0 * n * 32768 * 32
    assert dc == 2.0 * n * 128
    moe = get_config("deepseek-v2-236b")
    assert model_flops(moe, SHAPE_BY_NAME["train_4k"]) < \
        6.0 * moe.param_count() * 4096 * 256  # active < total


# ---------------------------------------------------------------------------
# Sharding rules (duck-typed mesh: only .axis_names / .shape used)
# ---------------------------------------------------------------------------


def _mesh(shape_map):
    m = types.SimpleNamespace()
    m.axis_names = tuple(shape_map)
    m.shape = dict(shape_map)
    return m


MESH = _mesh({"data": 16, "model": 16})
MESH3 = _mesh({"pod": 2, "data": 16, "model": 16})


class _Leaf:
    def __init__(self, *shape):
        self.shape = shape


def _spec(path_str, *shape, mesh=MESH):
    path = tuple(types.SimpleNamespace(key=k) for k in path_str.split("/"))
    return pspec_for_param(path, _Leaf(*shape), mesh)


def test_param_rules_basic():
    assert _spec("embed", 256000, 2304) == P("model")
    assert _spec("head/0/attn/w_q", 8192, 64, 128) == \
        P("data", "model")                       # qwen: 64 heads divisible
    assert _spec("head/0/mlp/w_gate", 8192, 49152) == P("data", "model")
    assert _spec("head/0/mlp/w_down", 49152, 8192) == P("model", "data")
    assert _spec("head/0/pre_norm/scale", 8192) == P()


def test_param_rules_divisibility_fallback():
    # gemma2: 8 q heads / 4 kv heads on a 16-way model axis -> replicated
    assert _spec("head/0/attn/w_q", 2304, 8, 256) == P("data")
    assert _spec("head/0/attn/w_k", 2304, 4, 256) == P("data")
    # but its FFN still gets TP
    assert _spec("head/0/mlp/w_gate", 2304, 9216) == P("data", "model")


def test_param_rules_body_stacking():
    # body params carry a leading period axis that must stay unsharded
    assert _spec("body/p0/mlp/w_gate", 13, 2304, 9216) == \
        P(None, "data", "model")


def test_param_rules_moe_expert_parallel():
    assert _spec("body/p0/moe/w_gate", 30, 160, 5120, 1536) == \
        P(None, "model", "data")
    assert _spec("body/p0/moe/w_down", 30, 160, 1536, 5120) == \
        P(None, "model", None, "data")
    # trailing-None normalization: P(None) == replicated
    assert tuple(_spec("body/p0/moe/w_router", 30, 5120, 160)) in (
        (), (None,))


def test_param_rules_vocab_fallback():
    # hubert vocab=504 does not divide 16 -> replicated embedding
    assert _spec("embed", 504, 1280) == P()


def test_batch_pspecs_dp_and_decode():
    cfg = get_config("gemma2-2b")
    tr = batch_pspecs(cfg, SHAPE_BY_NAME["train_4k"], MESH3)
    assert tr["tokens"] == P(("pod", "data"), None)
    dec = batch_pspecs(cfg, SHAPE_BY_NAME["decode_32k"], MESH)
    assert dec["tokens"] == P("data")
    # long_500k: batch=1 unshardable
    lng = batch_pspecs(cfg, SHAPE_BY_NAME["long_500k"], MESH)
    assert lng["tokens"] == P(None)


def test_cache_pspec_sequence_parallel_fallback():
    cfg = get_config("gemma3-12b")
    path = (types.SimpleNamespace(key="head"), types.SimpleNamespace(key="0"),
            types.SimpleNamespace(key="k"))
    # decode_32k: batch 128 shards on data
    spec = cache_pspec_for(path, _Leaf(128, 32768, 8, 256), cfg, MESH, 128)
    assert spec[0] == "data"
    # long_500k: batch 1 -> shard the sequence dim instead
    spec = cache_pspec_for(path, _Leaf(1, 524288, 8, 256), cfg, MESH, 1)
    # kv heads (8) don't divide 16 -> replicated; trailing Nones trimmed
    assert tuple(spec)[:2] == (None, "data")
    assert all(x is None for x in tuple(spec)[2:])


def test_dp_axes():
    assert dp_axes(MESH) == ("data",)
    assert dp_axes(MESH3) == ("pod", "data")
