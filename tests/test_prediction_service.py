"""PredictionService: the unified prediction pipeline.

Covers the versioned feature schema (v1 bit-identical to the legacy
vector, v2 node-shape-aware), the three-entry-point capacity parity
(legacy loop / update_capacity_table delegation / service API), the
epoch-invalidation contract under retraining (signature-cache entries
from epoch N must never serve an epoch N+1 lookup — asserted via a
canary forest swap and the stale-epoch counter), the on_samples online
retraining policy, and online retraining exercised inside a full
simulation run."""
import numpy as np
import pytest

from repro.core import (EngineConfig, GroundTruth, NodeResources,
                        PerfPredictor, PredictionService, ProfileStore,
                        QoSStore, SCHEMA_V1, SCHEMA_V2, FeatureSchema,
                        capacity_of, generate_dataset, get_schema,
                        make_scenario, scenario_simulation, scenario_world,
                        synthetic_functions, update_capacity_table)
from repro.core.cluster import Node
from repro.core.predictor import N_FEATURES, build_features

BIG = NodeResources(cpu_mcores=96_000.0, mem_mb=262_144.0,
                    mem_bw_gbps=136.0, llc_mb=120.0)


@pytest.fixture(scope="module")
def world():
    specs = synthetic_functions(5, seed=2)
    gt = GroundTruth(seed=0)
    store = ProfileStore(seed=0)
    qos = QoSStore(store, gt)
    pred = PerfPredictor(n_trees=10, max_depth=7, seed=0)
    X, y = generate_dataset(specs, gt, store, qos, 600, seed=1)
    pred.add_dataset(X, y)
    return specs, gt, store, qos, pred


def _service(world, **kw):
    specs, gt, store, qos, pred = world
    cfg = EngineConfig(**{k: v for k, v in kw.items()
                          if k not in ("schema", "predictor")})
    return PredictionService(kw.get("predictor", pred), store, qos, specs,
                             cfg, schema=kw.get("schema"))


# ---------------------------------------------------------------------------
# Feature schema
# ---------------------------------------------------------------------------


def test_schema_versions_and_lookup():
    assert SCHEMA_V1.version == 1 and SCHEMA_V1.n_features == N_FEATURES
    assert SCHEMA_V2.version == 2 and \
        SCHEMA_V2.n_features == N_FEATURES + 2
    assert get_schema(None) is SCHEMA_V1
    assert get_schema(2) is SCHEMA_V2
    assert get_schema(SCHEMA_V2) is SCHEMA_V2
    assert SCHEMA_V1 == FeatureSchema(1) and SCHEMA_V1 != SCHEMA_V2
    with pytest.raises(ValueError):
        FeatureSchema(3)


def test_schema_v1_row_bit_identical_to_legacy(world):
    specs, gt, store, qos, pred = world
    names = sorted(specs)
    prof = store.profile(specs[names[0]])
    neigh = [(store.profile(specs[names[1]]), 3.0, 1.0),
             (store.profile(specs[names[2]]), 2.0, 0.0)]
    legacy = build_features(qos.solo(specs[names[0]]), prof, 4.0, 1.0,
                            neigh)
    row = SCHEMA_V1.build_row(qos.solo(specs[names[0]]), prof, 4.0, 1.0,
                              neigh)
    assert row.dtype == legacy.dtype == np.float32
    assert np.array_equal(row, legacy)          # bitwise
    # v1 is node-shape-blind even when a shape is supplied
    row_big = SCHEMA_V1.build_row(qos.solo(specs[names[0]]), prof, 4.0,
                                  1.0, neigh, node_res=BIG)
    assert np.array_equal(row_big, legacy)


def test_schema_v2_appends_normalized_shape(world):
    specs, gt, store, qos, pred = world
    names = sorted(specs)
    prof = store.profile(specs[names[0]])
    v1 = SCHEMA_V1.build_row(qos.solo(specs[names[0]]), prof, 2.0, 0.0, [])
    std = SCHEMA_V2.build_row(qos.solo(specs[names[0]]), prof, 2.0, 0.0, [])
    big = SCHEMA_V2.build_row(qos.solo(specs[names[0]]), prof, 2.0, 0.0,
                              [], node_res=BIG)
    assert np.array_equal(std[:N_FEATURES], v1)   # v1 prefix untouched
    assert np.allclose(std[N_FEATURES:], [1.0, 1.0])   # reference shape
    assert np.allclose(big[N_FEATURES:], [2.0, 2.0])   # 2x node
    # the shape lands in the cache signature (v2) but not in v1's
    svc1 = _service(world, m_max=8)
    svc2 = _service(world, m_max=8, schema=2)
    coloc = {names[1]: (2.0, 0.0)}
    assert svc1.signature(coloc, names[0], node_res=BIG) == \
        svc1.signature(coloc, names[0])
    assert svc2.signature(coloc, names[0], node_res=BIG) != \
        svc2.signature(coloc, names[0])


def test_inference_engine_selection(world):
    svc = _service(world)
    assert svc.inference_engine == "numpy"
    with pytest.raises(ValueError, match="unknown inference engine"):
        svc.set_engine("tensorflow")
    svc.set_engine("numpy")
    assert svc.predictor.engine == "numpy"


def test_all_inference_engines_agree_on_capacities(world):
    """The uniform engine surface: numpy, jax (jnp gathers), and pallas
    (interpret-mode kernel on CPU) solve identical capacities through
    ``kernels.rfr_inference`` / ``kernels.ops.rfr_op``."""
    specs, gt, store, qos, pred = world
    svc = _service(world, m_max=8)
    names = sorted(specs)
    coloc = {names[1]: (2.0, 1.0), names[2]: (1.0, 0.0)}
    caps = {}
    for eng in ("numpy", "jax", "pallas"):
        svc.set_engine(eng)
        svc.invalidate()
        caps[eng], _ = svc.capacity(dict(coloc), names[0])
    svc.set_engine("numpy")
    assert caps["numpy"] == caps["jax"] == caps["pallas"]


# ---------------------------------------------------------------------------
# Three-entry-point capacity parity (schema v1)
# ---------------------------------------------------------------------------


def test_v1_capacity_parity_legacy_vs_delegation_vs_service(world):
    """The acceptance gate at node level: the legacy per-node loop, the
    ``update_capacity_table(engine=...)`` delegation, and the service's
    own ``update_nodes`` produce identical capacity tables."""
    specs, gt, store, qos, pred = world
    names = sorted(specs)
    rng = np.random.default_rng(5)
    nodes = []
    for _ in range(8):
        node = Node(NodeResources())
        for g in rng.choice(names, size=rng.integers(1, 4), replace=False):
            node.state(g).n_sat = int(rng.integers(1, 4))
            node.state(g).n_cached = int(rng.integers(0, 2))
        nodes.append(node)
    # 1) legacy reference loop
    ref = []
    for node in nodes:
        update_capacity_table(pred, store, qos, specs, node, m_max=8)
        ref.append({fn: e.capacity for fn, e in node.table.items()})
        node.table.clear()
    # 2) delegation through update_capacity_table(engine=service)
    svc = _service(world, m_max=8)
    for node, expect in zip(nodes, ref):
        update_capacity_table(pred, store, qos, specs, node, m_max=8,
                              engine=svc)
        assert {fn: e.capacity for fn, e in node.table.items()} == expect
        node.table.clear()
    # 3) the service API proper (fresh cache so it re-solves)
    svc2 = _service(world, m_max=8)
    svc2.update_nodes(nodes, m_max=8)
    for node, expect in zip(nodes, ref):
        assert {fn: e.capacity for fn, e in node.table.items()} == expect


# ---------------------------------------------------------------------------
# Epoch invalidation under retraining
# ---------------------------------------------------------------------------


def test_epoch_invalidation_canary_forest_swap(world):
    """Cache entries from epoch N must never serve a post-retrain epoch
    N+1 lookup.  A canary forest (trained on shifted labels, so its
    capacities differ) is swapped in via a retrain; the old capacity must
    be unobservable afterwards and the stale-epoch counter stay 0."""
    specs, gt, store, qos, _ = world
    pred = PerfPredictor(n_trees=8, max_depth=6, seed=3)
    X, y = generate_dataset(specs, gt, store, qos, 400, seed=9)
    pred.add_dataset(X, y)
    svc = PredictionService(pred, store, qos, specs, EngineConfig(m_max=10))
    names = sorted(specs)
    coloc = {names[1]: (2.0, 0.0)}
    cap_before, _rows = svc.capacity(dict(coloc), names[0])
    epoch_before = svc.epoch
    assert svc.capacity_hint(dict(coloc), names[0]) == cap_before
    # canary swap: retrain on labels scaled 4x -> capacities collapse
    retrained = svc.on_samples(list(X), list(4.0 * y), retrain=True)
    assert retrained
    assert svc.epoch == epoch_before + 1
    assert svc.stats.retrains == 1 and svc.stats.retrain_time_s > 0
    # epoch N entries are gone: no hint, and a fresh solve sees the
    # canary forest (strictly smaller capacity than the old epoch's)
    assert svc.capacity_hint(dict(coloc), names[0]) is None
    cap_after, rows_after = svc.capacity(dict(coloc), names[0])
    assert rows_after > 0                      # re-solved, not cached
    cap_ref, _ = capacity_of(pred, store, qos, specs, dict(coloc),
                             names[0], 10)
    assert cap_after == cap_ref                # canary forest's answer
    assert cap_after < cap_before              # the canary is observable
    assert svc.stats.stale_epoch_hits == 0     # eager invalidation held


def test_stale_epoch_counter_catches_foreign_entries(world):
    """Defense in depth: an entry whose epoch tag mismatches the current
    forest is counted and dropped, never served."""
    svc = _service(world, m_max=8)
    names = sorted(svc.specs)
    coloc = {names[1]: (1.0, 0.0)}
    cap, _ = svc.capacity(dict(coloc), names[0])
    key = svc.signature(coloc, names[0])
    epoch, _cap = svc._cache[key]
    svc._cache[key] = (epoch - 1, 99)          # forge a stale-epoch entry
    assert svc.capacity_hint(dict(coloc), names[0]) is None
    assert svc.stats.stale_epoch_hits == 1
    assert key not in svc._cache               # dropped, not retried


def test_on_samples_retrain_policy(world):
    specs, gt, store, qos, _ = world
    pred = PerfPredictor(n_trees=6, max_depth=6, seed=4)
    X, y = generate_dataset(specs, gt, store, qos, 300, seed=11)
    pred.add_dataset(X, y)
    svc = PredictionService(pred, store, qos, specs,
                            EngineConfig(m_max=6, retrain_every=10))
    assert not svc.on_samples(list(X[:4]), list(y[:4]))   # below threshold
    assert svc.stats.retrains == 0
    assert svc.on_samples(list(X[4:10]), list(y[4:10]))   # crosses it
    assert svc.stats.retrains == 1
    assert not svc.on_samples(list(X[10:14]), list(y[10:14]))  # reset
    assert not svc.on_samples(list(X[14:18]), list(y[14:18]),
                              retrain=False)              # forced off
    assert svc.on_samples([], [], retrain=True)           # forced on
    assert svc.stats.retrains == 2


def test_online_retraining_during_simulation_run():
    """The epoch machinery exercised end to end: a small heterogeneous
    scenario run with online retraining armed must actually retrain,
    refresh tables (billed separately), and finish with zero stale-epoch
    cache hits."""
    scenario = make_scenario("burst-storm", n_functions=5, duration_s=80,
                             target_nodes=10, seed=2)
    world = scenario_world(scenario, n_train=500, n_trees=8)
    sim = scenario_simulation(scenario, "jiagu", world=world,
                              collect_samples=True, online_retrain=True,
                              retrain_every=6, sample_every_s=5)
    res = sim.run()
    assert res.retrains >= 1
    assert res.retrain_time_s > 0.0
    assert res.refresh_rows > 0 and res.refresh_time_s > 0.0
    assert res.stale_epoch_hits == 0
    assert np.isfinite(np.asarray(res.density_series)).all()


# ---------------------------------------------------------------------------
# Node-shape-aware capacities (schema v2)
# ---------------------------------------------------------------------------


def test_v2_dataset_emits_per_shape_rows(world):
    specs, gt, store, qos, _ = world
    X, y = generate_dataset(specs, gt, store, qos, 300, seed=7, schema=2,
                            node_shapes=[NodeResources(), BIG])
    assert X.shape[1] == SCHEMA_V2.n_features
    shapes = set(map(tuple, np.round(X[:, N_FEATURES:], 3)))
    assert (1.0, 1.0) in shapes and (2.0, 2.0) in shapes


def test_v2_service_capacity_grows_with_node_size(world):
    """The point of the schema: the same colocation on a 2x node gets a
    capacity at least the standard node's, and strictly more for loads
    where the standard node is the binding constraint."""
    specs, gt, store, qos, _ = world
    pred = PerfPredictor(n_trees=10, max_depth=7, seed=0)
    X, y = generate_dataset(specs, gt, store, qos, 700, seed=1, schema=2,
                            node_shapes=[NodeResources(), BIG])
    pred.add_dataset(X, y)
    svc = PredictionService(pred, store, qos, specs, EngineConfig(m_max=40),
                            schema=2)
    names = sorted(specs)
    total_std = total_big = 0
    for fn in names[:4]:
        coloc = {names[4]: (2.0, 0.0)}
        cap_std, _ = svc.capacity(dict(coloc), fn, 40)
        cap_big, _ = svc.capacity(dict(coloc), fn, 40, node_res=BIG)
        total_std += cap_std
        total_big += cap_big
        assert cap_big >= cap_std
    assert total_big > total_std


# ---------------------------------------------------------------------------
# Learned per-shape QoS margins (schema v2)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def v2_service_pair(world):
    """One v2-trained forest over the std+2x fleet, served once with the
    fixed margin formula and once with learned per-shape margins."""
    specs, gt, store, qos, _ = world
    pred = PerfPredictor(n_trees=8, max_depth=7, seed=3)
    X, y = generate_dataset(specs, gt, store, qos, 500, seed=5, schema=2,
                            node_shapes=[gt.node, BIG])
    pred.add_dataset(X, y)
    fixed = PredictionService(pred, store, qos, specs, EngineConfig(),
                              schema=2)
    learned = PredictionService(
        pred, store, qos, specs,
        EngineConfig(learned_shape_margin=True), schema=2)
    return fixed, learned, gt


def test_learned_shape_margins_cover_fleet_shapes(v2_service_pair):
    _fixed, learned, gt = v2_service_pair
    margins = learned.shape_margins()
    std_key = learned.schema.shape_key(gt.node, learned.cfg.quant)
    big_key = learned.schema.shape_key(BIG, learned.cfg.quant)
    assert std_key in margins and big_key in margins
    for m in margins.values():
        assert learned.cfg.qos_margin_base <= m <= learned.cfg.margin_cap
    # the bound scale is driven by the learned margin, per shape
    assert learned.qos_bound_scale(gt.node) == \
        pytest.approx(1.0 / (1.0 + margins[std_key]))
    assert learned.qos_bound_scale(BIG) == \
        pytest.approx(1.0 / (1.0 + margins[big_key]))


def test_fixed_margin_formula_is_default_compatible(v2_service_pair):
    fixed, _learned, gt = v2_service_pair
    assert fixed.qos_bound_scale(gt.node) == \
        pytest.approx(1.0 / 1.06)
    r = BIG.cpu_mcores / gt.node.cpu_mcores
    assert fixed.qos_bound_scale(BIG) == \
        pytest.approx(1.0 / (1.0 + 0.06 + 0.08 * abs(r - 1.0)))


def test_learned_margin_falls_back_for_unseen_shape(v2_service_pair):
    _fixed, learned, gt = v2_service_pair
    tiny = NodeResources(cpu_mcores=12_000.0, mem_mb=32_768.0,
                         mem_bw_gbps=17.0, llc_mb=15.0)
    r = tiny.cpu_mcores / gt.node.cpu_mcores
    assert learned.qos_bound_scale(tiny) == \
        pytest.approx(1.0 / (1.0 + 0.06 + 0.08 * abs(r - 1.0)))


def test_learned_margins_relearned_per_epoch(v2_service_pair):
    _fixed, learned, _gt = v2_service_pair
    before = learned.shape_margins()
    assert learned._shape_margins is not None
    learned.invalidate()                # external cache clear
    assert learned._shape_margins is None
    learned.retrain()                   # epoch bump -> eager re-learn
    assert learned._shape_margins is not None
    after = learned.shape_margins()
    assert set(after) == set(before)    # same fleet shapes re-learned


def test_learned_margin_is_noop_under_v1(world):
    specs, _gt, store, qos, pred = world
    svc = PredictionService(pred, store, qos, specs,
                            EngineConfig(learned_shape_margin=True),
                            schema=1)
    assert svc.qos_bound_scale(BIG) == 1.0
    assert svc.shape_margins() == {}


def test_platform_validates_learned_margin_needs_v2():
    from repro.platform import PlatformConfig, PlatformConfigError
    with pytest.raises(PlatformConfigError, match="learned_shape_margin"):
        PlatformConfig.from_dict({
            "prediction": {"learned_shape_margin": True}}).validate()
    # with schema v2 the flag passes validation and reaches the service
    PlatformConfig.from_dict({
        "prediction": {"learned_shape_margin": True,
                       "schema_version": 2}}).validate()
