"""RFR predictor: accuracy against the hidden ground truth, convergence
with incremental samples (paper Fig 15), and the Fig-16 model zoo."""
import numpy as np
import pytest

from repro.core import (GroundTruth, PerfPredictor, ProfileStore, QoSStore,
                        generate_dataset, synthetic_functions)
from repro.core.predictor import MODEL_ZOO, RandomForestRegressor


@pytest.fixture(scope="module")
def dataset():
    specs = synthetic_functions(6, seed=0)
    gt = GroundTruth(seed=0)
    store = ProfileStore(seed=0)
    qos = QoSStore(store, gt)
    X, y = generate_dataset(specs, gt, store, qos, 1200, seed=3)
    return X, y


def _error(pred, X, y):
    p = pred if isinstance(pred, np.ndarray) else pred
    return float(np.mean(np.abs(p - y) / np.maximum(y, 1e-9)))


def test_rfr_generalizes(dataset):
    """Prediction error on a held-out split < 15% (paper reports ~10%)."""
    X, y = dataset
    n = len(y)
    tr, te = slice(0, int(0.8 * n)), slice(int(0.8 * n), n)
    m = RandomForestRegressor(n_trees=24, max_depth=8, seed=0)
    m.fit(X[tr], y[tr])
    err = _error(m.predict(X[te]), None, y[te])
    assert err < 0.15, err


def test_rfr_no_split_overfit(dataset):
    """Similar error on two disjoint test halves (paper Fig 15 Jg-1/2)."""
    X, y = dataset
    n = len(y)
    m = RandomForestRegressor(n_trees=24, max_depth=8, seed=0)
    m.fit(X[: int(0.8 * n)], y[: int(0.8 * n)])
    te = np.arange(int(0.8 * n), n)
    h1, h2 = te[::2], te[1::2]
    e1 = _error(m.predict(X[h1]), None, y[h1])
    e2 = _error(m.predict(X[h2]), None, y[h2])
    assert abs(e1 - e2) < 0.08


def test_incremental_convergence_for_new_function():
    """Error for an unseen function drops as runtime samples arrive and
    converges within ~5-30 samples (paper Fig 15-b)."""
    specs = synthetic_functions(6, seed=0)
    gt = GroundTruth(seed=0)
    store = ProfileStore(seed=0)
    qos = QoSStore(store, gt)
    names = sorted(specs)
    old = {k: specs[k] for k in names[:5]}
    new_fn = names[5]
    pred = PerfPredictor(n_trees=16, max_depth=8, retrain_every=1, seed=0)
    X, y = generate_dataset(old, gt, store, qos, 700, seed=1)
    pred.add_dataset(X, y)
    Xn, yn = generate_dataset({new_fn: specs[new_fn], names[0]: specs[
        names[0]]}, gt, store, qos, 80, seed=9)
    err_before = _error(pred.predict(Xn[40:]), None, yn[40:])
    for xi, yi in zip(Xn[:30], yn[:30]):
        pred.add_sample(xi, yi, retrain=False)
    pred.retrain()
    err_after = _error(pred.predict(Xn[40:]), None, yn[40:])
    # pressure features generalize across functions, so the pre-sample
    # error is already near the noise floor; the paper's claim reduces to
    # "converges within a couple dozen samples and stays accurate".
    assert err_after < max(err_before * 1.1, 0.12)
    assert err_after < 0.15


def test_model_zoo_runs_and_rfr_competitive(dataset):
    """Every Fig-16 baseline trains + predicts; RFR is within the top-2 by
    error (the paper's justification for choosing it)."""
    X, y = dataset
    n = len(y)
    tr, te = slice(0, int(0.8 * n)), slice(int(0.8 * n), n)
    errs = {}
    for name, ctor in MODEL_ZOO.items():
        m = ctor()
        m.fit(X[tr], y[tr])
        errs[name] = _error(np.asarray(m.predict(X[te])), None, y[te])
    rfr_key = "RFR (Jiagu)"
    assert rfr_key in errs
    order = sorted(errs, key=errs.get)
    assert order.index(rfr_key) <= 2, errs


def test_function_granularity_feature_size():
    """The paper's dimensionality claim: features are O(1) in the number
    of colocated instances."""
    from repro.core.predictor import N_FEATURES, build_features
    prof = np.ones(13)
    few = build_features(1.0, prof, 1, 0, [(prof, 1, 0)])
    many = build_features(1.0, prof, 30, 5, [(prof, float(i), 1.0)
                                             for i in range(20)])
    assert few.shape == many.shape == (N_FEATURES,)


def test_inference_batching_cost_flat():
    """Batched inference: 100 inputs cost far less than 100x one input
    (paper Fig 17-b)."""
    rng = np.random.default_rng(0)
    X = rng.standard_normal((1000, 31)).astype(np.float32)
    y = X[:, 0] * 2 + X[:, 1]
    m = PerfPredictor(n_trees=16, max_depth=8, seed=0)
    m.add_dataset(X[:500], y[:500])
    import time
    t0 = time.perf_counter()
    for i in range(20):
        m.predict(X[i: i + 1])
    t_single = time.perf_counter() - t0
    t0 = time.perf_counter()
    for i in range(20):
        m.predict(X[i * 25: (i + 1) * 25])
    t_batch = time.perf_counter() - t0
    assert t_batch < t_single * 5  # 25x the rows for <5x the time
