"""Multi-device distribution tests, run in a subprocess with 8 forced
host devices (the main pytest process must keep seeing 1 device)."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
import sys
sys.path.insert(0, {src!r})

from repro.configs.base import InputShape, get_smoke_config
from repro.distributed.steps import (make_decode_step, make_prefill_step,
                                     make_train_step)
from repro.launch.train import build_state, put_batch
from repro.data.pipeline import TokenPipeline
from repro.optim.adamw import AdamWConfig

results = {{}}
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
mesh2 = jax.make_mesh((4, 2), ("data", "model"))

for arch in {archs!r}:
    cfg = get_smoke_config(arch)
    shape = InputShape("t", 32, 8, "train")
    for name, m in [("multi", mesh), ("single", mesh2)]:
        b = make_train_step(cfg, m, shape,
                            opt_cfg=AdamWConfig(total_steps=4))
        b.lower().compile()
        results[f"{{arch}}--train--{{name}}"] = "ok"
    # decode path on the 3-axis mesh
    dshape = InputShape("d", 64, 8, "decode")
    make_decode_step(cfg, mesh, dshape).lower().compile()
    results[f"{{arch}}--decode--multi"] = "ok"

# numerics: distributed train step == single-device loss trajectory.
# Run this comparison in float32: in bf16 the per-step drift between
# different SPMD partitionings is ~bf16 eps (2^-8 ~ 0.4%) from matmul /
# reduction reassociation alone and compounds across steps, which would
# drown the partitioning bugs this check exists to catch.
import dataclasses
cfg = dataclasses.replace(get_smoke_config("gemma2-2b"), dtype="float32")
shape = InputShape("t", 32, 8, "train")
pipe = TokenPipeline(cfg, shape, seed=0)
losses = {{}}
for name, m in [("dist", mesh), ("solo", jax.make_mesh((1, 1),
                                                       ("data", "model")))]:
    b = make_train_step(cfg, m, shape, opt_cfg=AdamWConfig(total_steps=4))
    state = build_state(cfg, b, AdamWConfig(total_steps=4), seed=0)
    ls = []
    for i in range(3):
        batch = put_batch(pipe.batch(i), b.meta["batch_shardings"])
        state, metrics = b.fn(state, batch)
        ls.append(float(metrics["loss"]))
    losses[name] = ls
results["loss_dist"] = losses["dist"]
results["loss_solo"] = losses["solo"]
print("RESULTS " + json.dumps(results))
"""


@pytest.fixture(scope="module")
def subproc_results():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SCRIPT.format(src=os.path.abspath(src),
                            archs=["gemma2-2b", "deepseek-v2-236b",
                                   "mamba2-2.7b", "recurrentgemma-2b"])
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULTS ")][-1]
    return json.loads(line[len("RESULTS "):])


def test_smoke_archs_lower_and_compile_on_8dev_mesh(subproc_results):
    r = subproc_results
    for arch in ["gemma2-2b", "deepseek-v2-236b", "mamba2-2.7b",
                 "recurrentgemma-2b"]:
        assert r[f"{arch}--train--multi"] == "ok"
        assert r[f"{arch}--train--single"] == "ok"
        assert r[f"{arch}--decode--multi"] == "ok"


def test_distributed_loss_matches_single_device(subproc_results):
    r = subproc_results
    import numpy as np
    np.testing.assert_allclose(r["loss_dist"], r["loss_solo"], rtol=2e-3)
