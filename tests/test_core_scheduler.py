"""Jiagu core: cluster invariants (hypothesis), capacity semantics,
scheduler fast/slow paths, and baseline scheduler constraints."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:          # degraded deterministic fallback loop
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import (Cluster, GroundTruth, JiaguScheduler, K8sScheduler,
                        NodeResources, OwlScheduler, PerfPredictor,
                        ProfileStore, QoSStore, capacity_of,
                        generate_dataset, synthetic_functions,
                        update_capacity_table)
from repro.core.cluster import Node


# ---------------------------------------------------------------------------
# Cluster state machine properties
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(ops=st.lists(st.tuples(st.sampled_from(["deploy", "release",
                                               "logical", "evict_c",
                                               "evict_s"]),
                              st.integers(1, 3)), max_size=40))
def test_node_counts_never_negative_and_conserved(ops):
    node = Node(NodeResources())
    deployed = 0
    for op, k in ops:
        st_ = node.state("f")
        before = (st_.n_sat, st_.n_cached)
        if op == "deploy":
            node.deploy("f", k)
            deployed += k
        elif op == "release":
            node.release("f", k)
        elif op == "logical":
            node.logical_start("f", k)
        elif op == "evict_c":
            node.evict_cached("f", k)
        else:
            node.evict_sat("f", k)
        if "f" in node.funcs:
            st_ = node.funcs["f"]
            assert st_.n_sat >= 0 and st_.n_cached >= 0
            # release/logical conserve the total
            if op in ("release", "logical"):
                assert st_.n_sat + st_.n_cached == sum(before)


@settings(max_examples=30, deadline=None)
@given(k=st.integers(1, 5))
def test_release_is_inverse_of_logical_start(k):
    node = Node(NodeResources())
    node.deploy("f", 5)
    got = node.release("f", k)
    assert got == min(k, 5)
    back = node.logical_start("f", got)
    assert back == got
    assert node.funcs["f"].n_sat == 5 and node.funcs["f"].n_cached == 0


def test_deploy_staleness_semantics():
    """Deploying f marks OTHER functions' capacity entries stale; releases
    keep them fresh (capacity can only have grown)."""
    from repro.core.cluster import CapEntry
    node = Node(NodeResources())
    node.deploy("a", 1)
    node.table["a"] = CapEntry(capacity=4)
    node.table["b"] = CapEntry(capacity=4)
    node.deploy("b", 1)
    assert not node.table["a"].fresh
    assert node.table["b"].fresh
    node.table["a"].fresh = True
    node.release("b", 1)
    assert node.table["a"].fresh


# ---------------------------------------------------------------------------
# Capacity (needs a trained predictor — small but real)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def world():
    specs = synthetic_functions(4, seed=2)
    gt = GroundTruth(seed=0)
    store = ProfileStore(seed=0)
    qos = QoSStore(store, gt)
    pred = PerfPredictor(n_trees=12, max_depth=7, seed=0)
    X, y = generate_dataset(specs, gt, store, qos, 600, seed=1)
    pred.add_dataset(X, y)
    return specs, gt, store, qos, pred


def test_capacity_positive_on_empty_node(world):
    specs, gt, store, qos, pred = world
    fn = sorted(specs)[0]
    cap, rows = capacity_of(pred, store, qos, specs, {}, fn, m_max=12)
    assert cap >= 1          # a function alone on a node must fit
    assert rows == 12        # m_max rows, one batched inference


def test_capacity_monotone_in_neighbor_load(world):
    """More neighbor instances can never increase predicted capacity."""
    specs, gt, store, qos, pred = world
    fns = sorted(specs)
    f, g = fns[0], fns[1]
    caps = []
    for n_g in [0, 4, 10]:
        coloc = {g: (float(n_g), 0.0)} if n_g else {}
        cap, _ = capacity_of(pred, store, qos, specs, coloc, f, m_max=16)
        caps.append(cap)
    assert caps[0] >= caps[1] >= caps[2]


def test_update_capacity_table_covers_all_functions(world):
    specs, gt, store, qos, pred = world
    node = Node(NodeResources())
    fns = sorted(specs)[:3]
    for fn in fns:
        node.deploy(fn, 2)
    update_capacity_table(pred, store, qos, specs, node, m_max=8)
    for fn in fns:
        assert fn in node.table and node.table[fn].fresh


# ---------------------------------------------------------------------------
# Schedulers
# ---------------------------------------------------------------------------


def test_k8s_never_overcommits_requested_resources(world):
    specs, gt, store, qos, pred = world
    cluster = Cluster(specs)
    sched = K8sScheduler(cluster, store, qos)
    fns = sorted(specs)
    for i in range(40):
        sched.schedule(fns[i % len(fns)], 1, float(i))
    for node in cluster.nodes.values():
        assert node.cpu_requested(specs) <= node.res.cpu_mcores
        assert node.mem_used(specs) <= node.res.mem_mb


def test_owl_max_two_functions_per_node(world):
    specs, gt, store, qos, pred = world
    cluster = Cluster(specs)
    sched = OwlScheduler(cluster, store, qos)
    fns = sorted(specs)
    for i in range(30):
        sched.schedule(fns[i % len(fns)], 1, float(i))
    for node in cluster.nodes.values():
        assert len([f for f, s in node.funcs.items() if s.total > 0]) <= 2


def test_jiagu_fast_path_after_slow_path(world):
    """First instance of a function on a node = slow path; subsequent
    co-located instances under capacity = fast path, no inference."""
    specs, gt, store, qos, pred = world
    cluster = Cluster(specs)
    sched = JiaguScheduler(cluster, store, qos, pred, m_max=12)
    fn = sorted(specs)[0]
    sched.schedule(fn, 1, 0.0)
    assert sched.metrics.slow >= 1
    calls_before = pred.inference_calls
    slow_before = sched.metrics.slow
    sched.on_tick(10.0)      # flush async update
    calls_after_update = pred.inference_calls
    sched.schedule(fn, 1, 11.0)
    assert sched.metrics.fast >= 1
    assert sched.metrics.slow == slow_before      # no new slow path
    assert pred.inference_calls == calls_after_update  # fast path: 0 calls
    assert calls_after_update > calls_before  # async update did the work


def test_jiagu_batches_concurrent_arrivals(world):
    """Concurrency-aware scheduling: k co-arriving instances of one
    function are one decision."""
    specs, gt, store, qos, pred = world
    cluster = Cluster(specs)
    sched = JiaguScheduler(cluster, store, qos, pred, m_max=12)
    fn = sorted(specs)[0]
    sched.schedule(fn, 1, 0.0)
    sched.on_tick(10.0)
    node = next(iter(cluster.nodes.values()))
    cap = node.table[fn].capacity
    if cap >= 3:
        decisions_before = sched.metrics.decisions
        placements = sched.schedule(fn, 2, 11.0)
        assert sched.metrics.decisions == decisions_before + 1
        assert sum(p.count for p in placements) == 2


def test_jiagu_respects_memory_hard_limit(world):
    """Overcommit never violates the non-overcommittable memory."""
    specs, gt, store, qos, pred = world
    cluster = Cluster(specs)
    sched = JiaguScheduler(cluster, store, qos, pred, m_max=24)
    fns = sorted(specs)
    for i in range(60):
        sched.schedule(fns[i % len(fns)], 1, float(i))
        sched.on_tick(float(i) + 0.5)
    for node in cluster.nodes.values():
        assert node.mem_used(specs) <= node.res.mem_mb
