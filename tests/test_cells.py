"""Cell-sharded event-driven core (``repro.core.cells``).

Tier-1 gates for the sharded control plane:

  * **cells=1 bit-parity** — the single-cell ``CellSimulation`` (event
    loop + dirty-set measurement over the exact legacy assembly) must
    reproduce the legacy ``Simulation`` bit-for-bit on every
    deterministic counter, for every headline scheduler.
  * **Baseline reproduction** — the single-cell core must reproduce the
    checked-in ``BENCH_large_cluster.json`` quick baseline's first row
    exactly (the ISSUE's hard constraint: sharding must not move the
    published numbers).
  * **Event-queue determinism** — a multi-cell run is a deterministic
    function of its seeds: two assemblies from the same world produce
    identical counters, and the event gating really skips idle cells.
  * ``CellRouter`` share conservation / identity passthrough,
    ``CapacityExchange`` fanout, and the ``PlatformConfig.cells``
    section wiring.
"""
import numpy as np
import pytest

from repro.core import make_scenario, scenario_simulation, scenario_world
from repro.core.cells import (CapacityExchange, Cell, CellRouter,
                              CellSimulation, cell_scenario_simulation)
from repro.platform import Platform, PlatformConfigError

SYSTEMS = ("k8s", "jiagu", "harvesting")


def _det(res) -> dict:
    """Deterministic run counters: everything except wall-clock fields
    (latency percentiles differ between any two runs) and the
    predictor's cumulative inference counters (accumulate across runs
    sharing one world)."""
    s, a = res.sched, res.scaling
    return {
        "requests": res.requests,
        "violated_requests": res.violated_requests,
        "per_fn_violations": dict(res.per_fn_violations),
        "per_fn_requests": dict(res.per_fn_requests),
        "instance_seconds": res.instance_seconds,
        "node_seconds": res.node_seconds,
        "nodes_peak": res.nodes_peak,
        "density_series": list(res.density_series),
        "decisions": s.decisions, "placed": s.instances_placed,
        "fast": s.fast, "slow": s.slow, "failed": s.failed,
        "real_cold": a.real_cold_starts,
        "logical_cold": a.logical_cold_starts,
        "blocked_logical": a.blocked_logical,
        "migrations": a.migrations, "releases": a.releases,
        "evictions": a.evictions,
    }


@pytest.fixture(scope="module")
def parity_world():
    scenario = make_scenario("burst-storm", n_functions=6, duration_s=80,
                             target_nodes=16, seed=3)
    world = scenario_world(scenario, n_train=600, n_trees=8)
    return scenario, world


@pytest.mark.parametrize("system", SYSTEMS)
def test_cells1_bit_parity(parity_world, system):
    """cells=1 reproduces the legacy Simulation exactly — density, QoS,
    and every scheduling/scaling counter."""
    scenario, world = parity_world
    world.gt.reseed()
    legacy = scenario_simulation(scenario, system, world=world).run()
    world.gt.reseed()
    sharded = cell_scenario_simulation(scenario, system, n_cells=1,
                                       world=world)
    assert isinstance(sharded, CellSimulation)
    assert len(sharded.cells) == 1
    cells = sharded.run()
    a, b = _det(legacy), _det(cells)
    diverged = sorted(k for k in a if a[k] != b[k])
    assert not diverged, f"{system} diverged on {diverged}"
    assert legacy.density == cells.density
    assert legacy.qos_violation_rate == cells.qos_violation_rate


def test_cells1_reproduces_checked_in_quick_baseline():
    """The checked-in BENCH_large_cluster.json quick baseline's first
    sweep row (burst-storm@64, k8s — the first run against the fresh
    shared world, so its ground-truth RNG stream starts at zero) must
    be reproduced exactly by the single-cell event core."""
    from benchmarks.large_cluster import study_spec
    from repro.telemetry.report import load_bench

    data = load_bench("large_cluster")
    if data is None:
        pytest.skip("no checked-in BENCH_large_cluster.json")
    base = data["baseline"]
    assert base["mode"] == "quick"
    row = base["rows"][0]
    assert (row["scenario"], row["target_nodes"], row["system"]) == \
        ("burst-storm", 64, "k8s")
    spec = study_spec(quick=True, seed=0)["base"]
    scenario = make_scenario(
        "burst-storm",
        n_functions=spec["scenario"]["n_functions"],
        duration_s=spec["scenario"]["duration_s"],
        target_nodes=64, seed=spec["scenario"]["seed"],
        spec_seed=spec["scenario"]["spec_seed"])
    world = scenario_world(
        scenario, n_train=spec["prediction"]["n_train"],
        n_trees=spec["prediction"]["n_trees"])
    res = cell_scenario_simulation(scenario, "k8s", n_cells=1,
                                   world=world).run()
    s = res.sched
    got = {
        "density": round(res.density, 3),
        "qos_violation": round(res.qos_violation_rate, 4),
        "mean_nodes": round(res.node_seconds / max(res.ticks, 1), 1),
        "peak_nodes": res.nodes_peak,
        "rows_per_schedule": round(
            s.critical_inference_rows / max(s.decisions, 1), 2),
        "fast_frac": round(s.fast / max(s.fast + s.slow, 1), 3),
    }
    want = {k: row[k] for k in got}
    assert got == want


def test_multicell_event_queue_determinism():
    """A sharded run is a pure function of its seeds: two 3-cell
    assemblies from one world produce identical deterministic counters,
    the sparse trace leaves some cell-ticks idle (the event gating is
    live), and the capacity exchange gossips."""
    scenario = make_scenario("azure-sparse", n_functions=10,
                             duration_s=80, target_nodes=12, seed=7)
    world = scenario_world(scenario, n_train=600, n_trees=8)

    def arm():
        world.gt.reseed()
        sim = cell_scenario_simulation(scenario, "jiagu", n_cells=3,
                                       world=world)
        res = sim.run()
        return sim, _det(res)

    sim1, a = arm()
    sim2, b = arm()
    assert a == b
    assert len(sim1.cells) == 3
    # the event gating must actually skip idle cell-ticks on the
    # sparse long-tail population...
    assert sim1.idle_cell_ticks > 0
    assert sim1.idle_cell_ticks == sim2.idle_cell_ticks
    # ...and solved capacities gossip across cells
    assert sim1.exchange is not None
    assert sim1.exchange.published > 0
    assert sim1.exchange.fanout == \
        sim1.exchange.published * (len(sim1.services()) - 1)


def test_cell_router_identity_and_conservation():
    scenario = make_scenario("burst-storm", n_functions=4, duration_s=20,
                             target_nodes=8, seed=1)
    fns = sorted(scenario.specs)

    class _Scaler:
        on_fn_dirty = None

    def make_cell(i):
        return Cell(i, scenario.build_cluster(8), None, _Scaler())

    # single cell: the plan is the rps dict itself (no float math)
    solo = CellRouter([make_cell(0)])
    rps = {fns[0]: 3.0, fns[1]: 0.0}
    assert solo.split(rps, scenario.specs) == [rps]

    cells = [make_cell(0), make_cell(1)]
    router = CellRouter(cells, load_cap=0.85)
    # warm placements in both cells for fns[0]; fns[1] cold everywhere
    for cell, k in ((cells[0], 3), (cells[1], 1)):
        node = cell.cluster.add_node()
        node.deploy(fns[0], k)
    rps = {fns[0]: 500.0, fns[1]: 7.0, fns[2]: 0.0}
    shares = router.split(rps, scenario.specs)
    assert len(shares) == 2
    # conservation: per-fn shares sum to the global rps exactly
    total = sum(s.get(fns[0], 0.0) for s in shares)
    assert total == pytest.approx(500.0, abs=1e-9)
    # cold fn goes whole to its deterministic home cell
    home = router.home(fns[1])
    assert shares[home][fns[1]] == 7.0
    assert fns[1] not in shares[1 - home]
    # zero-rps fns appear nowhere
    assert all(fns[2] not in s for s in shares)
    # both warm cells carry some of the hot fn's load
    assert all(s.get(fns[0], 0.0) > 0 for s in shares)


def test_capacity_exchange_fanout_and_epoch():
    class _Svc:
        def __init__(self):
            self.got = []
            self.exchange = None

        def accept_exchange(self, key, epoch, cap):
            self.got.append((key, epoch, cap))

    a, b, c = _Svc(), _Svc(), _Svc()
    ex = CapacityExchange()
    for svc in (a, b, c):
        ex.join(svc)
        assert svc.exchange is ex
    ex.publish(a, "sig", 4, 11)
    assert a.got == []
    assert b.got == [("sig", 4, 11)]
    assert c.got == [("sig", 4, 11)]
    assert (ex.published, ex.fanout) == (1, 2)


def test_prediction_service_accept_exchange_epoch_guard(parity_world):
    """A gossiped capacity from a pre-retrain epoch must be dropped."""
    scenario, world = parity_world
    sim = cell_scenario_simulation(scenario, "jiagu", n_cells=2,
                                   world=world)
    svc = sim.services()[0]
    key = ("made-up-signature",)
    svc.accept_exchange(key, svc._epoch, 9)
    assert svc._cache[key] == (svc._epoch, 9)
    stale_key = ("stale-signature",)
    svc.accept_exchange(stale_key, svc._epoch - 1, 9)
    assert stale_key not in svc._cache


def test_platform_cells_section():
    base = {
        "scenario": {"kind": "burst-storm", "n_functions": 4,
                     "duration_s": 20, "target_nodes": 8, "seed": 0},
        "scheduler": {"name": "jiagu"},
        "prediction": {"n_train": 300, "n_trees": 8},
    }
    plat = Platform.build(config={**base, "cells": {"count": 2}})
    assert isinstance(plat.simulation, CellSimulation)
    assert len(plat.simulation.cells) == 2
    res = plat.run(duration_s=10)
    assert res.ticks == 10
    assert np.isfinite(res.density)
    # cells=1 (the default) keeps the legacy single-loop assembly
    plat1 = Platform.build(config=base)
    assert not isinstance(plat1.simulation, CellSimulation)
    with pytest.raises(PlatformConfigError):
        Platform.build(config={**base, "cells": {"count": 0}})
    with pytest.raises(PlatformConfigError):
        Platform.build(config={**base, "cells": {"load_cap": 1.5}})
    with pytest.raises(PlatformConfigError):
        Platform.build(config={**base, "cells": {"count": 2}},
                       router=object())
