"""End-to-end system behaviour: the full Jiagu stack vs baselines on
short traces — density ordering, QoS, fast-path dominance (paper §7)."""
import numpy as np
import pytest

from repro.core import (Autoscaler, Cluster, GroundTruth, GsightScheduler,
                        JiaguScheduler, K8sScheduler, PerfPredictor,
                        ProfileStore, QoSStore, ScalingConfig, SimConfig,
                        Simulation, generate_dataset, realworld_trace,
                        synthetic_functions, timer_trace)


@pytest.fixture(scope="module")
def world():
    specs = synthetic_functions(4, seed=7)
    gt = GroundTruth(seed=0)
    store = ProfileStore(seed=0)
    qos = QoSStore(store, gt)
    pred = PerfPredictor(n_trees=12, max_depth=7, seed=0)
    X, y = generate_dataset(specs, gt, store, qos, 800, seed=2)
    pred.add_dataset(X, y)
    return specs, gt, store, qos, pred


def _run(world, sched_name, trace, dual=True, release_s=20,
         keepalive_s=60.0):
    specs, gt, store, qos, pred = world
    cluster = Cluster(specs)
    if sched_name == "jiagu":
        sched = JiaguScheduler(cluster, store, qos, pred, m_max=12)
    elif sched_name == "gsight":
        sched = GsightScheduler(cluster, store, qos, pred)
    else:
        sched = K8sScheduler(cluster, store, qos)
    aut = Autoscaler(cluster, sched, ScalingConfig(
        release_s=release_s, keepalive_s=keepalive_s,
        dual_staged=dual and sched_name == "jiagu"))
    sim = Simulation(specs, trace, sched, aut, gt, store, qos,
                     predictor=pred if sched_name != "k8s" else None,
                     cfg=SimConfig(collect_samples=False))
    return sim.run()


@pytest.fixture(scope="module")
def paper_world():
    """The six ServerlessBench/FunctionBench workloads (the Fig-13
    world, where users over-provision heavily)."""
    from repro.core import BENCH_FUNCTIONS
    specs = dict(BENCH_FUNCTIONS)
    gt = GroundTruth(seed=0)
    store = ProfileStore(seed=0)
    qos = QoSStore(store, gt)
    pred = PerfPredictor(n_trees=16, max_depth=8, seed=0)
    X, y = generate_dataset(specs, gt, store, qos, 1200, seed=2)
    pred.add_dataset(X, y)
    return specs, gt, store, qos, pred


def test_jiagu_densier_than_k8s_with_acceptable_qos(paper_world):
    trace = realworld_trace(sorted(paper_world[0]), duration_s=400,
                            seed=11)
    r_j = _run(paper_world, "jiagu", trace)
    r_k = _run(paper_world, "k8s", trace)
    assert r_j.density > r_k.density * 1.1    # overcommitment wins
    assert r_j.qos_violation_rate < 0.10      # paper's acceptance bar
    assert r_k.qos_violation_rate < 0.10      # baseline world is sane


def test_fast_path_dominates_on_timer_trace(world):
    """Paper §7.2 best case: >80% of schedulings go through the fast
    path."""
    fn = sorted(world[0])[0]
    spec = world[0][fn]
    trace = timer_trace(fn, duration_s=600, period_s=60,
                        rps_per_inst=spec.saturated_rps)
    r = _run(world, "jiagu", trace, dual=False, keepalive_s=30.0)
    s = r.sched
    assert s.fast / max(s.fast + s.slow, 1) > 0.7
    assert s.slow <= 2                      # only the very first arrival
    assert s.mean_latency_ms < 5.0


def test_jiagu_fewer_inferences_than_gsight(world):
    trace = realworld_trace(sorted(world[0]), duration_s=300, seed=13)
    r_j = _run(world, "jiagu", trace, dual=False)
    r_g = _run(world, "gsight", trace)
    # critical-path inference rows per placed instance
    jiagu_rows = r_j.sched.critical_inference_rows / max(
        r_j.sched.instances_placed, 1)
    gsight_rows = r_g.sched.critical_inference_rows / max(
        r_g.sched.instances_placed, 1)
    assert jiagu_rows < gsight_rows


def test_dual_staged_improves_density(world):
    trace = realworld_trace(sorted(world[0]), duration_s=400, seed=17)
    r_ds = _run(world, "jiagu", trace, dual=True, release_s=15)
    r_no = _run(world, "jiagu", trace, dual=False)
    assert r_ds.density >= r_no.density * 0.98  # = or better
    assert r_ds.scaling.logical_cold_starts >= 0
    assert r_ds.scaling.releases > 0


def test_trace_at_clamps_out_of_range_and_rejects_unknown_fn():
    """Trace.at semantics: t past either end clamps to the trace edge
    (simulations may run longer than the trace program), and a lookup
    for a function the trace does not know is a KeyError, not a silent
    zero."""
    trace = timer_trace("f", duration_s=10, period_s=3)
    first, last = trace.rps["f"][0], trace.rps["f"][-1]
    assert first != last  # the clamp direction is actually observable
    assert trace.at("f", 9) == last
    assert trace.at("f", 10) == last        # one past the end
    assert trace.at("f", 10_000) == last    # far past the end
    assert trace.at("f", 0) == first
    assert trace.at("f", -1) == first       # negative t clamps, never
    assert trace.at("f", -999) == first     # wraps to the array tail
    with pytest.raises(KeyError, match="ghost"):
        trace.at("ghost", 0)


def test_simulation_accounting_consistent(world):
    trace = realworld_trace(sorted(world[0]), duration_s=200, seed=19)
    r = _run(world, "jiagu", trace)
    assert r.requests > 0
    assert 0 <= r.qos_violation_rate <= 1
    assert r.instance_seconds >= r.node_seconds  # >=1 instance per node
    for fn, v in r.per_fn_violations.items():
        assert v <= r.per_fn_requests[fn] + 1e-6
