import os
import sys

import numpy as np
import pytest

# src layout import path (tests run with or without PYTHONPATH=src)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no xla_force_host_platform_device_count here — smoke tests and
# benches must see 1 device (the dry-run sets its own flag in-process).


@pytest.fixture(autouse=True)
def _deterministic_global_rng():
    """Every test starts from the same legacy global numpy RNG state, so
    forest-dependent tests cannot depend on test/collection order (safe
    under ``pytest -p no:randomly`` and any reordering plugin).  All
    repro code seeds explicit ``default_rng`` instances; this pins down
    test-local and third-party ``np.random`` use."""
    np.random.seed(20260727)
    yield


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "tpu_only: real-hardware Pallas path (interpret=False) that the "
        "CPU interpret mode cannot run; auto-skipped off-TPU")
    config.addinivalue_line(
        "markers",
        "slow: large-cluster / long-trace tests kept out of tier-1; run "
        "with RUN_SLOW=1 (scripts/verify.sh --full)")


def _on_tpu() -> bool:
    try:
        import jax
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def pytest_collection_modifyitems(config, items):
    if os.environ.get("RUN_SLOW") != "1":
        skip_slow = pytest.mark.skip(
            reason="slow: set RUN_SLOW=1 (or scripts/verify.sh --full)")
        for item in items:
            if "slow" in item.keywords:
                item.add_marker(skip_slow)
    if _on_tpu():
        return
    skip = pytest.mark.skip(
        reason="tpu_only: needs real TPU (Pallas interpret=False)")
    for item in items:
        if "tpu_only" in item.keywords:
            item.add_marker(skip)
