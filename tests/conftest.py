import os
import sys

import pytest

# src layout import path (tests run with or without PYTHONPATH=src)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no xla_force_host_platform_device_count here — smoke tests and
# benches must see 1 device (the dry-run sets its own flag in-process).


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "tpu_only: real-hardware Pallas path (interpret=False) that the "
        "CPU interpret mode cannot run; auto-skipped off-TPU")
    config.addinivalue_line(
        "markers",
        "slow: large-cluster / long-trace tests kept out of tier-1; run "
        "with RUN_SLOW=1 (scripts/verify.sh --full)")


def _on_tpu() -> bool:
    try:
        import jax
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def pytest_collection_modifyitems(config, items):
    if os.environ.get("RUN_SLOW") != "1":
        skip_slow = pytest.mark.skip(
            reason="slow: set RUN_SLOW=1 (or scripts/verify.sh --full)")
        for item in items:
            if "slow" in item.keywords:
                item.add_marker(skip_slow)
    if _on_tpu():
        return
    skip = pytest.mark.skip(
        reason="tpu_only: needs real TPU (Pallas interpret=False)")
    for item in items:
        if "tpu_only" in item.keywords:
            item.add_marker(skip)
