import os
import sys

# src layout import path (tests run with or without PYTHONPATH=src)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no xla_force_host_platform_device_count here — smoke tests and
# benches must see 1 device (the dry-run sets its own flag in-process).
