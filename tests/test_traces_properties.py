"""Property tests for every trace generator: non-negative RPS, exact
duration, seed determinism, linear peak scaling, the ``flip`` out-of-phase
invariant and the ``timer`` two-level invariant.

Runs under real `hypothesis` when installed, else under the deterministic
fallback shim (same assertions, fixed-seed sampled inputs)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import (azure_sparse_trace, burst_storm_trace,
                        coldstart_churn_trace, diurnal_shift_trace,
                        flip_trace, realworld_trace, timer_trace)

#: the population-style generators: (fn_names, duration_s, seed, scale_rps)
POPULATION_GENERATORS = [realworld_trace, burst_storm_trace,
                         diurnal_shift_trace, coldstart_churn_trace,
                         azure_sparse_trace]


def _fns(n):
    return [f"fn{i:02d}" for i in range(n)]


# ---------------------------------------------------------------------------
# Shared invariants: shape, sign, finiteness, determinism
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(gen_i=st.integers(0, len(POPULATION_GENERATORS) - 1),
       n=st.integers(1, 6), duration=st.integers(30, 180),
       seed=st.integers(0, 9))
def test_nonnegative_finite_exact_duration(gen_i, n, duration, seed):
    gen = POPULATION_GENERATORS[gen_i]
    tr = gen(_fns(n), duration_s=duration, seed=seed)
    assert tr.duration_s == duration
    assert set(tr.rps) == set(_fns(n))
    for series in tr.rps.values():
        assert series.shape == (duration,)
        assert np.isfinite(series).all()
        assert (series >= 0.0).all()


@settings(max_examples=10, deadline=None)
@given(gen_i=st.integers(0, len(POPULATION_GENERATORS) - 1),
       n=st.integers(2, 5), seed=st.integers(0, 9))
def test_seed_determinism(gen_i, n, seed):
    """Same seed -> bit-identical series; different seed -> different
    trace (the scenario suite depends on reproducible worlds)."""
    gen = POPULATION_GENERATORS[gen_i]
    fns = _fns(n)
    a = gen(fns, duration_s=120, seed=seed)
    b = gen(fns, duration_s=120, seed=seed)
    for fn in fns:
        assert np.array_equal(a.rps[fn], b.rps[fn])
    c = gen(fns, duration_s=120, seed=seed + 100)
    assert any(not np.array_equal(a.rps[fn], c.rps[fn]) for fn in fns)


@settings(max_examples=10, deadline=None)
@given(gen_i=st.integers(0, len(POPULATION_GENERATORS) - 1),
       n=st.integers(1, 4), seed=st.integers(0, 9),
       mult=st.integers(2, 5))
def test_peak_scaling_is_linear(gen_i, n, seed, mult):
    """scale_rps multiplies a function's series linearly — the contract
    scenarios.scale_trace_to_nodes relies on to hit a target cluster
    size."""
    gen = POPULATION_GENERATORS[gen_i]
    fns = _fns(n)
    unit = {fn: 1.0 for fn in fns}
    scaled = {fn: float(mult) for fn in fns}
    a = gen(fns, duration_s=90, seed=seed, scale_rps=unit)
    b = gen(fns, duration_s=90, seed=seed, scale_rps=scaled)
    for fn in fns:
        assert np.allclose(b.rps[fn], a.rps[fn] * mult, rtol=1e-12)


# ---------------------------------------------------------------------------
# flip: out-of-phase oscillation (§7.2 worst case)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 4), period=st.integers(12, 36),
       rps=st.integers(1, 10))
def test_flip_out_of_phase_invariant(n, period, rps):
    duration = 8 * period
    fns = _fns(n)
    tr = flip_trace(fns, duration_s=duration, period_s=period,
                    rps=float(rps))
    base = tr.rps[fns[0]]
    # two-valued 0 <-> rps oscillation with period `period`
    for fn in fns:
        assert set(np.unique(tr.rps[fn])) <= {0.0, float(rps)}
        assert np.array_equal(tr.rps[fn][: duration - 2 * period],
                              tr.rps[fn][2 * period:])
    for i, fn in enumerate(fns):
        off = i * period // n   # the generator's stagger per function
        # each function is the first one time-shifted by i*step ...
        assert np.array_equal(tr.rps[fn][: duration - off],
                              base[off:] if off else base)
        # ... and genuinely out of phase with it (shift within a cycle)
        if 0 < off < 2 * period:
            assert not np.array_equal(tr.rps[fn], base)


# ---------------------------------------------------------------------------
# timer: two-level alternation (§7.2 best case)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(period=st.integers(10, 60), n_inst=st.integers(1, 6),
       rps_per_inst=st.integers(5, 30), n_periods=st.integers(2, 6))
def test_timer_two_level_invariant(period, n_inst, rps_per_inst,
                                   n_periods):
    duration = n_periods * period
    tr = timer_trace("f", duration_s=duration, period_s=period,
                     rps_per_inst=float(rps_per_inst), n_inst=n_inst)
    lo = rps_per_inst * n_inst * 0.95
    hi = rps_per_inst * (n_inst + 2) * 0.95
    series = tr.rps["f"]
    assert set(np.unique(series)) <= {lo, hi}
    for t in range(duration):
        expect = lo if (t // period) % 2 == 0 else hi
        assert series[t] == expect


def test_flip_and_timer_duration_and_sign():
    tr_f = flip_trace(_fns(3), duration_s=90, period_s=15)
    tr_t = timer_trace("f", duration_s=90, period_s=15)
    for tr in (tr_f, tr_t):
        assert tr.duration_s == 90
        for series in tr.rps.values():
            assert series.shape == (90,)
            assert (series >= 0.0).all()
