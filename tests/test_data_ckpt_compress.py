"""Data pipeline determinism, checkpoint atomicity/resharding, gradient
compression round trips."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt_lib
from repro.configs.base import InputShape, get_smoke_config
from repro.data.pipeline import ByteCorpus, TokenPipeline
from repro.distributed import compression as comp


def test_pipeline_deterministic_and_step_dependent():
    cfg = get_smoke_config("gemma2-2b")
    shape = InputShape("t", 32, 4, "train")
    p1 = TokenPipeline(cfg, shape, seed=3)
    p2 = TokenPipeline(cfg, shape, seed=3)
    b1, b2 = p1.batch(7), p2.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p1.batch(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].max() < cfg.vocab_size


def test_pipeline_shard_matches_global_slice():
    cfg = get_smoke_config("qwen1.5-110b")
    shape = InputShape("t", 16, 8, "train")
    p = TokenPipeline(cfg, shape, seed=0)
    full = p.batch(3)
    shard = p.shard_batch(3, 2, 6)
    np.testing.assert_array_equal(shard["tokens"], full["tokens"][2:6])


def test_pipeline_targets_are_next_tokens():
    cfg = get_smoke_config("gemma2-2b")
    shape = InputShape("t", 32, 2, "train")
    b = TokenPipeline(cfg, shape, seed=0).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_byte_corpus_reads_repo():
    c = ByteCorpus(root=os.path.dirname(os.path.dirname(__file__)),
                   max_bytes=1 << 16)
    b = c.batch(0, 4, 64)
    assert b["tokens"].shape == (4, 64)
    assert b["tokens"].max() < 256


def test_checkpoint_roundtrip_and_gc(tmp_path):
    state = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
             "step": jnp.asarray(5, jnp.int32)}
    for s in [1, 2, 3, 4]:
        ckpt_lib.save(str(tmp_path), s, state, keep=2)
    assert ckpt_lib.latest_step(str(tmp_path)) == 4
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(dirs) == 2  # keep-K GC
    abstract = jax.eval_shape(lambda: state)
    restored, meta = ckpt_lib.restore(str(tmp_path), abstract)
    assert meta["step"] == 4
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_checkpoint_restore_specific_step(tmp_path):
    s1 = {"w": jnp.ones((2,))}
    s2 = {"w": jnp.ones((2,)) * 2}
    ckpt_lib.save(str(tmp_path), 1, s1)
    ckpt_lib.save(str(tmp_path), 2, s2)
    restored, meta = ckpt_lib.restore(str(tmp_path),
                                      jax.eval_shape(lambda: s1), step=1)
    assert float(restored["w"][0]) == 1.0 and meta["step"] == 1


def test_checkpoint_restore_with_shardings(tmp_path):
    """Reshard-on-restore: restore into an explicit (1,1) mesh sharding —
    the mechanism elastic re-scaling uses."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    state = {"w": jnp.arange(8, dtype=jnp.float32)}
    ckpt_lib.save(str(tmp_path), 1, state)
    sh = {"w": NamedSharding(mesh, P("data"))}
    restored, _ = ckpt_lib.restore(str(tmp_path),
                                   jax.eval_shape(lambda: state), sh)
    assert restored["w"].sharding == sh["w"]


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(512).astype(np.float32))
    q, scale = comp.quantize(g)
    back = comp.dequantize(q, scale)
    assert q.dtype == jnp.int8
    max_err = float(jnp.max(jnp.abs(back - g)))
    assert max_err <= float(scale) * 0.5 + 1e-7


def test_error_feedback_accumulates():
    """EF carries what quantization dropped: across steps the *sum* of
    dequantized payloads approaches the sum of true gradients."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(64, np.float32)
    sent_sum = np.zeros(64, np.float32)
    err = jnp.zeros(64, jnp.float32)
    for _ in range(50):
        g = jnp.asarray((1e-4 * rng.standard_normal(64)).astype(np.float32))
        q, s, err = comp.ef_quantize(g, err)
        sent_sum += np.asarray(comp.dequantize(q, s))
        true_sum += np.asarray(g)
    # without EF, tiny gradients would quantize to ~0 every step
    assert np.linalg.norm(sent_sum - true_sum) <= \
        np.linalg.norm(true_sum) * 0.05 + 1e-5


def test_compressed_psum_shardmap():
    """compressed_psum inside shard_map over a 1-device axis behaves as
    identity-mean (the collective path the pod axis would take)."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    mesh = jax.make_mesh((1,), ("pod",))
    g = jnp.asarray(np.random.default_rng(2)
                    .standard_normal(32).astype(np.float32))
    err = jnp.zeros(32, jnp.float32)
    f = shard_map(lambda g, e: comp.compressed_psum(g, "pod", e),
                  mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
    mean_g, new_err = f(g, err)
    np.testing.assert_allclose(np.asarray(mean_g), np.asarray(g), atol=0.02)


def test_compress_grads_tree_shapes():
    grads = {"a": jnp.ones((4, 4)), "b": {"c": jnp.ones((3,)) * 1e-9}}
    err = comp.init_error_state(grads)
    out, new_err = comp.compress_grads_tree(grads, err)
    assert jax.tree.structure(out) == jax.tree.structure(grads)
    # 1e-9 gradients vanish under int8 but persist in the error state
    assert float(jnp.abs(new_err["b"]["c"]).max()) > 0 or \
        float(out["b"]["c"].max()) > 0
