"""System-level engine-vs-legacy regression: the gate that let
``SimConfig.use_capacity_engine`` default to True, extended to the
unified PredictionService.

The same full scenario trace is simulated three times from bit-identical
starting state — the legacy per-node capacity path, the default-attached
service path, and an explicitly constructed schema-v1
``PredictionService`` injected as the scheduler's engine — and
everything observable must match: final capacity tables, QoS-violation
rate, density, and the scheduling/scaling counters.  (The service is
allowed to be *cheaper* — fewer predictor calls — never *different*.)

Schema v2 is gated the other way: on the heterogeneous scenario
topology its capacities on the 2x node class must dominate v1's while
ground truth keeps them within QoS."""
import numpy as np
import pytest

from repro.core import (LARGE_NODE, EngineConfig, PredictionService,
                        SimConfig, make_scenario, scenario_simulation,
                        scenario_world)

KIND = "burst-storm"
DURATION = 100
TARGET_NODES = 14
N_FUNCTIONS = 6
SEED = 3


def _arm(mode: str):
    """One A/B/C arm built from scratch: same seeds -> same specs, trace,
    ground truth, profiles and forest for every arm."""
    scenario = make_scenario(KIND, n_functions=N_FUNCTIONS,
                             duration_s=DURATION,
                             target_nodes=TARGET_NODES, seed=SEED)
    world = scenario_world(scenario, n_train=700, n_trees=10)
    sim = scenario_simulation(scenario, "jiagu", world=world,
                              use_engine=(mode != "legacy"))
    if mode == "service":
        # replace the auto-attached service with one constructed through
        # the public PredictionService API (explicit schema v1)
        sim.scheduler.engine = PredictionService(
            world.predictor, world.store, world.qos, scenario.specs,
            EngineConfig(m_max=sim.scheduler.m_max), schema=1)
        sim._service = sim.scheduler.engine
    res = sim.run()
    tables = sorted(
        tuple(sorted((fn, e.capacity) for fn, e in node.table.items()))
        for node in sim.cluster.nodes.values())
    return res, tables, sim


@pytest.fixture(scope="module")
def ab():
    legacy = _arm("legacy")
    engine = _arm("engine")
    return legacy, engine


@pytest.fixture(scope="module")
def service_arm():
    return _arm("service")


def test_engine_defaults_on_and_attaches(ab):
    assert SimConfig().use_capacity_engine is True
    (_, _, sim_legacy), (_, _, sim_engine) = ab
    assert sim_legacy.scheduler.engine is None
    assert sim_engine.scheduler.engine is not None
    assert sim_engine.scheduler.engine.stats.solves > 0


def test_capacity_tables_identical(ab):
    (_, tables_l, _), (_, tables_e, _) = ab
    assert tables_l == tables_e


def test_qos_density_and_request_accounting_match(ab):
    (legacy, _, _), (engine, _, _) = ab
    assert np.isclose(legacy.qos_violation_rate, engine.qos_violation_rate,
                      rtol=1e-12, atol=1e-15)
    assert np.isclose(legacy.density, engine.density, rtol=1e-12)
    assert legacy.requests == pytest.approx(engine.requests, rel=1e-12)
    assert legacy.violated_requests == pytest.approx(
        engine.violated_requests, rel=1e-12)
    assert np.allclose(legacy.density_series, engine.density_series,
                       rtol=1e-12)


def test_scheduling_metrics_match(ab):
    (legacy, _, _), (engine, _, _) = ab
    ls, es = legacy.sched, engine.sched
    assert (ls.decisions, ls.fast, ls.slow, ls.failed,
            ls.instances_placed) == \
        (es.decisions, es.fast, es.slow, es.failed, es.instances_placed)


def test_scaling_metrics_match(ab):
    (legacy, _, _), (engine, _, _) = ab
    lsc, esc = legacy.scaling, engine.scaling
    assert (lsc.real_cold_starts, lsc.logical_cold_starts, lsc.releases,
            lsc.evictions, lsc.migrations) == \
        (esc.real_cold_starts, esc.logical_cold_starts, esc.releases,
         esc.evictions, esc.migrations)


def test_engine_is_cheaper_never_different(ab):
    """The whole point of the default flip: same behavior, fewer batched
    predictor calls on the async-update path."""
    (legacy, _, _), (engine, _, _) = ab
    assert engine.inference_calls < legacy.inference_calls


# ---------------------------------------------------------------------------
# PredictionService path (schema v1): identical to both other paths
# ---------------------------------------------------------------------------


def test_service_path_tables_identical_to_legacy_and_engine(ab,
                                                            service_arm):
    (_, tables_l, _), (_, tables_e, _) = ab
    _, tables_s, sim = service_arm
    assert tables_s == tables_l == tables_e
    assert sim.scheduler.engine.schema.version == 1
    assert sim.scheduler.engine.stats.solves > 0


def test_service_path_metrics_identical(ab, service_arm):
    (legacy, _, _), _ = ab
    service, _, _ = service_arm
    assert np.isclose(legacy.qos_violation_rate,
                      service.qos_violation_rate, rtol=1e-12, atol=1e-15)
    assert np.isclose(legacy.density, service.density, rtol=1e-12)
    ls, ss = legacy.sched, service.sched
    assert (ls.decisions, ls.fast, ls.slow, ls.failed,
            ls.instances_placed) == \
        (ss.decisions, ss.fast, ss.slow, ss.failed, ss.instances_placed)
    lsc, ssc = legacy.scaling, service.scaling
    assert (lsc.real_cold_starts, lsc.logical_cold_starts, lsc.releases,
            lsc.evictions, lsc.migrations) == \
        (ssc.real_cold_starts, ssc.logical_cold_starts, ssc.releases,
         ssc.evictions, ssc.migrations)


# ---------------------------------------------------------------------------
# Schema v2: node-shape-aware capacities dominate v1 on the big nodes
# ---------------------------------------------------------------------------


def test_schema_v2_dominates_v1_on_large_nodes_within_qos():
    """On the heterogeneous scenario topology, v2 capacities for the 2x
    node class must be at least v1's (which are standard-node capacities,
    conservative by construction) and strictly larger in aggregate —
    while the ground truth confirms the extra density still meets QoS."""
    scenario = make_scenario(KIND, n_functions=N_FUNCTIONS, duration_s=60,
                             target_nodes=TARGET_NODES, seed=SEED)
    # same training budget for both schemas; v2 needs the depth to carve
    # per-shape leaves (shape x pressure interactions)
    w1 = scenario_world(scenario, n_train=2000, n_trees=16, max_depth=10)
    w2 = scenario_world(scenario, n_train=2000, n_trees=16, max_depth=10,
                        schema_version=2)
    m_max = 48
    svc1 = PredictionService(w1.predictor, w1.store, w1.qos, scenario.specs,
                             EngineConfig(m_max=m_max), schema=1)
    svc2 = PredictionService(w2.predictor, w2.store, w2.qos, scenario.specs,
                             EngineConfig(m_max=m_max), schema=2)
    big = LARGE_NODE.res
    names = sorted(scenario.specs)
    rng = np.random.default_rng(7)
    total1 = total2 = 0
    violations1 = violations2 = 0
    for _ in range(16):
        fn = names[rng.integers(len(names))]
        coloc = {}
        # heavy mixes: the standard node must be the binding constraint,
        # otherwise both schemas saturate m_max and dominance is vacuous
        for g in rng.choice(names, size=rng.integers(2, 5), replace=False):
            if g != fn:
                coloc[g] = (float(rng.integers(3, 9)), 0.0)
        cap1, _ = svc1.capacity(dict(coloc), fn, m_max, node_res=big)
        cap2, _ = svc2.capacity(dict(coloc), fn, m_max, node_res=big)
        # v1 is node-shape-blind: conservative on the 2x node.  Forest
        # noise and v2's explicit QoS safety margin (v1 has none) allow
        # small local inversions; the aggregate must dominate.
        assert cap2 >= min(cap1 - 3, cap1 * 0.85), (fn, coloc, cap1, cap2)
        total1 += cap1
        total2 += cap2
        # ground-truth QoS check at each claimed capacity
        for caps, bucket in ((cap1, 1), (cap2, 2)):
            if caps <= 0:
                continue
            full = {fn: (scenario.specs[fn], float(caps), 0.0)}
            for g, (ns, nc) in coloc.items():
                full[g] = (scenario.specs[g], ns, nc)
            lat = w1.gt.latency(scenario.specs[fn], full, load_frac=1.0,
                                node_res=big)
            bad = lat > w1.qos.qos(scenario.specs[fn])
            if bucket == 1:
                violations1 += bad
            else:
                violations2 += bad
    assert total2 > total1 * 1.25       # strict aggregate dominance
    assert violations2 <= max(violations1, 1)   # no QoS regression
