"""System-level engine-vs-legacy regression: the gate that let
``SimConfig.use_capacity_engine`` default to True.

The same full scenario trace is simulated twice from bit-identical
starting state — once on the legacy per-node capacity path, once with the
CapacityEngine — and everything observable must match: final capacity
tables, QoS-violation rate, density, and the scheduling/scaling
counters.  (The engine is allowed to be *cheaper* — fewer predictor
calls — never *different*.)"""
import numpy as np
import pytest

from repro.core import (SimConfig, make_scenario, scenario_simulation,
                        scenario_world)

KIND = "burst-storm"
DURATION = 100
TARGET_NODES = 14
N_FUNCTIONS = 6
SEED = 3


def _arm(use_engine: bool):
    """One A/B arm built from scratch: same seeds -> same specs, trace,
    ground truth, profiles and forest for both arms."""
    scenario = make_scenario(KIND, n_functions=N_FUNCTIONS,
                             duration_s=DURATION,
                             target_nodes=TARGET_NODES, seed=SEED)
    world = scenario_world(scenario, n_train=700, n_trees=10)
    sim = scenario_simulation(scenario, "jiagu", world=world,
                              use_engine=use_engine)
    res = sim.run()
    tables = sorted(
        tuple(sorted((fn, e.capacity) for fn, e in node.table.items()))
        for node in sim.cluster.nodes.values())
    return res, tables, sim


@pytest.fixture(scope="module")
def ab():
    legacy = _arm(False)
    engine = _arm(True)
    return legacy, engine


def test_engine_defaults_on_and_attaches(ab):
    assert SimConfig().use_capacity_engine is True
    (_, _, sim_legacy), (_, _, sim_engine) = ab
    assert sim_legacy.scheduler.engine is None
    assert sim_engine.scheduler.engine is not None
    assert sim_engine.scheduler.engine.stats.solves > 0


def test_capacity_tables_identical(ab):
    (_, tables_l, _), (_, tables_e, _) = ab
    assert tables_l == tables_e


def test_qos_density_and_request_accounting_match(ab):
    (legacy, _, _), (engine, _, _) = ab
    assert np.isclose(legacy.qos_violation_rate, engine.qos_violation_rate,
                      rtol=1e-12, atol=1e-15)
    assert np.isclose(legacy.density, engine.density, rtol=1e-12)
    assert legacy.requests == pytest.approx(engine.requests, rel=1e-12)
    assert legacy.violated_requests == pytest.approx(
        engine.violated_requests, rel=1e-12)
    assert np.allclose(legacy.density_series, engine.density_series,
                       rtol=1e-12)


def test_scheduling_metrics_match(ab):
    (legacy, _, _), (engine, _, _) = ab
    ls, es = legacy.sched, engine.sched
    assert (ls.decisions, ls.fast, ls.slow, ls.failed,
            ls.instances_placed) == \
        (es.decisions, es.fast, es.slow, es.failed, es.instances_placed)


def test_scaling_metrics_match(ab):
    (legacy, _, _), (engine, _, _) = ab
    lsc, esc = legacy.scaling, engine.scaling
    assert (lsc.real_cold_starts, lsc.logical_cold_starts, lsc.releases,
            lsc.evictions, lsc.migrations) == \
        (esc.real_cold_starts, esc.logical_cold_starts, esc.releases,
         esc.evictions, esc.migrations)


def test_engine_is_cheaper_never_different(ab):
    """The whole point of the default flip: same behavior, fewer batched
    predictor calls on the async-update path."""
    (legacy, _, _), (engine, _, _) = ab
    assert engine.inference_calls < legacy.inference_calls
