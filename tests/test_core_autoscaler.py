"""Dual-staged scaling state machine: release timing, logical cold
starts, keep-alive eviction, on-demand migration (paper §5, Fig 10)."""
import pytest

from repro.core import (Autoscaler, Cluster, GroundTruth, JiaguScheduler,
                        PerfPredictor, ProfileStore, QoSStore,
                        ScalingConfig, generate_dataset,
                        synthetic_functions)


@pytest.fixture(scope="module")
def world():
    specs = synthetic_functions(3, seed=5)
    gt = GroundTruth(seed=0)
    store = ProfileStore(seed=0)
    qos = QoSStore(store, gt)
    pred = PerfPredictor(n_trees=12, max_depth=7, seed=0)
    X, y = generate_dataset(specs, gt, store, qos, 500, seed=1)
    pred.add_dataset(X, y)
    return specs, gt, store, qos, pred


def _mk(world, release_s=45.0, keepalive_s=60.0, dual=True, migrate=True):
    specs, gt, store, qos, pred = world
    cluster = Cluster(specs)
    sched = JiaguScheduler(cluster, store, qos, pred, m_max=12)
    aut = Autoscaler(cluster, sched, ScalingConfig(
        release_s=release_s, keepalive_s=keepalive_s, dual_staged=dual,
        migrate=migrate))
    return cluster, sched, aut


def _fn(world):
    return sorted(world[0])[0]


def _sat_rps(world, fn, n):
    return world[0][fn].saturated_rps * n * 0.99


def test_dual_staged_timeline(world):
    """Fig 10: load drop -> release after release_s (instances cached, not
    evicted) -> eviction only after keepalive_s."""
    cluster, sched, aut = _mk(world, release_s=10, keepalive_s=30)
    fn = _fn(world)
    for t in range(5):
        aut.tick(float(t), {fn: _sat_rps(world, fn, 4)})
        sched.on_tick(float(t) + 0.5)
    assert cluster.sat_count(fn) == 4
    # drop to 2-instances load
    t_drop = 5.0
    for i in range(9):
        aut.tick(t_drop + i, {fn: _sat_rps(world, fn, 2)})
    assert cluster.sat_count(fn) == 4          # release_s not reached
    assert cluster.cached_count(fn) == 0
    aut.tick(t_drop + 10.0, {fn: _sat_rps(world, fn, 2)})
    assert cluster.sat_count(fn) == 2          # released, not evicted
    assert cluster.cached_count(fn) == 2
    assert aut.metrics.releases == 2
    assert aut.metrics.evictions == 0
    # keep-alive expiry: ttl = keepalive - release = 20 s after release
    for i in range(25):
        aut.tick(t_drop + 11 + i, {fn: _sat_rps(world, fn, 2)})
    assert cluster.cached_count(fn) == 0       # finally evicted
    assert aut.metrics.evictions == 2


def test_logical_cold_start_on_load_rise(world):
    """A rise while instances are cached re-routes (<1 ms) instead of
    creating instances."""
    cluster, sched, aut = _mk(world, release_s=5, keepalive_s=120)
    fn = _fn(world)
    aut.tick(0.0, {fn: _sat_rps(world, fn, 4)})
    sched.on_tick(0.5)
    for i in range(7):
        aut.tick(1.0 + i, {fn: _sat_rps(world, fn, 2)})
    assert cluster.cached_count(fn) == 2
    real_before = aut.metrics.real_cold_starts
    aut.tick(10.0, {fn: _sat_rps(world, fn, 4)})
    assert cluster.sat_count(fn) == 4
    assert aut.metrics.logical_cold_starts >= 2
    assert aut.metrics.real_cold_starts == real_before
    # logical cold start cost is the re-route constant, not init_ms
    assert min(aut.metrics.cold_start_ms[-2:]) < 1.0


def test_traditional_keepalive_evicts_directly(world):
    cluster, sched, aut = _mk(world, keepalive_s=10, dual=False)
    fn = _fn(world)
    aut.tick(0.0, {fn: _sat_rps(world, fn, 3)})
    sched.on_tick(0.5)
    for i in range(12):
        aut.tick(1.0 + i, {fn: _sat_rps(world, fn, 1)})
    assert cluster.cached_count(fn) == 0       # never cached
    assert cluster.sat_count(fn) == 1
    assert aut.metrics.evictions == 2
    assert aut.metrics.releases == 0


def test_scale_up_from_zero_and_down_to_zero(world):
    cluster, sched, aut = _mk(world, release_s=3, keepalive_s=8)
    fn = _fn(world)
    aut.tick(0.0, {fn: 0.0})
    assert cluster.sat_count(fn) == 0
    aut.tick(1.0, {fn: _sat_rps(world, fn, 2)})
    assert cluster.sat_count(fn) == 2
    for i in range(15):
        aut.tick(2.0 + i, {fn: 0.0})
    assert cluster.sat_count(fn) == 0
    assert cluster.cached_count(fn) == 0
    assert len(cluster.nodes) == 0             # empty servers returned


def test_migration_frees_blocked_cached_instances(world):
    """When a node fills up so cached instances can't re-saturate, they
    migrate to a node with capacity headroom (paper Fig 14-b)."""
    specs, gt, store, qos, pred = world
    cluster, sched, aut = _mk(world, release_s=2, keepalive_s=500)
    fns = sorted(specs)
    fn, other = fns[0], fns[1]
    # two nodes running fn
    aut.tick(0.0, {fn: _sat_rps(world, fn, 6)})
    sched.on_tick(0.5)
    # drop fn so some instances get cached
    for i in range(5):
        aut.tick(1.0 + i, {fn: _sat_rps(world, fn, 2)})
    assert cluster.cached_count(fn) >= 1
    # squeeze capacity on the cached node by filling it with `other`
    cached_nodes = [n for n in cluster.nodes.values()
                    if fn in n.funcs and n.funcs[fn].n_cached > 0]
    assert cached_nodes
    node = cached_nodes[0]
    node.deploy(other, 6)
    from repro.core.capacity import update_capacity_table
    update_capacity_table(pred, store, qos, specs, node, m_max=12)
    # force a small capacity so n_sat + n_cached > capacity
    node.table[fn].capacity = max(node.funcs[fn].n_sat, 1)
    migrated_before = aut.metrics.migrations
    aut.tick(10.0, {fn: _sat_rps(world, fn, 2)})
    # either migrated away, or no target existed (then blocked counted)
    assert (aut.metrics.migrations > migrated_before
            or node.funcs[fn].n_cached == 0
            or aut.metrics.blocked_logical >= 0)
