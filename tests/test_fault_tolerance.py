"""Fault tolerance: straggler detection, watchdog, elastic mesh planning
(hypothesis), and the end-to-end fail+resume drill."""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:          # degraded deterministic fallback loop
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.distributed.fault_tolerance import (FailureInjector,
                                               InjectedFailure,
                                               StragglerDetector, Watchdog,
                                               plan_elastic_mesh)


def test_straggler_detector_flags_slow_host():
    sd = StragglerDetector(k_sigma=3.0, min_samples=5)
    rng = np.random.default_rng(0)
    for _ in range(50):
        for h in range(8):
            sd.record(h, 1.0 + 0.01 * rng.standard_normal())
        sd.record(8, 2.5 + 0.01 * rng.standard_normal())  # straggler
    assert sd.stragglers() == [8]


def test_straggler_detector_quiet_on_uniform_fleet():
    sd = StragglerDetector()
    for _ in range(30):
        for h in range(8):
            sd.record(h, 1.0)
    assert sd.stragglers() == []


def test_watchdog():
    t = [0.0]
    wd = Watchdog(timeout_s=10.0, clock=lambda: t[0])
    wd.beat(1)
    t[0] = 5.0
    assert not wd.stalled()
    t[0] = 16.0
    assert wd.stalled()
    wd.beat(2)
    assert not wd.stalled()


@settings(max_examples=100, deadline=None)
@given(n=st.integers(1, 4096))
def test_plan_elastic_mesh_properties(n):
    shape, axes = plan_elastic_mesh(n, model_parallel=16, pod_size=256)
    used = int(np.prod(shape))
    assert used <= n                       # never over-subscribes
    assert len(shape) == len(axes)
    if n >= 16:
        assert shape[-1] == 16             # TP degree preserved
        assert used >= (n // 256) * 256 or used >= 16
    if n >= 512:
        assert axes[0] == "pod"            # multi-pod when possible


def test_plan_elastic_mesh_shrinks_after_node_loss():
    full, _ = plan_elastic_mesh(512)
    degraded, axes = plan_elastic_mesh(512 - 16)   # lost one 16-chip node
    assert int(np.prod(degraded)) < int(np.prod(full))
    assert degraded[-1] == 16


def test_failure_injector_fires_once():
    inj = FailureInjector(fail_at_step=3)
    for i in range(3):
        inj.maybe_fail(i)
    with pytest.raises(InjectedFailure):
        inj.maybe_fail(3)
    inj.maybe_fail(3)  # second call: already fired


def test_train_fail_resume_end_to_end(tmp_path):
    """The full drill: train, die at step 6, resume from the step-4
    checkpoint, finish — final state exists and loss is finite."""
    from repro.configs.base import InputShape, get_smoke_config
    from repro.launch.train import train_loop
    cfg = get_smoke_config("gemma2-2b")
    shape = InputShape("t", 64, 2, "train")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ckpt = str(tmp_path / "ckpt")
    with pytest.raises(InjectedFailure):
        train_loop(cfg, shape, mesh, steps=10, ckpt_dir=ckpt,
                   save_every=4, fail_at=6, quiet=True)
    from repro.checkpoint import latest_step
    assert latest_step(ckpt) == 4
    _state, history = train_loop(cfg, shape, mesh, steps=10, ckpt_dir=ckpt,
                                 resume=True, save_every=4, quiet=True)
    assert len(history) == 6               # steps 4..9
    assert np.isfinite(history[-1])
    assert latest_step(ckpt) == 10


def test_resume_is_deterministic(tmp_path):
    """Stateless data pipeline + checkpointed state => resumed run
    reproduces the uninterrupted run's losses."""
    from repro.configs.base import InputShape, get_smoke_config
    from repro.launch.train import train_loop
    from repro.optim.adamw import AdamWConfig
    cfg = get_smoke_config("gemma2-2b")
    shape = InputShape("t", 64, 2, "train")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # one LR schedule for all runs (total_steps otherwise defaults to the
    # run length and the 4-step prefix would train under a shorter cosine)
    oc = AdamWConfig(total_steps=8, warmup_steps=1)
    _, h_straight = train_loop(cfg, shape, mesh, steps=8, quiet=True,
                               opt_cfg=oc)
    ckpt = str(tmp_path / "ckpt2")
    train_loop(cfg, shape, mesh, steps=4, ckpt_dir=ckpt, save_every=4,
               quiet=True, opt_cfg=oc)
    _, h_resumed = train_loop(cfg, shape, mesh, steps=8, ckpt_dir=ckpt,
                              resume=True, quiet=True, opt_cfg=oc)
    np.testing.assert_allclose(h_straight[4:], h_resumed, rtol=1e-4)
