"""Observer/telemetry parity: instrumentation must observe, never
perturb.

The same seeded scenario runs three ways — bare (zero observers, no
telemetry), through an ``EventHub`` with counting observers +
telemetry (``MetricsObserver`` + ``SpanTracer``), and with a
``JsonlObserver`` persisting every stream — and the simulation outcome
must be bit-identical: placements (density series), QoS accounting,
scheduler decision counters, scaling transitions.  This is the gate
that lets ``Platform.build`` default telemetry on whenever observers
are attached."""
import json
import math

import pytest

from repro.core.events import Observer, JsonlObserver
from repro.platform import Platform

MANIFEST = {
    "scenario": {"kind": "burst-storm", "n_functions": 6,
                 "duration_s": 40, "target_nodes": 12, "seed": 3},
    "prediction": {"n_train": 400, "n_trees": 8},
}


class CountingObserver(Observer):
    def __init__(self):
        self.ticks = 0
        self.schedules = 0
        self.scales = 0
        self.spans = 0

    def on_tick(self, now, sim):
        self.ticks += 1

    def on_schedule(self, now, fn, placements, trace=None):
        self.schedules += 1

    def on_scale(self, now, fn, event, count):
        self.scales += 1

    def on_span(self, span):
        self.spans += 1


def _fingerprint(res):
    """Everything the arms must agree on, bit for bit.  Wall-clock
    latency metrics are deliberately excluded (instrumented runs spend
    different real time); counters and simulated state are not."""
    s, a = res.sched, res.scaling
    return {
        "density": res.density,
        "density_series": list(res.density_series),
        "qos": res.qos_violation_rate,
        "requests": res.requests,
        "violated": res.violated_requests,
        "nodes_peak": res.nodes_peak,
        "node_seconds": res.node_seconds,
        "instance_seconds": res.instance_seconds,
        "decisions": s.decisions,
        "instances_placed": s.instances_placed,
        "fast": s.fast, "slow": s.slow, "failed": s.failed,
        "critical_rows": s.critical_inference_rows,
        "real_cold_starts": a.real_cold_starts,
        "logical_cold_starts": a.logical_cold_starts,
        "releases": a.releases,
        "evictions": a.evictions,
        "migrations": a.migrations,
    }


def _run(observers=()):
    plat = Platform.build(config=MANIFEST, observers=list(observers))
    return plat, _fingerprint(plat.run())


def test_bare_hub_and_jsonl_runs_are_bit_identical(tmp_path):
    bare_plat, bare = _run()
    assert bare_plat.telemetry is None           # nothing attached

    counters = [CountingObserver(), CountingObserver()]
    hub_plat, hub = _run(counters)
    assert hub_plat.telemetry is not None        # auto-on with observers

    jsonl = JsonlObserver(str(tmp_path / "events.jsonl"),
                          meta={"manifest": MANIFEST})
    with jsonl:
        _, persisted = _run([jsonl])

    assert bare == hub == persisted
    assert all(math.isfinite(v) for v in bare["density_series"])

    # the observers actually saw the run (this wasn't a no-op parity)
    for c in counters:
        assert c.ticks == MANIFEST["scenario"]["duration_s"]
        assert c.schedules > 0 and c.scales > 0 and c.spans > 0
    events = [json.loads(l)
              for l in (tmp_path / "events.jsonl").read_text().splitlines()]
    kinds = {e["event"] for e in events}
    assert {"meta", "tick", "schedule", "scale", "span"} <= kinds


def test_telemetry_registry_agrees_with_sim_counters():
    plat = Platform.build(
        config={**MANIFEST, "telemetry": {"metrics": True,
                                          "spans": True}})
    res = plat.run()
    snap = plat.metrics_snapshot()
    assert snap["sim.ticks"]["value"] == res.ticks
    assert snap["schedule.decisions"]["value"] == res.sched.decisions
    assert snap["schedule.instances_placed"]["value"] == \
        res.sched.instances_placed
    scale_total = sum(m["value"] for name, m in snap.items()
                     if name.startswith("scale."))
    a = res.scaling
    # one scale event per transition kind fired with its count
    assert scale_total == a.real_cold_starts + a.logical_cold_starts \
        + a.releases + a.evictions + a.migrations
    assert snap["run.density"]["value"] == pytest.approx(res.density)


def test_explicit_telemetry_does_not_change_results():
    _, bare = _run()
    plat = Platform.build(
        config={**MANIFEST, "telemetry": {"metrics": True,
                                          "spans": True}})
    instrumented = _fingerprint(plat.run())
    assert bare == instrumented
