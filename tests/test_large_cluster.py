"""Scenario -> Simulation at cluster scale.

A fast smoke (tier-1) checks the subsystem end to end on a small
heterogeneous cluster; the 64-node study is marked ``slow`` (run with
RUN_SLOW=1, e.g. ``scripts/verify.sh --full``) and asserts the headline
property — Jiagu density above the K8s requested-resource baseline at
large-cluster scale with NaN-free accounting."""
import numpy as np
import pytest

from repro.core import (LARGE_NODE, SCENARIO_KINDS, STANDARD_NODE,
                        make_scenario, scenario_simulation, scenario_world)


def _nan_free(res) -> bool:
    series = np.asarray(res.density_series, dtype=np.float64)
    scalars = np.asarray([res.density, res.qos_violation_rate,
                          res.requests, res.instance_seconds,
                          res.node_seconds], dtype=np.float64)
    return bool(np.isfinite(series).all() and np.isfinite(scalars).all())


def test_scenario_smoke_heterogeneous_small():
    """Tier-1: a tiny burst-storm scenario runs end to end on a mixed
    std/large fleet with sane, NaN-free accounting."""
    scenario = make_scenario("burst-storm", n_functions=5, duration_s=70,
                             target_nodes=10, seed=2)
    assert [c.name for c in scenario.node_classes] == ["std", "large"]
    world = scenario_world(scenario, n_train=500, n_trees=8)
    sim = scenario_simulation(scenario, "jiagu", world=world)
    res = sim.run()
    assert res.ticks == 70
    assert res.requests > 0
    assert _nan_free(res)
    # the deterministic node-shape cycle really mixes both classes: the
    # first full pool cycle of additions must produce both shapes
    pool_cycle = scenario.build_cluster()
    cycle_shapes = {pool_cycle.add_node().res.cpu_mcores
                    for _ in range(len(pool_cycle.res_pool))}
    assert cycle_shapes == {STANDARD_NODE.res.cpu_mcores,
                            LARGE_NODE.res.cpu_mcores}
    # ... and the sim's fleet grew far enough to include large nodes
    # (weights std:3 large:1 -> every 4th server is large)
    assert sim.cluster.nodes_added >= 4
    shapes = {n.res.cpu_mcores for n in sim.cluster.nodes.values()}
    assert shapes <= cycle_shapes


def test_all_scenario_kinds_build():
    for kind in SCENARIO_KINDS:
        scenario = make_scenario(kind, n_functions=4, duration_s=40,
                                 target_nodes=6, seed=1)
        assert scenario.kind == kind
        assert scenario.trace.duration_s == 40
        assert set(scenario.trace.rps) == set(scenario.specs)
    with pytest.raises(ValueError):
        make_scenario("no-such-kind", n_functions=2)


@pytest.mark.slow
def test_large_cluster_64_density_beats_baseline():
    """64-node study: overcommitment must beat requested-resource packing
    while QoS holds the paper's bar, with NaN-free series."""
    scenario = make_scenario("burst-storm", n_functions=24, duration_s=180,
                             target_nodes=64, seed=0)
    world = scenario_world(scenario, n_train=2000, n_trees=20)
    r_j = scenario_simulation(scenario, "jiagu", world=world).run()
    r_k = scenario_simulation(scenario, "k8s", world=world).run()
    assert _nan_free(r_j) and _nan_free(r_k)
    assert r_j.density > r_k.density          # density above baseline
    assert r_j.qos_violation_rate < 0.10      # paper's acceptance bar
    assert r_k.qos_violation_rate < 0.10
    assert r_j.nodes_peak >= 48               # actually ran at scale
