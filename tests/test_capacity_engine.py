"""CapacityEngine: batched/cached/vectorized solving must be an exact
drop-in for the legacy per-node path — identical capacities, identical
feature rows (bitwise), matching inference-row accounting — plus cache
semantics (hits, signature invalidation, retrain epoch)."""
import numpy as np
import pytest

from repro.core import (CapacityEngine, Cluster, EngineConfig, GroundTruth,
                        JiaguScheduler, NodeResources, PerfPredictor,
                        ProfileStore, QoSStore, capacity_of,
                        coloc_signature, generate_dataset,
                        synthetic_functions, update_capacity_table)
from repro.core.capacity import _neighbor_feats
from repro.core.capacity_engine import _Template
from repro.core.cluster import Node
from repro.core.predictor import build_features
from repro.engine import CapacityEngine as EngineViaSurface


@pytest.fixture(scope="module")
def world():
    specs = synthetic_functions(5, seed=2)
    gt = GroundTruth(seed=0)
    store = ProfileStore(seed=0)
    qos = QoSStore(store, gt)
    pred = PerfPredictor(n_trees=12, max_depth=7, seed=0)
    X, y = generate_dataset(specs, gt, store, qos, 700, seed=1)
    pred.add_dataset(X, y)
    return specs, gt, store, qos, pred


def _engine(world, **kw):
    specs, gt, store, qos, pred = world
    return CapacityEngine(pred, store, qos, specs,
                          EngineConfig(**kw) if kw else None)


def _random_nodes(specs, rng, n_nodes, n_patterns=4):
    """Nodes drawn from a small pool of load patterns (as large clusters
    are in practice), so signature sharing actually occurs."""
    names = sorted(specs)
    patterns = []
    for _ in range(n_patterns):
        k = int(rng.integers(1, 4))
        pat = {}
        for g in rng.choice(names, size=k, replace=False):
            pat[g] = (int(rng.integers(1, 5)), int(rng.integers(0, 3)))
        patterns.append(pat)
    nodes = []
    for _ in range(n_nodes):
        node = Node(NodeResources())
        for g, (ns, nc) in patterns[rng.integers(n_patterns)].items():
            node.state(g).n_sat = ns
            node.state(g).n_cached = nc
        nodes.append(node)
    return nodes


# ---------------------------------------------------------------------------
# Exactness vs the legacy reference
# ---------------------------------------------------------------------------


def test_feature_rows_bit_identical_to_build_features(world):
    """The vectorized assembly replicates build_features bit-for-bit —
    the property that makes every other equivalence in this file hold."""
    specs, gt, store, qos, pred = world
    names = sorted(specs)
    fn = names[0]
    coloc = {names[1]: (3.0, 1.0), names[2]: (2.0, 0.0),
             names[3]: (1.0, 2.0)}
    m_max = 9
    # legacy rows, exactly as capacity_of builds them
    spec = specs[fn]
    others = dict(coloc)
    legacy = []
    for m in range(1, m_max + 1):
        neigh = _neighbor_feats(store, specs, others, exclude=fn)
        legacy.append(build_features(qos.solo(spec), store.profile(spec),
                                     m, 0.0, neigh))
        for g, (ns, nc) in others.items():
            gspec = specs[g]
            neigh_g = _neighbor_feats(store, specs, {**others, fn: (m, 0.0)},
                                      exclude=g)
            legacy.append(build_features(qos.solo(gspec),
                                         store.profile(gspec), ns, nc,
                                         neigh_g))
    legacy = np.stack(legacy)
    tmpl = _Template(store, qos, specs, coloc, fn)
    batched, _bounds = tmpl.build(np.arange(1, m_max + 1))
    assert batched.dtype == legacy.dtype == np.float32
    assert np.array_equal(batched, legacy)  # bitwise


def test_single_solve_matches_capacity_of_randomized(world):
    specs, gt, store, qos, pred = world
    eng = _engine(world, m_max=16, cache=False)
    names = sorted(specs)
    rng = np.random.default_rng(7)
    for _ in range(40):
        coloc = {}
        for g in rng.choice(names, size=rng.integers(0, 4), replace=False):
            coloc[g] = (float(rng.integers(0, 5)), float(rng.integers(0, 3)))
        fn = names[rng.integers(len(names))]
        m_max = int(rng.integers(1, 17))
        cap_ref, _ = capacity_of(pred, store, qos, specs, dict(coloc), fn,
                                 m_max)
        cap_eng, _ = eng.capacity(dict(coloc), fn, m_max)
        assert cap_eng == cap_ref


def test_batched_node_update_matches_legacy_tables(world):
    specs, gt, store, qos, pred = world
    rng = np.random.default_rng(3)
    nodes = _random_nodes(specs, rng, n_nodes=12)
    ref_tables = []
    for node in nodes:
        update_capacity_table(pred, store, qos, specs, node, m_max=10)
        ref_tables.append({fn: e.capacity for fn, e in node.table.items()})
        node.table.clear()
    eng = _engine(world, m_max=10)
    eng.update_nodes(nodes, m_max=10)
    for node, ref in zip(nodes, ref_tables):
        got = {fn: e.capacity for fn, e in node.table.items()}
        assert got == ref
        assert all(e.fresh for e in node.table.values())


def test_row_accounting_matches_legacy_path(world):
    """With caching and early-exit disabled the engine builds exactly the
    rows the legacy sweep would (m_max * rows_per_m per scenario)."""
    specs, gt, store, qos, pred = world
    rng = np.random.default_rng(5)
    node_a, node_b = _random_nodes(specs, rng, n_nodes=2, n_patterns=2)
    rows_ref = update_capacity_table(pred, store, qos, specs, node_a,
                                     m_max=8)
    # same colocation pattern solved through the engine in parity mode
    eng = _engine(world, m_max=8, cache=False, early_exit=False)
    rows_eng = eng.update_node(node_a, m_max=8)
    assert rows_eng == rows_ref
    # and the delegation hook on update_capacity_table routes to it
    rows_hook = update_capacity_table(pred, store, qos, specs, node_a,
                                      m_max=8, engine=eng)
    assert rows_hook == rows_ref


# ---------------------------------------------------------------------------
# Cache semantics
# ---------------------------------------------------------------------------


def test_cache_hit_returns_same_table_and_bills_zero_rows(world):
    specs, gt, store, qos, pred = world
    eng = _engine(world, m_max=12)
    names = sorted(specs)
    coloc = {names[1]: (2.0, 1.0)}
    cap1, rows1 = eng.capacity(dict(coloc), names[0])
    assert rows1 > 0
    hits_before = eng.stats.cache_hits
    cap2, rows2 = eng.capacity(dict(coloc), names[0])
    assert cap2 == cap1
    assert rows2 == 0
    assert eng.stats.cache_hits == hits_before + 1
    # same multiset in a different insertion order is the same signature
    coloc2 = {names[1]: (2.0, 1.0)}
    assert eng.signature(coloc2, names[0]) == eng.signature(coloc, names[0])


def test_coalesced_duplicates_solved_once(world):
    """Identically-loaded nodes inside ONE drain share a single solve."""
    specs, gt, store, qos, pred = world
    eng = _engine(world, m_max=10)
    rng = np.random.default_rng(11)
    nodes = _random_nodes(specs, rng, n_nodes=10, n_patterns=2)
    eng.update_nodes(nodes, m_max=10)
    assert eng.stats.unique_solves + eng.stats.cache_hits \
        + eng.stats.coalesced_dupes == eng.stats.solves
    assert eng.stats.unique_solves < eng.stats.solves  # sharing happened


def test_invalidation_on_placement_change(world):
    """A deploy changes the colocation signature, so the cached table for
    the OLD placement is never served for the new one."""
    specs, gt, store, qos, pred = world
    eng = _engine(world, m_max=10)
    names = sorted(specs)
    node = Node(NodeResources())
    node.state(names[1]).n_sat = 2
    coloc_before = eng.node_coloc(node)
    sig_before = eng.signature(coloc_before, names[1])
    eng.update_node(node, m_max=10)
    cap_before = node.table[names[1]].capacity
    # placement change: a new function lands on the node
    node.deploy(names[2], 3)
    coloc_after = eng.node_coloc(node)
    assert eng.signature(coloc_after, names[1]) != sig_before
    assert eng.capacity_hint(coloc_after, names[1]) is None  # no stale hit
    eng.update_node(node, m_max=10)
    cap_ref, _ = capacity_of(pred, store, qos, specs, coloc_after,
                             names[1], 10)
    assert node.table[names[1]].capacity == cap_ref
    # the old signature's entry is still valid for nodes that DO look
    # like the old placement
    assert eng.capacity_hint(coloc_before, names[1]) == cap_before


def test_retrain_bumps_epoch_and_clears_cache(world):
    specs, gt, store, qos, pred = world
    # isolated predictor so we can retrain without disturbing `world`
    p2 = PerfPredictor(n_trees=6, max_depth=6, seed=3)
    X, y = generate_dataset(specs, gt, store, qos, 300, seed=9)
    p2.add_dataset(X, y)
    eng = CapacityEngine(p2, store, qos, specs, EngineConfig(m_max=8))
    names = sorted(specs)
    coloc = {names[1]: (2.0, 0.0)}
    eng.capacity(dict(coloc), names[0])
    assert eng.capacity_hint(dict(coloc), names[0]) is not None
    p2.add_sample(X[0], float(y[0]), retrain=False)
    p2.retrain()                                     # epoch bump
    assert eng.capacity_hint(dict(coloc), names[0]) is None


@pytest.mark.parametrize("bad", [dict(chunk_init=0), dict(chunk_init=-2),
                                 dict(chunk_growth=0),
                                 dict(max_cache_entries=0),
                                 dict(drain="gpu")])
def test_engine_config_rejects_nonterminating_sweeps(bad):
    """chunk_init < 1 or chunk_growth < 1 used to hang solve_many: the
    m-sweep chunks decay to empty and the drain loop never advances.
    Now rejected at construction."""
    with pytest.raises(ValueError):
        EngineConfig(**bad)
    EngineConfig(chunk_init=1, chunk_growth=1)  # degenerate-but-finite: ok


def test_cache_eviction_is_oldest_first_not_wholesale(world):
    """Hitting max_cache_entries used to clear() the whole cache — every
    warm entry lost at once, hit rate collapsing to zero right at the
    boundary.  Now the oldest entry alone is evicted."""
    specs, gt, store, qos, pred = world
    eng = _engine(world, m_max=6, max_cache_entries=4)
    names = sorted(specs)
    colocs = [{names[j]: (float(i + 1), 0.0)}
              for i in range(2) for j in range(1, 4)]
    caps = [eng.capacity(dict(c), names[0], 6)[0] for c in colocs]
    assert len(eng._cache) == 4
    # the 4 newest survive the boundary crossing (c2..c5); wholesale
    # clearing would have left only the entries inserted after the wipe
    hits_before = eng.stats.cache_hits
    for i in (2, 3, 4, 5):
        cap, rows = eng.capacity(dict(colocs[i]), names[0], 6)
        assert cap == caps[i] and rows == 0
    assert eng.stats.cache_hits == hits_before + 4
    # the evicted oldest miss and re-solve to the same value
    for i in (0, 1):
        assert eng.capacity_hint(dict(colocs[i]), names[0], 6) is None
        cap, rows = eng.capacity(dict(colocs[i]), names[0], 6)
        assert cap == caps[i] and rows > 0


def test_cache_eviction_keeps_one_in_one_out(world):
    """Past the bound, each cold insert evicts exactly one entry — the
    cache holds its size instead of collapsing."""
    specs, gt, store, qos, pred = world
    eng = _engine(world, m_max=6, max_cache_entries=4)
    names = sorted(specs)
    for step in range(10):
        eng.capacity({names[1]: (1.0, float(step))}, names[0], 6)
        assert len(eng._cache) == min(step + 1, 4)


# ---------------------------------------------------------------------------
# Scheduler / export-surface integration
# ---------------------------------------------------------------------------


def test_scheduler_with_engine_places_like_legacy(world):
    specs, gt, store, qos, pred = world
    fns = sorted(specs)
    seqs = {}
    for use_engine in (False, True):
        cluster = Cluster(specs)
        engine = _engine(world, m_max=12) if use_engine else None
        sched = JiaguScheduler(cluster, store, qos, pred, m_max=12,
                               engine=engine)
        seq = []
        for i in range(30):
            placements = sched.schedule(fns[i % len(fns)], 1 + i % 3,
                                        float(i))
            seq.append(tuple(p.count for p in placements))
            sched.on_tick(float(i) + 0.9)
        tables = [sorted((fn, e.capacity) for fn, e in n.table.items())
                  for n in cluster.nodes.values()]
        seqs[use_engine] = (seq, tables)
    assert seqs[False][0] == seqs[True][0]   # identical placement counts
    assert seqs[False][1] == seqs[True][1]   # identical capacity tables


def test_engine_drain_is_coalesced_into_few_predict_calls(world):
    """The headline behavior: a drain over many due nodes costs a handful
    of batched predictor calls, not one per (node, function)."""
    specs, gt, store, qos, pred = world
    eng = _engine(world, m_max=12)
    rng = np.random.default_rng(13)
    nodes = _random_nodes(specs, rng, n_nodes=32, n_patterns=5)
    calls_before = pred.inference_calls
    eng.update_nodes(nodes, m_max=12)
    calls = pred.inference_calls - calls_before
    n_scenarios = sum(len(eng.node_coloc(n)) for n in nodes)
    assert n_scenarios > 30
    assert calls <= 8  # chunk rounds, not per-scenario calls


def test_export_surface_and_signature_quantization(world):
    assert EngineViaSurface is CapacityEngine
    sig_a = coloc_signature({"f": (2.001, 0.0)}, "g", 10, quant=4.0)
    sig_b = coloc_signature({"f": (2.0, 0.0)}, "g", 10, quant=4.0)
    assert sig_a == sig_b                      # sub-step jitter coalesces
    sig_c = coloc_signature({"f": (2.5, 0.0)}, "g", 10, quant=4.0)
    assert sig_c != sig_a                      # real differences kept
