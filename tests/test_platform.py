"""The repro.platform control-plane API: config-tree round trip,
build-time validation, name-based registries, pluggable Router /
picker capabilities, and the observer hooks."""
import json
import os

import pytest

from repro.core import (Autoscaler, Cluster, GroundTruth, K8sScheduler,
                        ProfileStore, QoSStore, ScalingConfig,
                        make_scenario, scenario_world,
                        synthetic_functions)
from repro.platform import (CapacityProvider, EqualSplitRouter,
                            LogicalStartPicker, Observer, Platform,
                            PlatformConfig, PlatformConfigError,
                            ReleasePicker, Router, get_router,
                            get_scenario_builder, get_trace,
                            register_router, register_scheduler,
                            registered_routers, registered_scenarios,
                            registered_schedulers, registered_traces,
                            scheduler_entry)

SAMPLE_CSV = os.path.join(os.path.dirname(__file__), "data",
                          "sample_trace.csv")

SMALL = {
    "scenario": {"kind": "burst-storm", "n_functions": 3,
                 "duration_s": 40, "target_nodes": 6, "seed": 0},
    "prediction": {"n_train": 250, "n_trees": 6},
}


@pytest.fixture(scope="module")
def small_world():
    """One trained world shared by the behavioural tests (the scenario
    only varies in scheduler/router/observer wiring)."""
    cfg = PlatformConfig.from_dict(SMALL)
    from repro.platform import scenario_from_config
    scenario = scenario_from_config(cfg)
    world = scenario_world(scenario, n_train=250, n_trees=6)
    return cfg, scenario, world


# ---------------------------------------------------------------------------
# Config tree
# ---------------------------------------------------------------------------


def test_config_roundtrip_defaults():
    cfg = PlatformConfig()
    d = cfg.to_dict()
    json.dumps(d)                      # manifest must be JSON-able
    assert PlatformConfig.from_dict(d) == cfg


def test_config_roundtrip_custom():
    cfg = PlatformConfig.from_dict({
        "cluster": {"node_classes": [
            {"name": "std", "weight": 2},
            {"name": "huge", "cpu_mcores": 96_000.0,
             "mem_mb": 262_144.0, "weight": 1}],
            "max_nodes": 128},
        "scenario": {"kind": "diurnal-shift", "n_functions": 5,
                     "duration_s": 90, "target_nodes": 12, "seed": 3,
                     "spec_seed": 8, "trace_kw": {"n_regions": 2}},
        "scheduler": {"name": "gsight", "max_candidates": 3},
        "scaling": {"dual_staged": True, "release_s": 20.0},
        "prediction": {"schema_version": 2, "n_train": 100},
        "simulation": {"collect_samples": True, "seed": 4},
    })
    d = cfg.to_dict()
    json.dumps(d)
    back = PlatformConfig.from_dict(d)
    assert back == cfg
    assert back.cluster.node_classes[1].cpu_mcores == 96_000.0
    assert back.scenario.trace_kw == {"n_regions": 2}
    # node-class manifests materialize into real NodeClass topology
    classes = back.cluster.to_node_classes()
    assert [c.name for c in classes] == ["std", "huge"]
    assert classes[1].res.mem_mb == 262_144.0


def test_from_dict_rejects_unknown_sections_and_keys():
    with pytest.raises(PlatformConfigError, match="unknown sections"):
        PlatformConfig.from_dict({"schedulerz": {}})
    with pytest.raises(PlatformConfigError, match="unknown keys"):
        PlatformConfig.from_dict({"scheduler": {"nam": "jiagu"}})
    with pytest.raises(PlatformConfigError, match="expected a dict"):
        PlatformConfig.from_dict({"scaling": 7})


# ---------------------------------------------------------------------------
# Build-time validation
# ---------------------------------------------------------------------------


def test_validate_schema_v2_needs_engine_path():
    cfg = PlatformConfig.from_dict({
        "prediction": {"schema_version": 2},
        "simulation": {"use_capacity_engine": False}})
    with pytest.raises(PlatformConfigError, match="v1 feature layout"):
        cfg.validate()


def test_validate_online_retrain_needs_engine_and_samples():
    with pytest.raises(PlatformConfigError, match="on_samples"):
        PlatformConfig.from_dict({
            "prediction": {"online_retrain": True},
            "simulation": {"use_capacity_engine": False,
                           "collect_samples": True}}).validate()
    with pytest.raises(PlatformConfigError, match="collect_samples"):
        PlatformConfig.from_dict({
            "prediction": {"online_retrain": True}}).validate()


def test_validate_predictorless_scheduler_limits():
    with pytest.raises(PlatformConfigError, match="without a predictor"):
        PlatformConfig.from_dict({
            "scheduler": {"name": "k8s"},
            "prediction": {"schema_version": 2}}).validate()


def test_validate_unknown_inference_engine():
    with pytest.raises(PlatformConfigError, match="engine"):
        PlatformConfig.from_dict(
            {"prediction": {"engine": "cuda"}}).validate()


def test_build_mismatched_world_schema(small_world):
    _cfg, scenario, world = small_world   # world speaks schema v1
    cfg = PlatformConfig.from_dict({
        **SMALL, "prediction": {**SMALL["prediction"],
                                "schema_version": 2}})
    with pytest.raises(PlatformConfigError, match="mismatched .*schema"):
        Platform.build(scenario=scenario, config=cfg, world=world)


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------


def test_registry_unknown_names_raise():
    with pytest.raises(ValueError, match="unknown scheduler"):
        scheduler_entry("no-such-scheduler")
    with pytest.raises(ValueError, match="unknown scenario kind"):
        get_scenario_builder("no-such-kind")
    with pytest.raises(ValueError, match="unknown trace"):
        get_trace("no-such-trace")
    with pytest.raises(ValueError, match="unknown router"):
        get_router("no-such-router")
    with pytest.raises(ValueError, match="unknown scheduler"):
        PlatformConfig.from_dict(
            {"scheduler": {"name": "no-such-scheduler"}}).validate()


def test_registry_contents_and_duplicate_rejection():
    assert {"jiagu", "gsight", "k8s", "owl"} <= set(
        registered_schedulers())
    assert "replay" in registered_scenarios()
    assert {"timer", "flip", "replay"} <= set(registered_traces())
    assert "equal-split" in registered_routers()
    with pytest.raises(ValueError, match="already registered"):
        register_router("equal-split", EqualSplitRouter)
    assert scheduler_entry("jiagu").dual_staged_default
    assert not scheduler_entry("k8s").dual_staged_default


def test_register_custom_scheduler_and_build_from_manifest(small_world):
    _cfg, scenario, world = small_world
    name = "test-binpack"
    if name not in registered_schedulers():
        register_scheduler(
            name,
            lambda ctx: K8sScheduler(ctx.cluster, ctx.store, ctx.qos))
    plat = Platform.build(scenario=scenario,
                          config={**SMALL, "scheduler": {"name": name}},
                          world=world)
    res = plat.run()
    assert res.ticks == 40
    assert res.requests > 0


# ---------------------------------------------------------------------------
# Capability protocols
# ---------------------------------------------------------------------------


def test_schedulers_satisfy_picker_protocols():
    specs = synthetic_functions(2, seed=0)
    cluster = Cluster(specs)
    store = ProfileStore(seed=0)
    gt = GroundTruth(seed=0)
    qos = QoSStore(store, gt)
    k8s = K8sScheduler(cluster, store, qos)
    assert isinstance(k8s, ReleasePicker)
    assert isinstance(k8s, LogicalStartPicker)
    assert isinstance(EqualSplitRouter(), Router)
    aut = Autoscaler(cluster, k8s, ScalingConfig())
    assert isinstance(aut.capacity, CapacityProvider)


def test_dual_staged_meaningful_for_non_jiagu():
    """The satellite fix: a baseline scheduler that opts into
    dual_staged=True gets release -> logical-cold-start behaviour from
    the greedy default pickers (previously picks were silently [] and
    every rise paid a real cold start)."""
    specs = synthetic_functions(2, seed=5)
    fn = sorted(specs)[0]
    sat = specs[fn].saturated_rps * 0.99
    cluster = Cluster(specs)
    store = ProfileStore(seed=0)
    gt = GroundTruth(seed=0)
    qos = QoSStore(store, gt)
    sched = K8sScheduler(cluster, store, qos)
    aut = Autoscaler(cluster, sched, ScalingConfig(
        release_s=5, keepalive_s=60, dual_staged=True, migrate=False))
    for t in range(3):
        aut.tick(float(t), {fn: sat * 4})
    assert cluster.sat_count(fn) == 4
    for i in range(8):
        aut.tick(3.0 + i, {fn: sat * 2})
    assert cluster.cached_count(fn) == 2      # released, not evicted
    cold_before = aut.metrics.real_cold_starts
    aut.tick(12.0, {fn: sat * 4})
    assert aut.metrics.logical_cold_starts == 2
    assert aut.metrics.real_cold_starts == cold_before
    assert cluster.sat_count(fn) == 4


class _CountingRouter:
    """Delegates to the default equal split; a pluggable policy that
    must observe the exact same requests/violations."""

    name = "counting"

    def __init__(self):
        self.inner = EqualSplitRouter()
        self.calls = 0

    def route(self, spec, fn_rps, node, n_sat, total_sat):
        self.calls += 1
        return self.inner.route(spec, fn_rps, node, n_sat, total_sat)


def _fresh_world(scenario):
    """Per-run world rebuild: ``GroundTruth.measure`` draws measurement
    noise from a stateful RNG, so run-to-run parity needs both arms to
    start from identical world state (same discipline as the
    benchmark's ``ab_parity``)."""
    return scenario_world(scenario, n_train=250, n_trees=6)


def test_custom_router_observes_same_world(small_world):
    _cfg, scenario, _world = small_world
    base = Platform.build(scenario=scenario, config=SMALL,
                          world=_fresh_world(scenario)).run()
    router = _CountingRouter()
    alt = Platform.build(scenario=scenario, config=SMALL,
                         world=_fresh_world(scenario),
                         router=router).run()
    assert router.calls > 0
    assert alt.requests == base.requests
    assert alt.violated_requests == base.violated_requests
    assert alt.density == base.density
    assert alt.per_fn_violations == base.per_fn_violations


# ---------------------------------------------------------------------------
# Observer hooks
# ---------------------------------------------------------------------------


class _Counting(Observer):
    def __init__(self):
        self.ticks = 0
        self.schedules = 0
        self.placed = 0
        self.scales = {}
        self.retrains = 0

    def on_tick(self, now, sim):
        self.ticks += 1

    def on_schedule(self, now, fn, placements, trace=None):
        self.schedules += 1
        self.placed += sum(p.count for p in placements)

    def on_scale(self, now, fn, event, count):
        self.scales[event] = self.scales.get(event, 0) + count

    def on_retrain(self, service):
        self.retrains += 1


def test_observer_hooks_fire(small_world):
    _cfg, scenario, world = small_world
    obs = _Counting()
    plat = Platform.build(scenario=scenario, config=SMALL, world=world,
                          observers=[obs])
    res = plat.run()
    assert obs.ticks == res.ticks == 40
    assert obs.schedules > 0
    assert obs.placed == res.sched.instances_placed
    assert obs.scales.get("real_cold_start", 0) == \
        res.scaling.real_cold_starts
    released = res.scaling.releases
    assert obs.scales.get("release", 0) == released


def test_on_retrain_hook_fires(small_world):
    _cfg, scenario, world = small_world
    obs = _Counting()
    manifest = {
        **SMALL,
        "prediction": {**SMALL["prediction"], "online_retrain": True,
                       "retrain_every": 4},
        "simulation": {"collect_samples": True, "sample_every_s": 2},
    }
    plat = Platform.build(scenario=scenario, config=manifest,
                          world=world, observers=[obs])
    res = plat.run()
    assert res.retrains >= 1
    assert obs.retrains == res.retrains


# ---------------------------------------------------------------------------
# Replay scenario kind (real traces through the scenario suite)
# ---------------------------------------------------------------------------


def test_replay_scenario_runs_in_suite():
    scenario = make_scenario("replay", n_functions=3, duration_s=30,
                             target_nodes=4, seed=0, path=SAMPLE_CSV)
    assert scenario.kind == "replay"
    assert scenario.trace.duration_s == 30
    assert all(len(s) == 30 for s in scenario.trace.rps.values())
    plat = Platform.build(
        scenario=scenario,
        config={"scenario": {"kind": "replay", "n_functions": 3,
                             "duration_s": 30, "target_nodes": 4,
                             "trace_kw": {"path": SAMPLE_CSV}},
                "prediction": {"n_train": 200, "n_trees": 6}})
    res = plat.run()
    assert res.ticks == 30
    assert res.requests > 0


def test_replay_scenario_requires_path():
    with pytest.raises(ValueError, match="path"):
        make_scenario("replay", n_functions=2, duration_s=10,
                      target_nodes=2)


def test_replay_builder_from_config_alone():
    """Pure-manifest path: the replay kind resolves through the
    registry without prebuilding a Scenario."""
    plat = Platform.build(config={
        "scenario": {"kind": "replay", "n_functions": 2,
                     "duration_s": 20, "target_nodes": 3,
                     "trace_kw": {"path": SAMPLE_CSV}},
        "prediction": {"n_train": 200, "n_trees": 6}})
    assert plat.scenario.kind == "replay"
    assert plat.run().ticks == 20


# ---------------------------------------------------------------------------
# Shims stay consistent with the facade
# ---------------------------------------------------------------------------


def test_platform_matches_scenario_simulation_shim(small_world):
    """The facade and the legacy shim assemble the same world -> same
    results (identical seeds)."""
    from repro.core import scenario_simulation
    _cfg, scenario, _world = small_world
    res_shim = scenario_simulation(
        scenario, "jiagu", world=_fresh_world(scenario)).run()
    res_plat = Platform.build(scenario=scenario, config=SMALL,
                              world=_fresh_world(scenario)).run()
    assert res_plat.requests == res_shim.requests
    assert res_plat.density == res_shim.density
    assert res_plat.sched.decisions == res_shim.sched.decisions
    assert res_plat.scaling.real_cold_starts == \
        res_shim.scaling.real_cold_starts
