"""Loss/step functions and the from-scratch AdamW."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import InputShape, get_smoke_config
from repro.models import model as model_lib
from repro.models import steps as steps_lib
from repro.optim import adamw


def test_chunked_xent_matches_naive():
    cfg = get_smoke_config("gemma-7b")
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    shape = InputShape("t", 48, 2, "train")
    batch = steps_lib.make_train_batch(cfg, shape)
    h, _ = model_lib.final_hidden(cfg, params, batch)
    loss, w = steps_lib.chunked_xent(cfg, params, h, batch["targets"],
                                     chunk=16)
    logits = model_lib.logits_from_hidden(cfg, params, h).astype(
        jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, batch["targets"][..., None],
                             axis=-1)[..., 0]
    naive = jnp.sum(lse - ll)
    np.testing.assert_allclose(float(loss), float(naive), rtol=1e-5)
    assert float(w) == 48 * 2


def test_chunked_xent_respects_mask():
    cfg = get_smoke_config("gemma-7b")
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    shape = InputShape("t", 32, 2, "train")
    batch = steps_lib.make_train_batch(cfg, shape)
    h, _ = model_lib.final_hidden(cfg, params, batch)
    mask = jnp.zeros((2, 32), jnp.float32).at[:, :10].set(1.0)
    loss, w = steps_lib.chunked_xent(cfg, params, h, batch["targets"], mask)
    assert float(w) == 20
    assert np.isfinite(float(loss))


def test_adamw_quadratic_convergence():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=200, clip_norm=0)
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = adamw.init(params, cfg)

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        return adamw.update(params, g, state, cfg)

    for _ in range(150):
        params, state, m = step(params, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adamw_matches_reference_implementation():
    """Two steps against a hand-rolled numpy Adam (no decay/clip)."""
    cfg = adamw.AdamWConfig(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8,
                            weight_decay=0.0, clip_norm=0.0,
                            warmup_steps=0, total_steps=10,
                            min_lr_frac=1.0)
    w0 = np.array([1.0, 2.0], np.float32)
    g1 = np.array([0.1, -0.2], np.float32)
    g2 = np.array([0.3, 0.1], np.float32)
    params = {"w": jnp.asarray(w0)}
    state = adamw.init(params, cfg)
    params, state, _ = adamw.update(params, {"w": jnp.asarray(g1)}, state,
                                    cfg)
    params, state, _ = adamw.update(params, {"w": jnp.asarray(g2)}, state,
                                    cfg)
    # reference
    m = v = np.zeros(2)
    w = w0.copy()
    for t, g in enumerate([g1, g2], start=1):
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9 ** t)
        vh = v / (1 - 0.999 ** t)
        w = w - 1e-2 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(params["w"]), w, rtol=1e-5)


def test_adamw_weight_decay_skips_norms():
    cfg = adamw.AdamWConfig(lr=1e-1, weight_decay=0.5, warmup_steps=0,
                            total_steps=10, clip_norm=0, min_lr_frac=1.0)
    params = {"w_gate": jnp.ones((2,)), "scale": jnp.ones((2,))}
    state = adamw.init(params, cfg)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    new_params, _, _ = adamw.update(params, zero_g, state, cfg)
    assert float(new_params["w_gate"][0]) < 1.0   # decayed
    assert float(new_params["scale"][0]) == 1.0   # not decayed


def test_adamw_clip_bounds_update():
    cfg = adamw.AdamWConfig(lr=1.0, weight_decay=0.0, clip_norm=1.0,
                            warmup_steps=0, total_steps=10,
                            min_lr_frac=1.0)
    params = {"w": jnp.zeros((4,))}
    state = adamw.init(params, cfg)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw.update(params, huge, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_schedule_warmup_and_cosine():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    s0 = float(adamw.schedule(cfg, jnp.asarray(0.0)))
    s10 = float(adamw.schedule(cfg, jnp.asarray(10.0)))
    s100 = float(adamw.schedule(cfg, jnp.asarray(100.0)))
    assert s0 < 0.05 and abs(s10 - 1.0) < 1e-5
    assert abs(s100 - 0.1) < 1e-3
