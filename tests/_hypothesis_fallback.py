"""Minimal deterministic stand-in for `hypothesis` so property tests can
still collect and run where the real package is unavailable.

Implements just the surface these tests use: ``given`` (keyword
strategies), ``settings`` (max_examples honored, everything else
ignored), and the ``strategies`` namespace with ``integers``, ``lists``,
``tuples`` and ``sampled_from``.  Examples are drawn from a fixed-seed
generator, so the degraded loop is deterministic across runs — weaker
than hypothesis (no shrinking, no coverage-guided search) but the same
assertions run on a few dozen sampled inputs.
"""
from __future__ import annotations

import inspect
import zlib
from typing import Any, Callable

import numpy as np

DEFAULT_MAX_EXAMPLES = 30


class _Strategy:
    def __init__(self, draw: Callable[[np.random.Generator], Any]):
        self._draw = draw

    def example(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)


class strategies:  # noqa: N801 - mimics the `hypothesis.strategies` module
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        items = list(seq)
        return _Strategy(lambda rng: items[rng.integers(len(items))])

    @staticmethod
    def tuples(*strats: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(s.example(rng) for s in strats))

    @staticmethod
    def lists(elem: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        return _Strategy(
            lambda rng: [elem.example(rng)
                         for _ in range(rng.integers(min_size,
                                                     max_size + 1))])


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**strats: _Strategy):
    def deco(fn):
        def wrapper(*args, **kwargs):
            # settings() may sit above (attribute on wrapper) or below
            # (copied from fn by functools.wraps) this decorator.
            n = getattr(wrapper, "_fallback_max_examples",
                        DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for _ in range(n):
                drawn = {k: s.example(rng) for k, s in strats.items()}
                fn(*args, **drawn, **kwargs)
        # look like the original test, minus the strategy-supplied params
        # (so pytest does not treat them as fixtures); deliberately no
        # __wrapped__, which would resurrect the full signature.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__dict__.update(fn.__dict__)
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        wrapper._fallback_given = True
        return wrapper
    return deco
