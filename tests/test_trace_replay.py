"""CSV trace replay: real Azure/Huawei-style ``fn,timestamp,rps`` dumps
behind the same ``Trace`` interface as the generated programs."""
import os

import numpy as np
import pytest

from repro.core import Trace, replay_trace

FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "sample_trace.csv")


def test_replay_fixture_parses_and_buckets():
    trace = replay_trace(FIXTURE)
    assert isinstance(trace, Trace)
    assert trace.name == "sample_trace"
    assert sorted(trace.rps) == ["alpha", "beta"]
    # timestamps normalize to the earliest entry (t=100.0 -> second 0)
    # and the trace spans floor(103.9 - 100) + 1 = 4 seconds
    assert trace.duration_s == 4
    # same-second entries accumulate: alpha has 102.2->7, 102.9->3
    assert np.allclose(trace.rps["alpha"], [5.0, 10.0, 10.0, 0.0])
    assert np.allclose(trace.rps["beta"], [2.0, 0.0, 1.5, 4.0])


def test_replay_trace_interface_matches_generated_traces():
    trace = replay_trace(FIXTURE)
    # Trace.at clamp semantics (same contract as generated traces)
    assert trace.at("alpha", 0) == 5.0
    assert trace.at("alpha", -5) == 5.0            # clamps to the start
    assert trace.at("alpha", 999) == trace.rps["alpha"][-1]
    with pytest.raises(KeyError, match="ghost"):
        trace.at("ghost", 0)


def test_replay_is_deterministic_and_extendable():
    a = replay_trace(FIXTURE)
    b = replay_trace(FIXTURE, name="renamed", duration_s=10)
    for fn in a.rps:
        assert np.array_equal(a.rps[fn], b.rps[fn][:a.duration_s])
        assert np.all(b.rps[fn][a.duration_s:] == 0.0)
    assert b.name == "renamed"
    assert b.duration_s == 10


def test_replay_rejects_garbage(tmp_path):
    empty = tmp_path / "empty.csv"
    empty.write_text("fn,timestamp,rps\n")
    with pytest.raises(ValueError, match="no trace entries"):
        replay_trace(str(empty))
    bad = tmp_path / "bad.csv"
    bad.write_text("alpha,0.0,5\nalpha,oops,3\n")
    with pytest.raises(ValueError, match="non-numeric"):
        replay_trace(str(bad))
    neg = tmp_path / "neg.csv"
    neg.write_text("alpha,0.0,-5\n")
    with pytest.raises(ValueError, match="negative"):
        replay_trace(str(neg))
    short = tmp_path / "short.csv"
    short.write_text("alpha,0.0\n")
    with pytest.raises(ValueError, match="expected"):
        replay_trace(str(short))
    nan = tmp_path / "nan.csv"
    nan.write_text("alpha,nan,5\n")
    with pytest.raises(ValueError, match="non-finite"):
        replay_trace(str(nan))
    nan.write_text("alpha,0.0,nan\n")
    with pytest.raises(ValueError, match="non-finite"):
        replay_trace(str(nan))
