"""repro.policy: trace dataset round-trip, training determinism, the
versioned PolicyStore, and the hot-swapped ``"learned"`` stack.

The fixture ``tests/data/policy_traces.jsonl`` is a checked-in
``JsonlObserver`` stream of a short feature-traced jiagu-pipeline run
(current schema: per-candidate feature rows + chosen node + feasibility
rejections on every schedule record, cumulative QoS counters on every
tick, a trailing run summary), with two hand-made versionless (v1)
schedule records spliced in — old artifacts must stay readable."""
import json
import os

import numpy as np
import pytest

from repro.core.pipeline import CANDIDATE_FEATURES, TRACE_SCHEMA_VERSION
from repro.core.platform import (Platform, PlatformConfig,
                                 PlatformConfigError)
from repro.policy import (LearnedScorer, PolicyStore, PolicyStoreError,
                          TrainConfig, load_traces, matrices, merge,
                          normalization, reward_weights, split,
                          top1_agreement, train_policy)

FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "policy_traces.jsonl")


@pytest.fixture(scope="module")
def ds():
    return load_traces(FIXTURE)


@pytest.fixture(scope="module")
def trained(ds):
    """One tiny deterministic fit shared by the training tests."""
    train_ds, hold_ds = split(ds)
    policy, metrics = train_policy(
        train_ds, hold_ds, TrainConfig(hidden=16, epochs=30, seed=0))
    return policy, metrics


# ---------------------------------------------------------------------------
# Dataset round-trip
# ---------------------------------------------------------------------------


def test_fixture_records_carry_schema_and_features():
    schedules, ticks, summaries = [], [], []
    with open(FIXTURE) as f:
        for line in f:
            rec = json.loads(line)
            if rec.get("event") == "schedule":
                schedules.append(rec["trace"])
            elif rec.get("event") == "tick":
                ticks.append(rec)
            elif rec.get("event") == "summary":
                summaries.append(rec)
    v2 = [t for t in schedules if "schema_version" in t]
    v1 = [t for t in schedules if "schema_version" not in t]
    assert len(v1) == 2 and len(v2) >= 10
    captured = [t for t in v2 if "candidates" in t]
    assert len(captured) >= 10
    for t in captured:
        assert t["schema_version"] == TRACE_SCHEMA_VERSION
        assert t["chosen_node"] >= 0
        assert "rejected" in t
        for nid, row in t["candidates"]:
            assert len(row) == len(CANDIDATE_FEATURES)
    # the binder's capacity solves reject top-ranked candidates — the
    # signal the dataset masks out of the label set
    assert any(t["rejected"] for t in captured)
    # tick records carry the cumulative QoS counters the horizon
    # labelling bisects over
    assert all("requests" in t and "violated" in t for t in ticks)
    # the trailing run summary closes the stream
    (summary,) = summaries
    assert summary["scheduler"] == "jiagu-pipeline"
    assert summary["ticks"] == len(ticks)
    assert 0.0 <= summary["qos_violation_rate"] <= 1.0
    assert summary["density"] > 0
    assert set(summary["per_fn_violation_rate"]) <= {
        t.get("fn") for t in schedules}


def test_load_traces_roundtrip(ds):
    assert len(ds) >= 10
    assert ds.skipped_versionless == 2
    assert ds.feature_names == CANDIDATE_FEATURES
    assert ds.summary is not None and ds.summary["event"] == "summary"
    for d in ds.decisions:
        assert d.features.shape == (len(d.node_ids), ds.n_features)
        assert d.features.dtype == np.float32
        assert 0 <= d.chosen < len(d.node_ids)
        assert d.requested >= 1


def test_split_and_matrices_deterministic(ds):
    a_train, a_hold = split(ds)
    b_train, b_hold = split(ds)
    assert [d.now for d in a_train.decisions] == \
        [d.now for d in b_train.decisions]
    assert len(a_train) + len(a_hold) == len(ds)
    X, mask, y = matrices(ds)
    assert X.shape == (len(ds), ds.max_candidates, ds.n_features)
    assert mask.shape == X.shape[:2] and y.shape == (len(ds),)
    for i, d in enumerate(ds.decisions):
        assert int(mask[i].sum()) == len(d.node_ids)
        assert mask[i, y[i]] == 1.0    # the label is a real candidate
    mu, sd = normalization(X, mask)
    assert mu.shape == (ds.n_features,) and np.all(sd > 0)


def test_merge_accumulates(ds):
    both = merge([ds, ds])
    assert len(both) == 2 * len(ds)
    assert both.skipped_versionless == 2 * ds.skipped_versionless
    assert both.summary == ds.summary


def test_reward_weights_penalize_bad_outcomes(ds):
    import dataclasses
    flipped = dataclasses.replace(ds, decisions=[
        dataclasses.replace(d, qos_breach=(i % 2 == 0),
                            cold_start=(i % 3 == 0))
        for i, d in enumerate(ds.decisions)])
    w = reward_weights(flipped, qos_penalty=3.0, cold_penalty=0.5)
    assert w.shape == (len(ds),)
    assert abs(float(w.mean()) - 1.0) < 1e-6
    clean = [w[i] for i, d in enumerate(flipped.decisions)
             if not d.qos_breach and not d.cold_start]
    breached = [w[i] for i, d in enumerate(flipped.decisions)
                if d.qos_breach]
    assert breached and clean and max(breached) < min(clean)


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------


def test_train_is_deterministic(ds, trained):
    policy_a, metrics_a = trained
    train_ds, hold_ds = split(ds)
    policy_b, metrics_b = train_policy(
        train_ds, hold_ds, TrainConfig(hidden=16, epochs=30, seed=0))
    for k in policy_a:
        assert np.array_equal(policy_a[k], policy_b[k]), k
    assert metrics_a == metrics_b
    assert 0.0 <= metrics_a["holdout_agreement"] <= 1.0
    # the fit must at least beat uniform-random candidate picking
    X, mask, y = matrices(train_ds)
    chance = float(np.mean(1.0 / mask.sum(axis=1)))
    assert metrics_a["train_agreement"] > chance


def test_offline_rl_mode_reweights(ds):
    train_ds, _ = split(ds)
    _, metrics = train_policy(train_ds, None, TrainConfig(
        hidden=8, epochs=2, mode="offline-rl", qos_penalty=8.0))
    assert metrics["mode_weight_mean"] == pytest.approx(1.0, abs=1e-5)


# ---------------------------------------------------------------------------
# PolicyStore
# ---------------------------------------------------------------------------


def test_store_roundtrip_and_epochs(tmp_path, ds, trained):
    policy, metrics = trained
    store = PolicyStore(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        store.load()
    store.save(policy, epoch=0, mode="imitation",
               feature_names=ds.feature_names, metrics=metrics)
    store.save(policy, epoch=3, mode="offline-rl")
    assert store.epochs() == [0, 3] and store.latest_epoch() == 3
    loaded, meta = store.load()                    # latest wins
    assert meta["epoch"] == 3 and meta["mode"] == "offline-rl"
    pinned, meta0 = store.load(epoch=0)
    assert meta0["mode"] == "imitation"
    assert tuple(meta0["feature_names"]) == ds.feature_names
    assert meta0["metrics"]["holdout_agreement"] == \
        metrics["holdout_agreement"]
    for k, v in policy.items():
        assert np.array_equal(pinned[k], v), k
    with pytest.raises(FileNotFoundError):
        store.load(epoch=7)
    # truncated npz (no __meta__) is a store error, not a crash
    np.savez(tmp_path / "policy_e000005.npz", w1=policy["w1"])
    with pytest.raises(PolicyStoreError):
        store.load(epoch=5)


# ---------------------------------------------------------------------------
# The "learned" stack
# ---------------------------------------------------------------------------

SMOKE_MANIFEST = {
    "scenario": {"kind": "burst-storm", "n_functions": 4,
                 "duration_s": 20, "target_nodes": 8, "seed": 0},
    "scheduler": {"name": "learned"},
    "prediction": {"n_train": 300, "n_trees": 8},
}


def test_learned_stack_builds_from_config_dict():
    """The acceptance bar: ``"learned"`` runs straight from a pure
    PlatformConfig dict, no trained artifact on disk (heuristic
    fallback), and serves with zero stale-epoch decisions."""
    plat = Platform.build(config=dict(SMOKE_MANIFEST))
    res = plat.run()
    scorer = plat.scheduler.learned_scorer
    assert res.ticks == 20
    assert scorer.stats.batches > 0 and scorer.stats.scored_nodes > 0
    assert scorer.stats.stale_serves == 0
    assert scorer.policy is None          # heuristic mode: no weights


def test_learned_stack_serves_stored_policy(tmp_path, ds, trained):
    policy, _ = trained
    store = PolicyStore(str(tmp_path))
    store.save(policy, epoch=0, mode="imitation",
               feature_names=ds.feature_names)
    manifest = dict(SMOKE_MANIFEST,
                    policy={"store": str(tmp_path), "epoch": 0})
    plat = Platform.build(config=manifest)
    scorer = plat.scheduler.learned_scorer
    assert scorer.policy is not None and scorer.stats.swaps == 1
    res = plat.run()
    assert res.ticks == 20 and scorer.stats.batches > 0
    assert scorer.stats.stale_serves == 0


def test_hot_swap_keeps_stale_serves_zero(tmp_path, ds, trained):
    """A live PredictionService retrain bumps the serving epoch; the
    platform's listener re-tags the scorer inside the same synchronous
    callback, so post-retrain scoring never runs at a lagging epoch."""
    from repro.core.pipeline import DecisionContext

    policy, _ = trained
    PolicyStore(str(tmp_path)).save(policy, epoch=0, mode="imitation")
    plat = Platform.build(config=dict(
        SMOKE_MANIFEST, policy={"store": str(tmp_path)}))
    plat.run()
    sched = plat.scheduler
    scorer, svc = sched.learned_scorer, sched.prediction_service
    swaps0, epoch0 = scorer.stats.swaps, svc.epoch

    svc.retrain()                         # live epoch bump
    assert svc.epoch == epoch0 + 1
    assert scorer.stats.swaps == swaps0 + 1
    assert scorer.expected_epoch == svc.epoch == scorer.epoch

    fn = next(iter(plat.cluster.specs))
    ctx = DecisionContext(sched, fn, 1, 21.0, None)
    nodes = list(plat.cluster.nodes.values())[:4]
    scores = scorer.score_batch(ctx, nodes)
    assert len(scores) == len(nodes)
    assert scorer.stats.stale_serves == 0

    # a missed swap IS counted: mismatched expectation -> stale serve
    scorer.expect(scorer.epoch + 1)
    scorer.score_batch(ctx, nodes)
    assert scorer.stats.stale_serves == 1


def test_scorer_agrees_with_np_forward(ds, trained):
    """The jitted serving path and the numpy evaluation path score
    identically (padding rows don't leak into real scores)."""
    from repro.policy import np_scores

    policy, _ = trained
    scorer = LearnedScorer(policy, epoch=0)
    X, mask, y = matrices(ds)
    agree = top1_agreement(policy, X, mask, y)
    assert 0.0 <= agree <= 1.0
    d = ds.decisions[0]
    want = np_scores(policy, d.features)
    rows = d.features
    pad = 8 if len(rows) <= 8 else len(rows)
    got = np.asarray(scorer._fwd(np.concatenate(
        [rows, np.zeros((pad - len(rows), rows.shape[1]), np.float32)])
        if pad != len(rows) else rows))[:len(rows)]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_policy_config_validation():
    with pytest.raises(PlatformConfigError):
        PlatformConfig.from_dict(dict(
            SMOKE_MANIFEST, policy={"epoch": 3})).validate()
    with pytest.raises(PlatformConfigError):
        PlatformConfig.from_dict(dict(
            SMOKE_MANIFEST,
            pipeline={"decision_traces": False,
                      "trace_features": True})).validate()
    with pytest.raises(PlatformConfigError):
        PlatformConfig.from_dict(dict(
            SMOKE_MANIFEST, policy={"stor": "x"}))
