"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py
oracles, plus ops.py wrappers vs the model layer's expectations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rfr_inference import rfr_forest_apply
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.ssd_scan import ssd_scan


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,window", [("global", 0), ("local", 32),
                                         ("chunked", 32)])
@pytest.mark.parametrize("S", [64, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(kind, window, S, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    BH, D = 4, 32
    q = _rand(k1, (BH, S, D), dtype)
    k = _rand(k2, (BH, S, D), dtype)
    v = _rand(k3, (BH, S, D), dtype)
    out = flash_attention(q, k, v, causal=True, kind=kind, window=window,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, kind=kind,
                                   window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("softcap", [0.0, 20.0])
def test_flash_attention_softcap(softcap):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(k1, (2, 64, 16), jnp.float32) * 4
    k = _rand(k2, (2, 64, 16), jnp.float32) * 4
    v = _rand(k3, (2, 64, 16), jnp.float32)
    out = flash_attention(q, k, v, causal=True, softcap=softcap,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


def test_flash_attention_noncausal():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (_rand(kk, (2, 96, 16), jnp.float32) for kk in (k1, k2, k3))
    out = flash_attention(q, k, v, causal=False, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_attention_op_gqa_expansion():
    """ops.attention_op accepts (B, S, H, D) GQA layouts."""
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    B, S, Hq, Hkv, D = 2, 64, 4, 2, 16
    q = _rand(ks[0], (B, S, Hq, D), jnp.float32)
    k = _rand(ks[1], (B, S, Hkv, D), jnp.float32)
    v = _rand(ks[2], (B, S, Hkv, D), jnp.float32)
    out_pl = ops.attention_op(q, k, v, use_pallas=True, interpret=True)
    out_ref = ops.attention_op(q, k, v, use_pallas=False)
    assert out_pl.shape == (B, S, Hq, D)
    np.testing.assert_allclose(np.asarray(out_pl), np.asarray(out_ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,W", [(32, 64), (128, 128), (100, 96)])
def test_rglru_scan_matches_ref(S, W):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    B = 2
    a = jax.nn.sigmoid(_rand(ks[0], (B, S, W), jnp.float32))  # decay in (0,1)
    b = _rand(ks[1], (B, S, W), jnp.float32)
    got = rglru_scan(a, b, interpret=True)
    want = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_rglru_scan_with_initial_state():
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    B, S, W = 2, 48, 64
    a = jax.nn.sigmoid(_rand(ks[0], (B, S, W), jnp.float32))
    b = _rand(ks[1], (B, S, W), jnp.float32)
    h0 = _rand(ks[2], (B, W), jnp.float32)
    got = rglru_scan(a, b, h0, interpret=True)
    want = ref.rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_rglru_scan_is_associative_consistent():
    """Splitting a sequence and chaining states == one long scan."""
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    B, S, W = 1, 64, 32
    a = jax.nn.sigmoid(_rand(ks[0], (B, S, W), jnp.float32))
    b = _rand(ks[1], (B, S, W), jnp.float32)
    full = ref.rglru_scan_ref(a, b)
    h_mid = full[:, S // 2 - 1]
    second = ref.rglru_scan_ref(a[:, S // 2:], b[:, S // 2:], h_mid)
    np.testing.assert_allclose(np.asarray(second),
                               np.asarray(full[:, S // 2:]),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Mamba-2 SSD scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,chunk", [(64, 16), (128, 32), (96, 32)])
def test_ssd_scan_matches_ref(S, chunk):
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    B, H, P, N = 2, 3, 8, 16
    x = _rand(ks[0], (B, H, S, P), jnp.float32)
    dA = -jax.nn.softplus(_rand(ks[1], (B, H, S), jnp.float32))  # negative
    dt = jax.nn.softplus(_rand(ks[2], (B, H, S), jnp.float32))
    Bm = _rand(ks[3], (B, H, S, N), jnp.float32)
    Cm = _rand(ks[4], (B, H, S, N), jnp.float32)
    y, h = ssd_scan(x, dA, dt, Bm, Cm, chunk=chunk, interpret=True)
    y_ref, h_ref = ref.ssd_scan_ref(x, dA, dt, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               atol=2e-4, rtol=2e-4)


def test_ssd_scan_state_chaining():
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    B, H, S, P, N = 1, 2, 64, 4, 8
    x = _rand(ks[0], (B, H, S, P), jnp.float32)
    dA = -jax.nn.softplus(_rand(ks[1], (B, H, S), jnp.float32))
    dt = jax.nn.softplus(_rand(ks[2], (B, H, S), jnp.float32))
    Bm = _rand(ks[3], (B, H, S, N), jnp.float32)
    Cm = _rand(ks[4], (B, H, S, N), jnp.float32)
    y_full, h_full = ref.ssd_scan_ref(x, dA, dt, Bm, Cm)
    half = S // 2
    y1, h1 = ssd_scan(x[:, :, :half], dA[:, :, :half], dt[:, :, :half],
                      Bm[:, :, :half], Cm[:, :, :half], chunk=16,
                      interpret=True)
    y2, h2 = ssd_scan(x[:, :, half:], dA[:, :, half:], dt[:, :, half:],
                      Bm[:, :, half:], Cm[:, :, half:], h0=h1, chunk=16,
                      interpret=True)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, :, half:]),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               atol=3e-4, rtol=3e-4)


# ---------------------------------------------------------------------------
# RFR forest inference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("N,T,depth,F", [(32, 4, 3, 8), (100, 16, 6, 31)])
def test_rfr_forest_matches_ref(N, T, depth, F):
    rng = np.random.default_rng(0)
    NN = (1 << depth) - 1
    x = rng.standard_normal((N, F)).astype(np.float32)
    feat = rng.integers(0, F, (T, NN)).astype(np.int32)
    thr = rng.standard_normal((T, NN)).astype(np.float32)
    leaf = rng.standard_normal((T, 1 << depth)).astype(np.float32)
    got = rfr_forest_apply(jnp.asarray(x), jnp.asarray(feat),
                           jnp.asarray(thr), jnp.asarray(leaf),
                           interpret=True)
    want = ref.rfr_forest_ref(x, feat, thr, leaf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.tpu_only
def test_rfr_forest_real_kernel_cluster_batch():
    """The compiled (interpret=False) VMEM-resident forest kernel at a
    cluster-scale batch — the path CapacityEngine drains feed on TPU."""
    rng = np.random.default_rng(2)
    T, depth, F = 32, 8, 31
    NN = (1 << depth) - 1
    x = rng.standard_normal((2048, F)).astype(np.float32)
    feat = rng.integers(0, F, (T, NN)).astype(np.int32)
    thr = rng.standard_normal((T, NN)).astype(np.float32)
    leaf = rng.standard_normal((T, 1 << depth)).astype(np.float32)
    got = rfr_forest_apply(jnp.asarray(x), jnp.asarray(feat),
                           jnp.asarray(thr), jnp.asarray(leaf),
                           interpret=False)
    want = ref.rfr_forest_ref(x, feat, thr, leaf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_rfr_forest_apply_empty_batch():
    """N == 0 (a drain with nothing to solve) used to divide by zero:
    bn = min(block_n, 0) = 0 and grid = (N // bn,)."""
    rng = np.random.default_rng(3)
    T, depth, F = 4, 3, 8
    NN = (1 << depth) - 1
    feat = rng.integers(0, F, (T, NN)).astype(np.int32)
    thr = rng.standard_normal((T, NN)).astype(np.float32)
    leaf = rng.standard_normal((T, 1 << depth)).astype(np.float32)
    out = rfr_forest_apply(jnp.zeros((0, F), jnp.float32),
                           jnp.asarray(feat), jnp.asarray(thr),
                           jnp.asarray(leaf), interpret=True)
    assert out.shape == (0,)
    assert out.dtype == jnp.float32


@pytest.mark.parametrize("N,block_n", [(3, 256), (100, 32), (64, 64)])
def test_rfr_forest_apply_partial_blocks(N, block_n):
    """Batches smaller than block_n and non-multiples of it (padded
    grid) must match the oracle on the real rows."""
    rng = np.random.default_rng(4)
    T, depth, F = 6, 4, 10
    NN = (1 << depth) - 1
    x = rng.standard_normal((N, F)).astype(np.float32)
    feat = rng.integers(0, F, (T, NN)).astype(np.int32)
    thr = rng.standard_normal((T, NN)).astype(np.float32)
    leaf = rng.standard_normal((T, 1 << depth)).astype(np.float32)
    got = rfr_forest_apply(jnp.asarray(x), jnp.asarray(feat),
                           jnp.asarray(thr), jnp.asarray(leaf),
                           block_n=block_n, interpret=True)
    want = ref.rfr_forest_ref(x, feat, thr, leaf)
    assert got.shape == (N,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# RFR fused capacity m-sweep
# ---------------------------------------------------------------------------


def _sweep_case(seed, S=7, M=6, R=3, T=8, depth=4, F=9):
    """A padded scenario tensor exercising both padding encodings:
    +inf bounds (R padding rows, always pass) and -inf bounds (m beyond
    a scenario's own m_max, always fail)."""
    rng = np.random.default_rng(seed)
    NN = (1 << depth) - 1
    x = rng.standard_normal((S, M, R, F)).astype(np.float32)
    feat = rng.integers(0, F, (T, NN)).astype(np.int32)
    thr = rng.standard_normal((T, NN)).astype(np.float32)
    leaf = rng.standard_normal((T, 1 << depth)).astype(np.float32)
    # finite bounds in the prediction range so pass/fail actually varies
    bounds = rng.uniform(-0.6, 0.6, (S, M, R)).astype(np.float32)
    for s in range(S):
        r_real = int(rng.integers(1, R + 1))
        m_real = int(rng.integers(0, M + 1))
        bounds[s, :, r_real:] = np.inf      # padded rows pass
        bounds[s, m_real:, :] = -np.inf     # past this scenario's m_max
    return x, bounds, feat, thr, leaf


@pytest.mark.parametrize("use_pallas", [True, False])
@pytest.mark.parametrize("log_target", [False, True])
def test_rfr_capacity_sweep_matches_ref(use_pallas, log_target):
    x, bounds, feat, thr, leaf = _sweep_case(5)
    got = ops.rfr_sweep_op(jnp.asarray(x), jnp.asarray(bounds),
                           jnp.asarray(feat), jnp.asarray(thr),
                           jnp.asarray(leaf), use_pallas=use_pallas,
                           interpret=True, log_target=log_target)
    want = ref.rfr_capacity_sweep_ref(x, bounds, feat, thr, leaf,
                                      log_target=log_target)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_rfr_capacity_sweep_block_partitioning():
    """Scenario-block size must not change results (padded scenarios
    pass trivially and are sliced off)."""
    from repro.kernels.rfr_inference import rfr_capacity_sweep
    x, bounds, feat, thr, leaf = _sweep_case(6, S=11)
    want = ref.rfr_capacity_sweep_ref(x, bounds, feat, thr, leaf)
    for bs in (1, 3, 11, 64):
        got = rfr_capacity_sweep(jnp.asarray(x), jnp.asarray(bounds),
                                 jnp.asarray(feat), jnp.asarray(thr),
                                 jnp.asarray(leaf), block_s=bs,
                                 interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("use_pallas", [True, False])
def test_rfr_capacity_sweep_degenerate_shapes(use_pallas):
    rng = np.random.default_rng(7)
    T, depth, F = 4, 3, 6
    NN = (1 << depth) - 1
    feat = jnp.asarray(rng.integers(0, F, (T, NN)).astype(np.int32))
    thr = jnp.asarray(rng.standard_normal((T, NN)).astype(np.float32))
    leaf = jnp.asarray(rng.standard_normal((T, 1 << depth)).astype(
        np.float32))
    for S, M, R in [(0, 4, 2), (3, 0, 2), (3, 4, 0)]:
        out = ops.rfr_sweep_op(jnp.zeros((S, M, R, F), jnp.float32),
                               jnp.zeros((S, M, R), jnp.float32),
                               feat, thr, leaf, use_pallas=use_pallas,
                               interpret=True)
        assert out.shape == (S,)
        assert out.dtype == jnp.int32
        assert not np.asarray(out).any()


@pytest.mark.tpu_only
def test_rfr_capacity_sweep_real_kernel():
    """The compiled (interpret=False) fused sweep at drain scale."""
    x, bounds, feat, thr, leaf = _sweep_case(8, S=128, M=16, R=4,
                                             T=32, depth=8, F=31)
    got = ops.rfr_sweep_op(jnp.asarray(x), jnp.asarray(bounds),
                           jnp.asarray(feat), jnp.asarray(thr),
                           jnp.asarray(leaf), use_pallas=True,
                           interpret=False)
    want = ref.rfr_capacity_sweep_ref(x, bounds, feat, thr, leaf)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_rfr_op_consistent_with_trained_model():
    """The Pallas engine and the numpy engine of the actual predictor
    agree on real trained trees."""
    from repro.core.predictor import RandomForestRegressor
    rng = np.random.default_rng(1)
    X = rng.standard_normal((400, 10)).astype(np.float32)
    y = (X[:, 0] * 2 + np.sin(X[:, 1]) + 0.1 *
         rng.standard_normal(400)).astype(np.float64)
    m = RandomForestRegressor(n_trees=8, max_depth=5, seed=1)
    m.fit(X, y)
    p_np = m.predict(X[:64], engine="numpy")
    p_pl = m.predict(X[:64], engine="pallas")
    np.testing.assert_allclose(p_np, p_pl, atol=1e-4, rtol=1e-4)
