"""Serving engine: continuous batching, cache splicing correctness,
dual-staged data-plane semantics."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models import model as model_lib
from repro.serving.engine import Request, ServingEngine, ServingInstance


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("gemma2-2b")
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _req(rid, cfg, n=12, max_new=4, seed=None):
    rng = np.random.default_rng(seed if seed is not None else rid)
    return Request(rid=rid, prompt=rng.integers(
        0, cfg.vocab_size, n).astype(np.int32), max_new=max_new)


def test_all_requests_complete(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=2, max_len=64)
    eng.scale_up(2)
    for i in range(7):
        eng.submit(_req(i, cfg))
    done = eng.drain()
    assert len(done) == 7
    assert all(len(r.tokens) == 4 for r in done)
    assert all(r.t_done is not None and r.t_first_token is not None
               for r in done)


def test_batched_decode_matches_single_instance(setup):
    """Splicing a prefill into a slot then batch-decoding equals running
    the request alone (greedy tokens identical)."""
    cfg, params = setup
    req_a = _req(0, cfg, n=10, max_new=5, seed=42)
    req_b = _req(1, cfg, n=14, max_new=5, seed=43)
    solo = ServingEngine(cfg, params, slots=1, max_len=64)
    solo.scale_up(1)
    solo.submit(Request(0, req_a.prompt.copy(), 5))
    tokens_solo = solo.drain()[0].tokens

    both = ServingEngine(cfg, params, slots=2, max_len=64)
    both.scale_up(1)
    both.submit(Request(0, req_a.prompt.copy(), 5))
    both.submit(Request(1, req_b.prompt.copy(), 5))
    done = both.drain()
    tokens_shared = next(r for r in done if r.rid == 0).tokens
    assert tokens_solo == tokens_shared


def test_release_stops_traffic_logical_start_resumes(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=2, max_len=64)
    eng.scale_up(2)
    eng.release(1)
    assert eng.n_saturated() == 1
    for i in range(3):
        eng.submit(_req(i, cfg, max_new=2))
    eng.tick()
    cached_inst = [eng.instances[i] for i in eng.cached]
    assert all(inst.n_active() == 0 for inst in cached_inst)
    eng.logical_start(1)
    assert eng.n_saturated() == 2
    done = eng.drain()
    assert len(done) == 3


def test_evict_cached_removes_instances(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=1, max_len=32)
    eng.scale_up(3)
    eng.release(2)
    assert eng.evict_cached(2) == 2
    assert len(eng.instances) == 1
    assert eng.n_saturated() == 1


def test_instance_slot_reuse(setup):
    cfg, params = setup
    inst = ServingInstance(cfg, params, slots=1, max_len=64)
    r1 = _req(0, cfg, max_new=2)
    assert inst.admit(r1)
    assert not inst.admit(_req(1, cfg))  # full
    while inst.n_active():
        inst.step()
    assert inst.admit(_req(2, cfg, max_new=2))  # slot reusable
