"""Device-resident capacity drain: the fused single-pass m-sweep
(``EngineConfig(drain="device")``) must be an exact drop-in for the
chunked host drain — bit-identical capacity tables AND matching
EngineStats accounting — for both device engines (jnp gather sweep and
the Pallas kernel), cold and warm, homogeneous and heterogeneous
(schema-v2 node shapes, per-query m_max)."""
import numpy as np
import pytest

from repro.core import (CapacityEngine, EngineConfig, GroundTruth,
                        NodeResources, PerfPredictor, ProfileStore,
                        QoSStore, generate_dataset, synthetic_functions)
from repro.core.cluster import Node

STAT_KEYS = ("solves", "unique_solves", "cache_hits", "coalesced_dupes",
             "rows_built", "predict_calls")


@pytest.fixture(scope="module")
def world():
    specs = synthetic_functions(5, seed=2)
    gt = GroundTruth(seed=0)
    store = ProfileStore(seed=0)
    qos = QoSStore(store, gt)
    pred = PerfPredictor(n_trees=12, max_depth=7, seed=0)
    X, y = generate_dataset(specs, gt, store, qos, 700, seed=1)
    pred.add_dataset(X, y)
    return specs, gt, store, qos, pred


SHAPES = [NodeResources(),
          NodeResources(cpu_mcores=96_000.0, mem_mb=262_144.0),
          NodeResources(cpu_mcores=24_000.0, mem_mb=65_536.0)]


def _hetero_nodes(specs, rng, n_nodes, n_patterns=6):
    """Nodes drawn from a pattern pool (so signature sharing occurs)
    across three node shapes (so schema-v2 signatures diverge)."""
    names = sorted(specs)
    patterns = []
    for _ in range(n_patterns):
        pat = {}
        for g in rng.choice(names, size=int(rng.integers(1, 4)),
                            replace=False):
            pat[g] = (int(rng.integers(1, 5)), int(rng.integers(0, 3)))
        patterns.append(pat)
    nodes = []
    for i in range(n_nodes):
        node = Node(SHAPES[i % len(SHAPES)])
        for g, (ns, nc) in patterns[rng.integers(n_patterns)].items():
            node.state(g).n_sat = ns
            node.state(g).n_cached = nc
        nodes.append(node)
    return nodes


def _tables(nodes):
    return [sorted((fn, e.capacity) for fn, e in node.table.items())
            for node in nodes]


def _clear(nodes):
    for node in nodes:
        node.table.clear()


@pytest.fixture()
def restore_engine(world):
    pred = world[4]
    prev = pred.engine
    yield pred
    pred.engine = prev


def _three_way(world, schema, interpret=True):
    """Host numpy oracle (full sweep, for stats parity) vs device drains."""
    specs, gt, store, qos, pred = world
    rng = np.random.default_rng(17)
    nodes = _hetero_nodes(specs, rng, n_nodes=64)
    m_max = 12

    host = CapacityEngine(pred, store, qos, specs,
                          EngineConfig(m_max=m_max, early_exit=False),
                          schema=schema)
    host.update_nodes(nodes, m_max=m_max)
    ref_tables = _tables(nodes)
    ref_stats = host.stats.snapshot()
    _clear(nodes)

    for engine in ("jax", "pallas"):
        dev = CapacityEngine(pred, store, qos, specs,
                             EngineConfig(m_max=m_max, drain="device"),
                             schema=schema)
        if not interpret:
            dev._interpret = False
        pred.engine = engine
        dev.update_nodes(nodes, m_max=m_max)
        assert _tables(nodes) == ref_tables, f"capacity mismatch ({engine})"
        dev_stats = dev.stats.snapshot()
        for k in STAT_KEYS:
            assert dev_stats[k] == ref_stats[k], \
                f"{k}: device={dev_stats[k]} host={ref_stats[k]} ({engine})"
        # warm drain: every signature resolves as a device-side gather
        rows_before = dev.stats.rows_built
        _clear(nodes)
        warm_rows = dev.update_nodes(nodes, m_max=m_max)
        assert _tables(nodes) == ref_tables
        assert warm_rows == 0
        assert dev.stats.rows_built == rows_before
        assert dev.stats.cache_hits == ref_stats["cache_hits"] \
            + ref_stats["solves"]
        _clear(nodes)
    return ref_tables


def test_three_way_drain_parity_v1(world, restore_engine):
    """numpy host oracle vs engine="jax" vs fused Pallas sweep: identical
    capacity tables and identical EngineStats on a seeded 64-node run."""
    _three_way(world, schema=1)


def test_three_way_drain_parity_v2_hetero_shapes(world, restore_engine):
    """Same, node-shape-aware: schema-v2 rows, margins, and shape-keyed
    signatures must survive the device packing unchanged."""
    _three_way(world, schema=2)


def test_device_drain_heterogeneous_m_max(world, restore_engine):
    """Per-query m_max exercises the -inf padding (m beyond a scenario's
    own sweep must fail) inside one packed tensor."""
    specs, gt, store, qos, pred = world
    names = sorted(specs)
    rng = np.random.default_rng(23)
    queries = []
    for i in range(20):
        coloc = {}
        for g in rng.choice(names, size=int(rng.integers(0, 4)),
                            replace=False):
            coloc[g] = (float(rng.integers(1, 5)), float(rng.integers(0, 3)))
        fn = names[int(rng.integers(len(names)))]
        queries.append((coloc, fn, int(rng.integers(1, 17)), None))

    host = CapacityEngine(pred, store, qos, specs,
                          EngineConfig(cache=False, early_exit=False))
    want = [c for c, _r in host.solve_many(list(queries))]
    pred.engine = "pallas"
    dev = CapacityEngine(pred, store, qos, specs,
                         EngineConfig(cache=False, drain="device"))
    got = [c for c, _r in dev.solve_many(list(queries))]
    assert got == want


def test_device_drain_rows_billed_to_first_occurrence(world, restore_engine):
    """Same contract as the host drain: duplicate signatures inside one
    batch bill rows once, cache hits bill zero."""
    specs, gt, store, qos, pred = world
    names = sorted(specs)
    pred.engine = "jax"
    dev = CapacityEngine(pred, store, qos, specs,
                         EngineConfig(m_max=8, drain="device"))
    coloc = {names[1]: (2.0, 1.0)}
    q = (dict(coloc), names[0], 8, None)
    (c1, r1), (c2, r2) = dev.solve_many([q, q])
    assert c1 == c2
    assert r1 > 0 and r2 == 0          # dupe coalesced, billed once
    (c3, r3), = dev.solve_many([q])
    assert c3 == c1 and r3 == 0        # warm: device gather, zero rows
    assert dev.stats.coalesced_dupes == 1
    assert dev.stats.cache_hits == 1


def test_device_drain_empty_and_trivial_batches(world, restore_engine):
    specs, gt, store, qos, pred = world
    pred.engine = "jax"
    dev = CapacityEngine(pred, store, qos, specs,
                         EngineConfig(drain="device"))
    assert dev.solve_many([]) == []
    names = sorted(specs)
    (cap, rows), = dev.solve_many([({}, names[0], 0, None)])
    assert cap == 0 and rows == 0      # m_max=0: nothing admissible


def test_device_cache_eviction_compacts_slots(world, restore_engine):
    """The device capacity vector is bounded like the host cache:
    oldest slots evicted, survivors compacted, gathers still correct."""
    specs, gt, store, qos, pred = world
    names = sorted(specs)
    pred.engine = "jax"
    dev = CapacityEngine(pred, store, qos, specs,
                         EngineConfig(m_max=6, drain="device",
                                      max_cache_entries=3))
    colocs = [{names[j]: (float(i + 1), 0.0)}
              for i in range(2) for j in range(1, 4)]
    expect = {}
    for i, coloc in enumerate(colocs):
        (cap, _r), = dev.solve_many([(dict(coloc), names[0], 6, None)])
        expect[i] = cap
    assert len(dev._dev_slots) <= 3
    assert int(dev._dev_caps.shape[0]) == len(dev._dev_slots)
    # survivors (the 3 newest) still resolve warm with the right values
    for i in (3, 4, 5):
        (cap, rows), = dev.solve_many([(dict(colocs[i]), names[0], 6, None)])
        assert cap == expect[i] and rows == 0
    # evicted entries re-solve to the same capacity
    (cap, rows), = dev.solve_many([(dict(colocs[0]), names[0], 6, None)])
    assert cap == expect[0] and rows > 0


def test_device_drain_retrain_invalidates(world, restore_engine):
    """Epoch bump must clear the device-side cache too — a post-retrain
    gather can never serve a pre-retrain capacity."""
    specs, gt, store, qos, pred = world
    p2 = PerfPredictor(n_trees=6, max_depth=6, seed=3)
    X, y = generate_dataset(specs, gt, store, qos, 300, seed=9)
    p2.add_dataset(X, y)
    p2.engine = "jax"
    dev = CapacityEngine(p2, store, qos, specs,
                         EngineConfig(m_max=8, drain="device"))
    names = sorted(specs)
    q = ({names[1]: (2.0, 0.0)}, names[0], 8, None)
    dev.solve_many([q])
    assert dev._dev_slots and dev._dev_caps is not None
    p2.add_sample(X[0], float(y[0]), retrain=False)
    p2.retrain()
    (cap, rows), = dev.solve_many([q])
    assert rows > 0                    # re-solved, not served stale
    assert dev.stats.stale_epoch_hits == 0
    cap_ref, _ = CapacityEngine(p2, store, qos, specs,
                                EngineConfig(m_max=8)).capacity(
        {names[1]: (2.0, 0.0)}, names[0], 8)
    assert cap == cap_ref


@pytest.mark.tpu_only
def test_three_way_drain_parity_compiled(world, restore_engine):
    """The compiled (interpret=False) Pallas sweep on real hardware."""
    _three_way(world, schema=2, interpret=False)
