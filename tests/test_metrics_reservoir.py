"""Bounded metric reservoirs: exact aggregates, list-protocol drop-in
behaviour, and bounded memory on long runs."""
import numpy as np
import pytest

from repro.core import Reservoir
from repro.core.scheduler import SchedMetrics


def test_exact_history_below_capacity():
    r = Reservoir(cap=8)
    r.extend([3.0, 1.0, 2.0])
    assert list(r) == [3.0, 1.0, 2.0]
    assert r[-2:] == [1.0, 2.0]          # slicing (autoscaler tests use it)
    assert len(r) == 3 and r.count == 3
    assert r.mean == pytest.approx(2.0)
    assert r.p50 == pytest.approx(2.0)   # exact while count <= cap
    assert r.min == 1.0 and r.max == 3.0


def test_bounded_size_with_exact_running_aggregates():
    r = Reservoir(cap=64, seed=1)
    xs = np.linspace(0.0, 1.0, 10_000)
    r.extend(xs)
    assert len(r) == 64                  # memory stays bounded
    assert r.count == 10_000             # ...but the count is exact
    assert r.mean == pytest.approx(float(xs.mean()))   # exact running sum
    assert r.max == 1.0 and r.min == 0.0
    # the uniform sample keeps quantiles in the right neighbourhood
    assert abs(r.p50 - 0.5) < 0.2
    assert r.p99 > 0.7


def test_same_sequence_same_retained_indices():
    """Two reservoirs fed the same sequence retain the same positions —
    the property the engine-vs-legacy density_series parity relies on."""
    a, b = Reservoir(cap=16, seed=0), Reservoir(cap=16, seed=0)
    xs = np.arange(200.0)
    a.extend(xs)
    b.extend(xs * 2.0)
    assert np.array_equal(np.asarray(a) * 2.0, np.asarray(b))


def test_numpy_protocol_and_empty_behaviour():
    r = Reservoir(cap=4)
    assert not r
    assert r.mean == 0.0 and r.p99 == 0.0 and r.max == 0.0
    assert np.asarray(r, dtype=np.float64).shape == (0,)
    r.append(5)
    assert np.isfinite(np.asarray(r)).all()
    with pytest.raises(ValueError):
        Reservoir(cap=0)


def test_sched_metrics_expose_exact_percentile_accessors():
    m = SchedMetrics()
    m.sched_latencies.extend([1.0, 2.0, 3.0, 100.0])
    assert m.mean_latency_ms == pytest.approx(26.5)
    assert m.p50_latency_ms == pytest.approx(2.5)
    assert m.p99_latency_ms > 90.0
