"""Bounded metric reservoirs: exact aggregates, list-protocol drop-in
behaviour, bounded memory on long runs, and the ``histogram(bins)``
export (property-tested across the exact and estimated regimes).

Property tests run under real `hypothesis` when installed, else under
the deterministic fallback shim (same assertions, fixed-seed sampled
inputs)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import Reservoir
from repro.core.scheduler import SchedMetrics


def test_exact_history_below_capacity():
    r = Reservoir(cap=8)
    r.extend([3.0, 1.0, 2.0])
    assert list(r) == [3.0, 1.0, 2.0]
    assert r[-2:] == [1.0, 2.0]          # slicing (autoscaler tests use it)
    assert len(r) == 3 and r.count == 3
    assert r.mean == pytest.approx(2.0)
    assert r.p50 == pytest.approx(2.0)   # exact while count <= cap
    assert r.min == 1.0 and r.max == 3.0


def test_bounded_size_with_exact_running_aggregates():
    r = Reservoir(cap=64, seed=1)
    xs = np.linspace(0.0, 1.0, 10_000)
    r.extend(xs)
    assert len(r) == 64                  # memory stays bounded
    assert r.count == 10_000             # ...but the count is exact
    assert r.mean == pytest.approx(float(xs.mean()))   # exact running sum
    assert r.max == 1.0 and r.min == 0.0
    # the uniform sample keeps quantiles in the right neighbourhood
    assert abs(r.p50 - 0.5) < 0.2
    assert r.p99 > 0.7


def test_same_sequence_same_retained_indices():
    """Two reservoirs fed the same sequence retain the same positions —
    the property the engine-vs-legacy density_series parity relies on."""
    a, b = Reservoir(cap=16, seed=0), Reservoir(cap=16, seed=0)
    xs = np.arange(200.0)
    a.extend(xs)
    b.extend(xs * 2.0)
    assert np.array_equal(np.asarray(a) * 2.0, np.asarray(b))


def test_numpy_protocol_and_empty_behaviour():
    r = Reservoir(cap=4)
    assert not r
    assert r.mean == 0.0 and r.p99 == 0.0 and r.max == 0.0
    assert np.asarray(r, dtype=np.float64).shape == (0,)
    r.append(5)
    assert np.isfinite(np.asarray(r)).all()
    with pytest.raises(ValueError):
        Reservoir(cap=0)


def test_sched_metrics_expose_exact_percentile_accessors():
    m = SchedMetrics()
    m.sched_latencies.extend([1.0, 2.0, 3.0, 100.0])
    assert m.mean_latency_ms == pytest.approx(26.5)
    assert m.p50_latency_ms == pytest.approx(2.5)
    assert m.p99_latency_ms > 90.0


# ---------------------------------------------------------------------------
# histogram(bins) export
# ---------------------------------------------------------------------------


def test_histogram_empty_and_bad_bins():
    r = Reservoir(cap=8)
    counts, edges = r.histogram(bins=5)
    assert counts.sum() == 0 and len(counts) == 5 and len(edges) == 6
    with pytest.raises(ValueError):
        r.histogram(bins=0)


def test_histogram_explicit_bounds_clip_like_numpy():
    r = Reservoir(cap=16)
    r.extend([1.0, 2.0, 3.0, 100.0])
    counts, edges = r.histogram(bins=2, lo=0.0, hi=4.0)
    assert counts.sum() == 3.0            # 100.0 falls outside the range
    assert edges[0] == 0.0 and edges[-1] == 4.0


def test_histogram_degenerate_single_value():
    r = Reservoir(cap=8)
    r.extend([7.0, 7.0, 7.0])
    counts, edges = r.histogram(bins=4)
    assert counts.sum() == 3.0            # hi==lo widened, nothing lost
    assert edges[0] == 7.0


@settings(max_examples=30)
@given(values=st.lists(st.integers(min_value=-1000, max_value=1000),
                       min_size=1, max_size=200),
       cap=st.integers(min_value=4, max_value=64),
       bins=st.integers(min_value=1, max_value=20))
def test_histogram_sum_invariant_both_regimes(values, cap, bins):
    """Under default bounds the bucket mass always sums to the *exact*
    observation count — exact regime (count <= cap) bucket-for-bucket,
    estimated regime (count > cap) by rescaling the retained sample to
    the population size."""
    r = Reservoir(cap=cap, seed=1)
    r.extend(float(v) for v in values)
    counts, edges = r.histogram(bins=bins)
    assert len(counts) == bins and len(edges) == bins + 1
    assert counts.sum() == pytest.approx(r.count)
    assert (counts >= 0).all()
    assert edges[0] <= min(values) and edges[-1] >= max(values)
    if r.count <= cap:
        # exact regime: identical to numpy over the full history
        ref, _ = np.histogram([float(v) for v in values], bins=bins,
                              range=(edges[0], edges[-1]))
        assert np.array_equal(counts, ref.astype(float))


@settings(max_examples=20)
@given(n=st.integers(min_value=300, max_value=2000),
       bins=st.integers(min_value=2, max_value=12))
def test_histogram_estimated_regime_tracks_distribution(n, bins):
    """Beyond cap the rescaled sample histogram still integrates to the
    population count and spans the true min/max (tracked exactly)."""
    rng = np.random.default_rng(7)
    xs = rng.uniform(0.0, 10.0, size=n)
    r = Reservoir(cap=128, seed=2)
    r.extend(xs)
    counts, edges = r.histogram(bins=bins)
    assert r.count == n and len(r) == 128
    assert counts.sum() == pytest.approx(n)
    assert edges[0] == pytest.approx(float(xs.min()))
    assert edges[-1] == pytest.approx(float(xs.max()))
