"""repro.telemetry: typed metrics registry, span tracing, RunReport /
BENCH trajectories, the regression gate, the dashboard renderer, and
the platform wiring (``telemetry`` config section)."""
import json
import os

import pytest

from repro.core.events import EventHub, JsonlObserver
from repro.platform import Platform
from repro.telemetry import (NULL_TRACER, MetricsObserver,
                             MetricsRegistry, RunReport, SpanTracer,
                             Telemetry, Tolerances, append_bench,
                             bench_path, compare_reports, gate_study,
                             load_bench, promote_baseline,
                             publish_result)
from repro.telemetry.gate import main as gate_main
from repro.telemetry.report import BENCH_SCHEMA, REPORT_SCHEMA


def _quick_manifest(**telemetry):
    m = {
        "scenario": {"kind": "burst-storm", "n_functions": 4,
                     "duration_s": 20, "target_nodes": 8, "seed": 0},
        "prediction": {"n_train": 300, "n_trees": 8},
    }
    if telemetry:
        m["telemetry"] = telemetry
    return m


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_get_or_create_and_kind_conflict():
    reg = MetricsRegistry()
    c = reg.counter("a.count")
    assert reg.counter("a.count") is c
    c.inc()
    c.inc(2.5)
    assert c.snapshot() == {"kind": "counter", "value": 3.5}
    with pytest.raises(ValueError):
        c.inc(-1.0)
    with pytest.raises(TypeError):
        reg.gauge("a.count")          # one name, one type
    g = reg.gauge("b.level")
    g.set(7)
    h = reg.histogram("c.dist")
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    assert len(reg) == 3 and reg.names() == ["a.count", "b.level",
                                             "c.dist"]
    snap = reg.snapshot(bins=2)
    json.dumps(snap)                  # plain JSON-able
    assert snap["b.level"]["value"] == 7.0
    assert snap["c.dist"]["count"] == 3
    assert sum(c for _, c in snap["c.dist"]["buckets"]) == 3


def test_counter_snapshot_integral_values_stay_ints():
    reg = MetricsRegistry()
    c = reg.counter("n")
    c.inc(4)
    assert c.snapshot()["value"] == 4
    assert isinstance(c.snapshot()["value"], int)


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


def test_null_tracer_is_free_and_shared():
    cm1 = NULL_TRACER.span("anything", stats=object(), junk=1)
    cm2 = NULL_TRACER.span("other")
    assert cm1 is cm2                 # one shared no-op CM
    with cm1 as sp:
        assert sp is None
    assert NULL_TRACER.summary() == []
    assert NULL_TRACER.enabled is False


def test_span_tracer_records_emits_and_aggregates():
    emitted = []
    tr = SpanTracer(emit=emitted.append)
    with tr.span("solve", nodes=3) as sp:
        assert sp.name == "solve" and sp.attrs["nodes"] == 3
        with tr.span("inner") as inner:
            assert inner.depth == 1
    assert [s.name for s in tr.spans] == ["inner", "solve"]  # close order
    assert emitted == tr.spans
    assert tr.spans[1].dur_ms >= tr.spans[0].dur_ms >= 0.0
    rows = tr.summary()
    assert {r["name"] for r in rows} == {"solve", "inner"}
    d = tr.spans[1].to_dict()
    assert d["name"] == "solve" and d["nodes"] == 3 and "ms" in d
    json.dumps(d)


def test_span_counter_deltas_from_stats_snapshot():
    class Stats:
        def __init__(self):
            self.calls = 0

        def snapshot(self):
            return {"calls": self.calls, "still": 1.0}

    st = Stats()
    tr = SpanTracer()
    with tr.span("work", stats=st):
        st.calls += 5
    sp = tr.spans[0]
    assert sp.attrs["d_calls"] == 5
    assert "d_still" not in sp.attrs  # zero deltas elided


def test_span_tracer_bounded():
    tr = SpanTracer(max_spans=2)
    for _ in range(5):
        with tr.span("s"):
            pass
    assert len(tr.spans) == 2 and tr.dropped == 3


# ---------------------------------------------------------------------------
# MetricsObserver + publish_result through a real run
# ---------------------------------------------------------------------------


def test_platform_telemetry_section_explicit_on():
    plat = Platform.build(config=_quick_manifest(metrics=True,
                                                 spans=True,
                                                 histogram_bins=4))
    res = plat.run()
    snap = plat.metrics_snapshot()
    assert snap["sim.ticks"]["value"] == res.ticks
    assert snap["run.density"]["value"] == pytest.approx(res.density)
    assert snap["run.qos_violation_rate"]["value"] == pytest.approx(
        res.qos_violation_rate)
    assert snap["schedule.decisions"]["value"] == res.sched.decisions
    assert snap["schedule.instances_placed"]["value"] == \
        res.sched.instances_placed
    # spans reached both the tracer and the registry
    names = {r["name"] for r in plat.span_summary()}
    assert "schedule" in names and "capacity_solve" in names
    assert snap["span.schedule.ms"]["count"] == res.ticks
    json.dumps(snap)


def test_platform_telemetry_defaults_off_without_observers():
    plat = Platform.build(config=_quick_manifest())
    assert plat.telemetry is None
    assert plat.simulation.tracer is NULL_TRACER
    assert plat.service.tracer is NULL_TRACER
    plat.run()
    assert plat.metrics_snapshot() == {} and plat.span_summary() == []


def test_platform_telemetry_defaults_on_with_observers():
    plat = Platform.build(config=_quick_manifest(),
                          observers=[MetricsObserver()])
    assert plat.telemetry is not None
    assert plat.simulation.tracer is plat.telemetry.tracer


def test_publish_result_engine_stats_gauges():
    plat = Platform.build(config=_quick_manifest(metrics=True))
    plat.run()
    snap = plat.metrics_snapshot()
    assert "run.engine.solves" in snap
    assert snap["run.engine.solves"]["kind"] == "gauge"


def test_telemetry_bundle_shares_one_registry():
    t = Telemetry.create()
    assert t.observer.registry is t.registry   # falsy-when-empty trap


# ---------------------------------------------------------------------------
# RunReport + BENCH trajectory persistence
# ---------------------------------------------------------------------------


def _report(study="s", mode="quick", density=30.0, qos=0.01, **meta):
    return RunReport.build(
        study, mode, manifest={"m": 1},
        metrics={"d": density},
        rows=[{"scenario": "burst-storm", "target_nodes": 8,
               "system": "jiagu", "density": density,
               "qos_violation": qos, "cold_ms_p50": 5.0,
               "cold_ms_p99": 40.0, "sched_ms_p50": 1.0,
               "sched_ms_p99": 3.0}],
        meta=meta)


def test_run_report_round_trip_and_schema_check():
    rep = _report()
    d = rep.to_dict()
    json.dumps(d)
    back = RunReport.from_dict(d)
    assert back == rep
    assert rep.schema == REPORT_SCHEMA
    assert rep.git_sha and rep.config_hash
    with pytest.raises(ValueError):
        RunReport.from_dict({**d, "schema": "bogus@9"})


def test_append_bench_seeds_baseline_and_bounds_runs(tmp_path,
                                                     monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    p = append_bench(_report(density=30.0))
    assert p == bench_path("s") == str(tmp_path / "BENCH_s.json")
    data = load_bench("s")
    assert data["schema"] == BENCH_SCHEMA
    assert data["baseline"]["metrics"]["d"] == 30.0   # first run seeds it
    assert len(data["runs"]) == 1
    for i in range(5):
        append_bench(_report(density=31.0 + i), max_runs=3)
    data = load_bench("s")
    assert len(data["runs"]) == 3                     # bounded trajectory
    assert data["baseline"]["metrics"]["d"] == 30.0   # baseline pinned
    promote_baseline("s")
    assert load_bench("s")["baseline"]["metrics"]["d"] == 35.0


def test_load_bench_missing_and_bad_schema(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    assert load_bench("nope") is None
    (tmp_path / "BENCH_bad.json").write_text('{"schema": "x"}')
    with pytest.raises(ValueError):
        load_bench("bad")


# ---------------------------------------------------------------------------
# the regression gate
# ---------------------------------------------------------------------------


def test_gate_passes_within_tolerance_and_fails_beyond():
    base, fresh = _report(density=30.0), _report(density=29.0)
    deltas = compare_reports(base.to_dict(), fresh.to_dict())
    assert not [d for d in deltas if d.status == "FAIL"]
    worse = _report(density=30.0 * 0.9)   # -10% > 5% floor
    deltas = compare_reports(base.to_dict(), worse.to_dict())
    bad = [d for d in deltas if d.status == "FAIL"]
    assert bad and bad[0].metric == "density"


def test_gate_qos_hard_fails_absolute():
    base = _report(qos=0.01)
    ok = compare_reports(base.to_dict(), _report(qos=0.029).to_dict())
    assert not [d for d in ok if d.status == "FAIL"]
    bad = compare_reports(base.to_dict(), _report(qos=0.05).to_dict())
    assert [d for d in bad
            if d.status == "FAIL" and d.metric == "qos_violation"]


def test_gate_mode_mismatch_and_vanished_row():
    base = _report(mode="full")
    deltas = compare_reports(base.to_dict(), _report(mode="quick").to_dict())
    assert deltas[0].status == "FAIL" and deltas[0].metric == "mode"
    fresh = _report(mode="full")
    fresh.rows = []
    deltas = compare_reports(base.to_dict(), fresh.to_dict())
    assert [d for d in deltas
            if d.status == "FAIL" and d.fresh == "missing"]


def test_gate_tolerances_from_env(monkeypatch):
    monkeypatch.setenv("REPRO_GATE_DENSITY_TOL", "0.5")
    assert Tolerances.from_env().density == 0.5


def test_gate_study_missing_baseline_fails(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    deltas = gate_study("large_cluster")
    assert deltas[0].status == "FAIL"


def test_gate_main_end_to_end(tmp_path, monkeypatch, capsys):
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    append_bench(_report(study="large_cluster", density=30.0))
    assert gate_main(["--study", "large_cluster"]) == 0
    append_bench(_report(study="large_cluster", density=20.0))
    assert gate_main(["--study", "large_cluster"]) == 1
    out = capsys.readouterr().out
    assert "density" in out and "FAIL" in out
    # a looser CLI tolerance lets the same delta through
    assert gate_main(["--study", "large_cluster",
                      "--density-tol", "0.5"]) == 0
    # promotion moves the baseline; the gate then passes clean
    assert gate_main(["--promote", "large_cluster"]) == 0
    assert gate_main(["--study", "large_cluster"]) == 0


# ---------------------------------------------------------------------------
# JsonlObserver hardening (satellite)
# ---------------------------------------------------------------------------


def test_jsonl_observer_close_contract(tmp_path):
    path = tmp_path / "deep" / "nested" / "ev.jsonl"   # dirs auto-made
    obs = JsonlObserver(str(path), meta={"manifest": {"x": 1}})
    with obs:
        obs.on_scale(1.0, "fn", "release", 2)
        obs.flush()
        lines = path.read_text().splitlines()
        assert len(lines) == 2                  # durable before close
        assert json.loads(lines[0])["event"] == "meta"
    assert obs.closed
    with pytest.raises(ValueError):
        obs.on_scale(2.0, "fn", "release", 1)   # never truncates
    assert len(path.read_text().splitlines()) == 2
    obs.close()                                  # idempotent


def test_jsonl_observer_persists_spans(tmp_path):
    path = tmp_path / "ev.jsonl"
    with JsonlObserver(str(path)) as obs:
        tr = SpanTracer(emit=obs.on_span)
        with tr.span("retrain", epoch=2):
            pass
    rec = json.loads(path.read_text())
    assert rec["event"] == "span" and rec["name"] == "retrain"
    assert rec["epoch"] == 2 and rec["ms"] >= 0.0


# ---------------------------------------------------------------------------
# dashboard
# ---------------------------------------------------------------------------


def test_dashboard_renders_self_contained_html(tmp_path, monkeypatch):
    from repro.telemetry import dashboard as dash
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    append_bench(_report(study="large_cluster", density=30.0))
    append_bench(_report(study="large_cluster", density=31.0))
    ev = tmp_path / "benchmarks" / "artifacts" / "events"
    ev.mkdir(parents=True)
    with JsonlObserver(str(ev / "burst-storm_8_jiagu.jsonl"),
                       meta={"manifest": {"scheduler":
                                          {"name": "jiagu"}}}) as obs:
        obs._write({"event": "tick", "now": 0.0, "nodes": 4,
                    "instances": 80, "density": 20.0})
        obs._write({"event": "schedule", "now": 1.0, "fn": "f",
                    "placed": 2,
                    "trace": {"filtered": {"no-capacity": 3}}})
        obs._write({"event": "span", "name": "schedule", "seq": 0,
                    "depth": 0, "ms": 1.5})
    out = tmp_path / "dash.html"
    assert dash.main(["--out", str(out)]) == 0
    html = out.read_text()
    assert html.startswith("<!DOCTYPE html>")
    assert "<svg" in html and "large_cluster" in html
    assert "no-capacity" in html            # reason breakdown rendered
    assert "jiagu" in html
    assert "http" not in html.split("</style>")[1]  # no external assets
    # single self-contained file: nothing else was written next to it
    assert [p.name for p in out.parent.glob("dash*")] == ["dash.html"]


def test_dashboard_renders_empty_state(tmp_path):
    from repro.telemetry.dashboard import render
    html = render(root=str(tmp_path), events_dir=str(tmp_path))
    assert "no BENCH_" in html


# ---------------------------------------------------------------------------
# benchmark drivers persist reports only on the bench path
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_capacity_engine_bench_flag_persists_report(tmp_path,
                                                    monkeypatch):
    from benchmarks import capacity_engine
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    monkeypatch.setattr(capacity_engine, "save_artifact",
                        lambda *a, **k: None)
    # library call: repo root stays clean
    rows = capacity_engine.run(quick=True, bench=False)
    assert rows and not os.path.exists(
        str(tmp_path / "BENCH_capacity_engine.json"))
    # bench call: report lands in the trajectory and gates clean
    capacity_engine.run(quick=True, bench=True)
    data = load_bench("capacity_engine")
    assert data is not None
    assert data["runs"][-1]["rows"][0]["tables_equal"] is True
    deltas = gate_study("capacity_engine")
    assert deltas and not [d for d in deltas if d.status == "FAIL"]
