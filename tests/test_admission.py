"""Admission subsystem (``repro.admission``): queue conservation,
vertical-resize capacity safety, SLO-class accounting, and the
admission-off bit-parity gates.

Tier-1 gates for the admission axis:

  * **Queue conservation** — every request that ever arrived is exactly
    one of {released, dropped, still pending}, under randomized
    admit/release/drop sequences (property test) and end to end through
    a full platform run.
  * **Vertical capacity safety** — a shrink is only ever applied on a
    node whose live packing sits within its predicted-QoS capacity
    (checked at resize time via the same capacity-table lookup the
    resizer gates on).
  * **Admission-off bit-parity** — a ``PlatformConfig`` with
    ``admission.enabled=False`` builds the exact pre-admission control
    plane: every deterministic counter matches a config with no
    admission section at all, and the admission code is structurally
    absent (``Simulation.admission is None``).
  * **cells=1 parity with admission on** — the single-cell event core
    drives the per-cell controller identically to the legacy loop:
    class counters, queue totals and density match bit-for-bit.
  * **Trace schema v3** — DecisionTraces carry queue depth/age and SLO
    class; v2 records (no admission fields) stay readable by the
    policy dataset parser.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.admission import (ADMIT_STAGES, RELEASE_STAGES, BEST_EFFORT,
                             LATENCY_CRITICAL, AdmissionConfig,
                             AdmissionController, BoundedFifoAdmit,
                             FunctionQueue, GreedyQueueRelease,
                             PacedQueueRelease, ShedOldestAdmit,
                             VerticalScaler, delay_budget_s,
                             tag_slo_classes)
from repro.core import make_scenario, scenario_simulation, scenario_world
from repro.core.cells import cell_scenario_simulation
from repro.core.events import Observer
from repro.core.pipeline import (CANDIDATE_FEATURES, DecisionTrace,
                                 TRACE_SCHEMA_VERSION)
from repro.platform import Platform, PlatformConfig, PlatformConfigError
from repro.policy import load_traces

_EPS = 1e-6


# ---------------------------------------------------------------------------
# Queue conservation + backpressure (property tests)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 400)),
                    min_size=1, max_size=60),
       cap=st.integers(10, 500))
def test_queue_conservation_random_ops(ops, cap):
    """arrived == released + dropped + depth under any interleaving of
    push / pop / drop_newest / drop_oldest, and depth never negative."""
    q = FunctionQueue("fn", float(cap))
    for i, (op, amount) in enumerate(ops):
        amt = amount / 7.0          # fractional request mass
        if op == 0:
            q.push(float(i), amt)
        elif op == 1:
            buckets = q.pop(amt)
            assert all(c >= 0.0 for _t, c in buckets)
            # FIFO: released buckets come oldest-first
            times = [t for t, _c in buckets]
            assert times == sorted(times)
        elif op == 2:
            q.drop_newest(amt)
        else:
            q.drop_oldest(amt)
        assert q.depth >= -_EPS
        assert q.conservation_error() < _EPS


@settings(max_examples=30, deadline=None)
@given(arrivals=st.lists(st.integers(0, 300), min_size=5, max_size=40),
       cap_s=st.integers(1, 8), rate=st.integers(5, 120),
       admit_i=st.integers(0, 1), release_i=st.integers(0, 1))
def test_backpressure_bounded_storm(arrivals, cap_s, rate, admit_i,
                                    release_i):
    """A burst storm through any admit/release stage pair: the queue
    never exceeds its bound, per-tick releases never exceed the service
    rate, released delays are non-negative, and conservation holds."""
    admit = (BoundedFifoAdmit(), ShedOldestAdmit())[admit_i]
    release = (GreedyQueueRelease(), PacedQueueRelease())[release_i]
    cap = float(cap_s * rate)
    q = FunctionQueue("fn", cap)
    for t, arr in enumerate(arrivals):
        now = float(t)
        accepted, dropped = admit.admit(q, float(arr), now)
        assert dropped >= 0.0
        if admit_i == 0:
            # bounded-fifo rejects at the door: overflow never enters
            assert accepted + dropped == pytest.approx(float(arr))
        else:
            # shed-oldest admits everything; drops come from backlog
            assert accepted == pytest.approx(float(arr))
        assert q.depth <= cap + _EPS
        buckets = release.release(q, float(rate), now)
        got = sum(c for _t, c in buckets)
        assert got <= rate + _EPS
        assert all(now - t0 >= -_EPS for t0, _c in buckets)
        assert q.conservation_error() < _EPS
    # total backlog is bounded by the cap at every point, so the queue
    # really applied backpressure instead of absorbing the whole storm
    assert q.depth <= cap + _EPS
    assert q.arrived == pytest.approx(
        q.released + q.dropped + q.depth)


# ---------------------------------------------------------------------------
# SLO tagging + budgets
# ---------------------------------------------------------------------------


def test_slo_tagging_deterministic_and_stable():
    fns = [f"fn{i:02d}" for i in range(40)]
    tags = tag_slo_classes(fns, 0.5, seed=0)
    assert tags == tag_slo_classes(fns, 0.5, seed=0)
    assert set(tags.values()) == {LATENCY_CRITICAL, BEST_EFFORT}
    # population growth never re-tags existing functions
    grown = tag_slo_classes(fns + ["fn99"], 0.5, seed=0)
    assert all(grown[fn] == tags[fn] for fn in fns)
    # fraction extremes
    assert set(tag_slo_classes(fns, 0.0).values()) == {LATENCY_CRITICAL}
    assert set(tag_slo_classes(fns, 1.0).values()) == {BEST_EFFORT}
    # a different seed draws a different partition
    assert tag_slo_classes(fns, 0.5, seed=1) != tags


def test_delay_budget_per_class():
    assert delay_budget_s(LATENCY_CRITICAL, 0.25, 8.0) == 0.25
    assert delay_budget_s(BEST_EFFORT, 0.25, 8.0) == 8.0
    # unknown class falls back to the strict budget
    assert delay_budget_s(None, 0.25, 8.0) == 0.25


# ---------------------------------------------------------------------------
# Platform wiring + config validation
# ---------------------------------------------------------------------------

_SCENARIO = {"kind": "burst-storm", "n_functions": 8, "duration_s": 80,
             "target_nodes": 12, "seed": 5}


def _platform_cfg(admission=None):
    cfg = {"scenario": dict(_SCENARIO),
           "scheduler": {"name": "harvesting"}}
    if admission is not None:
        cfg["admission"] = admission
    return cfg


def test_admission_section_roundtrip_and_registry():
    cfg = PlatformConfig.from_dict(_platform_cfg(
        {"enabled": True, "vertical": True, "signal": "queue",
         "best_effort_frac": 0.25, "admit": "shed-oldest",
         "queue_release": "paced"}))
    assert cfg.admission.enabled and cfg.admission.vertical
    assert cfg.admission.best_effort_frac == 0.25
    # the admission stages live in the platform stage registry
    assert set(ADMIT_STAGES) == {"bounded-fifo", "shed-oldest"}
    assert set(RELEASE_STAGES) == {"greedy", "paced"}


@pytest.mark.parametrize("bad", [
    {"vertical": True},                          # vertical needs enabled
    {"enabled": True, "signal": "cpu"},          # unknown signal
    {"enabled": True, "best_effort_frac": 1.5},  # frac out of range
    {"enabled": True, "admit": "nope"},          # unregistered stage
    {"enabled": True, "queue_release": "nope"},
    {"enabled": True, "min_share": 0.0},         # share out of (0, 1]
    {"enabled": True, "target_drain_s": 0.0},
])
def test_admission_section_validation(bad):
    # unknown registry names surface as the registry's ValueError, the
    # consistency rules as PlatformConfigError (itself a ValueError)
    with pytest.raises(ValueError):
        PlatformConfig.from_dict(_platform_cfg(bad)).validate()


def test_unknown_stage_raises_in_controller():
    with pytest.raises(ValueError, match="unknown admission stage"):
        AdmissionController({}, AdmissionConfig(admit="nope"))


# ---------------------------------------------------------------------------
# Admission-off bit-parity
# ---------------------------------------------------------------------------


def _det(res) -> dict:
    """Deterministic counters (mirrors tests/test_cells.py plus the
    admission-axis fields)."""
    s, a = res.sched, res.scaling
    return {
        "requests": res.requests,
        "violated_requests": res.violated_requests,
        "instance_seconds": res.instance_seconds,
        "node_seconds": res.node_seconds,
        "nodes_peak": res.nodes_peak,
        "per_fn_requests": dict(res.per_fn_requests),
        "decisions": s.decisions, "placed": s.instances_placed,
        "fast": s.fast, "slow": s.slow, "failed": s.failed,
        "real_cold": a.real_cold_starts,
        "logical_cold": a.logical_cold_starts,
        "releases": a.releases, "evictions": a.evictions,
        "class_requests": dict(res.class_requests),
        "class_violations": dict(res.class_violations),
        "dropped": res.dropped_requests,
        "queue_depth_peak": res.queue_depth_peak,
        "vertical": (res.vertical_grows, res.vertical_shrinks),
    }


def test_disabled_section_is_bit_identical_to_no_section():
    """``admission.enabled=False`` must build the exact pre-admission
    control plane — structural absence, not a pass-through."""
    p1 = Platform.build(config=_platform_cfg())
    r1 = p1.run()
    p2 = Platform.build(config=_platform_cfg({"enabled": False}))
    assert p2.simulation.admission is None
    assert p2.autoscaler.admission is None
    r2 = p2.run()
    a, b = _det(r1), _det(r2)
    diverged = sorted(k for k in a if a[k] != b[k])
    assert not diverged, f"diverged on {diverged}"
    assert r1.density == r2.density
    assert r1.qos_violation_rate == r2.qos_violation_rate
    # no admission accounting leaked into the off-axis run
    assert not r2.class_requests and r2.queue_depth_peak == 0.0


def test_cells1_parity_with_admission_enabled():
    """The single-cell event core must drive the per-cell controller
    identically to the legacy run loop (enqueue before the autoscaler,
    drain before measurement) — bit-exact counters either way."""
    adm = AdmissionConfig(enabled=True, signal="queue")
    scenario = make_scenario("burst-storm", n_functions=6,
                             duration_s=80, target_nodes=16, seed=3)
    world = scenario_world(scenario, n_train=600, n_trees=8)
    world.gt.reseed()
    legacy = scenario_simulation(scenario, "harvesting", world=world,
                                 admission=adm).run()
    world.gt.reseed()
    cells = cell_scenario_simulation(scenario, "harvesting", n_cells=1,
                                     world=world, admission=adm).run()
    a, b = _det(legacy), _det(cells)
    diverged = sorted(k for k in a if a[k] != b[k])
    assert not diverged, f"diverged on {diverged}"
    # the admission axis was actually live in both runs
    assert legacy.class_requests


# ---------------------------------------------------------------------------
# End-to-end accounting + vertical capacity safety
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def admission_run():
    plat = Platform.build(config=_platform_cfg(
        {"enabled": True, "vertical": True, "signal": "queue",
         "target_drain_s": 1.0}))
    checker = _ShrinkSafetyObserver(plat)
    plat.add_observer(checker)
    res = plat.run()
    return plat, res, checker


class _ShrinkSafetyObserver(Observer):
    """At every vertical_shrink emission (synchronous with the resize
    pass, before any further mutation), re-check the resizer's gate:
    each node carrying a reduced share must pack within its
    predicted-QoS capacity per the same hint-then-table lookup."""

    def __init__(self, plat):
        self.plat = plat
        self.checked = 0
        self.violations = []

    def on_scale(self, now, fn, event, count):
        if event != "vertical_shrink":
            return
        svc = self.plat.scheduler.prediction_service
        for node in self.plat.cluster.nodes_with(fn):
            if fn not in node.shares:
                continue
            cap = svc.capacity_hint(svc.node_coloc(node), fn,
                                    node_res=node.res)
            if cap is None:
                entry = node.table.get(fn)
                cap = entry.capacity if entry is not None else None
            if cap is None:
                continue    # table entry expired since the resize
            self.checked += 1
            total = node.funcs[fn].total
            if total > cap:
                self.violations.append((now, fn, node.id, total, cap))


def test_vertical_shrinks_respect_capacity_table(admission_run):
    plat, res, checker = admission_run
    assert res.vertical_shrinks > 0, "no vertical activity to check"
    assert checker.checked > 0
    assert not checker.violations, checker.violations[:5]
    # shrunk shares are real reservations in (0, 1)
    shares = [s for node in plat.cluster.nodes.values()
              for s in node.shares.values()]
    assert shares and all(0.0 < s < 1.0 for s in shares)
    # and they raise per-function harvest bounds, never past bound_cap
    bounds = plat.scheduler.harvest_bounds
    assert bounds
    assert all(plat.scheduler.harvest_headroom <= b <= 0.98
               for b in bounds.values())


def test_per_class_accounting_conserves(admission_run):
    plat, res, checker = admission_run
    adm = plat.simulation.admission
    assert adm.conservation_error() < _EPS
    # every request is accounted to exactly one class
    assert set(res.class_requests) <= {LATENCY_CRITICAL, BEST_EFFORT}
    assert sum(res.class_requests.values()) == \
        pytest.approx(res.requests, rel=1e-6)
    for cls, viol in res.class_violations.items():
        assert 0.0 <= viol <= res.class_requests[cls] + _EPS
    # queue totals reconcile with the SimResult drops
    totals = adm.totals()
    assert totals["dropped"] == pytest.approx(res.dropped_requests)
    assert res.queue_depth_peak >= totals["depth"] - _EPS


def test_vertical_scaler_class_policy():
    """Unit policy checks: best-effort shrinks to the floor and packs
    to bound_cap; latency-critical keeps the guard both ways; queue
    pressure forces full reservation."""
    specs = {"be": None, "lc": None}
    slo = {"be": BEST_EFFORT, "lc": LATENCY_CRITICAL}
    v = VerticalScaler(specs, slo, min_share=0.5)
    assert v.target_share("be", queue_depth=5.0) == 1.0
    assert v.target_share("be", queue_depth=0.0) == 0.5
    assert v.target_share("lc", queue_depth=0.0) == \
        pytest.approx(0.5 + v.lc_guard)
    v.share = {"be": 0.5, "lc": 0.65}
    hb = v.harvest_bound("be", headroom=0.85)
    assert hb == pytest.approx(0.98)            # min(cap, .85/.5)
    # latency-critical cap keeps lc_guard below bound_cap but never
    # drops under the scheduler's global headroom
    hl = v.harvest_bound("lc", headroom=0.85)
    assert hl == pytest.approx(max(0.85, 0.98 - v.lc_guard))
    hl_low = v.harvest_bound("lc", headroom=0.5)
    assert hl_low == pytest.approx(0.5 / 0.65)  # min(0.83, .5/.65)
    assert v.harvest_bound("untouched", headroom=0.85) is None


# ---------------------------------------------------------------------------
# DecisionTrace schema v3 (+ v2 stays readable)
# ---------------------------------------------------------------------------


def _schedule_rec(trace_dict, now):
    return {"event": "schedule", "now": now, "fn": trace_dict["fn"],
            "placed": 1, "trace": trace_dict}


def test_trace_v3_fields_and_v2_readable():
    assert TRACE_SCHEMA_VERSION == 3
    nf = len(CANDIDATE_FEATURES)
    v3 = DecisionTrace(scheduler="jiagu-pipeline", fn="fn00", now=1.0,
                       requested=1)
    v3.candidates = [(0, [0.1] * nf), (1, [0.2] * nf)]
    v3.chosen_node = 1
    v3.queue_depth = 7.5
    v3.queue_age_s = 0.4
    v3.slo_class = BEST_EFFORT
    d3 = v3.summary()
    assert d3["schema_version"] == 3
    assert d3["queue_depth"] == 7.5
    assert d3["slo_class"] == BEST_EFFORT
    # admission off -> the v3 keys stay absent (v2-shaped record)
    off = DecisionTrace(scheduler="jiagu-pipeline", fn="fn01", now=2.0,
                        requested=1)
    off.candidates = [(0, [0.3] * nf)]
    off.chosen_node = 0
    assert "queue_depth" not in off.summary()
    # a stored v2 record (pre-admission artifact) and the v3 records
    # all parse into training rows; only versionless (v1) is skipped
    v2 = {"schema_version": 2, "now": 3.0, "fn": "fn02",
          "requested": 1, "candidates": [[0, [0.4] * nf]],
          "chosen_node": 0}
    v1 = {"now": 4.0, "fn": "fn03", "candidates": [[0, [0.5] * nf]],
          "chosen_node": 0}
    ds = load_traces([_schedule_rec(d3, 1.0),
                      _schedule_rec(off.summary(), 2.0),
                      _schedule_rec(v2, 3.0),
                      _schedule_rec(v1, 4.0)])
    assert len(ds.decisions) == 3
    assert ds.skipped_versionless == 1
    assert [d.fn for d in ds.decisions] == ["fn00", "fn01", "fn02"]


def test_autoscaler_stamps_traces_with_admission_context():
    """A pipeline-scheduler run with admission on emits v3 traces whose
    slo_class is populated (queue context rides every decision)."""
    cfg = {"scenario": dict(_SCENARIO),
           "scheduler": {"name": "jiagu-pipeline"},
           "admission": {"enabled": True, "signal": "queue"}}
    seen = []

    class Collect(Observer):
        def on_schedule(self, now, fn, placements, trace=None):
            if trace is not None:
                seen.append(trace)

    plat = Platform.build(config=cfg, observers=[Collect()])
    plat.run()
    assert seen
    assert all(t.slo_class in (LATENCY_CRITICAL, BEST_EFFORT)
               for t in seen)
    assert all(t.queue_depth >= 0.0 and t.queue_age_s >= 0.0
               for t in seen)
