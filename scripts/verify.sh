#!/usr/bin/env bash
# Tier-1 verification: the exact command the roadmap pins.
#   scripts/verify.sh            full suite
#   scripts/verify.sh tests/...  any extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
