#!/usr/bin/env bash
# Tier-1 verification: the exact command the roadmap pins.
#   scripts/verify.sh            full suite + platform smoke
#   scripts/verify.sh tests/...  any extra pytest args pass through
#   scripts/verify.sh --full     tier-1 + slow-marked tests + the quick
#                                large-cluster scenario benchmark (the
#                                engine-default A/B gate end to end) +
#                                the 256-node online-retraining / schema
#                                v1-vs-v2 gate
# The platform smoke step builds every registered scheduler — the four
# legacy ones, their pipeline-stack re-expressions, and the harvesting
# scheduler — against one scenario from pure PlatformConfig manifest
# dicts, runs 30 ticks each, and gates harvesting's QoS violation rate
# against the K8s baseline (python -m repro.platform).  The pipeline
# placement-parity gate runs in tier-1 (tests/test_pipeline.py) and at
# 256 nodes inside the quick large-cluster benchmark (--full).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [ "${1:-}" = "--full" ]; then
    shift
    RUN_SLOW=1 python -m pytest -x -q "$@"
    python -m repro.platform
    python -m benchmarks.large_cluster --quick
    python -m benchmarks.large_cluster --retrain-online --quick
    exit 0
fi
python -m pytest -x -q "$@"
python -m repro.platform
