#!/usr/bin/env bash
# Tier-1 verification: the exact command the roadmap pins.
#   scripts/verify.sh            full suite + platform smoke
#   scripts/verify.sh tests/...  any extra pytest args pass through
#   scripts/verify.sh --bench    benchmark regression gate only: run the
#                                quick large-cluster + capacity-engine
#                                studies (persisting RunReports into the
#                                repo-root BENCH_*.json trajectories;
#                                capacity-engine extends to 4096 nodes
#                                through the device-resident fused
#                                drain), then diff the fresh runs
#                                against the checked-in baselines
#                                (python -m repro.telemetry.gate; exits
#                                non-zero with a delta table on any
#                                density/QoS/latency regression, on a
#                                numpy-vs-device capacity-table parity
#                                break, or when the device per-solve-
#                                latency-vs-nodes log-log slope exceeds
#                                the baseline + slope tolerance), and
#                                render the self-contained HTML
#                                dashboard from the trajectories + the
#                                runs' JSONL event streams
#   scripts/verify.sh --full     tier-1 + slow-marked tests + the quick
#                                large-cluster scenario benchmark (the
#                                engine-default A/B gate end to end) +
#                                the 256-node online-retraining / schema
#                                v1-vs-v2 gate + the --bench regression
#                                gate
#   scripts/verify.sh --scale    sharded-control-plane smoke: one
#                                1k-node azure-sparse study through the
#                                cell-sharded event core (cells=4) plus
#                                the cells=1 bit-parity gate, no
#                                trajectory write
#                                (python -m benchmarks.scaling --smoke)
#   scripts/verify.sh --policy   learned-scheduler smoke: collect
#                                DecisionTraces, train the MLP scorer,
#                                serve it through the "learned" stack
#                                and gate QoS/density against K8s with
#                                zero stale-epoch serves, seconds-scale
#                                phases, no trajectory write
#                                (python -m benchmarks.policy --smoke)
#   scripts/verify.sh --admission  admission smoke: vertical-queue vs
#                                horizontal-only arms on one 24-node
#                                burst-storm seed, queue-conservation
#                                and per-class accounting gates, no
#                                trajectory write
#                                (python -m benchmarks.admission --smoke)
# The platform smoke step builds every registered scheduler — the four
# legacy ones, their pipeline-stack re-expressions, and the harvesting
# scheduler — against one scenario from pure PlatformConfig manifest
# dicts, runs 30 ticks each, and gates harvesting's QoS violation rate
# against the K8s baseline (python -m repro.platform).  The pipeline
# placement-parity gate runs in tier-1 (tests/test_pipeline.py) and at
# 256 nodes inside the quick large-cluster benchmark (--full).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

run_bench_gate() {
    # quick studies append fresh RunReports to the BENCH trajectories...
    python -m benchmarks.large_cluster --quick
    python -m benchmarks.capacity_engine --quick
    python -m benchmarks.scaling --quick
    python -m benchmarks.policy --quick
    python -m benchmarks.admission --quick
    # ...the gate diffs the fresh runs against the checked-in baselines
    # (hard-fails on density/QoS regressions; generous slack on the
    # wall-clock latency percentiles)...
    python -m repro.telemetry.gate
    # ...and the dashboard renders the trajectories + event streams
    python -m repro.telemetry.dashboard
}

if [ "${1:-}" = "--bench" ]; then
    shift
    run_bench_gate
    exit 0
fi
if [ "${1:-}" = "--scale" ]; then
    shift
    python -m benchmarks.scaling --smoke
    exit 0
fi
if [ "${1:-}" = "--policy" ]; then
    shift
    python -m benchmarks.policy --smoke
    exit 0
fi
if [ "${1:-}" = "--admission" ]; then
    shift
    python -m benchmarks.admission --smoke
    exit 0
fi
if [ "${1:-}" = "--full" ]; then
    shift
    RUN_SLOW=1 python -m pytest -x -q "$@"
    python -m repro.platform
    python -m benchmarks.large_cluster --retrain-online --quick
    run_bench_gate
    exit 0
fi
python -m pytest -x -q "$@"
python -m repro.platform
