"""repro.telemetry — unified observability for the control plane.

One subsystem, four layers:

* :mod:`~repro.telemetry.metrics` — typed metric instruments
  (``Counter`` / ``Gauge`` / ``Histogram`` on ``core.metrics.Reservoir``)
  in a ``MetricsRegistry``, fed by ``MetricsObserver`` through the
  ``EventHub`` and by ``publish_result`` at end-of-run;
* :mod:`~repro.telemetry.spans` — span-based control-plane tracing
  (``span("schedule")``, ``span("retrain")``, ``span("capacity_solve")``)
  with wall-clock + counter deltas, emitted through ``on_span`` into the
  same JSONL streams as ``DecisionTrace``; ``NULL_TRACER`` keeps
  uninstrumented runs free;
* :mod:`~repro.telemetry.report` — the schema-versioned ``RunReport``
  persisted as a ``BENCH_<study>.json`` trajectory (baseline + runs);
* :mod:`~repro.telemetry.gate` / :mod:`~repro.telemetry.dashboard` —
  the regression gate ``scripts/verify.sh --bench`` runs, and the
  self-contained HTML dashboard (``python -m repro.telemetry.dashboard``).

``Telemetry.create()`` bundles a registry + observer + tracer for
``Platform.build`` to wire in one call.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from .metrics import (Counter, Gauge, Histogram, MetricsObserver,
                      MetricsRegistry, publish_result)
from .report import (BENCH_SCHEMA, REPORT_SCHEMA, RunReport, append_bench,
                     bench_path, load_bench, manifest_hash,
                     promote_baseline, repo_root)
from .spans import NULL_TRACER, Span, SpanTracer

#: gate exports resolve lazily (PEP 562) so ``python -m
#: repro.telemetry.gate`` doesn't re-execute an already-imported module
#: (runpy's double-import warning)
_GATE_EXPORTS = ("DEFAULT_STUDIES", "Delta", "Tolerances",
                 "compare_reports", "gate_study", "print_delta_table")


def __getattr__(name: str):
    if name in _GATE_EXPORTS:
        from . import gate
        return getattr(gate, name)
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")


@dataclass
class Telemetry:
    """The bundle ``Platform.build`` attaches when telemetry is on:
    one registry, the observer feeding it, and the span tracer the
    simulator / prediction service publish through."""

    registry: MetricsRegistry = field(default_factory=MetricsRegistry)
    observer: Optional[MetricsObserver] = None
    tracer: Any = NULL_TRACER

    @classmethod
    def create(cls, metrics: bool = True, spans: bool = True,
               emit=None) -> "Telemetry":
        registry = MetricsRegistry()
        observer = MetricsObserver(registry) if metrics else None
        tracer = SpanTracer(emit=emit) if spans else NULL_TRACER
        return cls(registry=registry, observer=observer, tracer=tracer)

    def snapshot(self, bins: int = 0) -> Dict[str, Dict[str, Any]]:
        return self.registry.snapshot(bins)

    def span_summary(self) -> List[Dict[str, Any]]:
        return self.tracer.summary()


__all__ = [
    "Telemetry",
    # metrics
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "MetricsObserver", "publish_result",
    # spans
    "Span", "SpanTracer", "NULL_TRACER",
    # reports / trajectories
    "RunReport", "REPORT_SCHEMA", "BENCH_SCHEMA", "append_bench",
    "load_bench", "bench_path", "promote_baseline", "manifest_hash",
    "repo_root",
    # gate
    "Tolerances", "Delta", "compare_reports", "gate_study",
    "print_delta_table", "DEFAULT_STUDIES",
]
