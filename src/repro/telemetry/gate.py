"""Benchmark regression gate: diff a fresh ``RunReport`` against the
checked-in ``BENCH_<study>.json`` baseline.

    PYTHONPATH=src python -m repro.telemetry.gate [--study S ...]
        [--density-tol 0.05] [--qos-tol 0.02] [--latency-tol 3.0]
        [--promote S]

For every study the gate matches the latest recorded run against the
study's ``baseline`` entry row-by-row (rows are keyed by their sweep
coordinates — (scenario, target_nodes, system) for the large-cluster
study, (nodes,) for the capacity-engine scaling study) and applies
per-metric rules:

  * **density** — hard-fails when a fresh row's density drops more than
    ``density_tol`` (relative) below baseline: the deployment-density
    win is the paper's headline and must not silently erode.
  * **QoS violation rate** — hard-fails when fresh exceeds baseline by
    more than ``qos_tol`` (absolute).  QoS regressions are never
    tolerable noise: an overcommitting scheduler that breaks its <10%
    bar is wrong, not slow.
  * **latency percentiles** (cold-start / sched-cost p50/p99) — these
    carry real wall-clock components (forest inference time), so the
    slack is generous (``latency_tol`` relative, warn-first); they
    hard-fail only past the slack.
  * **deterministic counters** (engine calls/rows, tables_equal) —
    seeded runs make these reproducible; ``tables_equal`` flipping to
    False is a hard parity failure, call-count growth past
    ``counter_tol`` fails the capacity-engine study (the batching win
    regressed).

Exit status 0 = pass (warnings allowed), 1 = regression (the delta
table names every offending row).  ``--promote`` copies the latest run
over the baseline — run it only after reviewing an accepted change.
"""
from __future__ import annotations

import argparse
import os
import sys
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .report import (bench_path, load_bench, promote_baseline,
                     repo_root)

#: the studies verify.sh --bench gates by default
DEFAULT_STUDIES = ("large_cluster", "capacity_engine", "scaling",
                   "policy", "admission")


@dataclass
class Tolerances:
    density: float = 0.05     # relative density drop allowed
    qos: float = 0.02         # absolute QoS violation-rate increase
    latency: float = 3.0      # relative latency slack (wall-clock noise)
    counters: float = 0.25    # relative growth of deterministic counters
    slope: float = 0.3        # absolute slack on scaling-law exponents

    @classmethod
    def from_env(cls) -> "Tolerances":
        def f(name, default):
            return float(os.environ.get(name, default))
        return cls(density=f("REPRO_GATE_DENSITY_TOL", cls.density),
                   qos=f("REPRO_GATE_QOS_TOL", cls.qos),
                   latency=f("REPRO_GATE_LATENCY_TOL", cls.latency),
                   counters=f("REPRO_GATE_COUNTER_TOL", cls.counters),
                   slope=f("REPRO_GATE_SLOPE_TOL", cls.slope))


@dataclass
class Delta:
    study: str
    row: str
    metric: str
    base: Any
    fresh: Any
    status: str               # "ok" | "warn" | "FAIL"
    note: str = ""

    def table_row(self) -> Tuple[str, ...]:
        def fmt(v):
            if isinstance(v, float):
                return f"{v:.4g}"
            return str(v)
        return (self.study, self.row, self.metric, fmt(self.base),
                fmt(self.fresh), self.status, self.note)


@dataclass
class Rule:
    metric: str
    #: "min" — fresh must stay >= base*(1-tol); "max" — fresh must stay
    #: <= base*(1+tol); "max_abs" — fresh <= base + tol; "eq" — exact
    direction: str
    tol_name: Optional[str]   # Tolerances field, None for "eq"
    hard: bool = True


@dataclass
class StudyRules:
    key: Tuple[str, ...]
    rules: List[Rule] = field(default_factory=list)
    #: rules applied to the report-level ``metrics`` dict (scaling-law
    #: exponents, whole-sweep aggregates) rather than per-row values
    metric_rules: List[Rule] = field(default_factory=list)


STUDY_RULES: Dict[str, StudyRules] = {
    "large_cluster": StudyRules(
        key=("scenario", "target_nodes", "system"),
        rules=[Rule("density", "min", "density", hard=True),
               Rule("qos_violation", "max_abs", "qos", hard=True),
               Rule("cold_ms_p50", "max", "latency", hard=True),
               Rule("cold_ms_p99", "max", "latency", hard=True),
               Rule("sched_ms_p50", "max", "latency", hard=False),
               Rule("sched_ms_p99", "max", "latency", hard=True)]),
    "capacity_engine": StudyRules(
        key=("nodes",),
        rules=[Rule("tables_equal", "eq", None, hard=True),
               Rule("engine_calls", "max", "counters", hard=True),
               Rule("engine_rows", "max", "counters", hard=False),
               Rule("unique_solves", "max", "counters", hard=False),
               Rule("device_us_per_solve", "max", "latency", hard=False),
               Rule("device_calls", "max", "counters", hard=False)],
        # the device drain's headline: per-solve latency must stay flat
        # as the cluster grows (log-log slope ~<= 0), and the numpy-vs-
        # device capacity tables must stay bit-identical at every size
        metric_rules=[Rule("device_per_solve_slope", "max_abs", "slope",
                           hard=True),
                      Rule("tables_equal_all", "eq", None, hard=True)]),
    "scaling": StudyRules(
        key=("target_nodes",),
        rules=[Rule("density", "min", "density", hard=True),
               Rule("qos_violation", "max_abs", "qos", hard=True),
               Rule("wall_ms_per_node", "max", "latency", hard=False)],
        # the event core's headline: per-node wall-clock must stay
        # sub-linear in fleet size, and the single-cell event loop must
        # keep reproducing the legacy Simulation bit-for-bit
        metric_rules=[Rule("wallclock_per_node_slope", "max_abs",
                           "slope", hard=True),
                      Rule("cells_parity", "eq", None, hard=True)]),
    "policy": StudyRules(
        key=("system",),
        rules=[Rule("density", "min", "density", hard=True),
               Rule("qos_violation", "max_abs", "qos", hard=True),
               Rule("stale_serves", "eq", None, hard=True)],
        # the learned stack's headline: the scorer must keep imitating
        # the traced jiagu decisions (holdout top-1 agreement), its QoS
        # may not drift past the no-overcommit K8s baseline by more
        # than the absolute QoS tolerance, and the consolidation win
        # over K8s must not erode
        metric_rules=[Rule("imitation_agreement", "min", "qos",
                           hard=True),
                      Rule("learned_qos_excess", "max_abs", "qos",
                           hard=True),
                      Rule("learned_density_ratio", "min", "density",
                           hard=True),
                      Rule("stale_serves", "eq", None, hard=True)]),
    "admission": StudyRules(
        key=("system", "seed"),
        rules=[Rule("density", "min", "density", hard=True),
               Rule("qos_violation", "max_abs", "qos", hard=True),
               Rule("lc_violation", "max_abs", "qos", hard=False)],
        # the admission study's headline: the vertical-queue arm's
        # seed-mean density win over horizontal-only must not erode
        # (warn-first — per-seed deltas are noisy, the in-run
        # RuntimeError gate enforces win > 0 on every bench run), the
        # latency-critical violation excess may not drift past the
        # absolute QoS tolerance, and queue conservation must stay at
        # float-eps
        metric_rules=[Rule("density_win", "min", "density",
                           hard=False),
                      Rule("lc_excess", "max_abs", "qos", hard=True),
                      Rule("queue_delay_p99", "max", "latency",
                           hard=False),
                      Rule("conservation", "max_abs", "qos",
                           hard=True)]),
}
#: fallback for studies without registered rules: gate the headline
#: metrics if the rows carry them
_GENERIC = StudyRules(
    key=(), rules=[Rule("density", "min", "density", hard=True),
                   Rule("qos_violation", "max_abs", "qos", hard=True)])


def _row_key(row: Dict[str, Any], key: Tuple[str, ...]) -> str:
    if not key:
        return "-"
    return "/".join(str(row.get(k, "?")) for k in key)


def _apply_rule(study: str, row_name: str, rule: Rule, base_v, fresh_v,
                tol: Tolerances) -> Optional[Delta]:
    if base_v is None or fresh_v is None or base_v == "" or fresh_v == "":
        return None
    t = getattr(tol, rule.tol_name) if rule.tol_name else 0.0
    ok = True
    note = ""
    if rule.direction == "eq":
        ok = base_v == fresh_v
        note = "must match baseline" if not ok else ""
    elif rule.direction == "min":
        floor = base_v * (1.0 - t)
        ok = fresh_v >= floor
        if not ok:
            note = f"below {floor:.4g} (-{t:.0%} floor)"
    elif rule.direction == "max":
        ceil = base_v * (1.0 + t)
        ok = fresh_v <= ceil
        if not ok:
            note = f"above {ceil:.4g} (+{t:.0%} ceiling)"
    elif rule.direction == "max_abs":
        ceil = base_v + t
        ok = fresh_v <= ceil
        if not ok:
            note = f"above {ceil:.4g} (+{t} absolute)"
    else:                                              # pragma: no cover
        raise ValueError(f"unknown rule direction {rule.direction!r}")
    status = "ok" if ok else ("FAIL" if rule.hard else "warn")
    return Delta(study, row_name, rule.metric, base_v, fresh_v, status,
                 note)


def compare_reports(baseline: Dict[str, Any], fresh: Dict[str, Any],
                    tol: Optional[Tolerances] = None) -> List[Delta]:
    """Row-matched, rule-driven diff of two RunReport dicts.  Returns
    every evaluated delta; callers decide on ``status == "FAIL"``."""
    tol = tol or Tolerances()
    study = fresh.get("study", baseline.get("study", "?"))
    deltas: List[Delta] = []
    if baseline.get("mode") != fresh.get("mode"):
        deltas.append(Delta(
            study, "-", "mode", baseline.get("mode"), fresh.get("mode"),
            "FAIL", "baseline and fresh run modes differ — re-baseline"))
        return deltas
    if baseline.get("config_hash") != fresh.get("config_hash"):
        deltas.append(Delta(
            study, "-", "config_hash", baseline.get("config_hash"),
            fresh.get("config_hash"), "warn",
            "manifest changed since baseline (promote after review)"))
    spec = STUDY_RULES.get(study, _GENERIC)
    base_rows = {_row_key(r, spec.key): r
                 for r in baseline.get("rows", [])}
    fresh_rows = {_row_key(r, spec.key): r
                  for r in fresh.get("rows", [])}
    for name, brow in base_rows.items():
        frow = fresh_rows.get(name)
        if frow is None:
            deltas.append(Delta(study, name, "-", "present", "missing",
                                "FAIL", "row vanished from the sweep"))
            continue
        for rule in spec.rules:
            d = _apply_rule(study, name, rule, brow.get(rule.metric),
                            frow.get(rule.metric), tol)
            if d is not None:
                deltas.append(d)
    for name in fresh_rows:
        if name not in base_rows:
            deltas.append(Delta(study, name, "-", "missing", "present",
                                "ok", "new row (not in baseline)"))
    bmet = baseline.get("metrics") or {}
    fmet = fresh.get("metrics") or {}
    for rule in spec.metric_rules:
        d = _apply_rule(study, "metrics", rule, bmet.get(rule.metric),
                        fmet.get(rule.metric), tol)
        if d is not None:
            deltas.append(d)
    return deltas


def gate_study(study: str, tol: Optional[Tolerances] = None,
               root: Optional[str] = None) -> List[Delta]:
    """Gate one study's latest recorded run against its baseline."""
    data = load_bench(study, root)
    if data is None:
        return [Delta(study, "-", "-", "baseline", "missing", "FAIL",
                      f"no {os.path.basename(bench_path(study, root))} "
                      f"(run the benchmark, then commit the baseline)")]
    if not data.get("runs"):
        return [Delta(study, "-", "-", "runs", "empty", "FAIL",
                      "no recorded runs to gate")]
    return compare_reports(data["baseline"], data["runs"][-1], tol)


def print_delta_table(deltas: Sequence[Delta],
                      only_interesting: bool = True) -> None:
    """The human-readable delta table --bench prints on regression."""
    shown = [d for d in deltas
             if not only_interesting or d.status != "ok"]
    if not shown:
        print("# gate: all gated metrics within tolerance")
        return
    headers = ("study", "row", "metric", "baseline", "fresh", "status",
               "note")
    rows = [d.table_row() for d in shown]
    widths = [max(len(h), *(len(r[i]) for r in rows))
              for i, h in enumerate(headers)]
    line = "  ".join(h.ljust(w) for h, w in zip(headers, widths))
    print(line)
    print("  ".join("-" * w for w in widths))
    for r in rows:
        print("  ".join(c.ljust(w) for c, w in zip(r, widths)))


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="BENCH_*.json regression gate")
    ap.add_argument("--study", action="append", default=None,
                    help="study to gate (repeatable; default: "
                         f"{', '.join(DEFAULT_STUDIES)})")
    ap.add_argument("--root", default=None,
                    help="directory holding BENCH_*.json "
                         "(default: repo root / $REPRO_BENCH_DIR)")
    ap.add_argument("--density-tol", type=float, default=None)
    ap.add_argument("--qos-tol", type=float, default=None)
    ap.add_argument("--latency-tol", type=float, default=None)
    ap.add_argument("--counter-tol", type=float, default=None)
    ap.add_argument("--slope-tol", type=float, default=None)
    ap.add_argument("--promote", action="append", default=None,
                    metavar="STUDY",
                    help="promote STUDY's latest run to baseline and "
                         "exit (no gating)")
    ap.add_argument("--all", action="store_true",
                    help="print every evaluated delta, not just "
                         "warnings/failures")
    args = ap.parse_args(argv)

    if args.promote:
        for study in args.promote:
            promote_baseline(study, args.root)
            print(f"# gate: promoted latest {study} run to baseline "
                  f"({bench_path(study, args.root)})")
        return 0

    tol = Tolerances.from_env()
    for name in ("density", "qos", "latency", "counters", "slope"):
        cli = getattr(args, {"counters": "counter_tol"}.get(
            name, f"{name}_tol"))
        if cli is not None:
            setattr(tol, name, cli)

    studies = args.study or list(DEFAULT_STUDIES)
    deltas: List[Delta] = []
    for study in studies:
        deltas.extend(gate_study(study, tol, args.root))
    print(f"# gate: {len(studies)} studies "
          f"({', '.join(studies)}) @ {args.root or repo_root()}")
    print_delta_table(deltas, only_interesting=not args.all)
    failures = [d for d in deltas if d.status == "FAIL"]
    warns = [d for d in deltas if d.status == "warn"]
    print(f"# gate: {len(deltas)} deltas, {len(warns)} warnings, "
          f"{len(failures)} failures => "
          f"{'FAIL' if failures else 'PASS'}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
