"""``RunReport`` — one schema-versioned JSON record per benchmark run —
and the persisted ``BENCH_<study>.json`` trajectory files.

Jiagu's claims are quantitative (+54.8% density, 81–93.7% lower
scheduling cost, 57–69% less cold-start latency); before this module
the repo's own numbers lived in commit messages and vanished.  Every
benchmark driver now persists a ``RunReport`` into a versioned
``BENCH_<study>.json`` at the repo root:

    {"schema": "repro.telemetry/bench@1", "study": "large_cluster",
     "baseline": {<RunReport>},          # the accepted reference
     "runs": [{<RunReport>}, ...]}       # append-only trajectory

A ``RunReport`` carries the headline metrics (density, QoS violation
rate, cold-start p50/p99, sched-cost p50/p99, engine telemetry), the
per-configuration result rows, the git SHA, and a hash of the config
manifest that produced it — enough for ``repro.telemetry.gate`` to
decide whether a fresh run regressed and for the dashboard to render
the whole trajectory.

The trajectory is bounded (``max_runs``); the ``baseline`` entry only
moves when explicitly promoted (``gate --promote`` after an accepted
improvement), so the regression gate always compares against a
deliberately chosen reference, not merely the previous run.
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

REPORT_SCHEMA = "repro.telemetry/run-report@1"
BENCH_SCHEMA = "repro.telemetry/bench@1"
#: trajectory bound: plenty for a dashboard, never unbounded growth
MAX_RUNS_DEFAULT = 40

_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))


def repo_root() -> str:
    """The repo root BENCH files live in (``REPRO_BENCH_DIR``
    overrides, for tests and sandboxed runs)."""
    return os.environ.get("REPRO_BENCH_DIR", _REPO_ROOT)


def bench_path(study: str, root: Optional[str] = None) -> str:
    return os.path.join(root or repo_root(), f"BENCH_{study}.json")


def git_sha(short: bool = True) -> str:
    try:
        cmd = ["git", "rev-parse"] + (["--short"] if short else []) \
            + ["HEAD"]
        out = subprocess.run(
            cmd, cwd=_REPO_ROOT, capture_output=True, text=True,
            timeout=5)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def manifest_hash(manifest: Any) -> str:
    """Stable short hash of a JSON-able config manifest — two reports
    are comparable only if they ran the same configuration."""
    blob = json.dumps(manifest, sort_keys=True, separators=(",", ":"),
                      default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


@dataclass
class RunReport:
    """One benchmark run, ready to persist/gate/render."""

    study: str
    mode: str = "quick"                  # quick | full
    schema: str = REPORT_SCHEMA
    created_utc: str = ""
    git_sha: str = ""
    config_hash: str = ""
    #: headline scalars (density, qos, latency percentiles, engine
    #: telemetry) — typically a MetricsRegistry.snapshot() or a curated
    #: summary dict
    metrics: Dict[str, Any] = field(default_factory=dict)
    #: per-configuration result rows (one per scenario/size/system)
    rows: List[Dict[str, Any]] = field(default_factory=list)
    meta: Dict[str, Any] = field(default_factory=dict)

    @classmethod
    def build(cls, study: str, mode: str, manifest: Any = None,
              metrics: Optional[Dict[str, Any]] = None,
              rows: Optional[List[Dict[str, Any]]] = None,
              meta: Optional[Dict[str, Any]] = None) -> "RunReport":
        return cls(study=study, mode=mode,
                   created_utc=time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                             time.gmtime()),
                   git_sha=git_sha(),
                   config_hash=manifest_hash(manifest or {}),
                   metrics=dict(metrics or {}),
                   rows=[dict(r) for r in (rows or [])],
                   meta=dict(meta or {}))

    def to_dict(self) -> Dict[str, Any]:
        return {"schema": self.schema, "study": self.study,
                "mode": self.mode, "created_utc": self.created_utc,
                "git_sha": self.git_sha, "config_hash": self.config_hash,
                "metrics": self.metrics, "rows": self.rows,
                "meta": self.meta}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RunReport":
        if d.get("schema") != REPORT_SCHEMA:
            raise ValueError(
                f"unknown run-report schema {d.get('schema')!r} "
                f"(expected {REPORT_SCHEMA})")
        return cls(study=d["study"], mode=d.get("mode", "quick"),
                   schema=d["schema"],
                   created_utc=d.get("created_utc", ""),
                   git_sha=d.get("git_sha", ""),
                   config_hash=d.get("config_hash", ""),
                   metrics=dict(d.get("metrics", {})),
                   rows=list(d.get("rows", [])),
                   meta=dict(d.get("meta", {})))


# ---------------------------------------------------------------------------
# BENCH_<study>.json trajectory persistence
# ---------------------------------------------------------------------------


def _json_default(o):
    try:
        import numpy as np
        if isinstance(o, np.integer):
            return int(o)
        if isinstance(o, np.floating):
            return float(o)
        if isinstance(o, np.ndarray):
            return o.tolist()
    except ImportError:                                # pragma: no cover
        pass
    return str(o)


def load_bench(study: str, root: Optional[str] = None,
               path: Optional[str] = None) -> Optional[Dict[str, Any]]:
    """The parsed BENCH file, or None if it doesn't exist yet."""
    p = path or bench_path(study, root)
    if not os.path.exists(p):
        return None
    with open(p) as f:
        data = json.load(f)
    if data.get("schema") != BENCH_SCHEMA:
        raise ValueError(
            f"{p}: unknown bench schema {data.get('schema')!r} "
            f"(expected {BENCH_SCHEMA})")
    return data


def append_bench(report: RunReport, root: Optional[str] = None,
                 path: Optional[str] = None,
                 max_runs: int = MAX_RUNS_DEFAULT) -> str:
    """Append ``report`` to the study's trajectory (creating the file —
    and seeding its baseline — on first run) and return the path."""
    p = path or bench_path(report.study, root)
    data = load_bench(report.study, root, path=p)
    rec = report.to_dict()
    if data is None:
        data = {"schema": BENCH_SCHEMA, "study": report.study,
                "baseline": rec, "runs": []}
    data["runs"].append(rec)
    if max_runs and len(data["runs"]) > max_runs:
        data["runs"] = data["runs"][-max_runs:]
    d = os.path.dirname(p)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = p + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, default=_json_default)
        f.write("\n")
    os.replace(tmp, p)
    return p


def promote_baseline(study: str, root: Optional[str] = None,
                     path: Optional[str] = None) -> Dict[str, Any]:
    """Make the latest run the new accepted baseline (after a reviewed,
    deliberate improvement — the gate never does this on its own)."""
    p = path or bench_path(study, root)
    data = load_bench(study, root, path=p)
    if data is None or not data["runs"]:
        raise FileNotFoundError(
            f"no recorded runs for study {study!r} at {p}")
    data["baseline"] = data["runs"][-1]
    tmp = p + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, default=_json_default)
        f.write("\n")
    os.replace(tmp, p)
    return data
