"""Typed metrics registry: Counters, Gauges and Reservoir-backed
Histograms behind one namespace.

Control-plane components don't write here directly — they keep their
cheap local dataclasses (``SchedMetrics``, ``ScalingMetrics``,
``EngineStats``) on the hot path and the registry is fed through the
observer layer, which keeps the "hooks must not mutate simulation
state" contract trivially true:

  * ``MetricsObserver`` subscribes to the ``EventHub`` and folds the
    live streams (ticks, schedule decisions + ``DecisionTrace``,
    scaling transitions, retrains, spans) into registry metrics as the
    run progresses;
  * ``publish_result`` maps a finished ``SimResult`` (and the
    service's ``EngineStats``) into the same namespace, so the final
    registry snapshot is the single source every ``RunReport`` is
    built from.

Metric names are dotted (``schedule.decisions``, ``cluster.density``,
``span.retrain.ms``); ``MetricsRegistry.snapshot()`` returns plain
JSON-able dicts.
"""
from __future__ import annotations

from typing import Any, Dict, Iterator, Optional

from ..core.events import Observer
from ..core.metrics import Reservoir


class Counter:
    """Monotonically increasing count (events, rows, retrains)."""

    kind = "counter"
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        self.value += v

    def snapshot(self) -> Dict[str, Any]:
        v = self.value
        return {"kind": self.kind,
                "value": int(v) if float(v).is_integer() else v}


class Gauge:
    """Last-written level (density, node count, epoch)."""

    kind = "gauge"
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": self.kind, "value": self.value}


class Histogram:
    """Distribution metric backed by ``core.metrics.Reservoir``: exact
    count/mean/min/max always, exact percentiles while fewer than
    ``cap`` values were observed, bounded memory beyond."""

    kind = "histogram"
    __slots__ = ("name", "help", "reservoir")

    def __init__(self, name: str, help: str = "", cap: int = 512,
                 seed: int = 0):
        self.name = name
        self.help = help
        self.reservoir = Reservoir(cap=cap, seed=seed)

    def observe(self, v: float) -> None:
        self.reservoir.append(v)

    @property
    def count(self) -> int:
        return self.reservoir.count

    def snapshot(self, bins: int = 0) -> Dict[str, Any]:
        r = self.reservoir
        snap = {"kind": self.kind, "count": r.count, "mean": r.mean,
                "min": r.min, "max": r.max, "p50": r.p50, "p99": r.p99}
        if bins:
            counts, edges = r.histogram(bins)
            snap["buckets"] = [[round(float(lo), 6), float(c)]
                               for lo, c in zip(edges[:-1], counts)]
        return snap


class MetricsRegistry:
    """Get-or-create namespace of typed metrics.

    Re-requesting a name returns the existing instrument; requesting an
    existing name as a different kind raises (one name, one type)."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}

    def _get(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"requested {cls.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "", cap: int = 512,
                  seed: int = 0) -> Histogram:
        return self._get(Histogram, name, help, cap=cap, seed=seed)

    # -- access ------------------------------------------------------------

    def get(self, name: str):
        return self._metrics.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __iter__(self) -> Iterator[Any]:
        return iter(self._metrics.values())

    def __len__(self) -> int:
        return len(self._metrics)

    def names(self):
        return sorted(self._metrics)

    def snapshot(self, bins: int = 0) -> Dict[str, Dict[str, Any]]:
        """``{name: {kind, value | distribution summary}}`` — plain
        JSON-able dicts, the RunReport's ``metrics`` payload."""
        out = {}
        for name in self.names():
            m = self._metrics[name]
            out[name] = m.snapshot(bins) if m.kind == "histogram" \
                else m.snapshot()
        return out


class MetricsObserver(Observer):
    """Folds the live observer streams into a ``MetricsRegistry``.

    Pure consumer: reads event arguments, touches no simulation state
    (the observer-parity gate runs with and without it attached)."""

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        # explicit None check: an empty registry is falsy (__len__)
        self.registry = registry if registry is not None \
            else MetricsRegistry()

    # -- hooks -------------------------------------------------------------

    def on_tick(self, now: float, sim) -> None:
        reg = self.registry
        reg.counter("sim.ticks").inc()
        nodes = len(sim.cluster.nodes)
        inst = sim.cluster.total_instances()
        reg.gauge("cluster.nodes").set(nodes)
        reg.gauge("cluster.instances").set(inst)
        density = inst / nodes if nodes else 0.0
        reg.gauge("cluster.density").set(density)
        reg.histogram("cluster.density_series").observe(density)
        # pending-request backlog; only registered when the admission
        # axis is on (None otherwise), so off-axis snapshots carry no
        # admission names
        depth = sim.queue_depth_total()
        if depth is not None:
            reg.gauge("admission.queue_depth").set(depth)
            reg.histogram("admission.queue_depth_series").observe(depth)

    def on_schedule(self, now: float, fn: str, placements,
                    trace=None) -> None:
        reg = self.registry
        reg.counter("schedule.decisions").inc()
        reg.counter("schedule.instances_placed").inc(
            sum(p.count for p in placements))
        for p in placements:
            reg.histogram("schedule.latency_ms").observe(p.latency_ms)
        if trace is not None:
            if trace.failed:
                reg.counter("schedule.failed_requests").inc(trace.failed)
            for reason, n in trace.filtered.items():
                reg.counter(f"schedule.filtered.{reason}").inc(n)

    def on_scale(self, now: float, fn: str, event: str,
                 count: int) -> None:
        self.registry.counter(f"scale.{event}").inc(count)

    def on_retrain(self, service) -> None:
        reg = self.registry
        reg.counter("prediction.retrains").inc()
        reg.gauge("prediction.epoch").set(service.epoch)
        reg.gauge("prediction.samples").set(service.predictor.n_samples)

    def on_span(self, span) -> None:
        self.registry.histogram(f"span.{span.name}.ms").observe(
            span.dur_ms)


def publish_result(registry: MetricsRegistry, res,
                   engine_stats: Optional[Dict[str, float]] = None
                   ) -> MetricsRegistry:
    """Fold a finished ``SimResult`` (and optionally the prediction
    service's ``EngineStats.snapshot()``) into the registry — the
    end-of-run metrics every ``RunReport`` reads.  Gauges for levels
    and rates, counters for totals, histogram summaries re-exposed
    under stable names."""
    g, c = registry.gauge, registry.counter
    g("run.ticks").set(res.ticks)
    g("run.density").set(res.density)
    g("run.qos_violation_rate").set(res.qos_violation_rate)
    g("run.requests").set(res.requests)
    g("run.nodes_peak").set(res.nodes_peak)
    g("run.mean_nodes").set(res.node_seconds / max(res.ticks, 1))
    s = res.sched
    if s is not None:
        c("run.sched.decisions").inc(s.decisions)
        c("run.sched.instances_placed").inc(s.instances_placed)
        c("run.sched.fast").inc(s.fast)
        c("run.sched.slow").inc(s.slow)
        c("run.sched.failed").inc(s.failed)
        c("run.sched.critical_inference_rows").inc(
            s.critical_inference_rows)
        g("run.sched.latency_ms.mean").set(s.mean_latency_ms)
        g("run.sched.latency_ms.p50").set(s.p50_latency_ms)
        g("run.sched.latency_ms.p99").set(s.p99_latency_ms)
    a = res.scaling
    if a is not None:
        c("run.scaling.real_cold_starts").inc(a.real_cold_starts)
        c("run.scaling.logical_cold_starts").inc(a.logical_cold_starts)
        c("run.scaling.releases").inc(a.releases)
        c("run.scaling.evictions").inc(a.evictions)
        c("run.scaling.migrations").inc(a.migrations)
        g("run.cold_start_ms.mean").set(a.mean_cold_start_ms)
        g("run.cold_start_ms.p50").set(a.cold_start_ms.p50)
        g("run.cold_start_ms.p99").set(a.cold_start_ms.p99)
    if res.class_requests:
        # admission axis (repro.admission): per-SLO-class QoS, queue
        # delay distribution, drops and vertical resize totals
        for cls, rate in res.class_violation_rate().items():
            g(f"run.class.{cls}.requests").set(
                res.class_requests.get(cls, 0.0))
            g(f"run.class.{cls}.violation_rate").set(rate)
        c("run.admission.dropped").inc(res.dropped_requests)
        c("run.admission.vertical_grows").inc(res.vertical_grows)
        c("run.admission.vertical_shrinks").inc(res.vertical_shrinks)
        g("run.admission.queue_depth_peak").set(res.queue_depth_peak)
        q = res.queue_delay_s
        g("run.admission.queue_delay_s.mean").set(q.mean)
        g("run.admission.queue_delay_s.p50").set(q.p50)
        g("run.admission.queue_delay_s.p99").set(q.p99)
    if engine_stats:
        for k, v in engine_stats.items():
            g(f"run.engine.{k}").set(v)
    return registry
