"""Span-based control-plane tracing.

The simulator and the prediction service wrap their interesting
sections in spans::

    with tracer.span("schedule", now=now) as sp:
        ...
        sp.attrs["decisions"] = placed

A closed span records wall-clock duration, nesting depth, a sequence
number, and arbitrary attributes (counter deltas, sim time).  Spans are
emitted through the observer hub's ``on_span`` hook as they close, so
``JsonlObserver`` persists them into the same JSONL stream as the
``DecisionTrace`` records — one artifact per run tells the whole story.

``NULL_TRACER`` is the default everywhere: its ``span()`` is a shared
no-op context manager whose ``__enter__`` returns ``None``, so
uninstrumented runs pay two attribute lookups per span site and
allocate nothing (the observer-parity gates run with and without a real
tracer and must agree bit-for-bit — spans only *read* state).

Counter deltas: ``tracer.span(name, stats=obj)`` snapshots
``obj.snapshot()`` (any mapping-returning callable, e.g.
``PredictionService.stats``) on entry and records the numeric deltas on
exit — the "wall-clock + counter deltas" contract without span sites
hand-rolling bookkeeping.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional


class Span:
    """One closed (or in-flight) control-plane section."""

    __slots__ = ("name", "seq", "depth", "t_start_s", "dur_ms", "attrs",
                 "_stats", "_snap0")

    def __init__(self, name: str, seq: int, depth: int,
                 stats: Optional[Any] = None, **attrs: Any):
        self.name = name
        self.seq = seq
        self.depth = depth
        self.t_start_s = 0.0
        self.dur_ms = 0.0
        self.attrs: Dict[str, Any] = dict(attrs)
        self._stats = stats
        self._snap0: Optional[Dict[str, float]] = None

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "seq": self.seq, "depth": self.depth,
                "ms": round(self.dur_ms, 4), **self.attrs}

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, seq={self.seq}, "
                f"ms={self.dur_ms:.3f}, {self.attrs})")


class _NullSpanCM:
    """Shared no-op ``span()`` result: enters to None, records nothing."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_CM = _NullSpanCM()


class _NullTracer:
    """The do-nothing default tracer (see module docstring)."""

    enabled = False

    def span(self, name: str, stats: Optional[Any] = None,
             **attrs: Any) -> _NullSpanCM:
        return _NULL_CM

    def summary(self) -> List[Dict[str, Any]]:
        return []


NULL_TRACER = _NullTracer()


class _SpanCM:
    __slots__ = ("tracer", "sp")

    def __init__(self, tracer: "SpanTracer", sp: Span):
        self.tracer = tracer
        self.sp = sp

    def __enter__(self) -> Span:
        self.tracer._depth += 1
        self.sp.t_start_s = time.perf_counter()
        if self.sp._stats is not None:
            self.sp._snap0 = dict(self.sp._stats.snapshot())
        return self.sp

    def __exit__(self, *exc) -> bool:
        sp = self.sp
        sp.dur_ms = (time.perf_counter() - sp.t_start_s) * 1e3
        if sp._snap0 is not None:
            snap1 = self.sp._stats.snapshot()
            for k, v1 in snap1.items():
                d = v1 - sp._snap0.get(k, 0)
                if isinstance(d, float):
                    d = round(d, 6)
                if d:
                    sp.attrs[f"d_{k}"] = d
        self.tracer._depth -= 1
        self.tracer._finish(sp)
        return False


class SpanTracer:
    """Records spans in memory (bounded) and emits each closed span to an
    optional callback — typically ``EventHub.on_span``, which fans out
    to ``JsonlObserver`` and the metrics registry's observer."""

    enabled = True

    def __init__(self, emit: Optional[Callable[[Span], None]] = None,
                 max_spans: int = 100_000):
        self.spans: List[Span] = []
        self.dropped = 0
        self.max_spans = max_spans
        self._emit = emit
        self._depth = 0
        self._seq = 0

    def span(self, name: str, stats: Optional[Any] = None,
             **attrs: Any) -> _SpanCM:
        sp = Span(name, self._seq, self._depth, stats=stats, **attrs)
        self._seq += 1
        return _SpanCM(self, sp)

    def _finish(self, sp: Span) -> None:
        if len(self.spans) < self.max_spans:
            self.spans.append(sp)
        else:
            self.dropped += 1
        if self._emit is not None:
            self._emit(sp)

    # -- aggregation -------------------------------------------------------

    def summary(self) -> List[Dict[str, Any]]:
        """Per-name aggregate rows (count / total / mean / max ms),
        sorted by total wall time descending — the dashboard's
        flamegraph-style span table."""
        agg: Dict[str, Dict[str, Any]] = {}
        for sp in self.spans:
            row = agg.setdefault(sp.name, {
                "name": sp.name, "count": 0, "total_ms": 0.0,
                "max_ms": 0.0})
            row["count"] += 1
            row["total_ms"] += sp.dur_ms
            row["max_ms"] = max(row["max_ms"], sp.dur_ms)
        out = sorted(agg.values(), key=lambda r: -r["total_ms"])
        for row in out:
            row["mean_ms"] = row["total_ms"] / row["count"]
            row["total_ms"] = round(row["total_ms"], 4)
            row["mean_ms"] = round(row["mean_ms"], 4)
            row["max_ms"] = round(row["max_ms"], 4)
        return out
