"""Self-contained HTML run dashboard.

    PYTHONPATH=src python -m repro.telemetry.dashboard \
        [--root DIR] [--events DIR] [--out FILE]

Renders one static HTML file (inline CSS + SVG, no external assets, no
JS) from two sources:

  * the checked-in ``BENCH_<study>.json`` trajectories (baseline +
    recorded runs) — per-scheduler density / QoS / cold-start panels
    for the latest large-cluster run, capacity-engine scaling, and the
    headline-metric trajectory across runs;
  * a run's ``artifacts/events/*.jsonl`` observer streams — density
    over simulated time per scheduler, ``DecisionTrace`` rejection-
    reason breakdowns, and the span table (count / total / mean / max
    wall-clock per control-plane section, flamegraph-style widths).

Charts follow the repo's dataviz conventions: one fixed categorical
slot per scheduler (color follows the entity across every panel),
sequential single-hue bars for magnitudes, a legend plus direct value
labels, native ``<title>`` hover tooltips, and a table view under each
panel.  Light and dark render from the same markup via CSS custom
properties.
"""
from __future__ import annotations

import argparse
import html
import json
import math
import os
import sys
import time
from collections import defaultdict
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .report import load_bench, repo_root

#: fixed categorical slot per scheduler — identity keeps its hue in
#: every panel; unknown systems take the next free slot in this order
SYSTEM_ORDER = ("k8s", "jiagu", "harvesting", "gsight", "owl")
N_SLOTS = 8

_CSS = """
:root { color-scheme: light dark; }
body {
  margin: 0; padding: 24px 32px; background: var(--surface-0);
  color: var(--text-primary);
  font: 14px/1.45 -apple-system, "Segoe UI", Roboto, Helvetica, Arial,
        sans-serif;
}
body {
  --surface-0: #fcfcfb; --surface-1: #ffffff; --border: #e4e3df;
  --grid: #ecebe7; --text-primary: #0b0b0b; --text-secondary: #52514e;
  --text-muted: #8a8985; --seq: #2a78d6;
  --series-1: #2a78d6; --series-2: #eb6834; --series-3: #1baf7a;
  --series-4: #eda100; --series-5: #e87ba4; --series-6: #008300;
  --series-7: #4a3aa7; --series-8: #e34948;
}
@media (prefers-color-scheme: dark) {
  body {
    --surface-0: #1a1a19; --surface-1: #222221; --border: #3a3a37;
    --grid: #32322f; --text-primary: #ffffff;
    --text-secondary: #c3c2b7; --text-muted: #8a8985; --seq: #3987e5;
    --series-1: #3987e5; --series-2: #d95926; --series-3: #199e70;
    --series-4: #c98500; --series-5: #d55181; --series-6: #008300;
    --series-7: #9085e9; --series-8: #e66767;
  }
}
h1 { font-size: 20px; margin: 0 0 2px; }
h2 { font-size: 15px; margin: 0 0 8px; }
.sub { color: var(--text-secondary); margin-bottom: 20px; }
.grid { display: flex; flex-wrap: wrap; gap: 16px; }
.card {
  background: var(--surface-1); border: 1px solid var(--border);
  border-radius: 8px; padding: 16px 16px 10px;
}
.legend { display: flex; gap: 14px; flex-wrap: wrap; margin: 6px 0 2px;
          color: var(--text-secondary); font-size: 12px; }
.legend span.sw { display: inline-block; width: 10px; height: 10px;
                  border-radius: 3px; margin-right: 5px;
                  vertical-align: -1px; }
svg text { fill: var(--text-secondary); font-size: 11px; }
svg text.val { fill: var(--text-primary); }
svg text.muted { fill: var(--text-muted); }
svg line.grid { stroke: var(--grid); stroke-width: 1; }
svg line.axis { stroke: var(--border); stroke-width: 1; }
details { margin: 6px 0 2px; color: var(--text-secondary); }
details table { border-collapse: collapse; font-size: 12px;
                margin-top: 6px; }
details th, details td { border: 1px solid var(--border);
                         padding: 2px 8px; text-align: right; }
details th:first-child, details td:first-child { text-align: left; }
.empty { color: var(--text-muted); font-style: italic; }
"""


def _e(s: Any) -> str:
    return html.escape(str(s))


def _slot(system: str, order: List[str]) -> int:
    if system not in order:
        order.append(system)
    return (order.index(system) % N_SLOTS) + 1


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 100:
            return f"{v:,.0f}"
        if abs(v) >= 1:
            return f"{v:.2f}".rstrip("0").rstrip(".")
        return f"{v:.3g}"
    return str(v)


def _table(headers: Sequence[str], rows: Sequence[Sequence[Any]]) -> str:
    head = "".join(f"<th>{_e(h)}</th>" for h in headers)
    body = "".join(
        "<tr>" + "".join(f"<td>{_e(_fmt(c))}</td>" for c in r) + "</tr>"
        for r in rows)
    return (f"<details><summary>table view</summary><table>"
            f"<tr>{head}</tr>{body}</table></details>")


def _legend(series: Sequence[Tuple[str, int]]) -> str:
    items = "".join(
        f"<div><span class='sw' "
        f"style='background:var(--series-{slot})'></span>{_e(n)}</div>"
        for n, slot in series)
    return f"<div class='legend'>{items}</div>"


# ---------------------------------------------------------------------------
# SVG primitives
# ---------------------------------------------------------------------------


def _grouped_bars(groups: Sequence[Tuple[str, List[Tuple[str, float]]]],
                  slots: Dict[str, int], unit: str = "",
                  height: int = 190, label_vals: bool = True) -> str:
    """Vertical grouped bar chart: one group per sweep point, one
    4px-rounded bar per scheduler, 2px gaps, native tooltips."""
    if not groups:
        return "<div class='empty'>no data</div>"
    vmax = max((v for _, bars in groups for _, v in bars), default=0.0)
    vmax = vmax * 1.12 or 1.0
    n_series = max(len(bars) for _, bars in groups)
    bar_w, gap = 26, 2
    group_w = n_series * (bar_w + gap) + 26
    ml, mr, mt, mb = 44, 8, 8, 34
    w = ml + mr + group_w * len(groups)
    plot_h = height - mt - mb
    parts = [f"<svg viewBox='0 0 {w} {height}' width='{w}' "
             f"height='{height}' role='img'>"]
    for i in range(5):
        y = mt + plot_h * i / 4
        v = vmax * (1 - i / 4)
        parts.append(f"<line class='grid' x1='{ml}' y1='{y:.1f}' "
                     f"x2='{w - mr}' y2='{y:.1f}'/>")
        parts.append(f"<text x='{ml - 5}' y='{y + 3.5:.1f}' "
                     f"text-anchor='end'>{_fmt(v)}</text>")
    parts.append(f"<line class='axis' x1='{ml}' y1='{mt + plot_h}' "
                 f"x2='{w - mr}' y2='{mt + plot_h}'/>")
    for gi, (glabel, bars) in enumerate(groups):
        gx = ml + gi * group_w + 13
        for bi, (sname, v) in enumerate(bars):
            x = gx + bi * (bar_w + gap)
            h = plot_h * (v / vmax) if vmax else 0.0
            y = mt + plot_h - h
            slot = slots.get(sname, 1)
            r = min(4.0, h)
            parts.append(
                f"<path d='M{x},{mt + plot_h} v{-(h - r):.1f} "
                f"q0,{-r} {r},{-r} h{bar_w - 2 * r} q{r},0 {r},{r} "
                f"v{h - r:.1f} z' fill='var(--series-{slot})'>"
                f"<title>{_e(sname)} · {_e(glabel)}: "
                f"{_fmt(v)}{unit}</title></path>"
                if h > r else
                f"<rect x='{x}' y='{y:.1f}' width='{bar_w}' "
                f"height='{max(h, 0.5):.1f}' "
                f"fill='var(--series-{slot})'>"
                f"<title>{_e(sname)} · {_e(glabel)}: "
                f"{_fmt(v)}{unit}</title></rect>")
            if label_vals:
                parts.append(
                    f"<text class='val' x='{x + bar_w / 2}' "
                    f"y='{y - 4:.1f}' text-anchor='middle'>"
                    f"{_fmt(v)}</text>")
        cx = gx + (n_series * (bar_w + gap) - gap) / 2
        parts.append(f"<text x='{cx:.1f}' y='{height - 16}' "
                     f"text-anchor='middle'>{_e(glabel)}</text>")
    parts.append("</svg>")
    return "".join(parts)


def _hbars(items: Sequence[Tuple[str, float, str]],
           fill: str = "var(--seq)", width: int = 460) -> str:
    """Horizontal magnitude bars (sequential single hue): label,
    proportional bar, value label at the data end."""
    if not items:
        return "<div class='empty'>no data</div>"
    vmax = max(v for _, v, _ in items) or 1.0
    lw, vw, bh, gap = 190, 86, 16, 6
    bar_span = width - lw - vw - 12
    h = len(items) * (bh + gap) + 6
    parts = [f"<svg viewBox='0 0 {width} {h}' width='{width}' "
             f"height='{h}' role='img'>"]
    for i, (label, v, vtext) in enumerate(items):
        y = 3 + i * (bh + gap)
        bw = max(bar_span * v / vmax, 1.5)
        parts.append(f"<text x='{lw - 6}' y='{y + bh - 4}' "
                     f"text-anchor='end'>{_e(label[:30])}</text>")
        parts.append(
            f"<rect x='{lw}' y='{y}' rx='4' width='{bw:.1f}' "
            f"height='{bh}' fill='{fill}'>"
            f"<title>{_e(label)}: {_e(vtext)}</title></rect>")
        parts.append(f"<text class='val' x='{lw + bw + 6:.1f}' "
                     f"y='{y + bh - 4}'>{_e(vtext)}</text>")
    parts.append("</svg>")
    return "".join(parts)


def _lines(series: Dict[str, List[Tuple[float, float]]],
           slots: Dict[str, int], width: int = 460, height: int = 170,
           x_label: str = "", y_zero: bool = True) -> str:
    """Multi-series line chart (2px strokes, endpoint dots + direct
    labels)."""
    pts = [p for s in series.values() for p in s]
    if not pts:
        return "<div class='empty'>no data</div>"
    xs = [p[0] for p in pts]
    ys = [p[1] for p in pts]
    x0, x1 = min(xs), max(xs)
    y0 = 0.0 if y_zero else min(ys)
    y1 = max(ys) * 1.08 or 1.0
    if x1 <= x0:
        x1 = x0 + 1.0
    if y1 <= y0:
        y1 = y0 + 1.0
    ml, mr, mt, mb = 44, 64, 8, 26
    pw, ph = width - ml - mr, height - mt - mb

    def sx(x):
        return ml + pw * (x - x0) / (x1 - x0)

    def sy(y):
        return mt + ph * (1 - (y - y0) / (y1 - y0))

    parts = [f"<svg viewBox='0 0 {width} {height}' width='{width}' "
             f"height='{height}' role='img'>"]
    for i in range(5):
        gy = mt + ph * i / 4
        v = y1 - (y1 - y0) * i / 4
        parts.append(f"<line class='grid' x1='{ml}' y1='{gy:.1f}' "
                     f"x2='{width - mr}' y2='{gy:.1f}'/>")
        parts.append(f"<text x='{ml - 5}' y='{gy + 3.5:.1f}' "
                     f"text-anchor='end'>{_fmt(v)}</text>")
    parts.append(f"<line class='axis' x1='{ml}' y1='{mt + ph}' "
                 f"x2='{width - mr}' y2='{mt + ph}'/>")
    parts.append(f"<text class='muted' x='{ml}' y='{height - 8}'>"
                 f"{_e(x_label)} {_fmt(x0)} → {_fmt(x1)}</text>")
    for name, data in series.items():
        if not data:
            continue
        slot = slots.get(name, 1)
        path = " ".join(f"{sx(x):.1f},{sy(y):.1f}" for x, y in data)
        parts.append(f"<polyline points='{path}' fill='none' "
                     f"stroke='var(--series-{slot})' stroke-width='2'>"
                     f"<title>{_e(name)}</title></polyline>")
        lx, ly = data[-1]
        parts.append(f"<circle cx='{sx(lx):.1f}' cy='{sy(ly):.1f}' "
                     f"r='3' fill='var(--series-{slot})'/>")
        parts.append(f"<text x='{sx(lx) + 6:.1f}' "
                     f"y='{sy(ly) + 3.5:.1f}'>{_e(name)}</text>")
    parts.append("</svg>")
    return "".join(parts)


def _card(title: str, body: str, note: str = "") -> str:
    sub = f"<div class='sub' style='margin:0 0 6px'>{note}</div>" \
        if note else ""
    return f"<div class='card'><h2>{_e(title)}</h2>{sub}{body}</div>"


# ---------------------------------------------------------------------------
# Event-stream ingestion (artifacts/events/*.jsonl)
# ---------------------------------------------------------------------------


def load_event_streams(events_dir: str) -> List[Dict[str, Any]]:
    """Parse every ``*.jsonl`` stream into one summary dict per file:
    scheduler name, density-over-time samples, rejection-reason counts,
    scale-event counts, span aggregates."""
    streams: List[Dict[str, Any]] = []
    if not events_dir or not os.path.isdir(events_dir):
        return streams
    for fname in sorted(os.listdir(events_dir)):
        if not fname.endswith(".jsonl"):
            continue
        path = os.path.join(events_dir, fname)
        summary: Dict[str, Any] = {
            "file": fname, "system": None, "ticks": [], "qdepth": [],
            "reasons": defaultdict(int), "scale": defaultdict(int),
            "spans": {}, "schedules": 0, "events": 0}
        try:
            with open(path) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = json.loads(line)
                    except json.JSONDecodeError:
                        continue          # truncated tail of a crash
                    summary["events"] += 1
                    ev = rec.get("event")
                    if ev == "meta":
                        sched = (rec.get("manifest") or {}).get(
                            "scheduler") or {}
                        summary["system"] = sched.get("name")
                    elif ev == "tick":
                        summary["ticks"].append(
                            (rec.get("now", 0.0),
                             rec.get("density", 0.0)))
                        if "queue_depth" in rec:
                            summary["qdepth"].append(
                                (rec.get("now", 0.0),
                                 rec["queue_depth"]))
                    elif ev == "schedule":
                        summary["schedules"] += 1
                        for reason, n in (rec.get("trace") or {}).get(
                                "filtered", {}).items():
                            summary["reasons"][reason] += n
                    elif ev == "scale":
                        summary["scale"][rec.get("kind", "?")] += \
                            rec.get("count", 0)
                    elif ev == "span":
                        row = summary["spans"].setdefault(
                            rec.get("name", "?"),
                            {"count": 0, "total_ms": 0.0, "max_ms": 0.0})
                        row["count"] += 1
                        row["total_ms"] += rec.get("ms", 0.0)
                        row["max_ms"] = max(row["max_ms"],
                                            rec.get("ms", 0.0))
        except OSError:
            continue
        if summary["system"] is None:
            # fall back to the run_study naming convention
            # (<kind>_<nodes>_<system>.jsonl)
            stem = fname[:-6]
            summary["system"] = stem.rsplit("_", 1)[-1] or stem
        streams.append(summary)
    return streams


# ---------------------------------------------------------------------------
# Panels
# ---------------------------------------------------------------------------


def _latest(bench: Dict[str, Any]) -> Dict[str, Any]:
    runs = bench.get("runs") or []
    return runs[-1] if runs else bench.get("baseline", {})


def _metric_panels(run: Dict[str, Any], slots: Dict[str, int],
                   order: List[str]) -> str:
    rows = run.get("rows", [])
    if not rows:
        return ""
    systems = sorted({r["system"] for r in rows if "system" in r},
                     key=lambda s: (_slot(s, order)))
    panels = []
    for metric, title, unit in (
            ("density", "Density (instances / active node)", ""),
            ("qos_violation", "QoS violation rate", ""),
            ("cold_ms_p99", "Cold-start p99 (ms)", " ms")):
        groups = []
        for r in rows:
            if r.get("system") != systems[0] or metric not in r:
                continue
            glabel = f"{r.get('scenario', '?')}@{r.get('target_nodes')}"
            bars = []
            for s in systems:
                match = [x for x in rows
                         if x.get("system") == s
                         and x.get("scenario") == r.get("scenario")
                         and x.get("target_nodes")
                         == r.get("target_nodes")
                         and metric in x]
                if match:
                    bars.append((s, float(match[0][metric])))
            if bars:
                groups.append((glabel, bars))
        if not groups:
            continue
        svg = _grouped_bars(groups, slots, unit=unit)
        legend = _legend([(s, slots[s]) for s in systems])
        table = _table(
            ["scenario@nodes"] + systems,
            [[g] + [dict(bars).get(s, "") for s in systems]
             for g, bars in groups])
        panels.append(_card(title, legend + svg + table))
    return "".join(panels)


def _trajectory_panel(study: str, bench: Dict[str, Any],
                      slots: Dict[str, int], order: List[str]) -> str:
    """Headline metric across the recorded runs (the BENCH
    trajectory), baseline included as run 0."""
    runs = [bench.get("baseline")] + list(bench.get("runs") or [])
    runs = [r for r in runs if r]

    def headline(run) -> Dict[str, float]:
        rows = run.get("rows", [])
        out: Dict[str, List[float]] = defaultdict(list)
        for r in rows:
            if "density" in r and "system" in r:
                out[r["system"]].append(float(r["density"]))
            elif r.get("speedup") is not None:
                # device-drain-only rows (legacy path not run at that
                # size) carry speedup=None and don't enter the mean
                out["engine speedup"].append(float(r["speedup"]))
        return {k: sum(v) / len(v) for k, v in out.items() if v}

    series: Dict[str, List[Tuple[float, float]]] = defaultdict(list)
    for i, run in enumerate(runs):
        for name, v in headline(run).items():
            series[name].append((float(i), v))
    if not series:
        return ""
    for name in series:
        _slot(name, order)
    y_label = "mean density" if any(
        n != "engine speedup" for n in series) else "speedup (x)"
    svg = _lines(dict(series), slots, x_label="run #")
    shas = [r.get("git_sha", "?") for r in runs]
    table = _table(["run", "git", *series.keys()],
                   [[i, shas[i]] + [
                       dict(series[n]).get(float(i), "")
                       for n in series] for i in range(len(runs))])
    return _card(f"{study}: trajectory ({y_label}, run 0 = baseline)",
                 svg + table)


def _reasons_panel(streams: List[Dict[str, Any]]) -> str:
    per_system: Dict[str, Dict[str, int]] = defaultdict(
        lambda: defaultdict(int))
    for s in streams:
        for reason, n in s["reasons"].items():
            per_system[s["system"]][reason] += n
    if not per_system:
        return ""
    blocks = []
    for system, reasons in sorted(per_system.items()):
        items = [(reason, float(n), f"{n:,}")
                 for reason, n in sorted(reasons.items(),
                                         key=lambda kv: -kv[1])[:10]]
        blocks.append(f"<div class='sub' style='margin:8px 0 2px'>"
                      f"{_e(system)}</div>" + _hbars(items))
    table = _table(
        ["system", "reason", "count"],
        [[sys_, r, n] for sys_, rs in sorted(per_system.items())
         for r, n in sorted(rs.items(), key=lambda kv: -kv[1])])
    return _card("Decision-trace rejection reasons (per scheduler)",
                 "".join(blocks) + table,
                 note="why candidate nodes were filtered out of "
                      "placements, from the schedule event stream")


def _policy_panel(bench: Dict[str, Any], slots: Dict[str, int],
                  order: List[str]) -> str:
    """Learned-vs-baseline comparison from the latest policy run:
    density bars per system, QoS violation magnitudes, and the training
    / serving gate metrics (agreement, QoS excess, stale serves)."""
    latest = _latest(bench)
    rows = [r for r in latest.get("rows", []) if r.get("system")]
    if not rows:
        return ""
    for r in rows:
        slots[r["system"]] = _slot(r["system"], order)
    systems = [r["system"] for r in rows]
    density = [(r["system"], float(r.get("density", 0.0)))
               for r in rows]
    qos_items = [(r["system"], float(r.get("qos_violation", 0.0)),
                  f"{float(r.get('qos_violation', 0.0)):.4f}")
                 for r in rows]
    met = latest.get("metrics", {})
    note = (f"trained on {met.get('n_decisions', '?')} traced "
            f"decisions · imitation holdout agreement "
            f"{met.get('imitation_agreement', '?')} (gated ≥ 0.90) · "
            f"QoS excess over k8s {met.get('learned_qos_excess', '?')} "
            f"· density ratio {met.get('learned_density_ratio', '?')}x "
            f"k8s · stale-epoch serves {met.get('stale_serves', '?')}")
    legend = _legend([(s, slots[s]) for s in systems])
    svg = _grouped_bars([("density", density)], slots)
    table = _table(
        ["system", "density", "qos violation", "decisions", "placed",
         "stale serves"],
        [[r.get(k, "") for k in (
            "system", "density", "qos_violation", "decisions",
            "placed", "stale_serves")] for r in rows])
    return _card(
        "Learned policy vs baselines (latest policy run)",
        legend + svg
        + "<div class='sub' style='margin:8px 0 2px'>QoS violation "
          "rate</div>" + _hbars(qos_items) + table,
        note=note)


def _admission_panel(bench: Dict[str, Any], slots: Dict[str, int],
                     order: List[str]) -> str:
    """Per-SLO-class QoS comparison from the latest admission run:
    seed-mean violation rate per class, one bar per arm, plus the
    headline A/B metrics (density win, latency-critical excess)."""
    latest = _latest(bench)
    rows = [r for r in latest.get("rows", []) if r.get("system")]
    if not rows:
        return ""
    arms = sorted({r["system"] for r in rows})
    for a in arms:
        slots[a] = _slot(a, order)

    def mean(arm, key):
        vals = [float(r.get(key, 0.0)) for r in rows
                if r["system"] == arm]
        return sum(vals) / len(vals) if vals else 0.0

    groups = [(cls, [(a, mean(a, key)) for a in arms])
              for cls, key in (("latency-critical", "lc_violation"),
                               ("best-effort", "be_violation"),
                               ("overall", "qos_violation"))]
    met = latest.get("metrics", {})
    note = (f"seed-mean over {len(rows) // max(len(arms), 1)} seeds · "
            f"density win {met.get('density_win', '?')} (gated &gt; 0) "
            f"· latency-critical excess {met.get('lc_excess', '?')} · "
            f"queue delay p99 {met.get('queue_delay_p99', '?')}s · "
            f"{met.get('vertical_shrinks', '?')} vertical shrinks")
    legend = _legend([(a, slots[a]) for a in arms])
    svg = _grouped_bars(groups, slots)
    table = _table(
        ["arm", "seed", "density", "qos", "lc", "be", "queue p99 s",
         "shrinks"],
        [[r.get(k, "") for k in (
            "system", "seed", "density", "qos_violation",
            "lc_violation", "be_violation", "queue_delay_p99",
            "vertical_shrinks")] for r in rows])
    return _card(
        "Admission: per-SLO-class QoS by arm (latest admission run)",
        legend + svg + table, note=note)


def _queue_depth_panel(streams: List[Dict[str, Any]],
                       slots: Dict[str, int],
                       order: List[str]) -> str:
    """Pending-request backlog over simulated time, from the tick
    records of admission-enabled event streams."""
    series: Dict[str, List[Tuple[float, float]]] = {}
    for s in streams:
        if s["qdepth"] and s["system"]:
            prev = series.get(s["system"])
            if prev is None or len(s["qdepth"]) > len(prev):
                series[s["system"]] = s["qdepth"]
    if not series:
        return ""
    for name in series:
        _slot(name, order)
    svg = _lines(series, slots, width=560, x_label="sim time (s)")
    return _card("Queue depth over simulated time (events stream)",
                 svg,
                 note="fleet pending-request backlog per tick; only "
                      "admission-enabled runs emit the gauge")


def _density_over_time_panel(streams: List[Dict[str, Any]],
                             slots: Dict[str, int],
                             order: List[str]) -> str:
    series: Dict[str, List[Tuple[float, float]]] = {}
    for s in streams:
        if s["ticks"] and s["system"]:
            # one representative stream per scheduler (the largest run)
            prev = series.get(s["system"])
            if prev is None or len(s["ticks"]) > len(prev):
                series[s["system"]] = s["ticks"]
    if not series:
        return ""
    for name in series:
        _slot(name, order)
    svg = _lines(series, slots, width=560, x_label="sim time (s)")
    return _card("Density over simulated time (events stream)", svg)


def _spans_panel(streams: List[Dict[str, Any]]) -> str:
    agg: Dict[str, Dict[str, float]] = {}
    for s in streams:
        for name, row in s["spans"].items():
            dst = agg.setdefault(name, {"count": 0, "total_ms": 0.0,
                                        "max_ms": 0.0})
            dst["count"] += row["count"]
            dst["total_ms"] += row["total_ms"]
            dst["max_ms"] = max(dst["max_ms"], row["max_ms"])
    if not agg:
        return ""
    rows = sorted(agg.items(), key=lambda kv: -kv[1]["total_ms"])
    items = [(name, r["total_ms"],
              f"{r['total_ms']:,.1f} ms · {int(r['count'])}x")
             for name, r in rows]
    table = _table(
        ["span", "count", "total ms", "mean ms", "max ms"],
        [[name, int(r["count"]), round(r["total_ms"], 2),
          round(r["total_ms"] / max(r["count"], 1), 3),
          round(r["max_ms"], 2)] for name, r in rows])
    return _card("Control-plane spans (wall clock)",
                 _hbars(items) + table,
                 note="schedule / retrain / capacity_solve sections "
                      "from the span stream; bar = total wall time")


# ---------------------------------------------------------------------------
# Page assembly
# ---------------------------------------------------------------------------


def render(root: Optional[str] = None, events_dir: Optional[str] = None,
           studies: Optional[Sequence[str]] = None) -> str:
    root = root or repo_root()
    if studies is None:
        studies = sorted(
            f[len("BENCH_"):-len(".json")] for f in os.listdir(root)
            if f.startswith("BENCH_") and f.endswith(".json"))
    if events_dir is None:
        events_dir = os.path.join(root, "benchmarks", "artifacts",
                                  "events")
    benches = {}
    for study in studies:
        try:
            data = load_bench(study, root)
        except ValueError:
            data = None
        if data:
            benches[study] = data
    streams = load_event_streams(events_dir)

    order: List[str] = list(SYSTEM_ORDER)
    slots: Dict[str, int] = {}

    def ensure_slots(names):
        for n in names:
            slots[n] = _slot(n, order)

    for bench in benches.values():
        ensure_slots(r.get("system") for r in _latest(bench).get(
            "rows", []) if r.get("system"))
    ensure_slots(s["system"] for s in streams if s["system"])

    cards: List[str] = []
    lc = benches.get("large_cluster")
    if lc:
        cards.append(_metric_panels(_latest(lc), slots, order))
    for study, bench in benches.items():
        cards.append(_trajectory_panel(study, bench, slots, order))
    ce = benches.get("capacity_engine")
    if ce:
        rows = _latest(ce).get("rows", [])
        # device-drain-only rows (legacy skipped past its node cap)
        # have speedup=None: shown in the table, left out of the bars
        items = [(f"{r['nodes']} nodes", float(r["speedup"]),
                  f"{r['speedup']}x cold / "
                  f"{r.get('warm_speedup', 0)}x warm")
                 for r in rows
                 if "nodes" in r and r.get("speedup") is not None]
        if items:
            table = _table(
                ["nodes", "legacy ms", "engine ms", "warm ms",
                 "device ms", "device µs/solve", "speedup",
                 "call reduction"],
                [["" if r.get(k) is None else r.get(k, "") for k in (
                    "nodes", "legacy_ms", "engine_ms", "warm_ms",
                    "device_ms", "device_us_per_solve",
                    "speedup", "call_reduction")] for r in rows])
            cards.append(_card(
                "Capacity-engine speedup vs legacy (latest run)",
                _hbars(items) + table))
    sc = benches.get("scaling")
    if sc:
        latest = _latest(sc)
        rows = [r for r in latest.get("rows", [])
                if r.get("target_nodes") and r.get("wall_s")]
        if len(rows) >= 2:
            series = {
                "wall s": [(math.log10(r["target_nodes"]),
                            math.log10(max(r["wall_s"], 1e-3)))
                           for r in rows],
                "ms/node": [(math.log10(r["target_nodes"]),
                             math.log10(max(r["wall_ms_per_node"],
                                            1e-6)))
                            for r in rows],
            }
            ensure_slots(series)
            met = latest.get("metrics", {})
            table = _table(
                ["target nodes", "cells", "mean nodes", "wall s",
                 "ms/node", "density", "qos", "idle cell frac"],
                [[r.get(k, "") for k in (
                    "target_nodes", "cells", "mean_nodes", "wall_s",
                    "wall_ms_per_node", "density", "qos_violation",
                    "idle_cell_frac")] for r in rows])
            cards.append(_card(
                "Event-core scaling: fleet size vs wall clock "
                "(log-log)",
                _lines(series, slots, width=560,
                       x_label="log10 target nodes", y_zero=False)
                + table,
                note=f"cell-sharded event core, per-node wall-clock "
                     f"slope "
                     f"{met.get('wallclock_per_node_slope', '?')} "
                     f"(gated &lt; 1.0) · cells_parity="
                     f"{met.get('cells_parity', '?')}"))
    pol = benches.get("policy")
    if pol:
        cards.append(_policy_panel(pol, slots, order))
    adm = benches.get("admission")
    if adm:
        cards.append(_admission_panel(adm, slots, order))
    cards.append(_density_over_time_panel(streams, slots, order))
    cards.append(_queue_depth_panel(streams, slots, order))
    cards.append(_reasons_panel(streams))
    cards.append(_spans_panel(streams))

    sha = next((_latest(b).get("git_sha") for b in benches.values()
                if _latest(b).get("git_sha")), "unknown")
    body = "".join(c for c in cards if c) or \
        "<div class='empty'>no BENCH_*.json baselines and no event " \
        "streams found — run scripts/verify.sh --bench first</div>"
    n_events = sum(s["events"] for s in streams)
    return f"""<!DOCTYPE html>
<html lang="en"><head><meta charset="utf-8">
<meta name="viewport" content="width=device-width, initial-scale=1">
<title>repro.telemetry dashboard</title>
<style>{_CSS}</style></head>
<body>
<h1>repro.telemetry — benchmark &amp; run dashboard</h1>
<div class="sub">generated {time.strftime('%Y-%m-%d %H:%M:%SZ',
                                          time.gmtime())}
 · git {_e(sha)} · studies: {_e(', '.join(benches) or 'none')}
 · {len(streams)} event streams ({n_events:,} events)</div>
<div class="grid">{body}</div>
</body></html>
"""


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="render the self-contained telemetry dashboard")
    ap.add_argument("--root", default=None,
                    help="directory holding BENCH_*.json "
                         "(default: repo root / $REPRO_BENCH_DIR)")
    ap.add_argument("--events", default=None,
                    help="events JSONL dir (default: "
                         "<root>/benchmarks/artifacts/events)")
    ap.add_argument("--out", default=None,
                    help="output HTML path (default: "
                         "<root>/benchmarks/artifacts/dashboard.html)")
    args = ap.parse_args(argv)
    root = args.root or repo_root()
    out = args.out or os.path.join(root, "benchmarks", "artifacts",
                                   "dashboard.html")
    page = render(root, args.events)
    d = os.path.dirname(os.path.abspath(out))
    os.makedirs(d, exist_ok=True)
    with open(out, "w") as f:
        f.write(page)
    print(f"# dashboard: wrote {out} ({len(page) / 1024:.0f} KiB)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
