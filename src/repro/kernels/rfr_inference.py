"""Random-Forest-Regression batched inference — Pallas TPU kernels.

This is the paper's scheduling-latency hot spot (Table 2: model inference
~20 ms dominates cold starts once container init is <10 ms; Jiagu needs
~1 ms).  The forest is flattened to dense complete-tree arrays that fit
VMEM entirely (64 trees x depth 8 ~= 200 KB), so a capacity-solve batch of
inputs is scored in one kernel launch with zero HBM re-reads of the model:

    feat (T, 2^D - 1) int32   split feature per internal node
    thr  (T, 2^D - 1) f32     split threshold
    leaf (T, 2^D)     f32     leaf values

Descent is D unrolled levels of   idx = 2*idx + 1 + (x[feat[idx]] >= thr)
vectorized over (block_n inputs x T trees) — gathers over VMEM-resident
arrays.  Output is the tree-mean prediction.

Two kernels share the descent:

  * ``rfr_forest_apply`` — plain batched prediction, (N, F) -> (N,).
  * ``rfr_capacity_sweep`` — the fused capacity m-sweep.  Input is the
    padded scenario tensor (S, M, R, F): S capacity scenarios, M swept
    concurrencies, R feature rows per concurrency (target + colocated
    neighbors).  One pass descends every row, compares predictions
    against the per-row QoS bounds, reduces (all rows pass) over R and
    (running prefix of passing m) over M, and returns the max admissible
    m per scenario as (S,) int32 — no host round-trip per chunk.
    Padding is encoded in the bounds: +inf rows always pass (R padding),
    -inf rows always fail (m beyond a scenario's own m_max, capping its
    capacity there).

The un-jitted numpy training half lives in ``repro.core.predictor``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _descend(x, feat, thr, leaf, *, depth: int, n_trees: int,
             block_n: int, n_feat: int):
    """Shared VMEM forest descent: x (bn, F) -> tree-mean preds (bn,).
    feat/thr/leaf arrive flattened to 1-D."""
    NN = (1 << depth) - 1
    NL = 1 << depth
    tree_ids = jax.lax.broadcasted_iota(jnp.int32, (block_n, n_trees), 1)
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (block_n, n_trees), 0)
    idx = jnp.zeros((block_n, n_trees), jnp.int32)
    x_flat = x.reshape(-1)                          # (bn * F,)
    for _ in range(depth):
        node = tree_ids * NN + idx
        f = jnp.take(feat, node, axis=0)            # (bn, T)
        t = jnp.take(thr, node, axis=0)
        xv = jnp.take(x_flat, row_ids * n_feat + f, axis=0)
        idx = 2 * idx + 1 + (xv >= t).astype(jnp.int32)
    leaf_idx = tree_ids * NL + (idx - NN)
    vals = jnp.take(leaf, leaf_idx, axis=0)         # (bn, T)
    return jnp.mean(vals, axis=1)


def _kernel(x_ref, feat_ref, thr_ref, leaf_ref, out_ref, *, depth: int,
            n_trees: int, block_n: int, n_feat: int):
    preds = _descend(x_ref[...], feat_ref[...].reshape(-1),
                     thr_ref[...].reshape(-1), leaf_ref[...].reshape(-1),
                     depth=depth, n_trees=n_trees, block_n=block_n,
                     n_feat=n_feat)
    out_ref[:, 0] = preds


def rfr_forest_apply(x, feat, thr, leaf, *, block_n: int = 256,
                     interpret: bool = False):
    """x: (N, F) f32; feat/thr: (T, 2^D-1); leaf: (T, 2^D).
    Returns predictions (N,) f32.  Handles N == 0 (empty drain),
    N < block_n, and N not a multiple of block_n (zero-padded grid)."""
    N, F = x.shape
    T, NN = feat.shape
    depth = (NN + 1).bit_length() - 1
    assert (1 << depth) - 1 == NN, "complete tree layout required"
    if N == 0:
        # bn would be 0 and grid=(N // bn,) a division by zero
        return jnp.zeros((0,), jnp.float32)
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        x = jnp.pad(x, [(0, pad), (0, 0)])
    Np = x.shape[0]

    kernel = functools.partial(_kernel, depth=depth, n_trees=T,
                               block_n=bn, n_feat=F)
    out = pl.pallas_call(
        kernel,
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((bn, F), lambda i: (i, 0)),
            pl.BlockSpec((T, NN), lambda i: (0, 0)),
            pl.BlockSpec((T, NN), lambda i: (0, 0)),
            pl.BlockSpec((T, 1 << depth), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, 1), jnp.float32),
        interpret=interpret,
    )(x, feat, thr, leaf)
    return out[:N, 0]


def _sweep_kernel(x_ref, b_ref, feat_ref, thr_ref, leaf_ref, out_ref, *,
                  depth: int, n_trees: int, block_s: int, m_count: int,
                  rows_per_m: int, n_feat: int, log_target: bool):
    bn = block_s * m_count * rows_per_m
    x = x_ref[...].reshape(bn, n_feat)
    bounds = b_ref[...].reshape(bn)
    preds = _descend(x, feat_ref[...].reshape(-1),
                     thr_ref[...].reshape(-1), leaf_ref[...].reshape(-1),
                     depth=depth, n_trees=n_trees, block_n=bn,
                     n_feat=n_feat)
    if log_target:
        preds = jnp.exp(preds)
    ok = (preds <= bounds).reshape(block_s, m_count, rows_per_m)
    # all R rows of a concurrency must meet QoS; capacity is the longest
    # passing prefix of m = 1..M (a failing m caps every later m, exactly
    # the host sweep's early-exit semantics)
    m_ok = jnp.min(ok.astype(jnp.int32), axis=2)          # (bs, M)
    fails = jnp.cumsum(1 - m_ok, axis=1)
    caps = jnp.sum((fails == 0).astype(jnp.int32), axis=1)
    out_ref[:, 0] = caps


def rfr_capacity_sweep(x, bounds, feat, thr, leaf, *, block_s: int = 0,
                       interpret: bool = False, log_target: bool = False):
    """Fused capacity m-sweep: one Pallas pass over the whole padded
    scenario tensor.

    x: (S, M, R, F) f32 feature rows; bounds: (S, M, R) f32 QoS bounds
    (+inf = padded row, always passes; -inf = m beyond the scenario's
    m_max, always fails); feat/thr/leaf: the flattened forest.  With
    ``log_target`` predictions are exponentiated before the bound
    comparison (the predictor's log-latency regression).  Returns
    (S,) int32 — the max admissible concurrency per scenario.
    """
    S, M, R, F = x.shape
    T, NN = feat.shape
    depth = (NN + 1).bit_length() - 1
    assert (1 << depth) - 1 == NN, "complete tree layout required"
    if S == 0 or M == 0 or R == 0:
        return jnp.zeros((S,), jnp.int32)
    if block_s <= 0:
        # target ~512 feature rows per launch, at least one scenario
        block_s = max(1, 512 // (M * R))
    bs = min(block_s, S)
    pad = (-S) % bs
    x2 = x.reshape(S, M * R * F)
    b2 = bounds.reshape(S, M * R)
    if pad:
        x2 = jnp.pad(x2, [(0, pad), (0, 0)])
        # padded scenarios pass trivially (+inf) and are sliced off
        b2 = jnp.pad(b2, [(0, pad), (0, 0)],
                     constant_values=jnp.float32(jnp.inf))
    Sp = x2.shape[0]

    kernel = functools.partial(_sweep_kernel, depth=depth, n_trees=T,
                               block_s=bs, m_count=M, rows_per_m=R,
                               n_feat=F, log_target=log_target)
    out = pl.pallas_call(
        kernel,
        grid=(Sp // bs,),
        in_specs=[
            pl.BlockSpec((bs, M * R * F), lambda i: (i, 0)),
            pl.BlockSpec((bs, M * R), lambda i: (i, 0)),
            pl.BlockSpec((T, NN), lambda i: (0, 0)),
            pl.BlockSpec((T, NN), lambda i: (0, 0)),
            pl.BlockSpec((T, 1 << depth), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bs, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Sp, 1), jnp.int32),
        interpret=interpret,
    )(x2, b2, feat, thr, leaf)
    return out[:S, 0]
