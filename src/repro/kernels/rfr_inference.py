"""Random-Forest-Regression batched inference — Pallas TPU kernel.

This is the paper's scheduling-latency hot spot (Table 2: model inference
~20 ms dominates cold starts once container init is <10 ms; Jiagu needs
~1 ms).  The forest is flattened to dense complete-tree arrays that fit
VMEM entirely (64 trees x depth 8 ~= 200 KB), so a capacity-solve batch of
inputs is scored in one kernel launch with zero HBM re-reads of the model:

    feat (T, 2^D - 1) int32   split feature per internal node
    thr  (T, 2^D - 1) f32     split threshold
    leaf (T, 2^D)     f32     leaf values

Descent is D unrolled levels of   idx = 2*idx + 1 + (x[feat[idx]] >= thr)
vectorized over (block_n inputs x T trees) — gathers over VMEM-resident
arrays.  Output is the tree-mean prediction.

The un-jitted numpy training half lives in ``repro.core.predictor``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, feat_ref, thr_ref, leaf_ref, out_ref, *, depth: int,
            n_trees: int, block_n: int, n_feat: int):
    x = x_ref[...]                                  # (bn, F)
    feat = feat_ref[...].reshape(-1)                # (T * NN,)
    thr = thr_ref[...].reshape(-1)
    leaf = leaf_ref[...].reshape(-1)                # (T * NL,)
    NN = (1 << depth) - 1
    NL = 1 << depth

    tree_ids = jax.lax.broadcasted_iota(jnp.int32, (block_n, n_trees), 1)
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (block_n, n_trees), 0)
    idx = jnp.zeros((block_n, n_trees), jnp.int32)
    x_flat = x.reshape(-1)                          # (bn * F,)

    for _ in range(depth):
        node = tree_ids * NN + idx
        f = jnp.take(feat, node, axis=0)            # (bn, T)
        t = jnp.take(thr, node, axis=0)
        xv = jnp.take(x_flat, row_ids * n_feat + f, axis=0)
        idx = 2 * idx + 1 + (xv >= t).astype(jnp.int32)

    leaf_idx = tree_ids * NL + (idx - NN)
    vals = jnp.take(leaf, leaf_idx, axis=0)         # (bn, T)
    out_ref[:, 0] = jnp.mean(vals, axis=1)


def rfr_forest_apply(x, feat, thr, leaf, *, block_n: int = 256,
                     interpret: bool = False):
    """x: (N, F) f32; feat/thr: (T, 2^D-1); leaf: (T, 2^D).
    Returns predictions (N,) f32."""
    N, F = x.shape
    T, NN = feat.shape
    depth = (NN + 1).bit_length() - 1
    assert (1 << depth) - 1 == NN, "complete tree layout required"
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        x = jnp.pad(x, [(0, pad), (0, 0)])
    Np = x.shape[0]

    kernel = functools.partial(_kernel, depth=depth, n_trees=T,
                               block_n=bn, n_feat=F)
    out = pl.pallas_call(
        kernel,
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((bn, F), lambda i: (i, 0)),
            pl.BlockSpec((T, NN), lambda i: (0, 0)),
            pl.BlockSpec((T, NN), lambda i: (0, 0)),
            pl.BlockSpec((T, 1 << depth), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, 1), jnp.float32),
        interpret=interpret,
    )(x, feat, thr, leaf)
    return out[:N, 0]
