"""Pure-jnp oracles for every kernel — deliberately naive, O(S^2)/serial,
independent of both the Pallas kernels and the model-layer implementations
so they can grade either."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

_NEG_INF = -2.3819763e38


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        kind: str = "global", window: int = 0,
                        softcap: float = 0.0):
    """q, k, v: (BH, S, D). Full materialized softmax attention."""
    BH, S, D = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    valid = jnp.ones((S, S), bool)
    if causal:
        valid &= qp >= kp
    if kind == "local":
        valid &= (qp - kp) < window
    elif kind == "chunked":
        valid &= (qp // window) == (kp // window)
    s = jnp.where(valid[None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def rglru_scan_ref(a, b, h0=None):
    """Serial recurrence h_t = a_t h_{t-1} + b_t.  a, b: (B, S, W)."""
    B, S, W = a.shape
    h = jnp.zeros((B, W), jnp.float32) if h0 is None else h0

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    _, hs = jax.lax.scan(step, h, (jnp.moveaxis(a, 1, 0),
                                   jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)


def ssd_scan_ref(x, dA, dt, Bm, Cm, h0=None):
    """Serial SSM recurrence (token by token).

    x: (B,H,S,P); dA, dt: (B,H,S); Bm, Cm: (B,H,S,N).
    h_t = exp(dA_t) h_{t-1} + dt_t * x_t B_t^T;  y_t = h_t C_t.
    Returns (y (B,H,S,P), h_final (B,H,P,N))."""
    B, H, S, P = x.shape
    N = Bm.shape[-1]
    h = (jnp.zeros((B, H, P, N), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))

    def step(h, inp):
        x_t, dA_t, dt_t, B_t, C_t = inp  # (B,H,P), (B,H), (B,H), (B,H,N)
        h = (h * jnp.exp(dA_t)[..., None, None]
             + dt_t[..., None, None] * x_t[..., :, None] * B_t[..., None, :])
        y = jnp.einsum("bhpn,bhn->bhp", h, C_t)
        return h, y

    xs = (jnp.moveaxis(x, 2, 0), jnp.moveaxis(dA, 2, 0),
          jnp.moveaxis(dt, 2, 0), jnp.moveaxis(Bm, 2, 0),
          jnp.moveaxis(Cm, 2, 0))
    h_final, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 2).astype(x.dtype), h_final


def rfr_forest_ref(x, feat, thr, leaf):
    """Row-by-row, tree-by-tree descent in plain numpy semantics."""
    import numpy as np
    x = np.asarray(x)
    feat = np.asarray(feat)
    thr = np.asarray(thr)
    leaf = np.asarray(leaf)
    N = x.shape[0]
    T, NN = feat.shape
    depth = (NN + 1).bit_length() - 1
    out = np.zeros(N, np.float32)
    for n in range(N):
        acc = 0.0
        for t in range(T):
            idx = 0
            for _ in range(depth):
                if x[n, feat[t, idx]] >= thr[t, idx]:
                    idx = 2 * idx + 2
                else:
                    idx = 2 * idx + 1
            acc += leaf[t, idx - NN]
        out[n] = acc / T
    return jnp.asarray(out)


def rfr_capacity_sweep_ref(x, bounds, feat, thr, leaf,
                           log_target: bool = False):
    """Scalar-loop oracle for the fused capacity m-sweep: descend every
    (scenario, m, row) feature vector, compare against its QoS bound
    (+inf rows pass, -inf rows fail), and count the longest passing
    prefix of m per scenario.  Returns (S,) int32."""
    import numpy as np
    x = np.asarray(x)
    bounds = np.asarray(bounds)
    S, M, R, F = x.shape
    preds = np.asarray(rfr_forest_ref(x.reshape(S * M * R, F), feat, thr,
                                      leaf)).reshape(S, M, R)
    if log_target:
        preds = np.exp(preds)
    caps = np.zeros(S, np.int32)
    for s in range(S):
        for m in range(M):
            if np.all(preds[s, m] <= bounds[s, m]):
                caps[s] = m + 1
            else:
                break
    return jnp.asarray(caps)
