"""Flash attention forward — Pallas TPU kernel.

Targets the MXU with (block_q x head_dim) @ (head_dim x block_k) tiles held
in VMEM and the classic online-softmax running (m, l, acc) state in VMEM
scratch that persists across the sequential kv grid dimension.  Supports
causal, sliding-window (local) and aligned-chunk masking plus logit
softcapping (gemma2-style).

Layout: q, k, v are (BH, S, D) — batch and heads pre-merged by ops.py
(GQA callers repeat kv to q heads first; the model's XLA path keeps grouped
einsums, this kernel is the TPU hot-spot variant).

Grid: (BH, n_q_blocks, n_kv_blocks); kv innermost so scratch carries the
online softmax state; out written on the last kv step.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -2.3819763e38


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, kind: str, window: int,
            softcap: float, block_q: int, block_k: int, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)          # (bq, D)
    k = k_ref[0].astype(jnp.float32)          # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    valid = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        valid &= q_pos >= k_pos
    if kind == "local":
        valid &= (q_pos - k_pos) < window
    elif kind == "chunked":
        valid &= (q_pos // window) == (k_pos // window)
    s = jnp.where(valid, s, _NEG_INF)

    m_prev = m_ref[:, 0]                       # (bq,)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
    m_ref[:, 0] = m_cur
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jax.lax.dot(p.astype(v_ref.dtype), v_ref[0],
                                  preferred_element_type=jnp.float32))

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, kind: str = "global",
                    window: int = 0, softcap: float = 0.0,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False):
    """q, k, v: (BH, S, D) -> (BH, S, D)."""
    BH, S, D = q.shape
    bq = min(block_q, S)
    bk = min(block_k, S)
    while S % bq:
        bq -= 1
    while S % bk:
        bk -= 1
    n_q, n_kv = S // bq, S // bk
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, kind=kind, window=window,
        softcap=softcap, block_q=bq, block_k=bk, n_kv=n_kv)

    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
