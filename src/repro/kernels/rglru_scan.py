"""RG-LRU linear recurrence h_t = a_t * h_{t-1} + b_t — Pallas TPU kernel.

The recurrence is serial in time but fully parallel over (batch, width), so
the kernel tiles width into VMEM lanes and walks the sequence in blocks:
grid (B, n_w_blocks, n_s_blocks) with the sequence dim innermost; the carry
h lives in VMEM scratch persisting across sequence-grid steps.  Within a
block, a ``fori_loop`` performs ``block_s`` vectorized (width-wide) steps —
on TPU each step is one VPU multiply-add over the (8, 128)-tiled width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, h_ref, carry_ref, *, block_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        carry_ref[0, :] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0]                     # (block_s, block_w) fp32
    b = b_ref[0]

    def step(t, h):
        h = a[t] * h + b[t]
        h_ref[0, t, :] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, carry_ref[0, :])
    carry_ref[0, :] = h


def rglru_scan(a, b, h0=None, *, block_s: int = 256, block_w: int = 512,
               interpret: bool = False):
    """a, b: (B, S, W) fp32; h0: (B, W) fp32 or None. Returns h (B, S, W)."""
    B, S, W = a.shape
    bs = min(block_s, S)
    while S % bs:
        bs -= 1
    bw = min(block_w, W)
    while W % bw:
        bw -= 1
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)

    kernel = functools.partial(_kernel, block_s=bs)
    return pl.pallas_call(
        kernel,
        grid=(B, W // bw, S // bs),
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, bs, bw), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, bw), lambda bi, wi, si: (bi, wi)),
        ],
        out_specs=pl.BlockSpec((1, bs, bw), lambda bi, wi, si: (bi, si, wi)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
