"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

One grid step = one (batch, head, chunk) cell.  The chunk dimension is the
innermost (sequential) grid axis; the carried SSM state (head_dim x d_state)
lives in VMEM scratch across chunk steps.  Within a chunk everything is MXU
matmuls over (chunk x chunk) / (chunk x d_state) / (chunk x head_dim) tiles:

    y_diag = ((C B^T) .* L .* dt) x          within-chunk "attention"
    y_off  = exp(cum) .* (C h_in^T)          contribution of carried state
    h_out  = exp(sum_dA) h_in + x^T (B .* w) state update

Layouts (pre-transposed by ops.py): x (B, H, S, P); dt/dA (B, H, S);
Bm/Cm (B, H, S, N).  Outputs: y (B, H, S, P), final state (B, H, P, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, da_ref, dt_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref,
            state_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)        # (c, P)
    dA = da_ref[0, 0].astype(jnp.float32)      # (c,)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (c,)
    Bm = b_ref[0, 0].astype(jnp.float32)       # (c, N)
    Cm = c_ref[0, 0].astype(jnp.float32)       # (c, N)

    cums = jnp.cumsum(dA)                      # (c,)
    # lower-triangular decay matrix L[i, j] = exp(cums[i] - cums[j]), i >= j
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = cums[:, None] - cums[None, :]
    L = jnp.where(li >= lj, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    M = scores * L * dt[None, :]
    y = jax.lax.dot(M, x, preferred_element_type=jnp.float32)

    h_in = state_ref[...]                      # (P, N)
    y += jnp.exp(cums)[:, None] * jax.lax.dot_general(
        Cm, h_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    w = (jnp.exp(cums[-1] - cums) * dt)[:, None]   # (c, 1)
    h_new = (h_in * jnp.exp(cums[-1])
             + jax.lax.dot_general(x, Bm * w, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    state_ref[...] = h_new
    y_ref[0, 0] = y.astype(y_ref.dtype)
    hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


def ssd_scan(x, dA, dt, Bm, Cm, h0=None, *, chunk: int = 256,
             interpret: bool = False):
    """x: (B,H,S,P); dA, dt: (B,H,S); Bm, Cm: (B,H,S,N); h0: (B,H,P,N).
    Returns (y (B,H,S,P), h_final (B,H,P,N))."""
    B, H, S, P = x.shape
    N = Bm.shape[-1]
    c = min(chunk, S)
    while S % c:
        c -= 1
    nc = S // c
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    kernel = functools.partial(_kernel, chunk=c, n_chunks=nc)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, c, P), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, c), lambda b, h, i: (b, h, i)),
            pl.BlockSpec((1, 1, c), lambda b, h, i: (b, h, i)),
            pl.BlockSpec((1, 1, c, N), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, c, N), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, P), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dA, dt, Bm, Cm, h0)
