"""Jit'd public wrappers around the Pallas kernels.

Each op accepts model-native layouts, handles GQA head expansion /
transposes, and dispatches to the Pallas kernel (``use_pallas=True``,
``interpret=True`` for CPU validation) or the jnp oracle.  On this CPU
container the kernels are exercised in interpret mode; on TPU the same
call sites compile the real kernels.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .rfr_inference import rfr_capacity_sweep, rfr_forest_apply
from .rglru_scan import rglru_scan
from .ssd_scan import ssd_scan


@partial(jax.jit, static_argnames=("causal", "kind", "window", "softcap",
                                   "use_pallas", "interpret"))
def attention_op(q, k, v, *, causal=True, kind="global", window=0,
                 softcap=0.0, use_pallas=True, interpret=True):
    """q: (B, S, Hq, D); k, v: (B, S, Hkv, D) -> (B, S, Hq, D)."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    if Hkv != Hq:
        k = jnp.repeat(k, Hq // Hkv, axis=2)
        v = jnp.repeat(v, Hq // Hkv, axis=2)
    qm = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    km = k.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    vm = v.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    fn = (partial(flash_attention, interpret=interpret) if use_pallas
          else ref.flash_attention_ref)
    out = fn(qm, km, vm, causal=causal, kind=kind, window=window,
             softcap=softcap)
    return out.reshape(B, Hq, S, D).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def rglru_op(a, b, h0=None, *, use_pallas=True, interpret=True):
    """a, b: (B, S, W) fp32 -> h (B, S, W)."""
    if use_pallas:
        return rglru_scan(a, b, h0, interpret=interpret)
    return ref.rglru_scan_ref(a, b, h0)


@partial(jax.jit, static_argnames=("chunk", "use_pallas", "interpret"))
def ssd_op(x, dt, A, Bm, Cm, h0=None, *, chunk=256, use_pallas=True,
           interpret=True):
    """Model layout: x (B,S,H,P); dt (B,S,H) post-softplus; A (H,) negative;
    Bm, Cm: (B,S,H,N).  Returns (y (B,S,H,P), h_final (B,H,P,N))."""
    xt = x.transpose(0, 2, 1, 3)
    dtt = dt.transpose(0, 2, 1)
    dA = dtt * A[None, :, None]
    Bt = Bm.transpose(0, 2, 1, 3)
    Ct = Cm.transpose(0, 2, 1, 3)
    if use_pallas:
        y, h = ssd_scan(xt, dA, dtt, Bt, Ct, h0, chunk=chunk,
                        interpret=interpret)
    else:
        y, h = ref.ssd_scan_ref(xt, dA, dtt, Bt, Ct, h0)
    return y.transpose(0, 2, 1, 3), h


def _forest_gather(x, feat, thr, leaf):
    """Pure-jnp level-synchronous forest descent (the predictor's
    ``engine="jax"``): vectorized gathers, traceable under jit — the
    numpy ``ref.rfr_forest_ref`` oracle cannot run inside a traced
    function.  x (N, F) -> (N,) f32."""
    N = x.shape[0]
    T, NN = feat.shape
    depth = (NN + 1).bit_length() - 1
    t_ids = jnp.arange(T)[None, :]                       # (1, T)
    idx = jnp.zeros((N, T), jnp.int32)
    rows = jnp.arange(N)[:, None]                        # (N, 1)
    for _ in range(depth):
        f = feat[t_ids, idx]                             # (N, T)
        t = thr[t_ids, idx]
        go_right = (x[rows, f] >= t).astype(jnp.int32)
        idx = 2 * idx + 1 + go_right
    vals = leaf[t_ids, idx - NN]
    return jnp.mean(vals, axis=1).astype(jnp.float32)


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def rfr_op(x, feat, thr, leaf, *, use_pallas=True, interpret=True):
    """Forest inference: x (N, F) -> (N,) predictions."""
    if use_pallas:
        return rfr_forest_apply(x, feat, thr, leaf, interpret=interpret)
    return _forest_gather(x, feat, thr, leaf)


@partial(jax.jit, static_argnames=("use_pallas", "interpret", "log_target"))
def rfr_sweep_op(x, bounds, feat, thr, leaf, *, use_pallas=True,
                 interpret=True, log_target=False):
    """Fused capacity m-sweep: the device-resident drain's one pass.

    x (S, M, R, F) padded scenario feature rows; bounds (S, M, R) with
    +inf = padded row (always passes) and -inf = m beyond a scenario's
    m_max (always fails).  Returns (S,) int32 max-admissible m.
    ``use_pallas=False`` runs the same sweep as jnp gathers + reductions
    (the ``engine="jax"`` device path and the kernel's traced oracle)."""
    if use_pallas:
        return rfr_capacity_sweep(x, bounds, feat, thr, leaf,
                                  interpret=interpret,
                                  log_target=log_target)
    S, M, R, F = x.shape
    if S == 0 or M == 0 or R == 0:
        return jnp.zeros((S,), jnp.int32)
    preds = _forest_gather(x.reshape(S * M * R, F), feat, thr, leaf)
    if log_target:
        preds = jnp.exp(preds)
    ok = (preds <= bounds.reshape(-1)).reshape(S, M, R)
    m_ok = jnp.min(ok.astype(jnp.int32), axis=2)         # (S, M)
    fails = jnp.cumsum(1 - m_ok, axis=1)
    return jnp.sum((fails == 0).astype(jnp.int32), axis=1).astype(jnp.int32)
