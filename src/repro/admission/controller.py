"""``AdmissionController`` — the per-cell admission/vertical control
loop the simulator drives.

Each simulated tick splits into two admission phases around the
horizontal autoscaler:

  * ``enqueue(now, rps, cluster)`` — arrivals enter the per-function
    bounded queues (the configured admit stage decides overflow), and
    the *scaling signal* the autoscaler will see is derived from queue
    state instead of instantaneous rps (``signal="queue"``, the
    KEDA-style backpressure mode):

        signal = max(min(arrivals, service_rate), depth / target_drain_s)

    A one-tick spike beyond the fleet's current service rate lands in
    the queue; only a backlog that *persists* (depth still high after
    draining) raises the signal, so storms scale out over a few ticks
    of geometric catch-up instead of insta-scaling to the spike peak —
    fewer cold starts, and the burst becomes measurable queueing delay.
    ``signal="rps"`` keeps the legacy instantaneous signal (the
    horizontal-only benchmark arm) while the queues still meter and
    account traffic identically.
  * ``drain(now, cluster, res)`` — after scaling (logical cold starts
    are instant, so fresh capacity is already live), the release stage
    drains each backlog into service up to the fleet's current service
    rate.  The released traffic is what the measurement pass routes;
    its exact per-bucket queueing delays are sampled into
    ``SimResult.queue_delay_s`` and checked against the function's SLO
    class budget — latency-critical requests violate on a tight budget,
    best-effort absorbs queueing.  Overflow drops count as violated
    requests of their class (they were never served).

Per-request conservation (arrived == released + dropped + pending)
holds queue-by-queue; ``conservation_error()`` exposes the fleet
residual and the benchmark gates it at float-eps.

The controller is strictly per-cell state (its queues see only the
cell's traffic share), which keeps the ``cells=1`` wrap bit-exact:
disabled admission is ``None`` everywhere — not a pass-through object
— so every parity code path is structurally unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Set

from .queue import (BoundedFifoAdmit, FunctionQueue, GreedyQueueRelease,
                    PacedQueueRelease, ShedOldestAdmit)
from .slo import LATENCY_CRITICAL, delay_budget_s, tag_slo_classes
from .vertical import VerticalScaler

_EPS = 1e-9

#: admission-local stage factories (platform re-registers these under
#: its ``admit:`` / ``queue-release:`` registry keys for config-driven
#: selection; keeping the authoritative dicts here avoids an import
#: cycle with ``core.platform``)
ADMIT_STAGES = {
    BoundedFifoAdmit.name: BoundedFifoAdmit,
    ShedOldestAdmit.name: ShedOldestAdmit,
}
RELEASE_STAGES = {
    GreedyQueueRelease.name: GreedyQueueRelease,
    PacedQueueRelease.name: PacedQueueRelease,
}


@dataclass
class AdmissionConfig:
    """Standalone mirror of ``PlatformConfig.admission`` (same fields,
    same defaults) for direct library/test construction."""

    enabled: bool = True
    vertical: bool = False
    signal: str = "queue"            # "queue" | "rps"
    best_effort_frac: float = 0.5
    slo_seed: int = 0
    queue_cap_s: float = 8.0         # bound, in seconds of arrival rate
    target_drain_s: float = 2.0      # KEDA signal: drain backlog in ~2s
    lc_delay_budget_s: float = 0.25  # latency-critical queueing budget
    be_delay_budget_s: float = 8.0   # best-effort absorbs this much
    catch_up_mult: float = 1.5       # backlog catch-up cap, x arrival peak
    admit: str = "bounded-fifo"
    queue_release: str = "greedy"
    min_share: float = 0.5           # vertical shrink floor
    resize_every_s: float = 15.0


class AdmissionController:
    """Queues + SLO classes + (optionally) the vertical resizer for one
    cluster/cell."""

    def __init__(self, specs, cfg=None, *, store=None,
                 slo: Optional[Dict[str, str]] = None):
        self.specs = specs
        self.cfg = cfg = cfg or AdmissionConfig()
        self.slo: Dict[str, str] = dict(slo) if slo is not None else \
            tag_slo_classes(specs, cfg.best_effort_frac, cfg.slo_seed)
        try:
            self.admit_stage = ADMIT_STAGES[cfg.admit]()
            self.release_stage = RELEASE_STAGES[cfg.queue_release]()
        except KeyError as e:
            raise ValueError(
                f"unknown admission stage {e.args[0]!r} (admit: "
                f"{sorted(ADMIT_STAGES)}, queue-release: "
                f"{sorted(RELEASE_STAGES)})") from None
        self.queues: Dict[str, FunctionQueue] = {}
        self.vertical: Optional[VerticalScaler] = None
        if getattr(cfg, "vertical", False):
            self.vertical = VerticalScaler(
                specs, self.slo, min_share=cfg.min_share,
                resize_every_s=cfg.resize_every_s, store=store)
        #: functions with a non-empty backlog (drives drain + the
        #: event-core due sets)
        self._pending: Set[str] = set()
        #: per-tick drops buffered between enqueue and drain (drain
        #: owns all SimResult accounting)
        self._dropped_now: Dict[str, float] = {}
        #: peak-hold arrival-rate EWMA sizing the queue bound
        self._ewma: Dict[str, float] = {}
        #: post-drain backlog snapshot (fn -> depth) from the previous
        #: tick — the vertical resizer's pressure signal (mid-tick
        #: queue depth counts still-undrained arrivals, not pressure)
        self._backlog: Dict[str, float] = {}
        self.depth_peak = 0.0

    # -- phase 1: arrivals + scaling signal ------------------------------

    def enqueue(self, now: float, rps: Dict[str, float],
                cluster) -> Dict[str, float]:
        """Admit this tick's arrivals; return the autoscaler's signal
        (covers every function in ``rps`` plus any with backlog)."""
        cfg = self.cfg
        signal = dict(rps)
        fns = [fn for fn, v in rps.items() if v > _EPS]
        if self._pending:
            fns += [fn for fn in self._pending
                    if rps.get(fn, 0.0) <= _EPS]
        for fn in fns:
            spec = self.specs[fn]
            arr = rps.get(fn, 0.0)
            q = self.queues.get(fn)
            if q is None:
                q = self.queues[fn] = FunctionQueue(
                    fn, cfg.queue_cap_s * spec.saturated_rps)
            # peak-hold EWMA keeps the bound from collapsing onto a
            # still-draining backlog the tick a storm ends
            ew = max(arr, 0.9 * self._ewma.get(fn, 0.0))
            self._ewma[fn] = ew
            q.cap = cfg.queue_cap_s * max(spec.saturated_rps, ew)
            _accepted, dropped = self.admit_stage.admit(q, arr, now)
            if dropped > _EPS:
                self._dropped_now[fn] = \
                    self._dropped_now.get(fn, 0.0) + dropped
            if q.depth > _EPS:
                self._pending.add(fn)
                if q.depth > self.depth_peak:
                    self.depth_peak = q.depth
            else:
                self._pending.discard(fn)
            if cfg.signal == "queue":
                # catch-up provisioning to drain the backlog in
                # ~target_drain_s, capped at catch_up_mult x the
                # peak-held arrival rate: a storm-sized backlog must
                # not insta-scale the fleet to the backlog itself
                # (that is the horizontal-only failure mode the queue
                # exists to absorb)
                catch_up = min(q.depth / cfg.target_drain_s,
                               cfg.catch_up_mult * max(ew, arr))
                if self.slo.get(fn) == LATENCY_CRITICAL:
                    # latency-critical cannot afford queueing (any
                    # queued tick blows a sub-second budget): scale on
                    # instantaneous arrivals plus backlog catch-up
                    signal[fn] = max(arr, catch_up)
                else:
                    # best-effort absorbs the burst: the autoscaler
                    # sees at most current capacity until a backlog
                    # *persists* past drains — geometric catch-up
                    # instead of insta-scaling to the storm peak
                    rate = cluster.sat_count(fn) * spec.saturated_rps
                    signal[fn] = max(min(arr, rate), catch_up)
            else:
                signal[fn] = arr
        return signal

    def pending_fns(self) -> Set[str]:
        return set(self._pending)

    # -- phase 2: drain into service + accounting ------------------------

    def drain(self, now: float, cluster, res) -> Dict[str, float]:
        """Release backlog into service at the fleet's current rate;
        account queue delays, class budgets and drops into ``res``.
        Returns the served rps dict the measurement pass routes."""
        cfg = self.cfg
        served: Dict[str, float] = {}
        for fn in sorted(self._pending):
            q = self.queues[fn]
            spec = self.specs[fn]
            rate = cluster.sat_count(fn) * spec.saturated_rps
            buckets = self.release_stage.release(q, rate, now)
            cls = self.slo.get(fn)
            budget = delay_budget_s(cls, cfg.lc_delay_budget_s,
                                    cfg.be_delay_budget_s)
            got = viol = 0.0
            for t0, c in buckets:
                d = now - t0
                got += c
                res.queue_delay_s.append(d)
                if d > budget:
                    viol += c
            if got > _EPS:
                served[fn] = got
            if viol > _EPS:
                # queueing blew the class budget: violated regardless
                # of how fast execution itself is (the requests are
                # still served and counted by the measurement pass)
                res.violated_requests += viol
                res.per_fn_violations[fn] = \
                    res.per_fn_violations.get(fn, 0.0) + viol
                res.class_violations[cls] = \
                    res.class_violations.get(cls, 0.0) + viol
            if q.depth <= _EPS:
                self._pending.discard(fn)
        self._backlog = {fn: q.depth for fn, q in self.queues.items()
                         if q.depth > _EPS}
        if self._dropped_now:
            for fn, d in self._dropped_now.items():
                cls = self.slo.get(fn)
                # never served: count arrival AND violation here (the
                # measurement pass will not see these requests)
                res.requests += d
                res.violated_requests += d
                res.dropped_requests += d
                res.per_fn_requests[fn] = \
                    res.per_fn_requests.get(fn, 0.0) + d
                res.per_fn_violations[fn] = \
                    res.per_fn_violations.get(fn, 0.0) + d
                res.class_requests[cls] = \
                    res.class_requests.get(cls, 0.0) + d
                res.class_violations[cls] = \
                    res.class_violations.get(cls, 0.0) + d
            self._dropped_now.clear()
        return served

    # -- vertical + trace hooks ------------------------------------------

    def vertical_tick(self, now: float, cluster, scheduler,
                      events) -> None:
        if self.vertical is not None:
            self.vertical.tick(now, cluster, scheduler, self._backlog,
                               events)

    def stamp_trace(self, trace, fn: str, now: float) -> None:
        """Decision-trace admission context (schema v3 fields)."""
        q = self.queues.get(fn)
        trace.queue_depth = q.depth if q is not None else 0.0
        trace.queue_age_s = q.oldest_age(now) if q is not None else 0.0
        trace.slo_class = self.slo.get(fn)

    # -- bookkeeping ------------------------------------------------------

    def totals(self) -> Dict[str, float]:
        t = {"arrived": 0.0, "released": 0.0, "dropped": 0.0,
             "depth": 0.0}
        for q in self.queues.values():
            t["arrived"] += q.arrived
            t["released"] += q.released
            t["dropped"] += q.dropped
            t["depth"] += q.depth
        return t

    def queue_depth(self) -> float:
        return sum(q.depth for q in self.queues.values())

    def conservation_error(self) -> float:
        return max((q.conservation_error()
                    for q in self.queues.values()), default=0.0)

    def finalize(self, res) -> None:
        """Fold end-of-run admission state into the SimResult (cells
        call this once per cell controller; counters accumulate)."""
        res.queue_depth_peak = max(res.queue_depth_peak,
                                   self.depth_peak)
        if self.vertical is not None:
            res.vertical_grows += self.vertical.grows
            res.vertical_shrinks += self.vertical.shrinks
