"""Bounded per-function pending-request queues with pluggable
admit/release stages.

Jiagu admits every request instantly: a burst storm translates 1:1
into scale-up demand and the only latency a request can suffer is
execution latency.  Real platforms (KEDA-style queue scalers,
Knative's activator) put a bounded buffer in front of each function:
requests beyond the fleet's current service rate *queue*, queue depth
and age become the autoscaler's signal, and overflow is shed.  This
module is that buffer:

  * ``FunctionQueue`` — one bounded FIFO per function.  Depth is
    fractional (the simulator works in request-rates, not discrete
    requests); arrivals enter as per-tick *buckets* stamped with their
    arrival time, so a FIFO drain knows the exact queueing delay of
    every released request without per-request bookkeeping.
  * ``AdmitStage`` implementations decide what happens at the bound:
    ``bounded-fifo`` rejects the newest arrivals (classic bounded
    queue), ``shed-oldest`` admits the new traffic and drops the
    stalest backlog (bounded staleness — the dropped requests would
    have blown their delay budget anyway).
  * ``QueueReleaseStage`` implementations decide how fast the backlog
    drains into service: ``greedy`` releases up to the fleet's full
    current service rate, ``paced`` keeps a fraction of it in reserve
    so a draining backlog cannot re-saturate freshly placed instances.

Conservation is the load-bearing invariant — every request that ever
arrived is exactly one of {released, dropped, still pending}:

    arrived == released + dropped + depth

``FunctionQueue.conservation_error()`` exposes the residual and
``tests/test_admission.py`` drives it with randomized admit/release/
drop sequences.

Stages are registered in the platform stage registry under the
``admit:`` and ``queue-release:`` kinds (see ``core/platform.py``), so
``PlatformConfig.admission`` selects them by name like any pipeline
stage.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, List, Tuple

#: released-bucket record: (arrival_time, count)
Released = List[Tuple[float, float]]

_EPS = 1e-9


class FunctionQueue:
    """Bounded FIFO of pending requests for one function.

    ``buckets`` holds ``[arrival_time, count]`` pairs in arrival order;
    ``depth`` mirrors their sum so depth reads are O(1).  All counts
    are floats (request *mass* per tick, matching the simulator's
    rate-based traffic model)."""

    __slots__ = ("fn", "cap", "buckets", "depth",
                 "arrived", "released", "dropped")

    def __init__(self, fn: str, cap: float):
        self.fn = fn
        self.cap = float(cap)
        self.buckets: Deque[List[float]] = deque()
        self.depth = 0.0
        # lifetime conservation counters
        self.arrived = 0.0
        self.released = 0.0
        self.dropped = 0.0

    # -- primitive ops (stages build on these) --------------------------

    def push(self, now: float, count: float) -> None:
        if count <= _EPS:
            return
        self.arrived += count
        if self.buckets and self.buckets[-1][0] == now:
            self.buckets[-1][1] += count
        else:
            self.buckets.append([now, count])
        self.depth += count

    def drop_newest(self, count: float) -> float:
        """Shed up to ``count`` of the most recent arrivals (reject at
        the door).  Returns the amount actually dropped."""
        got = 0.0
        while count > _EPS and self.buckets:
            t, c = self.buckets[-1]
            take = min(c, count)
            if take >= c - _EPS:
                self.buckets.pop()
                take = c
            else:
                self.buckets[-1][1] = c - take
            got += take
            count -= take
        self.depth -= got
        self.dropped += got
        return got

    def drop_oldest(self, count: float) -> float:
        """Shed up to ``count`` of the stalest backlog."""
        got = 0.0
        while count > _EPS and self.buckets:
            t, c = self.buckets[0]
            take = min(c, count)
            if take >= c - _EPS:
                self.buckets.popleft()
                take = c
            else:
                self.buckets[0][1] = c - take
            got += take
            count -= take
        self.depth -= got
        self.dropped += got
        return got

    def pop(self, count: float) -> Released:
        """FIFO-release up to ``count`` requests into service.  Returns
        the released ``(arrival_time, count)`` buckets (oldest first)
        so the caller can account exact queueing delays."""
        out: Released = []
        while count > _EPS and self.buckets:
            t, c = self.buckets[0]
            take = min(c, count)
            if take >= c - _EPS:
                self.buckets.popleft()
                take = c
            else:
                self.buckets[0][1] = c - take
            out.append((t, take))
            count -= take
        got = sum(c for _t, c in out)
        self.depth -= got
        self.released += got
        return out

    # -- reads ----------------------------------------------------------

    def oldest_age(self, now: float) -> float:
        """Age of the queue head — the worst queueing delay any pending
        request has accumulated so far."""
        return (now - self.buckets[0][0]) if self.buckets else 0.0

    def conservation_error(self) -> float:
        """|arrived - released - dropped - depth| — zero (to float eps)
        by construction; tests and the benchmark assert it."""
        return abs(self.arrived - self.released - self.dropped
                   - self.depth)


# ---------------------------------------------------------------------------
# Admit stages (what happens at the bound)
# ---------------------------------------------------------------------------


class BoundedFifoAdmit:
    """Classic bounded queue: arrivals beyond the cap are rejected at
    the door (newest dropped first)."""

    name = "bounded-fifo"

    def admit(self, q: FunctionQueue, arriving: float,
              now: float) -> Tuple[float, float]:
        """Returns (accepted, dropped)."""
        if arriving <= _EPS:
            return 0.0, 0.0
        accepted = min(arriving, max(q.cap - q.depth, 0.0))
        dropped = arriving - accepted
        q.push(now, accepted)
        if dropped > _EPS:
            # account the rejection on the queue's conservation ledger
            q.arrived += dropped
            q.dropped += dropped
        else:
            dropped = 0.0
        return accepted, dropped


class ShedOldestAdmit:
    """Bounded staleness: new traffic always enters; overflow sheds the
    oldest backlog (it would have blown its delay budget anyway)."""

    name = "shed-oldest"

    def admit(self, q: FunctionQueue, arriving: float,
              now: float) -> Tuple[float, float]:
        if arriving <= _EPS:
            return 0.0, 0.0
        q.push(now, arriving)
        dropped = 0.0
        if q.depth > q.cap:
            dropped = q.drop_oldest(q.depth - q.cap)
        return arriving, dropped


# ---------------------------------------------------------------------------
# Release stages (how fast the backlog drains into service)
# ---------------------------------------------------------------------------


class GreedyQueueRelease:
    """Drain up to the fleet's full current service rate."""

    name = "greedy"

    def release(self, q: FunctionQueue, capacity_rps: float,
                now: float) -> Released:
        if capacity_rps <= _EPS or q.depth <= _EPS:
            return []
        return q.pop(capacity_rps)


class PacedQueueRelease:
    """Drain to at most ``pace`` of the service rate, keeping headroom
    so a deep backlog cannot re-saturate freshly placed instances the
    tick they appear."""

    name = "paced"

    def __init__(self, pace: float = 0.9):
        self.pace = pace

    def release(self, q: FunctionQueue, capacity_rps: float,
                now: float) -> Released:
        if capacity_rps <= _EPS or q.depth <= _EPS:
            return []
        return q.pop(capacity_rps * self.pace)
