"""``repro.admission`` — vertical scaling, queue-backed admission and
SLO classes as first-class scenario axes.

Three coupled pieces (see module docstrings for detail):

  * :mod:`.queue` — bounded per-function pending-request queues with
    pluggable admit/release stages (registered in the platform stage
    registry as ``admit:*`` / ``queue-release:*``);
  * :mod:`.slo` — ``latency-critical`` vs ``best-effort`` population
    tagging with per-class queue-delay budgets;
  * :mod:`.vertical` — per-function cpu-reservation resize, solved
    through the PredictionService capacity table, driving the
    harvesting scheduler's per-function harvest bounds;
  * :mod:`.controller` — the per-cell ``AdmissionController`` the
    simulator's run loops drive (``enqueue`` -> autoscale -> ``drain``
    -> measure).

Everything is default-off: a ``PlatformConfig`` without an enabled
``admission`` section builds the exact pre-admission control plane
(``AdmissionController`` is ``None``, not a pass-through), which is
what the admission-off bit-parity gates in ``tests/test_admission.py``
pin down.
"""
from .controller import (ADMIT_STAGES, RELEASE_STAGES, AdmissionConfig,
                         AdmissionController)
from .queue import (BoundedFifoAdmit, FunctionQueue, GreedyQueueRelease,
                    PacedQueueRelease, ShedOldestAdmit)
from .slo import (BEST_EFFORT, LATENCY_CRITICAL, SLO_CLASSES,
                  delay_budget_s, tag_slo_classes)
from .vertical import VerticalScaler

__all__ = [
    "AdmissionConfig", "AdmissionController", "ADMIT_STAGES",
    "RELEASE_STAGES", "FunctionQueue", "BoundedFifoAdmit",
    "ShedOldestAdmit", "GreedyQueueRelease", "PacedQueueRelease",
    "VerticalScaler", "LATENCY_CRITICAL", "BEST_EFFORT", "SLO_CLASSES",
    "tag_slo_classes", "delay_budget_s",
]
