"""Vertical resize: shrink/grow a running instance's cpu reservation,
re-solved through the PredictionService capacity table.

"Tiny Autoscalers" (arxiv 2203.00592) shows per-function dynamic CPU
allocation is a utilization win independent of horizontal scaling.
Here the autoscaler's tick ends with a vertical pass that harvests the
*reservation* of over-provisioned best-effort functions:

  * every function's cpu request is conservative (``cpu_req``); its
    solo-run profile measured what it actually uses at saturation
    (``mcpu``, observable — the ground-truth ``cpu_work`` stays
    hidden, exactly the paper's profiling methodology).  The safe
    floor of a shrink is ``mcpu / (cpu_req * safe_util)``: the
    instance keeps its working cpu plus ``1/safe_util`` slack.
  * shrinks apply per node (``Node.shares``) and only after the
    PredictionService confirms the node's current packing is within
    its predicted-QoS capacity (``capacity_hint`` against the live
    colocation) — a resize never violates predicted QoS, and nodes
    whose table the service has not solved yet are left alone.
  * a shrunk function's *harvest bound* rises: its instances reserve
    ``share`` of their former footprint, so the harvesting scheduler
    may pack ``headroom / share`` of the predicted capacity (capped at
    ``bound_cap < 1`` — the bound can approach but never exceed the
    capacity table, so packing stays inside the predicted-QoS-safe
    region).  This is the per-function harvest bound the PR-5
    follow-up asked for; with no vertical activity every function
    falls back to the scheduler's global scalar and placement is
    bit-identical.
  * queue pressure (depth > 0) or any latency-critical tag grows the
    function straight back to full share — growth is always safe (it
    only returns reservation).

Grow/shrink transitions are emitted through ``events.on_scale`` as
``"vertical_grow"`` / ``"vertical_shrink"`` (count = instances whose
reservation changed), riding the same observer stream as every other
scaling transition.
"""
from __future__ import annotations

from typing import Dict, Optional

from .slo import BEST_EFFORT

_EPS = 1e-9


class VerticalScaler:
    """Plans and applies per-function cpu-share targets."""

    def __init__(self, specs, slo: Dict[str, str], *,
                 min_share: float = 0.5,
                 safe_util: float = 0.8,
                 bound_cap: float = 0.98,
                 lc_guard: float = 0.15,
                 resize_every_s: float = 15.0,
                 store=None):
        self.specs = specs
        self.slo = slo
        self.min_share = min_share
        self.safe_util = safe_util
        self.bound_cap = bound_cap
        #: extra reservation a latency-critical shrink keeps above the
        #: floor (best-effort is harvested first and deepest)
        self.lc_guard = lc_guard
        self.resize_every_s = resize_every_s
        self.store = store
        #: current per-function share (absent -> 1.0, never resized)
        self.share: Dict[str, float] = {}
        self.grows = 0
        self.shrinks = 0
        self._last = float("-inf")

    # -- policy ----------------------------------------------------------

    def floor_share(self, fn: str) -> float:
        """The lowest safe reservation share for ``fn``: solo-measured
        cpu over the request, with ``1/safe_util`` slack — clamped into
        ``[min_share, 1]``.  Falls back to ``min_share`` when no
        profile store is attached."""
        spec = self.specs[fn]
        if self.store is None:
            return self.min_share
        mcpu = float(self.store.profile(spec)[0])
        safe = mcpu / max(spec.cpu_req * self.safe_util, _EPS)
        return min(1.0, max(self.min_share, safe))

    def target_share(self, fn: str, queue_depth: float) -> float:
        """Any function with an empty (post-drain) queue shrinks toward
        its measured solo footprint — pressure means full reservation.
        Best-effort goes all the way to the floor; latency-critical
        keeps ``lc_guard`` extra reservation on top of it (harvested
        last, per the class contract)."""
        if queue_depth > _EPS:
            return 1.0
        floor = self.floor_share(fn)
        if self.slo.get(fn) == BEST_EFFORT:
            return floor
        return min(1.0, floor + self.lc_guard)

    def harvest_bound(self, fn: str, headroom: float) -> Optional[float]:
        """Per-function harvest bound implied by the current share, or
        None for the scheduler's global default (share == 1).  The cap
        is class-tiered: best-effort may pack to ``bound_cap`` of the
        predicted capacity, latency-critical keeps ``lc_guard`` of the
        bound in reserve — harvested last, shallower."""
        s = self.share.get(fn, 1.0)
        if s >= 1.0 - _EPS:
            return None
        cap = self.bound_cap
        if self.slo.get(fn) != BEST_EFFORT:
            cap = max(headroom, self.bound_cap - self.lc_guard)
        return min(cap, headroom / s)

    # -- application ------------------------------------------------------

    def tick(self, now: float, cluster, scheduler, depths,
             events) -> None:
        """One vertical pass (rate-limited to ``resize_every_s``):
        retarget every function with live instances, apply per-node,
        refresh the scheduler's per-function harvest bounds.

        ``depths`` maps fn -> *post-drain* backlog (the controller's
        snapshot from the previous tick's drain): mid-tick the queues
        always hold this tick's still-undrained arrivals, which is not
        pressure — only backlog that survived a drain is."""
        if now - self._last < self.resize_every_s:
            return
        self._last = now
        svc = getattr(scheduler, "prediction_service", None)
        if svc is None:
            return      # no capacity table to solve resizes against
        bounds = getattr(scheduler, "harvest_bounds", None)
        headroom = getattr(scheduler, "harvest_headroom", 0.85)
        for fn in self.specs:
            if cluster.sat_count(fn) + cluster.cached_count(fn) <= 0:
                if self.share.pop(fn, None) is not None and \
                        bounds is not None:
                    bounds.pop(fn, None)
                continue
            target = self.target_share(fn, depths.get(fn, 0.0))
            cur = self.share.get(fn, 1.0)
            if abs(target - cur) <= _EPS:
                continue
            changed = self._apply(fn, target, cur, cluster, svc)
            if changed:
                if target >= 1.0 - _EPS:
                    self.share.pop(fn, None)
                    self.grows += 1
                else:
                    self.share[fn] = target
                    self.shrinks += 1
                events.on_scale(now, fn,
                                "vertical_grow" if target > cur
                                else "vertical_shrink", changed)
            if bounds is not None:
                b = self.harvest_bound(fn, headroom)
                if b is None:
                    bounds.pop(fn, None)
                else:
                    bounds[fn] = b

    def _apply(self, fn: str, target: float, cur: float, cluster,
               svc) -> int:
        """Apply ``target`` share on every node hosting ``fn``.  Grows
        are unconditional (returning reservation is always safe);
        shrinks require the node's live packing to sit within its
        predicted-QoS capacity.  Returns instances resized."""
        changed = 0
        for node in cluster.nodes_with(fn):
            st = node.funcs.get(fn)
            if st is None or st.total <= 0:
                continue
            if target < cur:
                # predicted-QoS capacity for the node's live packing:
                # the service cache when the exact colocation was
                # solved, else the node's async-maintained capacity
                # table entry (the Jiagu pre-decision table)
                cap = svc.capacity_hint(svc.node_coloc(node), fn,
                                        node_res=node.res)
                if cap is None:
                    entry = node.table.get(fn)
                    cap = entry.capacity if entry is not None else None
                if cap is None or st.total > cap:
                    continue    # unsolved or already at predicted edge
            if target >= 1.0 - _EPS:
                if node.shares.pop(fn, None) is not None:
                    changed += st.total
            else:
                if node.shares.get(fn) != target:
                    node.shares[fn] = target
                    changed += st.total
        return changed
