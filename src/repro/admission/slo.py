"""SLO classes as a scenario population axis.

Two classes partition every function population:

  * ``latency-critical`` — the paper's implicit default: every request
    carries the function's QoS latency target and queueing beyond a
    tight budget is a violation.  Harvested last: a vertical shrink
    keeps a guard reservation above the measured floor and any queue
    pressure restores the full request.
  * ``best-effort`` — batch-ish traffic that absorbs queueing (a
    generous queue-delay budget) and is harvested first: the vertical
    resizer shrinks its cpu reservations toward the solo-run footprint
    and the harvesting scheduler packs it deeper.

Tagging is a pure function of (function name, fraction, seed) via the
same salted-hash trick ``profiles.py`` uses for intrinsic resource
behaviour — deterministic across processes, order-independent, and —
critically for the admission-off parity gates — it consumes **no** RNG
stream: ``scenario_functions`` draws exactly the same population
whether or not SLO classes are in play.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Iterable

LATENCY_CRITICAL = "latency-critical"
BEST_EFFORT = "best-effort"
SLO_CLASSES = (LATENCY_CRITICAL, BEST_EFFORT)


def _hash_unit(name: str, salt: str) -> float:
    h = hashlib.sha256(f"{name}:{salt}".encode()).digest()
    return int.from_bytes(h[:8], "little") / 2**64


def tag_slo_classes(fn_names: Iterable[str], best_effort_frac: float,
                    seed: int = 0) -> Dict[str, str]:
    """Deterministically tag ``best_effort_frac`` of the population as
    best-effort (per-name salted hash — stable under population growth:
    adding functions never re-tags existing ones)."""
    out: Dict[str, str] = {}
    for fn in fn_names:
        u = _hash_unit(fn, f"slo:{seed}")
        out[fn] = BEST_EFFORT if u < best_effort_frac \
            else LATENCY_CRITICAL
    return out


def delay_budget_s(slo_class: str, lc_budget_s: float,
                   be_budget_s: float) -> float:
    """Queue-delay budget for one class — beyond it, released requests
    count as violated for that class."""
    return be_budget_s if slo_class == BEST_EFFORT else lc_budget_s
