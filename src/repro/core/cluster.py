"""Cluster state: nodes, per-node function instance counts, capacity tables.

Counts, not instance objects: the paper's operations (deploy, release,
logical cold start, migrate, evict) are all count transitions on a
(node, function) pair; instance identity never matters.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Iterator, Optional, Sequence, Tuple

from .interference import NodeResources
from .profiles import FunctionSpec


@dataclass
class FuncState:
    n_sat: int = 0
    n_cached: int = 0
    # timestamps for keep-alive bookkeeping (newest-first not needed; the
    # autoscaler tracks per-function timers cluster-wide)

    @property
    def total(self) -> int:
        return self.n_sat + self.n_cached


@dataclass
class CapEntry:
    capacity: int          # max saturated instances of fn on this node
    fresh: bool = True     # False once a *different* function arrived


class Node:
    _ids = itertools.count()

    def __init__(self, res: NodeResources):
        self.id = next(Node._ids)
        self.res = res
        self.funcs: Dict[str, FuncState] = {}
        self.table: Dict[str, CapEntry] = {}
        self.update_pending_until: float = -1.0

    # -- state access ----------------------------------------------------

    def state(self, fn: str) -> FuncState:
        return self.funcs.setdefault(fn, FuncState())

    def colocation(self, specs: Dict[str, FunctionSpec]
                   ) -> Dict[str, Tuple[FunctionSpec, float, float]]:
        return {n: (specs[n], s.n_sat, s.n_cached)
                for n, s in self.funcs.items() if s.total > 0}

    def n_instances(self) -> int:
        return sum(s.total for s in self.funcs.values())

    def mem_used(self, specs: Dict[str, FunctionSpec]) -> float:
        return sum(specs[n].mem_req * s.total for n, s in self.funcs.items())

    def cpu_requested(self, specs: Dict[str, FunctionSpec]) -> float:
        return sum(specs[n].cpu_req * s.total for n, s in self.funcs.items())

    def is_empty(self) -> bool:
        return self.n_instances() == 0

    # -- mutations (keep table freshness in sync) -------------------------

    def deploy(self, fn: str, k: int = 1):
        self.state(fn).n_sat += k
        for g, e in self.table.items():
            if g != fn:
                e.fresh = False  # their capacity assumed the old count of fn

    def release(self, fn: str, k: int = 1):
        s = self.state(fn)
        k = min(k, s.n_sat)
        s.n_sat -= k
        s.n_cached += k
        # capacities can only have grown -> stale values remain safe
        return k

    def logical_start(self, fn: str, k: int = 1) -> int:
        s = self.state(fn)
        k = min(k, s.n_cached)
        s.n_cached -= k
        s.n_sat += k
        for g, e in self.table.items():
            if g != fn:
                e.fresh = False
        return k

    def evict_cached(self, fn: str, k: int = 1) -> int:
        s = self.state(fn)
        k = min(k, s.n_cached)
        s.n_cached -= k
        if s.total == 0:
            self.funcs.pop(fn, None)
            self.table.pop(fn, None)
        return k

    def evict_sat(self, fn: str, k: int = 1) -> int:
        s = self.state(fn)
        k = min(k, s.n_sat)
        s.n_sat -= k
        if s.total == 0:
            self.funcs.pop(fn, None)
            self.table.pop(fn, None)
        return k


class Cluster:
    """Elastic node pool (paper §6: new server requested when no node fits;
    empty servers are returned).

    ``res_pool`` makes the fleet heterogeneous: newly requested servers
    cycle deterministically through the pool's node shapes (the scenario
    subsystem builds weighted pools from its ``NodeClass`` mix), so the
    same scenario always produces the same node-size sequence."""

    def __init__(self, specs: Dict[str, FunctionSpec],
                 res: Optional[NodeResources] = None,
                 max_nodes: int = 1000,
                 res_pool: Optional[Sequence[NodeResources]] = None):
        if res is not None and res_pool:
            raise ValueError("pass either res (homogeneous fleet) or "
                             "res_pool (heterogeneous mix), not both")
        self.specs = specs
        self.res_pool: Tuple[NodeResources, ...] = \
            tuple(res_pool) if res_pool else ()
        self.res = res or (self.res_pool[0] if self.res_pool
                           else NodeResources())
        self.nodes: Dict[int, Node] = {}
        self.max_nodes = max_nodes
        self.nodes_added = 0

    def add_node(self) -> Node:
        res = self.res_pool[self.nodes_added % len(self.res_pool)] \
            if self.res_pool else self.res
        node = Node(res)
        self.nodes[node.id] = node
        self.nodes_added += 1
        return node

    def reap_empty(self) -> int:
        dead = [nid for nid, n in self.nodes.items() if n.is_empty()]
        for nid in dead:
            del self.nodes[nid]
        return len(dead)

    def nodes_with(self, fn: str) -> Iterator[Node]:
        for n in self.nodes.values():
            if fn in n.funcs and n.funcs[fn].total > 0:
                yield n

    def total_instances(self) -> int:
        return sum(n.n_instances() for n in self.nodes.values())

    def sat_count(self, fn: str) -> int:
        return sum(n.funcs[fn].n_sat for n in self.nodes.values()
                   if fn in n.funcs)

    def cached_count(self, fn: str) -> int:
        return sum(n.funcs[fn].n_cached for n in self.nodes.values()
                   if fn in n.funcs)

    def mem_headroom(self, node: Node, fn: str) -> int:
        """How many more instances of fn fit in (non-overcommitted) memory."""
        spec = self.specs[fn]
        free = node.res.mem_mb - node.mem_used(self.specs)
        return max(0, int(free // spec.mem_req))
