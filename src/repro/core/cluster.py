"""Cluster state: nodes, per-node function instance counts, capacity tables.

Counts, not instance objects: the paper's operations (deploy, release,
logical cold start, migrate, evict) are all count transitions on a
(node, function) pair; instance identity never matters.

Clusters maintain incremental aggregates over those transitions: every
``Node`` mutation notifies its owning cluster (standalone nodes have no
owner and skip the bookkeeping), which keeps per-function sat/cached
totals, a function -> hosting-node index, per-node instance totals and
a dirty set of maybe-empty nodes in sync.  ``sat_count`` /
``cached_count`` / ``total_instances`` are O(1), ``nodes_with`` walks
only hosting nodes, and ``reap_empty`` touches only nodes whose count
actually hit zero — the foundation of the event-driven simulation core
(`core/cells.py`), where idle nodes cost nothing between load changes.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from .interference import NodeResources
from .profiles import FunctionSpec


@dataclass
class FuncState:
    n_sat: int = 0
    n_cached: int = 0
    # timestamps for keep-alive bookkeeping (newest-first not needed; the
    # autoscaler tracks per-function timers cluster-wide)

    @property
    def total(self) -> int:
        return self.n_sat + self.n_cached


@dataclass
class CapEntry:
    capacity: int          # max saturated instances of fn on this node
    fresh: bool = True     # False once a *different* function arrived


class Node:
    _ids = itertools.count()

    def __init__(self, res: NodeResources):
        self.id = next(Node._ids)
        self.res = res
        self.funcs: Dict[str, FuncState] = {}
        self.table: Dict[str, CapEntry] = {}
        #: per-function cpu-reservation share in (0, 1] set by the
        #: vertical resizer (``repro.admission``); absent == 1.0 (full
        #: request).  Only ``cpu_requested`` reads it, and entries are
        #: dropped with the function's last instance, so an empty dict
        #: keeps the pre-admission cluster bit-identical.
        self.shares: Dict[str, float] = {}
        self.update_pending_until: float = -1.0
        #: owning Cluster, set by ``Cluster.add_node`` — standalone nodes
        #: (benchmark fixtures, capacity-table unit tests) stay None and
        #: skip aggregate bookkeeping entirely
        self.owner: Optional["Cluster"] = None

    # -- state access ----------------------------------------------------

    def state(self, fn: str) -> FuncState:
        return self.funcs.setdefault(fn, FuncState())

    def colocation(self, specs: Dict[str, FunctionSpec]
                   ) -> Dict[str, Tuple[FunctionSpec, float, float]]:
        return {n: (specs[n], s.n_sat, s.n_cached)
                for n, s in self.funcs.items() if s.total > 0}

    def n_instances(self) -> int:
        return sum(s.total for s in self.funcs.values())

    def mem_used(self, specs: Dict[str, FunctionSpec]) -> float:
        return sum(specs[n].mem_req * s.total for n, s in self.funcs.items())

    def cpu_requested(self, specs: Dict[str, FunctionSpec]) -> float:
        if not self.shares:
            return sum(specs[n].cpu_req * s.total
                       for n, s in self.funcs.items())
        return sum(specs[n].cpu_req * self.shares.get(n, 1.0) * s.total
                   for n, s in self.funcs.items())

    def is_empty(self) -> bool:
        return self.n_instances() == 0

    def _notify(self, fn: str, d_sat: int, d_cached: int):
        if self.owner is not None and (d_sat or d_cached):
            self.owner._on_change(self, fn, d_sat, d_cached)

    # -- mutations (keep table freshness in sync) -------------------------

    def deploy(self, fn: str, k: int = 1):
        self.state(fn).n_sat += k
        for g, e in self.table.items():
            if g != fn:
                e.fresh = False  # their capacity assumed the old count of fn
        self._notify(fn, k, 0)

    def release(self, fn: str, k: int = 1):
        s = self.state(fn)
        k = min(k, s.n_sat)
        s.n_sat -= k
        s.n_cached += k
        # capacities can only have grown -> stale values remain safe
        self._notify(fn, -k, k)
        return k

    def logical_start(self, fn: str, k: int = 1) -> int:
        s = self.state(fn)
        k = min(k, s.n_cached)
        s.n_cached -= k
        s.n_sat += k
        for g, e in self.table.items():
            if g != fn:
                e.fresh = False
        self._notify(fn, k, -k)
        return k

    def add_cached(self, fn: str, k: int = 1):
        """Receive k warm (cached) instances — the migration landing op."""
        self.state(fn).n_cached += k
        self._notify(fn, 0, k)

    def evict_cached(self, fn: str, k: int = 1) -> int:
        s = self.state(fn)
        k = min(k, s.n_cached)
        s.n_cached -= k
        if s.total == 0:
            self.funcs.pop(fn, None)
            self.table.pop(fn, None)
            self.shares.pop(fn, None)
        self._notify(fn, 0, -k)
        return k

    def evict_sat(self, fn: str, k: int = 1) -> int:
        s = self.state(fn)
        k = min(k, s.n_sat)
        s.n_sat -= k
        if s.total == 0:
            self.funcs.pop(fn, None)
            self.table.pop(fn, None)
            self.shares.pop(fn, None)
        self._notify(fn, -k, 0)
        return k


class Cluster:
    """Elastic node pool (paper §6: new server requested when no node fits;
    empty servers are returned).

    ``res_pool`` makes the fleet heterogeneous: newly requested servers
    cycle deterministically through the pool's node shapes (the scenario
    subsystem builds weighted pools from its ``NodeClass`` mix), so the
    same scenario always produces the same node-size sequence."""

    def __init__(self, specs: Dict[str, FunctionSpec],
                 res: Optional[NodeResources] = None,
                 max_nodes: int = 1000,
                 res_pool: Optional[Sequence[NodeResources]] = None):
        if res is not None and res_pool:
            raise ValueError("pass either res (homogeneous fleet) or "
                             "res_pool (heterogeneous mix), not both")
        self.specs = specs
        self.res_pool: Tuple[NodeResources, ...] = \
            tuple(res_pool) if res_pool else ()
        self.res = res or (self.res_pool[0] if self.res_pool
                           else NodeResources())
        self.nodes: Dict[int, Node] = {}
        self.max_nodes = max_nodes
        self.nodes_added = 0
        # -- incremental aggregates, maintained by Node._notify ----------
        self._sat: Dict[str, int] = {}          # fn -> saturated total
        self._cached: Dict[str, int] = {}       # fn -> cached total
        self._hosting: Dict[str, Set[int]] = {}  # fn -> ids with total > 0
        self._node_total: Dict[int, int] = {}   # id -> instance total
        self._node_cached: Dict[int, int] = {}  # id -> cached total (>0 only)
        self._maybe_empty: Set[int] = set()     # ids whose total hit 0
        self._n_instances = 0

    def add_node(self) -> Node:
        res = self.res_pool[self.nodes_added % len(self.res_pool)] \
            if self.res_pool else self.res
        node = Node(res)
        node.owner = self
        self.nodes[node.id] = node
        self.nodes_added += 1
        self._node_total[node.id] = 0
        self._maybe_empty.add(node.id)  # empty until something deploys
        return node

    def _on_change(self, node: Node, fn: str, d_sat: int, d_cached: int):
        """Fold one (node, fn) count transition into the aggregates."""
        self._sat[fn] = self._sat.get(fn, 0) + d_sat
        self._cached[fn] = self._cached.get(fn, 0) + d_cached
        self._n_instances += d_sat + d_cached
        st = node.funcs.get(fn)
        hosting = self._hosting.setdefault(fn, set())
        if st is not None and st.total > 0:
            hosting.add(node.id)
        else:
            hosting.discard(node.id)
        total = self._node_total.get(node.id, 0) + d_sat + d_cached
        self._node_total[node.id] = total
        if total == 0:
            self._maybe_empty.add(node.id)
        cached = self._node_cached.get(node.id, 0) + d_cached
        if cached:
            self._node_cached[node.id] = cached
        else:
            self._node_cached.pop(node.id, None)

    def reap_empty(self) -> int:
        if not self._maybe_empty:
            return 0
        dead = [nid for nid in sorted(self._maybe_empty)
                if nid in self.nodes and self._node_total.get(nid, 0) == 0]
        for nid in dead:
            node = self.nodes.pop(nid)
            node.owner = None
            self._node_total.pop(nid, None)
            self._node_cached.pop(nid, None)
        self._maybe_empty.clear()
        return len(dead)

    def nodes_with(self, fn: str) -> Iterator[Node]:
        """Nodes hosting fn (total > 0), ascending node id — the same
        order the legacy full scan produced (dict insertion order is
        monotonic in id)."""
        ids = self._hosting.get(fn)
        if not ids:
            return
        for nid in sorted(ids):
            node = self.nodes.get(nid)
            if node is not None:
                yield node

    def nodes_with_cached(self) -> List[Node]:
        """Nodes holding any cached instances, ascending id — the only
        possible migration sources, so ``Autoscaler._migrate`` scans
        just these instead of the whole fleet."""
        return [self.nodes[nid] for nid in sorted(self._node_cached)
                if nid in self.nodes]

    def hosting_ids(self, fn: str) -> Set[int]:
        """Ids of nodes hosting fn (live view — copy before mutating)."""
        return self._hosting.get(fn) or set()

    def total_instances(self) -> int:
        return self._n_instances

    def sat_count(self, fn: str) -> int:
        return self._sat.get(fn, 0)

    def cached_count(self, fn: str) -> int:
        return self._cached.get(fn, 0)

    def mem_headroom(self, node: Node, fn: str) -> int:
        """How many more instances of fn fit in (non-overcommitted) memory."""
        spec = self.specs[fn]
        free = node.res.mem_mb - node.mem_used(self.specs)
        return max(0, int(free // spec.mem_req))
