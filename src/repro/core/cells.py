"""Cell-sharded, event-driven simulation core — 10k-node studies at
sub-linear per-node cost.

The legacy ``Simulation`` is one global tick loop: every tick visits
every spec in the autoscaler and every node in ``_measure``.  This
module partitions the fleet into **cells** — each owning its own
cluster slice, scheduler, autoscaler and ``PredictionService`` — and
drives them with an event-driven per-cell loop:

  * **Cross-cell routing** (``CellRouter``): a per-tick share plan
    generalizing ``LocalityRouter``'s waterfill one level up — a
    function's traffic prefers its warmest, least-contended *cells*
    (capped at ``load_cap`` of their saturated throughput) and spills
    the remainder proportionally; functions with no placements anywhere
    are assigned a deterministic home cell (crc32 — stable across
    processes, unlike builtin ``hash``).  With one cell the plan is an
    identity passthrough, which is what makes ``cells=1`` bit-exact.
  * **Event kinds** driving a cell's work between load changes: load
    arrivals (a function's cell share going positive), drop transitions
    (share hitting zero arms the release timer), autoscaler **wakes**
    (a per-cell heap of release-timer and keep-alive-ledger expiries,
    from ``Autoscaler.next_wake``), and **dirty marks** (out-of-band
    releases via ``Autoscaler.on_fn_dirty``).  A cell with no due
    functions, no pending scheduler work and clean migrate/reap indexes
    costs a few dict checks per tick.
  * **Dirty-set measurement** (``simulator.measure_cluster``): only
    nodes hosting functions with live traffic are measured, in the
    exact node order (and ground-truth RNG sequence) of the legacy full
    scan.  The dirty-set path is exact whenever the scheduler does not
    learn from idle-node observations (``needs_idle_observe`` — Owl
    keeps the full scan).
  * **Capacity exchange** (``CapacityExchange``): freshly solved
    capacities gossip to sibling cells' services (epoch-checked), so a
    colocation pattern solved in one cell is cache-warm fleet-wide —
    the cell-level replacement for the global capacity table.

``cells=1`` reproduces the legacy ``Simulation`` bit-for-bit (density,
QoS, scheduling and scaling counters) — gated by
``tests/test_cells.py`` and the ``cells_parity`` metric in
``BENCH_scaling.json``.
"""
from __future__ import annotations

import heapq
import math
import zlib
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .autoscaler import Autoscaler, ScalingMetrics
from .capacity import QoSStore
from .cluster import Cluster, Node
from .events import EventHub
from .interference import GroundTruth
from .profiles import FunctionSpec, ProfileStore
from .predictor import PerfPredictor, build_features
from .scheduler import BaseScheduler, SchedMetrics
from .simulator import SimConfig, SimResult, measure_cluster
from .traces import Trace
from ..telemetry.spans import NULL_TRACER


class Cell:
    """One shard of the control plane: a cluster slice plus the
    scheduler/autoscaler/router that own it, and the event state
    (wake heap, dirty functions, previous active set) the event loop
    drives it with."""

    def __init__(self, cell_id: int, cluster: Cluster,
                 scheduler: BaseScheduler, autoscaler: Autoscaler,
                 router=None):
        self.id = cell_id
        self.cluster = cluster
        self.scheduler = scheduler
        self.autoscaler = autoscaler
        self.router = router
        #: functions touched out-of-band since their last visit
        #: (scheduler-initiated releases entering the keep-alive ledger)
        self.dirty: Set[str] = set()
        #: the previous tick's active set — the difference yields the
        #: drop-transition event that arms the release timer
        self.prev_active: Set[str] = set()
        self._wakes: List[Tuple[float, str]] = []
        autoscaler.on_fn_dirty = self.dirty.add

    def push_wake(self, t: float, fn: str) -> None:
        heapq.heappush(self._wakes, (t, fn))

    def pop_due_wakes(self, now: float) -> Set[str]:
        due: Set[str] = set()
        while self._wakes and self._wakes[0][0] <= now:
            due.add(heapq.heappop(self._wakes)[1])
        return due


class CellRouter:
    """Per-tick cross-cell traffic shares, one waterfill level above
    ``LocalityRouter``: cells hosting a function's saturated instances
    are ordered by contention (foreign instances per own saturated
    instance), loaded up to ``load_cap`` of their saturated throughput,
    and overload is spread proportionally to instance counts — so the
    per-cell shares sum to the function's RPS exactly.  Cold functions
    (no placements anywhere) go whole to a deterministic home cell.

    With a single cell ``split`` returns the global RPS dict untouched:
    no float division ever runs, which is what keeps ``cells=1``
    bit-identical to the legacy loop."""

    def __init__(self, cells: Sequence[Cell], load_cap: float = 0.85):
        self.cells = list(cells)
        self.load_cap = load_cap

    def home(self, fn: str) -> int:
        return zlib.crc32(fn.encode()) % len(self.cells)

    def split(self, rps: Dict[str, float],
              specs: Dict[str, FunctionSpec]) -> List[Dict[str, float]]:
        cells = self.cells
        if len(cells) == 1:
            return [rps]
        shares: List[Dict[str, float]] = [{} for _ in cells]
        inst_totals = [c.cluster.total_instances() for c in cells]
        for fn, fn_rps in rps.items():
            if fn_rps <= 1e-9:
                continue
            sats = [c.cluster.sat_count(fn) for c in cells]
            total_sat = sum(sats)
            if total_sat == 0:
                shares[self.home(fn)][fn] = fn_rps
                continue
            spec = specs[fn]

            def contention(i: int) -> float:
                own = sats[i] + cells[i].cluster.cached_count(fn)
                return (inst_totals[i] - own) / max(sats[i], 1)

            order = sorted((i for i in range(len(cells)) if sats[i] > 0),
                           key=lambda i: (contention(i), i))
            remaining = fn_rps
            take_by: Dict[int, float] = {}
            for i in order:
                take = min(remaining, sats[i] * spec.saturated_rps
                           * self.load_cap)
                take_by[i] = take
                remaining -= take
            if remaining > 1e-9:
                for i in order:
                    take_by[i] += remaining * sats[i] / total_sat
            for i, take in take_by.items():
                if take > 1e-12:
                    shares[i][fn] = take
        return shares


class CapacityExchange:
    """Cell-level capacity gossip: every capacity one cell's
    ``PredictionService`` solves is offered to every sibling service
    (``accept_exchange`` — epoch-checked, silently dropped across a
    retrain boundary), replacing the global capacity table the legacy
    single-service world shared for free."""

    def __init__(self):
        self.services: List = []
        self.published = 0
        self.fanout = 0

    def join(self, service) -> None:
        self.services.append(service)
        service.exchange = self

    def publish(self, src, key, epoch: int, cap: int) -> None:
        self.published += 1
        for svc in self.services:
            if svc is not src:
                svc.accept_exchange(key, epoch, cap)
                self.fanout += 1


class _FleetView:
    """Read-only duck-type of ``Cluster`` over every cell (observers
    read ``sim.cluster.nodes`` / ``total_instances``)."""

    def __init__(self, cells: Sequence[Cell]):
        self._cells = cells

    @property
    def nodes(self) -> Dict[int, Node]:
        out: Dict[int, Node] = {}
        for c in self._cells:
            out.update(c.cluster.nodes)
        return out

    def total_instances(self) -> int:
        return sum(c.cluster.total_instances() for c in self._cells)


class CellSimulation:
    """The event-driven run loop over a list of ``Cell``s — the same
    contract as ``Simulation.run`` (one ``SimResult``, observer hooks,
    span tracing), with per-cell scheduling work gated on due events.

    Per tick: split traffic across cells (``CellRouter``) -> per cell,
    compute the due set (active ∪ drop-transitions ∪ due wakes ∪ dirty)
    and run scheduler/autoscaler only when something is due or
    migrate/reap indexes are dirty -> dirty-set measurement per cell ->
    sample collection / accounting exactly like the legacy loop."""

    def __init__(self, cells: Sequence[Cell],
                 specs: Dict[str, FunctionSpec], trace: Trace,
                 ground_truth: GroundTruth, store: ProfileStore,
                 qos: QoSStore, predictor: Optional[PerfPredictor] = None,
                 cfg: Optional[SimConfig] = None, *,
                 cell_router: Optional[CellRouter] = None,
                 events: Optional[EventHub] = None,
                 exchange: Optional[CapacityExchange] = None):
        self.cells = list(cells)
        self.specs = specs
        self.trace = trace
        self.gt = ground_truth
        self.store = store
        self.qos = qos
        self.predictor = predictor
        self.cfg = cfg or SimConfig()
        self.cell_router = cell_router or CellRouter(self.cells)
        self.events = events or EventHub()
        self.exchange = exchange
        self.tracer = NULL_TRACER
        self._rng = np.random.default_rng(self.cfg.seed)
        self._spec_index = {fn: i for i, fn in enumerate(specs)}
        self._fleet = self.cells[0].cluster if len(self.cells) == 1 \
            else _FleetView(self.cells)
        #: cell-ticks where scheduling was skipped entirely (idle cell)
        self.idle_cell_ticks = 0
        self.cell_ticks = 0

    # -- Simulation-compatible surface ---------------------------------

    @property
    def cluster(self):
        return self._fleet

    @property
    def scheduler(self) -> BaseScheduler:
        return self.cells[0].scheduler

    @property
    def autoscaler(self) -> Autoscaler:
        return self.cells[0].autoscaler

    @property
    def router(self):
        return self.cells[0].router

    @property
    def _service(self):
        return self.cells[0].scheduler.prediction_service

    def schedulers(self) -> List[BaseScheduler]:
        """Every cell's scheduler — platform-level wiring (decision
        traces, picker-stage overrides) must reach all of them, not
        just the representative ``scheduler`` property."""
        return [c.scheduler for c in self.cells]

    def services(self) -> List:
        """Every cell's PredictionService (None entries dropped)."""
        return self._services()

    def _services(self) -> List:
        out = []
        for c in self.cells:
            svc = c.scheduler.prediction_service
            if svc is not None:
                out.append(svc)
        return out

    # ------------------------------------------------------------------

    def run(self, duration_s: Optional[int] = None) -> SimResult:
        T = duration_s or self.trace.duration_s
        res = SimResult(name=self.cells[0].scheduler.name, ticks=T)
        #: observers read the accumulating result mid-run (tick records
        #: carry cumulative QoS counters for offline outcome labelling)
        self.live_result = res
        services = self._services()
        svc0 = [s.stats.snapshot() for s in services]
        for t in range(T):
            now = float(t)
            rps = {fn: self.trace.at(fn, t) for fn in self.trace.rps}
            shares = self.cell_router.split(rps, self.specs)
            with self.tracer.span("schedule") as sp:
                if sp is not None:
                    d0 = sum(c.scheduler.metrics.decisions
                             for c in self.cells)
                    p0 = sum(c.scheduler.metrics.instances_placed
                             for c in self.cells)
                for cell, cell_rps in zip(self.cells, shares):
                    self._tick_cell(cell, now, cell_rps)
                if sp is not None:
                    sp.attrs["now"] = now
                    sp.attrs["decisions"] = sum(
                        c.scheduler.metrics.decisions
                        for c in self.cells) - d0
                    sp.attrs["placed"] = sum(
                        c.scheduler.metrics.instances_placed
                        for c in self.cells) - p0
            for cell, cell_rps in zip(self.cells, shares):
                self._measure_cell(cell, now, cell_rps, res)
            if (self.cfg.collect_samples and self.predictor is not None
                    and t % self.cfg.sample_every_s == 0):
                self._collect_sample()
            inst = sum(c.cluster.total_instances() for c in self.cells)
            nodes = sum(len(c.cluster.nodes) for c in self.cells)
            res.instance_seconds += inst
            res.node_seconds += nodes
            res.nodes_peak = max(res.nodes_peak, nodes)
            res.density_series.append(inst / nodes if nodes else 0.0)
            self.events.on_tick(now, self)
        res.sched = self._merged_sched()
        res.scaling = self._merged_scaling()
        if self.predictor is not None:
            res.inference_rows = self.predictor.inference_count
            res.inference_calls = self.predictor.inference_calls
            res.mean_inference_ms = self.predictor.mean_inference_ms
        if services:
            for s, s0 in zip(services, svc0):
                st = s.stats.snapshot()
                res.retrains += int(st["retrains"]
                                    - s0.get("retrains", 0))
                res.retrain_time_s += \
                    st["retrain_time_s"] - s0.get("retrain_time_s", 0.0)
                res.refresh_rows += \
                    int(st["refresh_rows"] - s0.get("refresh_rows", 0))
                res.refresh_time_s += \
                    st["refresh_time_s"] - s0.get("refresh_time_s", 0.0)
                res.stale_epoch_hits += int(
                    st["stale_epoch_hits"]
                    - s0.get("stale_epoch_hits", 0))
        for cell in self.cells:
            adm = cell.autoscaler.admission
            if adm is not None:
                adm.finalize(res)
        self.events.on_result(res)
        return res

    def queue_depth_total(self) -> Optional[float]:
        """Fleet-wide pending-queue depth, or None when admission is off
        (mirrors ``Simulation.queue_depth_total``)."""
        depths = [cell.autoscaler.admission.queue_depth()
                  for cell in self.cells
                  if cell.autoscaler.admission is not None]
        return sum(depths) if depths else None

    # ------------------------------------------------------------------

    def _tick_cell(self, cell: Cell, now: float,
                   cell_rps: Dict[str, float]) -> None:
        """One cell's scheduling pass: visit only *due* functions.

        Due = functions with live traffic this tick, functions whose
        traffic just dropped to zero (the legacy loop's
        ``_below_since[fn] = now`` arming tick), functions with an
        expired wake (release timer / keep-alive ledger head), and
        functions dirtied out-of-band.  A skipped function's
        ``_tick_fn`` is provably a no-op: zero expected instances, no
        armed timer, no ledger entries due."""
        self.cell_ticks += 1
        active = {fn for fn, v in cell_rps.items() if v > 1e-9}
        adm = cell.autoscaler.admission
        if adm is not None:
            # admission phase 1 (per-cell queues): arrivals enter the
            # cell's bounded queues; the autoscaler sees the backlog-
            # derived signal, and functions with pending backlog stay
            # due even when their instantaneous share dropped to zero
            cell_rps = adm.enqueue(now, cell_rps, cell.cluster)
            active = active | adm.pending_fns()
        due = active | (cell.prev_active - active)
        due |= cell.pop_due_wakes(now)
        if cell.dirty:
            due |= cell.dirty
            cell.dirty.clear()
        cell.prev_active = active
        cl = cell.cluster
        sched = cell.scheduler
        if due or sched.has_pending_work():
            sched.on_tick(now)
        if due or cl._node_cached or cl._maybe_empty:
            order = sorted(due, key=self._spec_index.__getitem__)
            cell.autoscaler.tick(now, cell_rps, fns=order)
            for fn in order:
                wake = cell.autoscaler.next_wake(fn)
                if wake is not None:
                    cell.push_wake(wake, fn)
        else:
            self.idle_cell_ticks += 1

    def _measure_cell(self, cell: Cell, now: float,
                      cell_rps: Dict[str, float], res: SimResult) -> None:
        adm = cell.autoscaler.admission
        if adm is not None:
            # admission phase 2: the cell's backlog drains into its
            # just-scaled slice; measurement routes served traffic
            cell_rps = adm.drain(now, cell.cluster, res)
        if not cell.prev_active and not cell.scheduler.needs_idle_observe:
            return      # no live traffic: nothing measurable, no-op observes
        sat_totals = {fn: cell.cluster.sat_count(fn)
                      for fn in cell.prev_active} \
            if not cell.scheduler.needs_idle_observe \
            else {fn: cell.cluster.sat_count(fn) for fn in self.specs}
        measure_cluster(now, cell.cluster, self.specs, cell_rps,
                        sat_totals, cell.router, cell.scheduler,
                        self.gt, self.qos, res,
                        slo=None if adm is None else adm.slo)

    def _collect_sample(self) -> None:
        """Mirror of ``Simulation._collect_sample`` over the fleet:
        busy nodes are enumerated cell by cell (ascending cell id, node
        id within — the legacy enumeration order at ``cells=1``), one
        is drawn from this simulation's own RNG stream, and its rows go
        through the *owning* cell's service."""
        svc0 = self._service
        v2 = svc0 is not None and svc0.schema.version >= 2
        busy: List[Node] = []
        owners: List[Cell] = []
        for cell in self.cells:
            for n in cell.cluster.nodes.values():
                if any(s.n_sat > 0 for s in n.funcs.values()) \
                        and (v2 or n.res == self.gt.node):
                    busy.append(n)
                    owners.append(cell)
        if not busy:
            return
        pick = int(self._rng.integers(len(busy)))
        node, owner = busy[pick], owners[pick]
        svc = owner.scheduler.prediction_service
        coloc = node.colocation(self.specs)
        counts = {g: (float(s[1]), float(s[2])) for g, s in coloc.items()}
        node_res = node.res if v2 else None
        Xs, ys = [], []
        for fn, (spec, n_sat, n_cached) in coloc.items():
            if n_sat <= 0:
                continue
            if svc is not None:
                x = svc.feature_row(fn, n_sat, n_cached, counts, node_res)
            else:
                neigh = [(self.store.profile(self.specs[g]), ns, nc)
                         for g, (ns, nc) in counts.items() if g != fn]
                x = build_features(self.qos.solo(spec),
                                   self.store.profile(spec), n_sat,
                                   n_cached, neigh)
            y = self.gt.measure(spec, coloc, load_frac=1.0,
                                node_res=node_res)
            Xs.append(x)
            ys.append(y)
        if not Xs:
            return
        if svc is not None and self.cfg.online_retrain:
            if svc.on_samples(Xs, ys):
                # retrain fired on the shared forest: every cell's
                # tables were computed by the old epoch — refresh each
                # cell through its own service
                for c in self.cells:
                    s = c.scheduler.prediction_service
                    if s is not None and c.scheduler.accepts_service:
                        s.refresh_tables(list(c.cluster.nodes.values()),
                                         c.scheduler.m_max)
        else:
            for x, yv in zip(Xs, ys):
                self.predictor.add_sample(x, yv, retrain=False)

    # -- metric merging -------------------------------------------------

    def _merged_sched(self) -> SchedMetrics:
        if len(self.cells) == 1:
            return self.cells[0].scheduler.metrics
        out = SchedMetrics()
        for c in self.cells:
            m = c.scheduler.metrics
            out.decisions += m.decisions
            out.instances_placed += m.instances_placed
            out.fast += m.fast
            out.slow += m.slow
            out.failed += m.failed
            out.sched_time_ms += m.sched_time_ms
            out.sched_latencies.extend(m.sched_latencies)
            out.critical_inference_rows += m.critical_inference_rows
            out.critical_inference_calls += m.critical_inference_calls
            out.async_inference_rows += m.async_inference_rows
            out.async_updates += m.async_updates
        return out

    def _merged_scaling(self) -> ScalingMetrics:
        if len(self.cells) == 1:
            return self.cells[0].autoscaler.metrics
        out = ScalingMetrics()
        for c in self.cells:
            m = c.autoscaler.metrics
            out.real_cold_starts += m.real_cold_starts
            out.logical_cold_starts += m.logical_cold_starts
            out.blocked_logical += m.blocked_logical
            out.migrations += m.migrations
            out.releases += m.releases
            out.evictions += m.evictions
            out.cold_start_ms.extend(m.cold_start_ms)
        return out


# ---------------------------------------------------------------------------
# Assembly
# ---------------------------------------------------------------------------


def cell_scenario_simulation(scenario, scheduler: str = "jiagu", *,
                             n_cells: int = 4,
                             world=None,
                             router_factory=None,
                             cell_load_cap: float = 0.85,
                             exchange: bool = True,
                             max_nodes: Optional[int] = None,
                             events: Optional[EventHub] = None,
                             **build_kw) -> CellSimulation:
    """Assemble a ``CellSimulation`` for a scenario: the fleet's node
    budget splits evenly across ``n_cells`` cells, each wired exactly
    like ``scenario_simulation`` wires one simulation (same scheduler
    registry, autoscaler config, service attachment and schema
    validation — reused via ``build_simulation`` per cell, against the
    shared world).  ``router_factory`` builds one per-cell router
    (default: the paper's equal split); ``build_kw`` passes through to
    ``build_simulation`` (release_s, m_max, use_engine, ...)."""
    from .scenarios import build_simulation, scenario_world, \
        scenario_simulation, scheduler_entry  # late: avoid import cycle

    if n_cells < 1:
        raise ValueError(f"n_cells must be >= 1, got {n_cells}")
    if world is None:
        world = scenario_world(scenario,
                               schema_version=build_kw.get(
                                   "schema_version") or 1)
    if n_cells == 1:
        # the parity configuration: one cell, one cluster, the exact
        # legacy assembly — CellSimulation drives it event-style
        sim = scenario_simulation(scenario, scheduler, world=world,
                                  max_nodes=max_nodes, events=events,
                                  **build_kw)
        cells = [Cell(0, sim.cluster, sim.scheduler, sim.autoscaler,
                      router=sim.router)]
        return CellSimulation(cells, sim.specs, sim.trace, sim.gt,
                              sim.store, sim.qos,
                              predictor=sim.predictor, cfg=sim.cfg,
                              events=sim.events)

    pred = world.predictor \
        if scheduler_entry(scheduler).needs_predictor else None
    total_max = max_nodes or max(4 * scenario.target_nodes, 64)
    per_cell_max = max(1, math.ceil(total_max / n_cells))
    build_kw = dict(build_kw)
    build_kw.pop("schema_version", None)
    cells: List[Cell] = []
    for i in range(n_cells):
        router = router_factory() if router_factory is not None else None
        sim = build_simulation(
            scenario.specs, scenario.trace,
            scenario.build_cluster(per_cell_max),
            world.gt, world.store, world.qos, scheduler, pred,
            schema_version=world.schema_version, router=router,
            events=events, **build_kw)
        cells.append(Cell(i, sim.cluster, sim.scheduler, sim.autoscaler,
                          router=sim.router))
    ex = None
    if exchange:
        ex = CapacityExchange()
        for cell in cells:
            svc = cell.scheduler.prediction_service
            if svc is not None:
                ex.join(svc)
    cfg = SimConfig(seed=build_kw.get("sim_seed", 0),
                    schema_version=world.schema_version,
                    collect_samples=build_kw.get("collect_samples", False),
                    online_retrain=build_kw.get("online_retrain", False),
                    retrain_every=build_kw.get("retrain_every"))
    if build_kw.get("sample_every_s") is not None:
        cfg.sample_every_s = build_kw["sample_every_s"]
    return CellSimulation(
        cells, scenario.specs, scenario.trace, world.gt, world.store,
        world.qos, predictor=pred, cfg=cfg,
        cell_router=CellRouter(cells, load_cap=cell_load_cap),
        events=events, exchange=ex)


__all__ = ["Cell", "CellRouter", "CapacityExchange", "CellSimulation",
           "cell_scenario_simulation"]
