"""Function specs and solo-run profiles (paper §4.1, Table 3).

A *function* is the scheduling unit.  Its profile is a 13-dim vector of
solo-run resource metrics (the paper's Table 3, adapted to our TPU-serving
deployment but kept at the same dimensionality so the predictor is
unchanged).  Profiles are produced by ``solo_run_profile`` — a simulated
profiling-node run against the ground-truth interference model with no
neighbors — exactly the paper's solo-run methodology: the predictor only
ever sees measured (simulated-measured) data, never ground-truth internals.

Two function families ship:
  * the six ServerlessBench/FunctionBench workloads used in the paper's
    evaluation (rnn, image-resize, linpack, log-processing, chameleon,
    gzip), and
  * one serving function per assigned model architecture (a replica of the
    model with its decode-step resource footprint) — the TPU adaptation.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field, replace
from typing import Dict, Optional

import numpy as np

PROFILE_METRICS = (
    "mcpu",             # CPU utilization (millicores)
    "instructions",     # instructions retired (G/s)
    "ipc",              # instructions per cycle
    "ctx_switches",     # context switches (k/s)
    "mlp",              # memory-level parallelism
    "l1d_mpki", "l1i_mpki", "l2_mpki", "llc_mpki",
    "dtlb_mpki", "itlb_mpki",
    "branch_mpki",
    "mem_bw",           # memory bandwidth (GB/s)
)
N_PROFILE = len(PROFILE_METRICS)


@dataclass(frozen=True)
class FunctionSpec:
    """Static user-visible function configuration."""

    name: str
    cpu_req: float          # requested millicores (user config, conservative)
    mem_req: float          # requested MB
    saturated_rps: float    # autoscaler threshold (requests/s per instance)
    exec_ms: float          # mean execution time of one request
    # intrinsic resource behaviour (drives the ground-truth model);
    # hidden from the scheduler — only solo-run profiles are observable.
    cpu_work: float = 0.5   # fraction of cpu_req actually used at saturation
    mem_work: float = 0.6   # fraction of mem_req actually used
    bw_demand: float = 2.0  # GB/s at saturated load
    cache_mb: float = 4.0   # working-set pressure on LLC (MB)
    cpu_sens: float = 1.0   # latency sensitivity to CPU contention
    bw_sens: float = 1.0    # ... to bandwidth contention
    cache_sens: float = 1.0  # ... to cache contention


def _hash_unit(name: str, salt: str) -> float:
    h = hashlib.sha256(f"{name}:{salt}".encode()).digest()
    return int.from_bytes(h[:8], "little") / 2**64


# ---------------------------------------------------------------------------
# The six paper workloads (ServerlessBench / FunctionBench)
# ---------------------------------------------------------------------------

# bw/cache footprints scale with requested CPU (a 2000-mcore slot of a
# 48-core node packs ~24x: per-instance demands must keep requested-
# resource packing near the calibration invariant in interference.py).
BENCH_FUNCTIONS: Dict[str, FunctionSpec] = {
    # name            cpu_req mem_req  rps  exec   cpu_w mem_w  bw  cache  sens(c,b,$)
    "rnn": FunctionSpec("rnn", 2000, 1024, 20, 45.0, 0.37, 0.55, 1.4, 2.0,
                        cpu_sens=1.2, bw_sens=1.1, cache_sens=0.9),
    "img_resize": FunctionSpec("img_resize", 2000, 1024, 30, 30.0, 0.33,
                               0.50, 2.0, 3.0, cpu_sens=0.9, bw_sens=1.4,
                               cache_sens=1.2),
    "linpack": FunctionSpec("linpack", 2000, 1024, 15, 60.0, 0.47, 0.40,
                            0.8, 1.5, cpu_sens=1.5, bw_sens=0.7,
                            cache_sens=1.1),
    "log_proc": FunctionSpec("log_proc", 2000, 1024, 50, 18.0, 0.25, 0.45,
                             1.7, 2.5, cpu_sens=0.8, bw_sens=1.2,
                             cache_sens=1.3),
    "chameleon": FunctionSpec("chameleon", 2000, 1024, 25, 35.0, 0.30, 0.60,
                              1.1, 1.8, cpu_sens=1.0, bw_sens=0.9,
                              cache_sens=1.0),
    "gzip": FunctionSpec("gzip", 2000, 1024, 18, 52.0, 0.42, 0.35, 2.4,
                         3.5, cpu_sens=1.1, bw_sens=1.5, cache_sens=1.4),
}


def arch_function(arch_name: str, param_count: int, d_model: int,
                  n_layers: int) -> FunctionSpec:
    """A serving-replica function derived from a model architecture.

    Resource behaviour scales with model size: decode is HBM-bandwidth
    bound (bw ~ active bytes), CPU host work scales with layers (dispatch),
    cache pressure with d_model.  Deterministic per arch.
    """
    gb = param_count * 2 / 1e9  # bf16 weights
    u = _hash_unit(arch_name, "fn")
    return FunctionSpec(
        name=f"serve-{arch_name}",
        cpu_req=1000 + 500 * round(4 * u),
        mem_req=512 + 256 * round(gb),
        saturated_rps=max(4.0, 60.0 / (1 + gb)),
        exec_ms=8.0 + 15.0 * gb + 10.0 * u,
        cpu_work=0.25 + 0.2 * u,
        mem_work=0.5 + 0.3 * _hash_unit(arch_name, "mem"),
        bw_demand=(0.3 + min(gb, 2.0)) * (1000 + 500 * round(4 * u)) / 1000.0,
        cache_mb=(0.5 + d_model / 4096.0) * (1000 + 500 * round(4 * u)) / 1000.0,
        cpu_sens=0.8 + 0.6 * _hash_unit(arch_name, "cs"),
        bw_sens=0.8 + 0.8 * _hash_unit(arch_name, "bs"),
        cache_sens=0.7 + 0.8 * _hash_unit(arch_name, "$s"),
    )


def arch_functions() -> Dict[str, FunctionSpec]:
    from ..configs import get_smoke_config, get_config, list_archs
    out = {}
    for a in list_archs():
        cfg = get_config(a)
        f = arch_function(a, cfg.param_count(), cfg.d_model, cfg.n_layers)
        out[f.name] = f
    return out


def synthetic_functions(n: int, seed: int = 0) -> Dict[str, FunctionSpec]:
    """Arbitrary-size function population for scalability experiments
    (paper Fig 15: 30 / 60 functions)."""
    rng = np.random.default_rng(seed)
    out = {}
    for i in range(n):
        name = f"fn{i:03d}"
        out[name] = FunctionSpec(
            name=name,
            cpu_req=float(rng.choice([1000, 2000, 4000])),
            mem_req=float(rng.choice([512, 1024, 2048])),
            saturated_rps=float(rng.uniform(8, 60)),
            exec_ms=float(rng.uniform(10, 80)),
            cpu_work=float(rng.uniform(0.22, 0.55)),  # paper Fig 4: heavy over-provisioning
            mem_work=float(rng.uniform(0.3, 0.8)),
            cpu_sens=float(rng.uniform(0.6, 1.6)),
            bw_sens=float(rng.uniform(0.6, 1.6)),
            cache_sens=float(rng.uniform(0.6, 1.6)),
        )
        # footprints proportional to the requested-CPU slot size
        slots = out[name].cpu_req / 1000.0
        out[name] = replace(
            out[name],
            bw_demand=slots * float(rng.uniform(0.3, 1.2)),
            cache_mb=slots * float(rng.uniform(0.5, 2.0)),
        )
    return out


# ---------------------------------------------------------------------------
# Solo-run profiling (simulated profiling node)
# ---------------------------------------------------------------------------


def solo_run_profile(fn: FunctionSpec, noise_rng: Optional[np.random.Generator]
                     = None) -> np.ndarray:
    """13-dim observable profile vector measured at saturated solo load.

    Derived from the *observable consequences* of the spec's intrinsic
    behaviour (plus small measurement noise), mirroring a perf run on the
    profiling node.  The predictor sees only this.
    """
    used_cpu = fn.cpu_req * fn.cpu_work
    instr = used_cpu / 1000.0 * 2.8  # ~2.8 G instr/s per busy core
    ipc = 1.1 + 0.8 / (1.0 + fn.bw_demand / 3.0)
    ctx = 0.5 + fn.saturated_rps * 0.05
    mlp = 2.0 + fn.bw_demand * 0.6
    l1d = 8.0 + fn.cache_mb * 0.4
    l1i = 1.0 + 0.2 * fn.cache_sens
    l2 = 3.0 + fn.cache_mb * 0.5
    llc = 0.5 + fn.cache_mb * 0.25 * fn.cache_sens
    dtlb = 0.3 + fn.mem_work * 0.5
    itlb = 0.05 + 0.02 * fn.cpu_sens
    branch = 2.0 + 1.5 * fn.cpu_sens
    bw = fn.bw_demand
    v = np.array([used_cpu, instr, ipc, ctx, mlp, l1d, l1i, l2, llc, dtlb,
                  itlb, branch, bw], np.float64)
    if noise_rng is not None:
        v = v * (1.0 + noise_rng.normal(0.0, 0.01, v.shape))
    return v


class ProfileStore:
    """Profiles collected on the profiling nodes; O(n) total cost
    (one solo run per function — the paper's scalability column)."""

    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)
        self._profiles: Dict[str, np.ndarray] = {}
        self._solo_lat: Dict[str, float] = {}
        self.profiling_runs = 0

    def profile(self, fn: FunctionSpec) -> np.ndarray:
        if fn.name not in self._profiles:
            self._profiles[fn.name] = solo_run_profile(fn, self._rng)
            self.profiling_runs += 1
        return self._profiles[fn.name]

    def solo_latency(self, fn: FunctionSpec, ground_truth) -> float:
        """P90 latency of a saturated solo instance (measured once)."""
        if fn.name not in self._solo_lat:
            self._solo_lat[fn.name] = ground_truth.solo_latency(fn)
        return self._solo_lat[fn.name]
