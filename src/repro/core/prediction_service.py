"""Unified prediction service: one pipeline behind every prediction
entry point.

Before this module, the prediction pipeline was duplicated across the
stack: ``capacity.capacity_of`` built feature rows in Python loops,
``capacity_engine.CapacityEngine`` re-implemented the same assembly
vectorized, ``GsightScheduler`` and ``simulator._collect_sample`` each
had their own ``build_features`` call sites, and the feature layout was
a hard-coded 31-vector that could not express node size — so capacities
on big nodes of a heterogeneous fleet silently inherited small-node
predictions (conservative, never optimistic, but systematically wasteful).

``PredictionService`` owns the whole pipeline:

  * the **forest** (a ``PerfPredictor``) and its inference engine
    selection (``engine={"numpy","jax","pallas"}``, routed through
    ``repro.kernels.rfr_inference`` for the TPU hot path),
  * a versioned **FeatureSchema** — v1 is the legacy 31-dim vector
    (bit-identical to ``predictor.build_features``; the parity oracle),
    v2 appends normalized node-shape features (cpu_mcores, mem_mb of the
    *hosting* node) so one forest serves heterogeneous fleets,
  * **batched capacity solving** — the coalesced / cached / vectorized
    machinery grown in PR 1 (``CapacityEngine`` is now an alias of this
    class): one ``predict_many`` pass per drain round, canonical
    colocation-signature cache, chunked early-exit m-sweep,
  * **epoch / retrain bookkeeping** — cache entries are tagged with the
    forest epoch; ``on_samples()`` ingests runtime measurements and
    applies the online retraining policy, bumping the epoch and clearing
    the cache so a post-retrain lookup can never serve a pre-retrain
    capacity (``stats.stale_epoch_hits`` counts any entry whose tag
    mismatches the current epoch — it must stay 0, and the large-cluster
    ``--retrain-online`` benchmark asserts it).

``JiaguScheduler``, ``GsightScheduler``, ``update_capacity_table``, the
autoscaler's capacity hints, and the simulator's runtime sample
collection are all thin clients of this service.

Bit-compatibility contract (schema v1): assembled rows replicate
``build_features`` float64 op-for-op (same accumulation order), so
service capacities are identical to the legacy per-node results — the
parity tests and the 24->512-node benchmark both assert it.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .capacity import M_MAX_DEFAULT, QoSStore
from .cluster import CapEntry, Node
from .interference import NodeResources
from .predictor import (N_FEATURES, PerfPredictor,
                        RandomForestRegressor, build_features)
from .profiles import N_PROFILE, FunctionSpec, ProfileStore
from ..telemetry.spans import NULL_TRACER

# v1 feature layout (see predictor.build_features)
_SOLO = 0
_PROF = slice(1, 1 + N_PROFILE)
_NSAT = 1 + N_PROFILE
_NCACHED = 2 + N_PROFILE
_AGG = slice(3 + N_PROFILE, 3 + 2 * N_PROFILE)
_TOTSAT = 3 + 2 * N_PROFILE
_TOTCACHED = 4 + 2 * N_PROFILE

#: the reference (profiling-node) shape node-size features normalize to
REFERENCE_NODE = NodeResources()
N_SHAPE_FEATURES = 2   # normalized (cpu_mcores, mem_mb) of the host node

INFERENCE_ENGINES = ("numpy", "jax", "pallas")

#: capacity-drain strategies: "host" is the chunked early-exit m-sweep
#: (numpy rows shipped to the predictor once per chunk round), "device"
#: the fused single-pass sweep (one padded scenario tensor, one
#: ``rfr_sweep_op`` launch, capacities gathered device-side)
DRAIN_MODES = ("host", "device")

Coloc = Dict[str, Tuple[float, float]]
SigKey = Tuple


# ---------------------------------------------------------------------------
# Versioned feature schema
# ---------------------------------------------------------------------------


class FeatureSchema:
    """Versioned feature-vector layout shared by every prediction entry
    point (capacity solving, per-schedule inference, runtime training
    rows, offline dataset generation).

      * **v1** — the paper's 31-dim function-granularity vector, built
        by ``predictor.build_features``.  Node-shape-blind: predictions
        made for the profiling-node shape apply to every node (the
        conservative legacy behaviour, kept as the parity oracle).
      * **v2** — node-shape-aware.  Two changes, both *normalized to
        the reference profiling-node shape*:

          1. every count/pressure column (the target's own sat/cached
             counts, the concurrency-weighted aggregate profile, and the
             node totals) is scaled by ``ref_cpu / host_cpu`` — a
             colocation on a 2x node reads half the pressure, which
             matches how the interference channels (cpu, bandwidth,
             cache) dilute with node capacity and keeps rows from
             differently-sized nodes on one latency manifold (appending
             raw shape columns alone leaves same-pressure rows from
             different shapes aliased, and raw counts at mismatched
             ranges hand the trees spurious shape-correlated splits —
             both make the forest optimistic in pockets);
          2. ``N_SHAPE_FEATURES`` trailing columns carry the hosting
             node's (cpu_mcores, mem_mb) normalized to the reference
             shape — (2.0, 2.0) for a 2x node, (1.0, 1.0) standard —
             so residual shape effects stay resolvable.

        Trained with per-node-shape rows, the forest then resolves that
        a given colocation pressures a big node less — big nodes stop
        inheriting small-node capacities.  On the reference shape both
        changes are identities, so v2 rows for standard nodes carry the
        exact v1 prefix.
    """

    def __init__(self, version: int):
        if version not in (1, 2):
            raise ValueError(f"unknown feature-schema version {version!r}")
        self.version = version
        self.n_shape = 0 if version == 1 else N_SHAPE_FEATURES
        self.n_features = N_FEATURES + self.n_shape

    # -- node-shape block -------------------------------------------------

    def shape_features(self, node_res: Optional[NodeResources] = None
                       ) -> np.ndarray:
        """The trailing shape block as float64 (empty for v1)."""
        if self.version == 1:
            return np.empty(0, np.float64)
        nr = node_res or REFERENCE_NODE
        return np.array([nr.cpu_mcores / REFERENCE_NODE.cpu_mcores,
                         nr.mem_mb / REFERENCE_NODE.mem_mb], np.float64)

    def pressure_scale(self, node_res: Optional[NodeResources] = None
                       ) -> float:
        """Scale of the node-level pressure block relative to the
        reference shape (1.0 for v1 and for the reference node)."""
        if self.version == 1 or node_res is None:
            return 1.0
        return REFERENCE_NODE.cpu_mcores / node_res.cpu_mcores

    def shape_key(self, node_res: Optional[NodeResources],
                  quant: float = 4.0) -> Tuple[float, ...]:
        """Quantized shape block for cache signatures (empty for v1, so
        v1 signatures stay exactly the PR-1 ``coloc_signature`` keys)."""
        if self.version == 1:
            return ()
        q = max(quant, 1e-9)
        return tuple(round(float(v) * q) / q
                     for v in self.shape_features(node_res))

    # -- row assembly -----------------------------------------------------

    def build_row(self, solo_lat: float, profile: np.ndarray, n_sat: float,
                  n_cached: float,
                  neighbors: Sequence[Tuple[np.ndarray, float, float]],
                  node_res: Optional[NodeResources] = None) -> np.ndarray:
        """One feature row.  v1 delegates to ``build_features`` verbatim
        (bit-identical); v2 rescales the node-level pressure block to
        the hosting shape and appends the normalized shape columns."""
        base = build_features(solo_lat, profile, n_sat, n_cached, neighbors)
        if self.version == 1:
            return base
        row = base.astype(np.float64)
        scale = self.pressure_scale(node_res)
        if scale != 1.0:
            row[_NSAT] *= scale
            row[_NCACHED] *= scale
            row[_AGG] *= scale
            row[_TOTSAT] *= scale
            row[_TOTCACHED] *= scale
        return np.concatenate(
            [row, self.shape_features(node_res)]).astype(np.float32)

    def __repr__(self) -> str:
        return f"FeatureSchema(v{self.version}, {self.n_features} features)"

    def __eq__(self, other) -> bool:
        return isinstance(other, FeatureSchema) and \
            other.version == self.version

    def __hash__(self) -> int:
        return hash(("FeatureSchema", self.version))


SCHEMA_V1 = FeatureSchema(1)
SCHEMA_V2 = FeatureSchema(2)


def get_schema(schema: Union[int, FeatureSchema, None]) -> FeatureSchema:
    """Normalize an ``int`` version / schema object / None to a schema."""
    if schema is None:
        return SCHEMA_V1
    if isinstance(schema, FeatureSchema):
        return schema
    return {1: SCHEMA_V1, 2: SCHEMA_V2}.get(schema) or FeatureSchema(schema)


# ---------------------------------------------------------------------------
# Solver configuration / telemetry
# ---------------------------------------------------------------------------


@dataclass
class EngineConfig:
    m_max: int = M_MAX_DEFAULT
    cache: bool = True
    early_exit: bool = True       # chunked m-sweep vs full legacy sweep
    chunk_init: int = 4           # first chunk of the m-sweep
    chunk_growth: int = 2         # geometric growth of later chunks
    quant: float = 4.0            # signature quantization steps per unit
    max_cache_entries: int = 65536
    # online retraining policy: retrain after this many on_samples() rows
    # (None -> the predictor's own retrain_every)
    retrain_every: Optional[int] = None
    # Schema-v2 QoS safety margins: capacities must clear
    # QoS / (1 + base + shape*distance), distance = |host/ref cpu - 1|.
    # v2 predictions are boundary-accurate (v1's node-shape blindness
    # made it accidentally conservative, absorbing forest noise for
    # free), so v2 supplies the slack explicitly: a flat base margin on
    # every shape plus a term growing with shape-extrapolation distance
    # (profiling data is densest at the reference shape).  0 disables.
    qos_margin_base: float = 0.06
    shape_margin: float = 0.08
    # learn the per-shape margin from per-shape validation error over
    # the accumulated dataset instead of the fixed shape_margin/unit
    # formula (schema v2 only; recomputed every forest epoch; shapes
    # with no validation rows fall back to the fixed formula)
    learned_shape_margin: bool = False
    margin_quantile: float = 0.9   # validation-error quantile per shape
    margin_cap: float = 0.5        # learned margins are clamped to
    #                                [qos_margin_base, margin_cap]
    # capacity-drain strategy: "host" (chunked early-exit m-sweep) or
    # "device" (fused single-pass Pallas/jnp sweep, see solve_many)
    drain: str = "host"

    def __post_init__(self):
        if self.chunk_init < 1:
            raise ValueError(
                f"chunk_init must be >= 1 (got {self.chunk_init}): an "
                "empty first chunk never advances the m-sweep, so "
                "solve_many's drain loop would spin forever")
        if self.chunk_growth < 1:
            raise ValueError(
                f"chunk_growth must be >= 1 (got {self.chunk_growth}): "
                "shrinking chunks decay to empty before m_max and the "
                "drain loop never terminates")
        if self.max_cache_entries < 1:
            raise ValueError("max_cache_entries must be >= 1 "
                             f"(got {self.max_cache_entries})")
        if self.drain not in DRAIN_MODES:
            raise ValueError(f"unknown drain mode {self.drain!r} "
                             f"(have {DRAIN_MODES})")


@dataclass
class EngineStats:
    solves: int = 0               # scenarios requested
    unique_solves: int = 0        # scenarios actually solved
    cache_hits: int = 0
    coalesced_dupes: int = 0      # same-signature scenarios within a drain
    rows_built: int = 0
    predict_calls: int = 0        # batched rounds issued to the predictor
    cache_epochs: int = 0         # times the cache was cleared (retrain)
    stale_epoch_hits: int = 0     # epoch-tag mismatches served (MUST be 0)
    retrains: int = 0             # on_samples()-triggered retrains
    retrain_time_s: float = 0.0   # forest refit wall time (background)
    refresh_rows: int = 0         # post-retrain table-refresh rows
    refresh_time_s: float = 0.0   # post-retrain table-refresh wall time

    def snapshot(self) -> Dict[str, float]:
        return dict(self.__dict__)


def coloc_signature(coloc: Coloc, fn: str, m_max: int,
                    quant: float = 4.0) -> SigKey:
    """Canonical cache key for 'capacity of `fn` among `coloc`'.

    The target's own counts are excluded (the m-sweep replaces them, as
    in ``capacity_of``); neighbor counts are quantized to 1/quant steps
    and sorted, so the key is a true multiset signature — two nodes with
    the same colocation mix share one solve.
    """
    q = max(quant, 1e-9)
    sig = tuple(sorted(
        (g, round(ns * q) / q, round(nc * q) / q)
        for g, (ns, nc) in coloc.items() if g != fn and ns + nc > 0))
    return (fn, int(m_max), sig)


# ---------------------------------------------------------------------------
# Vectorized scenario assembly + chunked sweep state
# ---------------------------------------------------------------------------


class _Template:
    """Precomputed per-scenario constants for vectorized row assembly.

    Rows for one m, in legacy order: [target@m, neighbor_1, ...].  Every
    float64 accumulation mirrors build_features exactly:

      target agg   = prof_f*m  then += prof_g*ns_g   (coloc order)
      neighbor agg = (prof_g*ns_g + sum_{h!=g} prof_h*ns_h) + prof_f*m

    Schema v2 appends the (constant per scenario) normalized node-shape
    block as trailing columns; v1 layouts are bit-identical to PR 1.
    """

    def __init__(self, store: ProfileStore, qos: QoSStore,
                 specs: Dict[str, FunctionSpec], coloc: Coloc, fn: str,
                 schema: Optional[FeatureSchema] = None,
                 node_res: Optional[NodeResources] = None,
                 bound_scale: float = 1.0):
        self.schema = schema or SCHEMA_V1
        self.shape = self.schema.shape_features(node_res)
        self.pressure_scale = self.schema.pressure_scale(node_res)
        self.bound_scale = bound_scale
        spec = specs[fn]
        self.prof_f = store.profile(spec)
        self.solo_f = qos.solo(spec)
        self.qos_f = qos.qos(spec)
        names = [g for g, (ns, nc) in coloc.items()
                 if g != fn and ns + nc > 0]
        counts = {g: coloc[g] for g in names}
        self.neigh: List[Tuple[float, float, np.ndarray, float, float]] = []
        contribs = {g: store.profile(specs[g]) * counts[g][0] for g in names}
        for g in names:
            ns, nc = counts[g]
            gspec = specs[g]
            # base_agg: prof_g*ns_g then += prof_h*ns_h for h != g in order
            base = store.profile(gspec) * ns
            for h in names:
                if h != g:
                    base = base + contribs[h]
            self.neigh.append((ns, nc, store.profile(gspec),
                               qos.solo(gspec), qos.qos(gspec), base))
        self.contribs = [contribs[g] for g in names]
        self.tot_sat_base = float(sum(c[0] for c in counts.values()))
        self.tot_cached_base = float(sum(c[1] for c in counts.values()))
        self.rows_per_m = 1 + len(self.neigh)
        self.bounds_per_m = np.asarray(
            [self.qos_f] + [nb[4] for nb in self.neigh]) * self.bound_scale

    def build(self, ms: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Feature matrix + QoS bounds for concurrencies `ms` (ascending).
        Returns (len(ms)*rows_per_m, n_features) float32 and bounds."""
        c = len(ms)
        R = self.rows_per_m
        msf = ms.astype(np.float64)
        X = np.empty((c, R, self.schema.n_features), np.float64)
        # target rows: n_sat = m, n_cached = 0
        X[:, 0, _SOLO] = self.solo_f
        X[:, 0, _PROF] = self.prof_f
        X[:, 0, _NSAT] = msf
        X[:, 0, _NCACHED] = 0.0
        agg_t = msf[:, None] * self.prof_f
        for contrib in self.contribs:
            agg_t = agg_t + contrib
        X[:, 0, _AGG] = agg_t
        X[:, 0, _TOTSAT] = msf + self.tot_sat_base
        X[:, 0, _TOTCACHED] = self.tot_cached_base
        # neighbor rows: fn@m is their last-added neighbor
        for j, (ns, nc, prof_g, solo_g, _qos_g, base) in \
                enumerate(self.neigh):
            r = j + 1
            X[:, r, _SOLO] = solo_g
            X[:, r, _PROF] = prof_g
            X[:, r, _NSAT] = ns
            X[:, r, _NCACHED] = nc
            X[:, r, _AGG] = base + msf[:, None] * self.prof_f
            X[:, r, _TOTSAT] = self.tot_sat_base + msf
            X[:, r, _TOTCACHED] = self.tot_cached_base
        if self.schema.n_shape:
            X[:, :, N_FEATURES:] = self.shape
        out = X.reshape(c * R, self.schema.n_features).astype(np.float32)
        if self.schema.n_shape and self.pressure_scale != 1.0:
            # scale AFTER the float32 cast of the base block, mirroring
            # build_row (float32 base -> float64 * scale -> float32), so
            # solver rows are bitwise identical to training/per-schedule
            # rows for every node shape, not just power-of-two ratios
            for cols in (_NSAT, _NCACHED, _AGG, _TOTSAT, _TOTCACHED):
                out[:, cols] = (out[:, cols].astype(np.float64)
                                * self.pressure_scale).astype(np.float32)
        bounds = np.tile(self.bounds_per_m, c)
        return out, bounds


class _Solve:
    """State machine for one unique scenario's chunked m-sweep."""

    def __init__(self, tmpl: _Template, m_max: int):
        self.tmpl = tmpl
        self.m_max = m_max
        self.next_m = 1
        self.capacity = 0
        self.rows = 0
        self.done = m_max <= 0

    def take_chunk(self, size: int) -> np.ndarray:
        hi = min(self.next_m + size - 1, self.m_max)
        ms = np.arange(self.next_m, hi + 1)
        self.next_m = hi + 1
        return ms

    def absorb(self, ms: np.ndarray, ok: np.ndarray):
        """ok: (len(ms)*rows_per_m,) bool — pass/fail per feature row."""
        per_m = self.tmpl.rows_per_m
        blocks = ok.reshape(len(ms), per_m)
        for i, m in enumerate(ms):
            if blocks[i].all():
                self.capacity = int(m)
            else:
                self.done = True
                return
        if self.next_m > self.m_max:
            self.done = True


# Internal query form: (coloc, fn, m_max, node_res)
_Query = Tuple[Coloc, str, int, Optional[NodeResources]]


class PredictionService:
    """Owns the forest, the feature schema, batched capacity solving, the
    colocation-signature cache, and epoch/retrain bookkeeping; see module
    docstring.  ``CapacityEngine`` is an alias of this class."""

    def __init__(self, predictor: PerfPredictor, store: ProfileStore,
                 qos: QoSStore, specs: Dict[str, FunctionSpec],
                 cfg: Optional[EngineConfig] = None, *,
                 schema: Union[int, FeatureSchema, None] = None,
                 engine: Optional[str] = None,
                 drain: Optional[str] = None):
        self.predictor = predictor
        self.store = store
        self.qos = qos
        self.specs = specs
        self.cfg = cfg or EngineConfig()
        if drain is not None:
            # keyword override without mutating a caller-shared config
            self.cfg = replace(self.cfg, drain=drain)
        self.schema = get_schema(schema)
        if engine is not None:
            self.set_engine(engine)
        self.stats = EngineStats()
        #: span tracer for retrain / capacity-solve sections (no-op by
        #: default; ``Platform.build`` swaps in a real one when
        #: telemetry is enabled)
        self.tracer = NULL_TRACER
        self._cache: Dict[SigKey, Tuple[int, int]] = {}  # key -> (epoch, cap)
        # device-resident signature cache: solved capacities live in one
        # growing device vector; repeat signatures resolve as a gather
        self._dev_slots: Dict[SigKey, int] = {}          # key -> slot index
        self._dev_caps = None                            # jnp (n_slots,) i32
        self._interpret: Optional[bool] = None           # pallas off-TPU
        self._epoch = predictor.retrain_count
        self._pending_samples = 0
        self._retrain_listeners: List = []
        # learned per-shape QoS margins (shape_key -> margin); cached
        # per forest epoch when cfg.learned_shape_margin.  Learned
        # eagerly here and after each retrain so the probe-forest fit
        # never lands on a scheduling critical path.
        self._shape_margins: Optional[Dict[Tuple[float, ...], float]] = None
        #: cross-cell capacity exchange (``cells.CapacityExchange``):
        #: when joined, every freshly solved capacity is published so
        #: sibling cells' services can serve it cache-warm.  None (the
        #: default) is zero-overhead.
        self.exchange = None
        if self.cfg.learned_shape_margin and predictor.fitted:
            self.shape_margins()

    # -- inference engine selection --------------------------------------

    def set_engine(self, name: str):
        """Select the RFR inference engine for every prediction issued
        through this service (numpy / jax / pallas, the last routing
        through the VMEM-resident ``kernels.rfr_inference`` path)."""
        if name not in INFERENCE_ENGINES:
            raise ValueError(f"unknown inference engine {name!r} "
                             f"(have {INFERENCE_ENGINES})")
        self.predictor.engine = name

    @property
    def inference_engine(self) -> str:
        return self.predictor.engine

    @property
    def epoch(self) -> int:
        """Current forest epoch (bumped by every retrain)."""
        return self._epoch

    # -- feature assembly (the build_features client surface) -------------

    def feature_row(self, fn: str, n_sat: float, n_cached: float,
                    coloc: Optional[Coloc] = None,
                    node_res: Optional[NodeResources] = None) -> np.ndarray:
        """One schema row for `fn` at (n_sat, n_cached) among `coloc`
        (which may include fn itself; fn's entry is excluded from the
        neighbor block) hosted on a ``node_res``-shaped node."""
        spec = self.specs[fn]
        neigh = [(self.store.profile(self.specs[g]), ns, nc)
                 for g, (ns, nc) in (coloc or {}).items()
                 if g != fn and ns + nc > 0]
        return self.schema.build_row(self.qos.solo(spec),
                                     self.store.profile(spec), n_sat,
                                     n_cached, neigh, node_res)

    def rows_for_coloc(self, coloc: Coloc,
                       node_res: Optional[NodeResources] = None
                       ) -> Tuple[List[str], np.ndarray, np.ndarray]:
        """One row + QoS bound per function in `coloc` (dict order).

        Bounds carry the schema-v2 safety margin (``qos_bound_scale``),
        so per-schedule admission checks (Gsight) apply the same slack
        as the capacity solver."""
        scale = self.qos_bound_scale(node_res)
        names, rows, bounds = [], [], []
        for g, (ns, nc) in coloc.items():
            if ns + nc <= 0:
                continue
            names.append(g)
            rows.append(self.feature_row(g, ns, nc, coloc, node_res))
            bounds.append(self.qos.qos(self.specs[g]) * scale)
        return names, (np.stack(rows) if rows
                       else np.empty((0, self.schema.n_features),
                                     np.float32)), np.asarray(bounds)

    # -- prediction -------------------------------------------------------

    def predict(self, X: np.ndarray) -> np.ndarray:
        """One batched inference through the selected engine."""
        return self.predictor.predict(X)

    def predict_many(self, Xs: Sequence[np.ndarray]) -> List[np.ndarray]:
        return self.predictor.predict_many(Xs)

    # -- cache / epoch ----------------------------------------------------

    def _check_epoch(self):
        if self.predictor.retrain_count != self._epoch:
            self.invalidate()
            self._epoch = self.predictor.retrain_count

    def invalidate(self):
        """Drop every cached capacity (predictor retrained, or external
        state the signatures cannot see has changed)."""
        if self._cache:
            self._cache.clear()
        self._dev_slots.clear()
        self._dev_caps = None
        self._shape_margins = None   # re-learn against the new forest
        self.stats.cache_epochs += 1

    def signature(self, coloc: Coloc, fn: str,
                  m_max: Optional[int] = None,
                  node_res: Optional[NodeResources] = None) -> SigKey:
        key = coloc_signature(coloc, fn, m_max or self.cfg.m_max,
                              self.cfg.quant)
        shape = self.schema.shape_key(node_res, self.cfg.quant)
        return key + (shape,) if shape else key

    def _cache_get(self, key: SigKey) -> Optional[int]:
        """Epoch-checked cache lookup.  An entry tagged with a different
        epoch than the current forest must never be served: it is counted
        (``stale_epoch_hits`` — asserted 0 by the retrain benchmarks,
        since ``invalidate`` clears eagerly) and dropped."""
        ent = self._cache.get(key)
        if ent is None:
            return None
        epoch, cap = ent
        if epoch != self._epoch:
            self.stats.stale_epoch_hits += 1
            del self._cache[key]
            return None
        return cap

    def _cache_put(self, key: SigKey, cap: int):
        """Insert one solved capacity, evicting oldest-first (dict
        insertion order) at ``max_cache_entries`` — the wholesale
        ``clear()`` this replaces dropped every warm entry the moment
        the bound was hit, triggering a cluster-wide re-solve storm."""
        if not self.cfg.cache:
            return
        if key not in self._cache:
            while len(self._cache) >= self.cfg.max_cache_entries:
                self._cache.pop(next(iter(self._cache)))
        self._cache[key] = (self._epoch, cap)
        if self.exchange is not None:
            self.exchange.publish(self, key, self._epoch, cap)

    def accept_exchange(self, key: SigKey, epoch: int, cap: int):
        """Receive a capacity solved by a sibling cell's service.  Only
        same-epoch entries are accepted (all cells share one forest, so
        epochs agree except transiently around a retrain) and the entry
        lands without re-publishing."""
        if not self.cfg.cache or epoch != self._epoch:
            return
        if key not in self._cache:
            while len(self._cache) >= self.cfg.max_cache_entries:
                self._cache.pop(next(iter(self._cache)))
        self._cache[key] = (epoch, cap)

    def shape_margins(self) -> Dict[Tuple[float, ...], float]:
        """Per-shape QoS margins learned from per-shape *validation*
        error (``cfg.learned_shape_margin``).

        A deterministic 1-in-4 holdout of the accumulated dataset is
        scored against a **probe forest** fit on the remaining rows
        (same hyperparameters as the serving forest) — the serving
        forest trains on everything, so scoring the holdout with it
        would report biased-low in-sample residuals and hand poorly-
        extrapolated shapes margins that are too tight.  Holdout rows
        are grouped by their quantized shape block (the same keys the
        signature cache uses) and each shape's margin is the
        ``margin_quantile`` of its relative error, clamped to
        [qos_margin_base, margin_cap].  Called eagerly on construction
        and after every ``retrain()`` — the probe fit is background
        work, billed with retraining; ``qos_bound_scale`` only ever
        *reads* the cached result (after an external ``invalidate``
        the fixed formula applies until the next retrain re-learns),
        so the fit can never land on a scheduling critical path."""
        if self._shape_margins is not None:
            return self._shape_margins
        margins: Dict[Tuple[float, ...], float] = {}
        X, y = self.predictor.dataset()
        if self.schema.version >= 2 and len(y) >= 8 \
                and X.shape[1] == self.schema.n_features:
            idx = np.arange(len(y))
            val = idx[3::4]              # deterministic 1-in-4 holdout
            train = np.setdiff1d(idx, val)
            Xv, yv = X[val], y[val]
            model = self.predictor.model
            probe = RandomForestRegressor(
                model.n_trees, model.max_depth,
                model.min_samples_leaf, seed=model.seed + 1)
            yt = y[train]
            if self.predictor.log_target:
                yt = np.log(np.maximum(yt, 1e-6))
            probe.fit(X[train], yt)
            pred = probe.predict(Xv)
            if self.predictor.log_target:
                pred = np.exp(pred)
            rel = np.abs(pred - yv) / np.maximum(yv, 1e-9)
            q = max(self.cfg.quant, 1e-9)
            keys = [tuple(round(float(v) * q) / q for v in row)
                    for row in Xv[:, N_FEATURES:]]
            groups: Dict[Tuple[float, ...], List[float]] = {}
            for key, err in zip(keys, rel):
                groups.setdefault(key, []).append(float(err))
            for key, errs in groups.items():
                m = float(np.quantile(np.asarray(errs),
                                      self.cfg.margin_quantile))
                margins[key] = min(max(m, self.cfg.qos_margin_base),
                                   self.cfg.margin_cap)
        self._shape_margins = margins
        return margins

    def qos_bound_scale(self, node_res: Optional[NodeResources] = None
                        ) -> float:
        """Schema-v2 QoS tightening (1.0 under v1 — the parity paths
        are untouched): flat base margin + shape-extrapolation term,
        or — with ``cfg.learned_shape_margin`` — the margin learned
        from that shape's validation error (fixed formula as the
        fallback for shapes with no validation rows)."""
        if self.schema.version == 1:
            return 1.0
        # cached margins only: a lazy recompute here would put the
        # probe-forest fit inside a scheduling-latency timing window
        if self.cfg.learned_shape_margin and self._shape_margins:
            learned = self._shape_margins.get(
                self.schema.shape_key(node_res, self.cfg.quant))
            if learned is not None:
                return 1.0 / (1.0 + learned)
        margin = self.cfg.qos_margin_base
        if node_res is not None and self.cfg.shape_margin:
            r = node_res.cpu_mcores / REFERENCE_NODE.cpu_mcores
            margin += self.cfg.shape_margin * abs(r - 1.0)
        return 1.0 / (1.0 + margin)

    def capacity_hint(self, coloc: Coloc, fn: str,
                      m_max: Optional[int] = None,
                      node_res: Optional[NodeResources] = None
                      ) -> Optional[int]:
        """Cached capacity for this colocation, or None.  Never runs
        inference — safe on any non-critical decision path (migration
        targeting, consolidation)."""
        self._check_epoch()
        return self._cache_get(self.signature(coloc, fn, m_max, node_res))

    # -- solving ----------------------------------------------------------

    def capacity(self, coloc: Coloc, fn: str, m_max: Optional[int] = None,
                 node_res: Optional[NodeResources] = None
                 ) -> Tuple[int, int]:
        """Capacity of `fn` under `coloc` on a ``node_res``-shaped node;
        returns (capacity, rows_built).  Same contract as
        ``capacity.capacity_of`` (cache hits bill 0 rows)."""
        return self.solve_many(
            [(coloc, fn, m_max or self.cfg.m_max, node_res)])[0]

    def solve_many(self, queries: Sequence[Tuple]
                   ) -> List[Tuple[int, int]]:
        """Solve many (coloc, fn, m_max[, node_res]) scenarios with
        coalesced batched inference.  Duplicate signatures within the
        batch are solved once; rows are billed to the first occurrence
        only.

        ``cfg.drain`` selects the strategy: the chunked host m-sweep
        below, or the device-resident fused sweep
        (``_solve_many_device``) — one padded scenario tensor, one
        kernel pass, no per-chunk host round trips."""
        norm: List[_Query] = [q if len(q) == 4 else (*q, None)
                              for q in queries]
        self._check_epoch()
        self.stats.solves += len(norm)
        if self.cfg.drain == "device":
            return self._solve_many_device(norm)
        results: List[Optional[Tuple[int, int]]] = [None] * len(norm)
        unique: Dict[SigKey, _Solve] = {}
        assignment: List[Optional[SigKey]] = [None] * len(norm)
        for i, (coloc, fn, m_max, node_res) in enumerate(norm):
            key = self.signature(coloc, fn, m_max, node_res)
            if self.cfg.cache:
                cap = self._cache_get(key)
                if cap is not None:
                    results[i] = (cap, 0)
                    self.stats.cache_hits += 1
                    continue
            if key in unique:
                self.stats.coalesced_dupes += 1
            else:
                unique[key] = _Solve(
                    _Template(self.store, self.qos, self.specs, coloc, fn,
                              self.schema, node_res,
                              self.qos_bound_scale(node_res)), m_max)
                self.stats.unique_solves += 1
            assignment[i] = key

        active = [s for s in unique.values() if not s.done]
        size = self.cfg.chunk_init if self.cfg.early_exit else \
            max((s.m_max for s in active), default=1)
        while active:
            batch = []
            for s in active:
                ms = s.take_chunk(size)
                X, bounds = s.tmpl.build(ms)
                s.rows += len(X)
                batch.append((s, ms, X, bounds))
            self.stats.rows_built += sum(len(b[2]) for b in batch)
            preds = self.predictor.predict_many([b[2] for b in batch])
            self.stats.predict_calls += 1
            for (s, ms, _X, bounds), p in zip(batch, preds):
                s.absorb(ms, p <= bounds)
            active = [s for s in active if not s.done]
            size *= self.cfg.chunk_growth

        for key, s in unique.items():
            self._cache_put(key, s.capacity)
        billed: set = set()
        for i, key in enumerate(assignment):
            if key is None:
                continue
            s = unique[key]
            results[i] = (s.capacity, 0 if key in billed else s.rows)
            billed.add(key)
        return results  # type: ignore[return-value]

    # -- device-resident drain (the fused Pallas/jnp m-sweep) -------------

    def _pallas_interpret(self) -> bool:
        """Pallas kernels run compiled on TPU, interpret-mode anywhere
        else (the CPU validation path)."""
        if self._interpret is None:
            try:
                import jax
                self._interpret = jax.default_backend() != "tpu"
            except Exception:          # pragma: no cover - no jax at all
                self._interpret = True
        return self._interpret

    def _solve_many_device(self, norm: List[_Query]
                           ) -> List[Tuple[int, int]]:
        """Device-resident capacity solving: the whole drain's candidate
        feature matrix is assembled as ONE padded (S, M, R, F) jnp
        tensor and the full m-sweep runs in a single fused forest pass
        (``kernels.ops.rfr_sweep_op``) that returns max-admissible m per
        scenario — no host round-trip per chunk, host work O(unique
        signatures) instead of O(nodes x chunk rounds).

        Row assembly stays in the float64 numpy ``_Template.build`` —
        the solver's bit-compatibility contract (device rows are the
        host oracle's rows, so capacity tables are bit-identical by
        construction); everything after the one transfer — forest
        descent, QoS comparison, the running all-pass reduction over m,
        and cached-capacity resolution (a gather over the device-side
        capacity vector keyed by colocation signature) — is
        device-resident and jitted.  ``predictor.engine == "pallas"``
        routes to the fused Pallas kernel, anything else to the jnp
        gather sweep."""
        from ..kernels import ops
        import jax.numpy as jnp

        t0 = time.perf_counter()
        n = len(norm)
        if n == 0:
            return []
        persist = self.cfg.cache
        # next free slot = device-vector length, NOT len(_dev_slots):
        # re-solves (host entry evicted) overwrite their slot and leave
        # an orphan element behind, so the dict can run shorter than
        # the vector — handing out len(_dev_slots) would collide
        base = int(self._dev_caps.shape[0]) \
            if persist and self._dev_caps is not None else 0
        # key -> (template, m_max, slot, first query index)
        new: Dict[SigKey, Tuple[_Template, int, int, int]] = {}
        slot_ids = np.zeros(n, np.int32)
        first_rows = [0] * n
        for i, (coloc, fn, m_max, node_res) in enumerate(norm):
            key = self.signature(coloc, fn, m_max, node_res)
            if persist:
                slot = self._dev_slots.get(key)
                if slot is not None and self._cache_get(key) is not None:
                    slot_ids[i] = slot
                    self.stats.cache_hits += 1
                    continue
            ent = new.get(key)
            if ent is not None:
                self.stats.coalesced_dupes += 1
                slot_ids[i] = ent[2]
                continue
            tmpl = _Template(self.store, self.qos, self.specs, coloc, fn,
                             self.schema, node_res,
                             self.qos_bound_scale(node_res))
            slot = base + len(new)
            new[key] = (tmpl, m_max, slot, i)
            slot_ids[i] = slot
            first_rows[i] = max(m_max, 0) * tmpl.rows_per_m
            self.stats.unique_solves += 1

        caps_new = None
        if new:
            with self.tracer.span("device_sweep", stats=self.stats) as sp:
                F = self.schema.n_features
                S = len(new)
                Mp = max(max(mm for _t, mm, _s, _i in new.values()), 1)
                Rp = max(t.rows_per_m for t, _mm, _s, _i in new.values())
                X = np.zeros((S, Mp, Rp, F), np.float32)
                # +inf bound = padded row, passes; -inf = past this
                # scenario's own m_max, fails (capacity capped there)
                B = np.full((S, Mp, Rp), np.inf, np.float32)
                rows_built = 0
                for j, (tmpl, mm, _slot, _i) in enumerate(new.values()):
                    R = tmpl.rows_per_m
                    if mm > 0:
                        rows, bounds = tmpl.build(np.arange(1, mm + 1))
                        X[j, :mm, :R, :] = rows.reshape(mm, R, F)
                        B[j, :mm, :R] = bounds.reshape(mm, R)
                    B[j, max(mm, 0):, :] = -np.inf
                    rows_built += max(mm, 0) * R
                feat, thr, leaf = self.predictor.model.device_arrays()
                caps_new = ops.rfr_sweep_op(
                    jnp.asarray(X), jnp.asarray(B), feat, thr, leaf,
                    use_pallas=(self.predictor.engine == "pallas"),
                    interpret=self._pallas_interpret(),
                    log_target=self.predictor.log_target)
                self.stats.rows_built += rows_built
                self.stats.predict_calls += 1
                if sp is not None:
                    sp.attrs["scenarios"] = S
                    sp.attrs["rows"] = rows_built
                    sp.attrs["padded_shape"] = [S, Mp, Rp, F]
            if persist:
                self._dev_caps = caps_new if self._dev_caps is None \
                    else jnp.concatenate([self._dev_caps, caps_new])

        # resolve every query with one device-side gather
        all_caps = self._dev_caps if persist else caps_new
        caps_host = np.asarray(jnp.take(all_caps, jnp.asarray(slot_ids)))
        if persist:
            for key, (_t, _mm, slot, i) in new.items():
                self._dev_slots[key] = slot
                self._cache_put(key, int(caps_host[i]))
            self._dev_evict()
        if new:
            self.predictor.record_inference(
                rows_built, time.perf_counter() - t0)
        return [(int(caps_host[i]), first_rows[i]) for i in range(n)]

    def _dev_evict(self):
        """Bound the device capacity vector like the host cache: drop
        oldest slots past ``max_cache_entries`` and compact the
        survivors with one gather."""
        import jax.numpy as jnp
        excess = len(self._dev_slots) - self.cfg.max_cache_entries
        if excess <= 0:
            return
        keep = list(self._dev_slots)[excess:]
        idx = jnp.asarray(np.asarray(
            [self._dev_slots[k] for k in keep], np.int32))
        self._dev_caps = jnp.take(self._dev_caps, idx)
        self._dev_slots = {k: i for i, k in enumerate(keep)}

    # -- node-level API (the async-update path) ---------------------------

    def node_coloc(self, node: Node) -> Coloc:
        return {g: (float(s.n_sat), float(s.n_cached))
                for g, s in node.funcs.items() if s.total > 0}

    def update_node(self, node: Node, m_max: Optional[int] = None) -> int:
        return self.update_nodes([node], m_max)

    def update_nodes(self, nodes: Sequence[Node],
                     m_max: Optional[int] = None) -> int:
        """Recompute every capacity-table entry of every node in one
        coalesced drain (node-shape-aware under schema v2).  Returns
        total inference rows billed."""
        with self.tracer.span("capacity_solve", stats=self.stats) as sp:
            mm = m_max or self.cfg.m_max
            queries: List[_Query] = []
            owners: List[Tuple[Node, str]] = []
            for node in nodes:
                coloc = self.node_coloc(node)
                for fn in coloc:
                    queries.append((coloc, fn, mm, node.res))
                    owners.append((node, fn))
            total_rows = 0
            for (node, fn), (cap, rows) in zip(owners,
                                               self.solve_many(queries)):
                node.table[fn] = CapEntry(capacity=cap, fresh=True)
                total_rows += rows
            if sp is not None:
                sp.attrs["nodes"] = len(nodes)
                sp.attrs["rows"] = total_rows
        return total_rows

    # -- online retraining (the runtime dataset-maintenance loop) ---------

    def on_samples(self, X: Sequence[np.ndarray], y: Sequence[float],
                   retrain: Optional[bool] = None) -> bool:
        """Ingest runtime (features, label) measurements and apply the
        online retraining policy.

        ``retrain=None`` retrains once ``cfg.retrain_every`` (default:
        the predictor's own ``retrain_every``) samples accumulated since
        the last retrain; True forces one; False only accumulates.
        Returns whether a retrain fired (callers then refresh capacity
        tables off the critical path via ``refresh_tables``)."""
        for xi, yi in zip(X, y):
            self.predictor.add_sample(xi, yi, retrain=False)
        self._pending_samples += len(y)
        if retrain is None:
            every = self.cfg.retrain_every \
                if self.cfg.retrain_every is not None \
                else self.predictor.retrain_every
            retrain = self._pending_samples >= every
        if retrain:
            self.retrain()
            return True
        return False

    def retrain(self):
        """Refit the forest on the full accumulated dataset; bumps the
        epoch and eagerly clears the signature cache so no post-retrain
        lookup can see a pre-retrain capacity.  Wall time is billed to
        ``stats.retrain_time_s`` (background work, never the scheduling
        critical path)."""
        with self.tracer.span("retrain", stats=self.stats) as sp:
            t0 = time.perf_counter()
            self.predictor.retrain()
            self._check_epoch()     # epoch bump -> invalidate()
            if self.cfg.learned_shape_margin:
                # re-learn margins against the new forest now
                # (background, billed with the retrain) rather than
                # lazily on the next capacity solve
                self.shape_margins()
            self.stats.retrain_time_s += time.perf_counter() - t0
            self.stats.retrains += 1
            self._pending_samples = 0
            if sp is not None:
                sp.attrs["epoch"] = self._epoch
                sp.attrs["samples"] = self.predictor.n_samples
        for cb in self._retrain_listeners:
            cb(self)

    def add_retrain_listener(self, cb) -> None:
        """Register ``cb(service)`` to fire after every retrain (forest
        refit + epoch bump + cache clear) — the platform's ``on_retrain``
        observer hook subscribes here."""
        self._retrain_listeners.append(cb)

    def refresh_tables(self, nodes: Sequence[Node],
                       m_max: Optional[int] = None) -> int:
        """Post-retrain capacity-table refresh over `nodes`, billed
        separately (``stats.refresh_rows`` / ``refresh_time_s``) so the
        retrain benchmarks can report table-refresh cost apart from both
        retraining and scheduling-critical-path inference."""
        t0 = time.perf_counter()
        rows = self.update_nodes(nodes, m_max)
        self.stats.refresh_time_s += time.perf_counter() - t0
        self.stats.refresh_rows += rows
        return rows


#: PR-1 name for the service's batched-capacity surface; kept as a true
#: alias (one class, no wrapper) so ``repro.engine.CapacityEngine`` and
#: every existing call site keep working.
CapacityEngine = PredictionService
