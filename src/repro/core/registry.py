"""Generic name-based component registry.

One implementation behind every ``repro.platform`` registry
(schedulers, scenario kinds, trace programs, routers): a dict with
duplicate-registration protection and a consistent unknown-name error
that lists what *is* registered.  ``register`` doubles as a decorator
when called without an object.
"""
from __future__ import annotations

from typing import Any, Dict, List


class Registry:
    """Named components of one ``kind`` (used in error messages)."""

    def __init__(self, kind: str):
        self.kind = kind
        self._items: Dict[str, Any] = {}

    def register(self, name: str, obj: Any = None, *,
                 overwrite: bool = False):
        """Register ``obj`` under ``name``; duplicate names raise unless
        ``overwrite=True``.  With ``obj=None`` returns a decorator."""
        def _do(o):
            if name in self._items and not overwrite:
                raise ValueError(
                    f"{self.kind} {name!r} already registered "
                    f"(pass overwrite=True to replace)")
            self._items[name] = o
            return o
        return _do if obj is None else _do(obj)

    def get(self, name: str) -> Any:
        obj = self._items.get(name)
        if obj is None:
            raise ValueError(f"unknown {self.kind} {name!r} "
                             f"(registered: {self.names()})")
        return obj

    def names(self) -> List[str]:
        return sorted(self._items)

    def __contains__(self, name: str) -> bool:
        return name in self._items
