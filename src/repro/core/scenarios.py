"""Cluster-scale scenarios: topology x population x trace program.

The paper evaluates on a homogeneous 24-node testbed with six functions
and four same-shaped traces.  The CapacityEngine (PR 1) makes 512-node
simulation affordable; this module supplies the *worlds* to run at that
scale.  A ``Scenario`` composes:

  * **cluster topology** — a weighted mix of ``NodeClass`` shapes
    (heterogeneous fleets: standard profiling-node-shaped servers plus
    larger ones; ``Cluster.res_pool`` cycles the mix deterministically),
  * **function population** — a synthetic population whose request share
    follows a skewed Zipf popularity law (a few hot functions, a long
    tail — the Azure-style population shape), and
  * **trace program** — one of the generators in ``traces``: correlated
    burst storms, migrating diurnal peaks, heavy-tailed cold-start
    churn, the sparse-invocation long tail, or the paper's real-world
    shape — scaled so mean load fills a target node count.

``scenario_simulation`` assembles the full stack (ground truth, profile
store, predictor trained on profiling-node data, scheduler, autoscaler)
for a scenario, so benchmarks and tests build 64-512-node studies from
one call.  The predictor is always trained against the *standard* node
class — the paper's profiling nodes are one shape; capacity predictions
on bigger nodes are conservative (pressures only drop with node size),
which is the safe direction for QoS.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .autoscaler import Autoscaler, ScalingConfig
from .capacity import M_MAX_DEFAULT, QoSStore
from .cluster import Cluster
from .events import EventHub
from .interference import GroundTruth, NodeResources
from .predictor import PerfPredictor
from .profiles import FunctionSpec, ProfileStore, synthetic_functions
from .registry import Registry
from .scheduler import (SchedulerBuildContext, build_scheduler,
                        scheduler_entry)
from .simulator import SimConfig, Simulation, generate_dataset
from .traces import (Trace, azure_sparse_trace, burst_storm_trace,
                     coldstart_churn_trace, diurnal_shift_trace,
                     realworld_trace, replay_trace)


@dataclass(frozen=True)
class NodeClass:
    """One server shape in the fleet mix."""

    name: str
    res: NodeResources
    weight: int = 1         # relative share of the fleet


#: standard node = the paper's testbed shape = the profiling-node shape
STANDARD_NODE = NodeClass("std", NodeResources(), weight=3)
#: double-size node (2x every capacity) — predictions made against the
#: standard shape are conservative here, never optimistic
LARGE_NODE = NodeClass("large", NodeResources(
    cpu_mcores=96_000.0, mem_mb=262_144.0, mem_bw_gbps=136.0,
    llc_mb=120.0), weight=1)

#: the generated scenario kinds (the large-cluster study sweeps these);
#: the full registry — including ``replay`` and anything user-registered
#: — is ``registered_scenarios()``
SCENARIO_KINDS = ("burst-storm", "diurnal-shift", "coldstart-churn",
                  "azure-sparse", "realworld")


# ---------------------------------------------------------------------------
# Scenario-kind registry (the repro.platform name-based selection)
# ---------------------------------------------------------------------------

_SCENARIOS = Registry("scenario kind")


def register_scenario(kind: str, trace_builder=None, *,
                      overwrite: bool = False):
    """Register a scenario kind: a trace-program builder with the
    ``(fn_names, duration_s=..., seed=..., scale_rps=..., **kw)``
    signature, selectable by name from ``make_scenario`` and
    ``PlatformConfig`` manifests.  Usable as a decorator."""
    return _SCENARIOS.register(kind, trace_builder, overwrite=overwrite)


def get_scenario_builder(kind: str):
    return _SCENARIOS.get(kind)


def registered_scenarios() -> List[str]:
    return _SCENARIOS.names()


for _kind, _builder in (("burst-storm", burst_storm_trace),
                        ("diurnal-shift", diurnal_shift_trace),
                        ("coldstart-churn", coldstart_churn_trace),
                        ("azure-sparse", azure_sparse_trace),
                        ("realworld", realworld_trace)):
    register_scenario(_kind, _builder)
del _kind, _builder


@register_scenario("replay")
def replay_scenario_trace(fn_names: Sequence[str], duration_s: int = 3600,
                          seed: int = 0,
                          scale_rps: Optional[Dict[str, float]] = None,
                          *, path=None, name: Optional[str] = None
                          ) -> Trace:
    """Feed a real invocation dump (``traces.replay_trace`` CSV format)
    through the scenario machinery: the recorded per-function series are
    assigned to the synthetic population (seed-permuted, cycling when
    the population outnumbers the recording), normalized to unit mean so
    the population's Zipf popularity shares (``scale_rps``) and the
    ``scale_trace_to_nodes`` cluster-size rescale stay meaningful, and
    tiled/clamped to ``duration_s``.  Pass the CSV via
    ``make_scenario("replay", ..., path=...)`` (the ``trace_kw``
    passthrough) — so Azure/Huawei-style dumps run in the large-cluster
    suite exactly like the generated trace programs."""
    if path is None:
        raise ValueError("replay scenario requires path=<csv> "
                         "(make_scenario trace_kw)")
    src = replay_trace(path)
    recorded = [src.rps[k] for k in sorted(src.rps)]
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(recorded))
    out: Dict[str, np.ndarray] = {}
    for i, fn in enumerate(fn_names):
        base = recorded[order[i % len(recorded)]]
        mean = float(base.mean())
        shape = base / mean if mean > 0 else base
        series = np.resize(shape, duration_s)  # tile/clamp to duration
        out[fn] = series * float((scale_rps or {}).get(fn, 1.0))
    return Trace(name or f"replay-{src.name}-seed{seed}", out, duration_s)


@dataclass
class Scenario:
    """A complete simulation world description (topology + population +
    trace), ready to be built into a ``Simulation``."""

    name: str
    kind: str
    specs: Dict[str, FunctionSpec]
    trace: Trace
    node_classes: List[NodeClass]
    target_nodes: int
    seed: int = 0

    def res_pool(self) -> List[NodeResources]:
        """Deterministic weighted node-shape cycle for ``Cluster``."""
        pool: List[NodeResources] = []
        for cls in self.node_classes:
            pool.extend([cls.res] * max(int(cls.weight), 1))
        return pool

    def build_cluster(self, max_nodes: Optional[int] = None) -> Cluster:
        return Cluster(self.specs, max_nodes=max_nodes or
                       max(4 * self.target_nodes, 64),
                       res_pool=self.res_pool())

    @property
    def standard_res(self) -> NodeResources:
        return self.node_classes[0].res


# ---------------------------------------------------------------------------
# Population: Zipf-skewed request shares
# ---------------------------------------------------------------------------


def zipf_weights(n: int, s: float = 1.2, seed: int = 0) -> np.ndarray:
    """Normalized Zipf popularity over a shuffled rank assignment (so the
    hot functions are not always the lexicographically first ones)."""
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** -s
    w /= w.sum()
    rng = np.random.default_rng(seed)
    return w[rng.permutation(n)]


def scenario_functions(n_functions: int, seed: int = 0
                       ) -> Dict[str, FunctionSpec]:
    """Function population for the large-cluster scenarios.

    Mirrors the paper's Fig-4 observation — users over-provision heavily,
    so *requested*-resource packing (the K8s baseline) leaves large true
    headroom on every channel.  Differs from ``synthetic_functions`` (the
    Fig-15 scalability family) in its per-slot bandwidth/cache footprints:
    those sit near the node's interference knee already at requested
    packing, which leaves no safe overcommit room and makes a density
    study read as pure QoS noise.  Here footprints are sized so requested
    packing is safe (interference multiplier ~1.0-1.1) and ~1.5-2x that
    density crosses the QoS headroom — the calibration invariant of
    ``interference.NodeResources``."""
    rng = np.random.default_rng(seed + 17)
    out: Dict[str, FunctionSpec] = {}
    for i in range(n_functions):
        name = f"sfn{i:03d}"
        cpu_req = float(rng.choice([1000.0, 2000.0, 2000.0, 4000.0]))
        slots = cpu_req / 1000.0
        out[name] = FunctionSpec(
            name=name,
            cpu_req=cpu_req,
            mem_req=float(rng.choice([512.0, 1024.0, 2048.0])),
            saturated_rps=float(rng.uniform(8, 60)),
            exec_ms=float(rng.uniform(10, 80)),
            cpu_work=float(rng.uniform(0.22, 0.5)),
            mem_work=float(rng.uniform(0.3, 0.7)),
            bw_demand=slots * float(rng.uniform(0.2, 0.75)),
            cache_mb=slots * float(rng.uniform(0.3, 1.1)),
            cpu_sens=float(rng.uniform(0.7, 1.5)),
            bw_sens=float(rng.uniform(0.7, 1.5)),
            cache_sens=float(rng.uniform(0.7, 1.5)),
        )
    return out


# ---------------------------------------------------------------------------
# Scaling a trace program to a target cluster size
# ---------------------------------------------------------------------------


def expected_mean_nodes(trace: Trace, specs: Dict[str, FunctionSpec],
                        node_cpu_mcores: float) -> float:
    """Mean requested-CPU demand of the trace, in nodes (the K8s packing
    yardstick: instances hold their *requested* cores)."""
    mcores = 0.0
    for fn, series in trace.rps.items():
        spec = specs[fn]
        mean_inst = float(np.mean(series)) / spec.saturated_rps
        mcores += mean_inst * spec.cpu_req
    return mcores / max(node_cpu_mcores, 1e-9)


def scale_trace_to_nodes(trace: Trace, specs: Dict[str, FunctionSpec],
                         target_nodes: int,
                         node_classes: Sequence[NodeClass],
                         utilization: float = 0.8) -> Trace:
    """Uniformly rescale every function's RPS so the trace's mean
    requested-CPU demand fills ``utilization`` of ``target_nodes`` mean-
    shaped nodes.  Peak demand then overshoots the target (bursts), which
    is the point — the elastic pool must breathe around it."""
    tot_w = sum(max(int(c.weight), 1) for c in node_classes)
    mean_cpu = sum(c.res.cpu_mcores * max(int(c.weight), 1)
                   for c in node_classes) / max(tot_w, 1)
    demand = expected_mean_nodes(trace, specs, mean_cpu)
    factor = target_nodes * utilization / max(demand, 1e-9)
    return Trace(trace.name,
                 {fn: series * factor for fn, series in trace.rps.items()},
                 trace.duration_s)


# ---------------------------------------------------------------------------
# Scenario construction
# ---------------------------------------------------------------------------


def make_scenario(kind: str, *, specs: Optional[Dict[str, FunctionSpec]]
                  = None, n_functions: int = 24, duration_s: int = 600,
                  target_nodes: int = 64, seed: int = 0,
                  spec_seed: Optional[int] = None,
                  zipf_s: float = 1.2, heterogeneous: bool = True,
                  node_classes: Optional[Sequence[NodeClass]] = None,
                  utilization: float = 0.8,
                  name: Optional[str] = None, **trace_kw) -> Scenario:
    """Build one scenario: Zipf-popular population + `kind` trace program
    (any registered scenario kind) scaled to `target_nodes`, on a (by
    default heterogeneous) fleet.

    ``spec_seed`` decouples the function population's seed from the
    trace seed (defaults to ``seed``); ``node_classes`` overrides the
    ``heterogeneous`` std/large default with an explicit topology mix.
    ``trace_kw`` passes through to the trace generator (e.g.
    ``coherence=`` for burst storms, ``n_regions=`` for diurnal shift,
    ``path=`` for replayed CSV dumps).
    """
    builder = get_scenario_builder(kind)
    if specs is None:
        specs = scenario_functions(
            n_functions, seed=seed if spec_seed is None else spec_seed)
    names = sorted(specs)
    # skewed popularity -> per-function peak RPS shares; normalized to a
    # mean of 1 so the global rescale below sets the absolute level
    w = zipf_weights(len(names), s=zipf_s, seed=seed + 1)
    scale_rps = {fn: float(len(names) * wi) for fn, wi in zip(names, w)}
    trace = builder(names, duration_s=duration_s, seed=seed,
                    scale_rps=scale_rps, **trace_kw)
    classes = list(node_classes) if node_classes else (
        [STANDARD_NODE, LARGE_NODE] if heterogeneous else [STANDARD_NODE])
    trace = scale_trace_to_nodes(trace, specs, target_nodes, classes,
                                 utilization)
    return Scenario(name or f"{kind}-n{target_nodes}-seed{seed}", kind,
                    specs, trace, classes, target_nodes, seed)


def scenario_suite(kinds: Sequence[str] = SCENARIO_KINDS, **kw
                   ) -> List[Scenario]:
    """One scenario per kind, sharing population and topology settings."""
    return [make_scenario(kind, **kw) for kind in kinds]


# ---------------------------------------------------------------------------
# World / simulation assembly
# ---------------------------------------------------------------------------


@dataclass
class ScenarioWorld:
    """The observable + hidden state shared by every system run on one
    scenario (ground truth keyed to the standard node class)."""

    scenario: Scenario
    gt: GroundTruth
    store: ProfileStore
    qos: QoSStore
    predictor: PerfPredictor
    schema_version: int = 1


def scenario_world(scenario: Scenario, *, n_train: int = 2000,
                   n_trees: int = 24, max_depth: int = 8,
                   seed: Optional[int] = None,
                   schema_version: int = 1) -> ScenarioWorld:
    """Ground truth + profiles + a predictor trained offline on
    profiling/training-node data.

    Training colocations span more kinds and a deeper packing budget
    than the six-function paper world's defaults: Zipf-populated
    scenarios routinely pack 6+ kinds and >1.6x requested CPU onto a
    node, and the forest extrapolates flat (optimistically) past its
    training ceiling — exactly where overcommitting breaks QoS.

    ``schema_version=1`` trains the legacy node-shape-blind vector on
    standard-shape rows only (predictions on bigger nodes stay
    conservative — the parity oracle); ``schema_version=2`` emits
    per-node-shape rows over the scenario's ``NodeClass`` mix so the
    forest resolves node size."""
    s = scenario.seed if seed is None else seed
    gt = GroundTruth(node=scenario.standard_res, seed=s)
    store = ProfileStore(seed=s)
    qos = QoSStore(store, gt)
    pred = PerfPredictor(n_trees=n_trees, max_depth=max_depth, seed=s)
    shapes = [cls.res for cls in scenario.node_classes] \
        if schema_version >= 2 else None
    X, y = generate_dataset(
        scenario.specs, gt, store, qos, n_train, seed=s + 2,
        max_kinds=min(8, len(scenario.specs)), max_count=30,
        budget_range=(0.25, 2.4), schema=schema_version,
        node_shapes=shapes)
    pred.add_dataset(X, y)
    return ScenarioWorld(scenario, gt, store, qos, pred, schema_version)


def build_simulation(specs: Dict[str, FunctionSpec], trace: Trace,
                     cluster: Cluster, gt: GroundTruth,
                     store: ProfileStore, qos: QoSStore,
                     scheduler: str = "jiagu",
                     predictor: Optional[PerfPredictor] = None, *,
                     dual: bool = True, release_s: float = 45.0,
                     keepalive_s: float = 60.0, init_ms: float = 8.4,
                     migrate: bool = True, m_max: int = M_MAX_DEFAULT,
                     use_engine: Optional[bool] = None,
                     collect_samples: bool = False,
                     schema_version: int = 1,
                     online_retrain: bool = False,
                     retrain_every: Optional[int] = None,
                     sample_every_s: Optional[int] = None,
                     dual_staged: Optional[bool] = None,
                     max_candidates: int = 4,
                     sim_seed: int = 0,
                     router=None,
                     learned_shape_margin: bool = False,
                     harvest_headroom: float = 0.85,
                     qos_release_cooldown_s: float = 30.0,
                     admission=None,
                     events: Optional[EventHub] = None) -> Simulation:
    """The one scheduler-dispatch/autoscaler/SimConfig assembly, shared
    by ``scenario_simulation``, ``platform.Platform.build`` and
    ``benchmarks.common.make_sim``.  Schedulers come from the name-based
    registry (``scheduler.register_scheduler``), so any registered
    policy is selectable by string.

    ``use_engine=None`` keeps the ``SimConfig`` default (the
    PredictionService path); ``False`` forces the legacy per-node
    reference path — the A/B knob the parity harness flips.
    ``schema_version`` selects the feature schema of the attached
    service (the predictor must be trained on matching rows) and
    ``online_retrain``/``retrain_every`` arm the in-run incremental
    retraining loop.  ``dual_staged=None`` applies the registry's
    per-scheduler default (dual-staged for Jiagu, traditional
    keep-alive for the baselines, gated by ``dual``); an explicit bool
    forces it for any scheduler — the greedy picker defaults make the
    release / logical-cold-start machinery meaningful for all of them.
    ``router``/``events`` plug the routing policy and observer hub.
    ``admission`` takes an ``AdmissionConfig`` (or any object with its
    fields, e.g. the platform's ``AdmissionSection``) and attaches an
    ``AdmissionController`` to the simulation and autoscaler; ``None``
    — the default — builds the exact pre-admission control plane.
    """
    entry = scheduler_entry(scheduler)
    sched = build_scheduler(scheduler, SchedulerBuildContext(
        cluster=cluster, store=store, qos=qos, specs=specs,
        predictor=predictor, m_max=m_max, max_candidates=max_candidates,
        schema_version=schema_version, retrain_every=retrain_every,
        learned_shape_margin=learned_shape_margin,
        harvest_headroom=harvest_headroom,
        qos_release_cooldown_s=qos_release_cooldown_s))
    if dual_staged is None:
        dual_staged = dual and entry.dual_staged_default
    aut = Autoscaler(cluster, sched, ScalingConfig(
        release_s=release_s, keepalive_s=keepalive_s,
        dual_staged=dual_staged, init_ms=init_ms,
        migrate=migrate), events=events)
    # scheduler-initiated releases (harvesting's QoS-breach give-back)
    # enter the autoscaler's keep-alive ledger instead of a private one
    sched.release_ledger = aut
    cfg = SimConfig(collect_samples=collect_samples, seed=sim_seed,
                    schema_version=schema_version,
                    online_retrain=online_retrain,
                    retrain_every=retrain_every,
                    learned_shape_margin=learned_shape_margin)
    if sample_every_s is not None:
        cfg.sample_every_s = sample_every_s
    if use_engine is not None:
        cfg.use_capacity_engine = use_engine
    sim = Simulation(specs, trace, sched, aut, gt, store, qos,
                     predictor=predictor, cfg=cfg, router=router,
                     events=events)
    if admission is not None:
        # late import: core stays importable without the admission
        # package on the path, and admission-off builds never touch it
        from ..admission import AdmissionConfig, AdmissionController
        adm_cfg = admission if isinstance(admission, AdmissionConfig) \
            else AdmissionConfig(**{
                f.name: getattr(admission, f.name)
                for f in dataclasses.fields(AdmissionConfig)
                if hasattr(admission, f.name)})
        ctl = AdmissionController(specs, adm_cfg, store=store)
        sim.admission = ctl
        # the autoscaler drives the end-of-tick vertical pass and
        # stamps decision traces with queue context (schema v3)
        aut.admission = ctl
    return sim


def scenario_simulation(scenario: Scenario, scheduler: str = "jiagu", *,
                        world: Optional[ScenarioWorld] = None,
                        dual: bool = True, release_s: float = 45.0,
                        keepalive_s: float = 60.0, init_ms: float = 8.4,
                        migrate: bool = True, m_max: int = M_MAX_DEFAULT,
                        use_engine: Optional[bool] = None,
                        collect_samples: bool = False,
                        online_retrain: bool = False,
                        retrain_every: Optional[int] = None,
                        sample_every_s: Optional[int] = None,
                        n_train: int = 2000, n_trees: int = 24,
                        schema_version: Optional[int] = None,
                        max_nodes: Optional[int] = None,
                        dual_staged: Optional[bool] = None,
                        max_candidates: int = 4,
                        sim_seed: int = 0,
                        router=None,
                        learned_shape_margin: bool = False,
                        harvest_headroom: float = 0.85,
                        qos_release_cooldown_s: float = 30.0,
                        admission=None,
                        events: Optional[EventHub] = None) -> Simulation:
    """Assemble a full Simulation for `scenario` (world built on demand,
    heterogeneous elastic cluster from the scenario's node classes).

    The feature schema follows the world's (a v2-trained forest must see
    v2 rows); pass ``schema_version`` only when building the world here.
    """
    if world is None:
        world = scenario_world(scenario, n_train=n_train, n_trees=n_trees,
                               schema_version=schema_version or 1)
    elif schema_version not in (None, world.schema_version):
        raise ValueError(
            f"schema_version={schema_version} conflicts with the prebuilt "
            f"world's schema v{world.schema_version}; rebuild the world "
            f"with scenario_world(..., schema_version={schema_version})")
    pred = world.predictor \
        if scheduler_entry(scheduler).needs_predictor else None
    return build_simulation(
        scenario.specs, scenario.trace, scenario.build_cluster(max_nodes),
        world.gt, world.store, world.qos, scheduler, pred, dual=dual,
        release_s=release_s, keepalive_s=keepalive_s, init_ms=init_ms,
        migrate=migrate, m_max=m_max, use_engine=use_engine,
        collect_samples=collect_samples, online_retrain=online_retrain,
        retrain_every=retrain_every, sample_every_s=sample_every_s,
        schema_version=world.schema_version, dual_staged=dual_staged,
        max_candidates=max_candidates, sim_seed=sim_seed,
        router=router, learned_shape_margin=learned_shape_margin,
        harvest_headroom=harvest_headroom,
        qos_release_cooldown_s=qos_release_cooldown_s,
        admission=admission, events=events)
