"""Cluster-scale batched capacity engine.

The legacy path (``capacity.capacity_of`` / ``update_capacity_table``)
solves one (node, function) scenario at a time: it builds its feature
matrix row-by-row in Python, sweeps m = 1..m_max exhaustively, and pays
one ``predictor.predict`` call per function per node — so background
inference cost grows linearly with cluster size.  The paper's own
measurement (Fig. 17-b: batching 100 inputs into one inference adds
~2 ms) says that cost should be paid *once per drain*, not once per node.

``CapacityEngine`` owns all capacity solving for the cluster and applies
three ideas:

  1. **Coalescing** — all pending scenarios (every due node x every
     colocated function) are drained together; each round builds one
     feature matrix spanning every unresolved scenario and scores it with
     a single ``PerfPredictor.predict_many`` call, which routes through
     the numpy / jax / Pallas RFR engine so the VMEM-resident forest
     kernel sees cluster-scale batches.

  2. **Caching** — solved capacities are keyed by a canonical colocation
     signature: the quantized multiset of ``(fn, n_sat, n_cached)`` of
     the target's neighbors.  The many identically-loaded nodes of a
     large cluster share one solve.  Keys are content-addressed, so any
     placement / release / eviction changes the signature and naturally
     misses; predictor retraining bumps the epoch and clears the cache.

  3. **Vectorized assembly + early exit** — feature rows for a scenario
     are assembled as numpy blocks broadcast over the m-sweep (no
     per-row Python loop), and the sweep runs in geometrically growing
     chunks so rows for hopeless concurrencies past the first QoS
     failure are never built.

Bit-compatibility contract: the assembled rows replicate ``build_features``
float64 op-for-op (same accumulation order), so engine capacities are
identical to the legacy per-node results — the parity tests and the
24->512-node benchmark both assert it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .capacity import M_MAX_DEFAULT, QoSStore
from .cluster import CapEntry, Node
from .predictor import N_FEATURES, PerfPredictor
from .profiles import N_PROFILE, FunctionSpec, ProfileStore

# feature layout (see predictor.build_features)
_SOLO = 0
_PROF = slice(1, 1 + N_PROFILE)
_NSAT = 1 + N_PROFILE
_NCACHED = 2 + N_PROFILE
_AGG = slice(3 + N_PROFILE, 3 + 2 * N_PROFILE)
_TOTSAT = 3 + 2 * N_PROFILE
_TOTCACHED = 4 + 2 * N_PROFILE

Coloc = Dict[str, Tuple[float, float]]
SigKey = Tuple


@dataclass
class EngineConfig:
    m_max: int = M_MAX_DEFAULT
    cache: bool = True
    early_exit: bool = True       # chunked m-sweep vs full legacy sweep
    chunk_init: int = 4           # first chunk of the m-sweep
    chunk_growth: int = 2         # geometric growth of later chunks
    quant: float = 4.0            # signature quantization steps per unit
    max_cache_entries: int = 65536


@dataclass
class EngineStats:
    solves: int = 0               # scenarios requested
    unique_solves: int = 0        # scenarios actually solved
    cache_hits: int = 0
    coalesced_dupes: int = 0      # same-signature scenarios within a drain
    rows_built: int = 0
    predict_calls: int = 0        # batched rounds issued to the predictor
    cache_epochs: int = 0         # times the cache was cleared (retrain)

    def snapshot(self) -> Dict[str, int]:
        return dict(self.__dict__)


def coloc_signature(coloc: Coloc, fn: str, m_max: int,
                    quant: float = 4.0) -> SigKey:
    """Canonical cache key for 'capacity of `fn` among `coloc`'.

    The target's own counts are excluded (the m-sweep replaces them, as
    in ``capacity_of``); neighbor counts are quantized to 1/quant steps
    and sorted, so the key is a true multiset signature — two nodes with
    the same colocation mix share one solve regardless of dict order.
    """
    q = max(quant, 1e-9)
    sig = tuple(sorted(
        (g, round(ns * q) / q, round(nc * q) / q)
        for g, (ns, nc) in coloc.items() if g != fn and ns + nc > 0))
    return (fn, int(m_max), sig)


class _Template:
    """Precomputed per-scenario constants for vectorized row assembly.

    Rows for one m, in legacy order: [target@m, neighbor_1, ...].  Every
    float64 accumulation mirrors build_features exactly:

      target agg   = prof_f*m  then += prof_g*ns_g   (coloc order)
      neighbor agg = (prof_g*ns_g + sum_{h!=g} prof_h*ns_h) + prof_f*m
    """

    def __init__(self, store: ProfileStore, qos: QoSStore,
                 specs: Dict[str, FunctionSpec], coloc: Coloc, fn: str):
        spec = specs[fn]
        self.prof_f = store.profile(spec)
        self.solo_f = qos.solo(spec)
        self.qos_f = qos.qos(spec)
        names = [g for g, (ns, nc) in coloc.items()
                 if g != fn and ns + nc > 0]
        counts = {g: coloc[g] for g in names}
        self.neigh: List[Tuple[float, float, np.ndarray, float, float]] = []
        contribs = {g: store.profile(specs[g]) * counts[g][0] for g in names}
        for g in names:
            ns, nc = counts[g]
            gspec = specs[g]
            # base_agg: prof_g*ns_g then += prof_h*ns_h for h != g in order
            base = store.profile(gspec) * ns
            for h in names:
                if h != g:
                    base = base + contribs[h]
            self.neigh.append((ns, nc, store.profile(gspec),
                               qos.solo(gspec), qos.qos(gspec), base))
        self.contribs = [contribs[g] for g in names]
        self.tot_sat_base = float(sum(c[0] for c in counts.values()))
        self.tot_cached_base = float(sum(c[1] for c in counts.values()))
        self.rows_per_m = 1 + len(self.neigh)
        self.bounds_per_m = np.asarray(
            [self.qos_f] + [nb[4] for nb in self.neigh])

    def build(self, ms: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Feature matrix + QoS bounds for concurrencies `ms` (ascending).
        Returns (len(ms)*rows_per_m, 31) float32 and matching bounds."""
        c = len(ms)
        R = self.rows_per_m
        msf = ms.astype(np.float64)
        X = np.empty((c, R, N_FEATURES), np.float64)
        # target rows: n_sat = m, n_cached = 0
        X[:, 0, _SOLO] = self.solo_f
        X[:, 0, _PROF] = self.prof_f
        X[:, 0, _NSAT] = msf
        X[:, 0, _NCACHED] = 0.0
        agg_t = msf[:, None] * self.prof_f
        for contrib in self.contribs:
            agg_t = agg_t + contrib
        X[:, 0, _AGG] = agg_t
        X[:, 0, _TOTSAT] = msf + self.tot_sat_base
        X[:, 0, _TOTCACHED] = self.tot_cached_base
        # neighbor rows: fn@m is their last-added neighbor
        for j, (ns, nc, prof_g, solo_g, _qos_g, base) in \
                enumerate(self.neigh):
            r = j + 1
            X[:, r, _SOLO] = solo_g
            X[:, r, _PROF] = prof_g
            X[:, r, _NSAT] = ns
            X[:, r, _NCACHED] = nc
            X[:, r, _AGG] = base + msf[:, None] * self.prof_f
            X[:, r, _TOTSAT] = self.tot_sat_base + msf
            X[:, r, _TOTCACHED] = self.tot_cached_base
        bounds = np.tile(self.bounds_per_m, c)
        return X.reshape(c * R, N_FEATURES).astype(np.float32), bounds


class _Solve:
    """State machine for one unique scenario's chunked m-sweep."""

    def __init__(self, tmpl: _Template, m_max: int):
        self.tmpl = tmpl
        self.m_max = m_max
        self.next_m = 1
        self.capacity = 0
        self.rows = 0
        self.done = m_max <= 0

    def take_chunk(self, size: int) -> np.ndarray:
        hi = min(self.next_m + size - 1, self.m_max)
        ms = np.arange(self.next_m, hi + 1)
        self.next_m = hi + 1
        return ms

    def absorb(self, ms: np.ndarray, ok: np.ndarray):
        """ok: (len(ms)*rows_per_m,) bool — pass/fail per feature row."""
        per_m = self.tmpl.rows_per_m
        blocks = ok.reshape(len(ms), per_m)
        for i, m in enumerate(ms):
            if blocks[i].all():
                self.capacity = int(m)
            else:
                self.done = True
                return
        if self.next_m > self.m_max:
            self.done = True


class CapacityEngine:
    """Owns all capacity solving for the cluster; see module docstring."""

    def __init__(self, predictor: PerfPredictor, store: ProfileStore,
                 qos: QoSStore, specs: Dict[str, FunctionSpec],
                 cfg: Optional[EngineConfig] = None):
        self.predictor = predictor
        self.store = store
        self.qos = qos
        self.specs = specs
        self.cfg = cfg or EngineConfig()
        self.stats = EngineStats()
        self._cache: Dict[SigKey, int] = {}
        self._epoch = predictor.retrain_count

    # -- cache ------------------------------------------------------------

    def _check_epoch(self):
        if self.predictor.retrain_count != self._epoch:
            self.invalidate()
            self._epoch = self.predictor.retrain_count

    def invalidate(self):
        """Drop every cached capacity (predictor retrained, or external
        state the signatures cannot see has changed)."""
        if self._cache:
            self._cache.clear()
        self.stats.cache_epochs += 1

    def signature(self, coloc: Coloc, fn: str,
                  m_max: Optional[int] = None) -> SigKey:
        return coloc_signature(coloc, fn, m_max or self.cfg.m_max,
                               self.cfg.quant)

    def capacity_hint(self, coloc: Coloc, fn: str,
                      m_max: Optional[int] = None) -> Optional[int]:
        """Cached capacity for this colocation, or None.  Never runs
        inference — safe on any non-critical decision path (migration
        targeting, consolidation)."""
        self._check_epoch()
        return self._cache.get(self.signature(coloc, fn, m_max))

    # -- solving ----------------------------------------------------------

    def capacity(self, coloc: Coloc, fn: str,
                 m_max: Optional[int] = None) -> Tuple[int, int]:
        """Capacity of `fn` under `coloc`; returns (capacity, rows_built).
        Same contract as ``capacity.capacity_of`` (cache hits bill 0)."""
        return self.solve_many([(coloc, fn, m_max or self.cfg.m_max)])[0]

    def solve_many(self, queries: Sequence[Tuple[Coloc, str, int]]
                   ) -> List[Tuple[int, int]]:
        """Solve many (coloc, fn, m_max) scenarios with coalesced batched
        inference.  Duplicate signatures within the batch are solved once;
        rows are billed to the first occurrence only."""
        self._check_epoch()
        self.stats.solves += len(queries)
        results: List[Optional[Tuple[int, int]]] = [None] * len(queries)
        unique: Dict[SigKey, _Solve] = {}
        assignment: List[Optional[SigKey]] = [None] * len(queries)
        for i, (coloc, fn, m_max) in enumerate(queries):
            key = coloc_signature(coloc, fn, m_max, self.cfg.quant)
            if self.cfg.cache and key in self._cache:
                results[i] = (self._cache[key], 0)
                self.stats.cache_hits += 1
                continue
            if key in unique:
                self.stats.coalesced_dupes += 1
            else:
                unique[key] = _Solve(
                    _Template(self.store, self.qos, self.specs, coloc, fn),
                    m_max)
                self.stats.unique_solves += 1
            assignment[i] = key

        active = [s for s in unique.values() if not s.done]
        size = self.cfg.chunk_init if self.cfg.early_exit else \
            max((s.m_max for s in active), default=1)
        while active:
            batch = []
            for s in active:
                ms = s.take_chunk(size)
                X, bounds = s.tmpl.build(ms)
                s.rows += len(X)
                batch.append((s, ms, X, bounds))
            self.stats.rows_built += sum(len(b[2]) for b in batch)
            preds = self.predictor.predict_many([b[2] for b in batch])
            self.stats.predict_calls += 1
            for (s, ms, _X, bounds), p in zip(batch, preds):
                s.absorb(ms, p <= bounds)
            active = [s for s in active if not s.done]
            size *= self.cfg.chunk_growth

        for key, s in unique.items():
            if self.cfg.cache:
                if len(self._cache) >= self.cfg.max_cache_entries:
                    self._cache.clear()
                self._cache[key] = s.capacity
        billed: set = set()
        for i, key in enumerate(assignment):
            if key is None:
                continue
            s = unique[key]
            results[i] = (s.capacity, 0 if key in billed else s.rows)
            billed.add(key)
        return results  # type: ignore[return-value]

    # -- node-level API (the async-update path) ---------------------------

    def node_coloc(self, node: Node) -> Coloc:
        return {g: (float(s.n_sat), float(s.n_cached))
                for g, s in node.funcs.items() if s.total > 0}

    def update_node(self, node: Node, m_max: Optional[int] = None) -> int:
        return self.update_nodes([node], m_max)

    def update_nodes(self, nodes: Sequence[Node],
                     m_max: Optional[int] = None) -> int:
        """Recompute every capacity-table entry of every node in one
        coalesced drain.  Returns total inference rows billed."""
        mm = m_max or self.cfg.m_max
        queries: List[Tuple[Coloc, str, int]] = []
        owners: List[Tuple[Node, str]] = []
        for node in nodes:
            coloc = self.node_coloc(node)
            for fn in coloc:
                queries.append((coloc, fn, mm))
                owners.append((node, fn))
        total_rows = 0
        for (node, fn), (cap, rows) in zip(owners,
                                           self.solve_many(queries)):
            node.table[fn] = CapEntry(capacity=cap, fresh=True)
            total_rows += rows
        return total_rows
