"""Cluster-scale batched capacity engine — compatibility surface.

The engine machinery grown in PR 1 (coalesced drains, the canonical
colocation-signature cache, vectorized bit-identical feature assembly,
chunked early-exit m-sweep) now lives in the unified
``prediction_service`` module, where it shares one pipeline with the
versioned feature schema, the schedulers' per-schedule inference, and
the online-retraining loop.  ``CapacityEngine`` is a true alias of
``PredictionService`` — one class, not a wrapper — so every PR-1 call
site, test, and benchmark keeps working unchanged.
"""
from .prediction_service import (CapacityEngine, EngineConfig, EngineStats,
                                 PredictionService, _Solve, _Template,
                                 coloc_signature)

__all__ = ["CapacityEngine", "EngineConfig", "EngineStats",
           "PredictionService", "coloc_signature"]
