"""Bounded metric collection for cluster-scale runs.

512-node full-trace simulations emit one scheduling-latency sample per
decision and one density sample per tick; unbounded Python lists grow
into hundreds of MB over long traces.  ``Reservoir`` keeps a fixed-size
uniform sample (Vitter's Algorithm R) plus *exact* running aggregates
(count / sum / min / max), so means are always exact and the p50/p99
accessors are exact whenever fewer than ``cap`` values were recorded
(every tier-1 test and the quick benchmarks) and an unbiased estimate
beyond that.

The sampling RNG is seeded per-reservoir, so two simulations that record
the same value sequence retain the same indices — the engine-vs-legacy
A/B parity harness compares ``density_series`` elementwise and stays
valid under bounding.
"""
from __future__ import annotations

from typing import Iterable, Iterator, List, Optional, Tuple

import numpy as np


class Reservoir:
    """Fixed-capacity uniform sample with exact running aggregates.

    Supports enough of the list protocol (append / extend / len / iter /
    indexing / numpy conversion) to be a drop-in for the metric lists it
    replaces.  While ``count <= cap`` the retained buffer IS the full
    history, in insertion order; past that point Algorithm R overwrites
    arbitrary slots, so ordered access (``r[-1]``, slices) stops meaning
    "most recent" — use it only on short runs or for order-free reads
    (the aggregate/quantile accessors are always valid).
    """

    __slots__ = ("cap", "count", "total", "_min", "_max", "_items", "_rng")

    def __init__(self, cap: int = 512, seed: int = 0,
                 values: Optional[Iterable[float]] = None):
        if cap <= 0:
            raise ValueError("Reservoir capacity must be positive")
        self.cap = cap
        self.count = 0
        self.total = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        self._items: List[float] = []
        self._rng = np.random.default_rng(seed)
        if values is not None:
            self.extend(values)

    # -- recording --------------------------------------------------------

    def append(self, x: float):
        x = float(x)
        self.count += 1
        self.total += x
        if x < self._min:
            self._min = x
        if x > self._max:
            self._max = x
        if len(self._items) < self.cap:
            self._items.append(x)
        else:
            # Algorithm R: keep each of the `count` values with equal
            # probability cap/count
            j = int(self._rng.integers(self.count))
            if j < self.cap:
                self._items[j] = x

    def extend(self, xs: Iterable[float]):
        for x in xs:
            self.append(x)

    # -- exact aggregates -------------------------------------------------

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    @property
    def min(self) -> float:
        return self._min if self.count else 0.0

    @property
    def max(self) -> float:
        return self._max if self.count else 0.0

    # -- quantiles (exact until sampling kicks in) ------------------------

    def percentile(self, q: float) -> float:
        """The q-th percentile of the recorded stream.

        While ``count <= cap`` the retained buffer is the complete
        history, so this is the *exact* ``np.percentile`` of every value
        ever appended (the regime all tier-1 tests and the quick
        benchmarks run in).  Once Algorithm R starts sampling
        (``count > cap``) it becomes an unbiased estimate computed over
        the uniform ``cap``-sized sample."""
        if not self._items:
            return 0.0
        return float(np.percentile(np.asarray(self._items, np.float64), q))

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p99(self) -> float:
        return self.percentile(99.0)

    # -- histogram export (the telemetry layer's Histogram metric) --------

    def histogram(self, bins: int = 10,
                  lo: Optional[float] = None,
                  hi: Optional[float] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
        """``(counts, edges)`` over ``bins`` equal-width buckets.

        Bounds default to the *exact* running min/max (so the histogram
        always covers the full recorded range, even values the sample
        dropped).  While ``count <= cap`` the counts are exact integers;
        beyond that each retained value stands for ``count / cap``
        stream values (fractional counts).  Under the default bounds the
        sum invariant ``counts.sum() == count`` holds in both regimes;
        explicit narrower ``lo``/``hi`` exclude out-of-range values from
        the sum, exactly like ``np.histogram``.  Empty reservoir ->
        zero counts over [0, 1]."""
        if bins <= 0:
            raise ValueError("histogram needs a positive bin count")
        if not self._items:
            return (np.zeros(bins, np.float64),
                    np.linspace(0.0, 1.0, bins + 1))
        lo = self._min if lo is None else float(lo)
        hi = self._max if hi is None else float(hi)
        if hi <= lo:
            hi = lo + 1.0
        counts, edges = np.histogram(
            np.asarray(self._items, np.float64), bins=bins,
            range=(lo, hi))
        counts = counts.astype(np.float64)
        if self.count != len(self._items):
            counts *= self.count / len(self._items)
        return counts, edges

    # -- list / numpy protocol -------------------------------------------

    def __len__(self) -> int:
        return len(self._items)

    def __iter__(self) -> Iterator[float]:
        return iter(self._items)

    def __getitem__(self, idx):
        return self._items[idx]

    def __bool__(self) -> bool:
        return self.count > 0

    def __array__(self, dtype=None, copy=None):
        arr = np.asarray(self._items, dtype=dtype or np.float64)
        return arr.copy() if copy else arr

    def __repr__(self) -> str:
        return (f"Reservoir(cap={self.cap}, count={self.count}, "
                f"mean={self.mean:.4g})")
