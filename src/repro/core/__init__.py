"""Jiagu's core: pre-decision scheduling + dual-staged scaling (the
paper's contribution), the RFR predictor, the cluster simulator, and the
K8s/Gsight/Owl baselines."""
from .autoscaler import (Autoscaler, ScalingConfig, ScalingMetrics,
                         SchedulerCapacityProvider)
from .capacity import QOS_MULT, QoSStore, capacity_of, update_capacity_table
from .cells import (CapacityExchange, Cell, CellRouter, CellSimulation,
                    cell_scenario_simulation)
from .cluster import CapEntry, Cluster, FuncState, Node
from .events import EventHub, JsonlObserver, Observer
from .harvesting import HarvestingScheduler
from .interference import GroundTruth, NodeResources
from .pipeline import (CandidatePass, DecisionContext, DecisionTrace,
                       PipelineGsightScheduler, PipelineHostMixin,
                       PipelineJiaguScheduler, PipelineK8sScheduler,
                       PipelineOwlScheduler, SchedulingPipeline,
                       TraceBinding)
from .metrics import Reservoir
from .prediction_service import (DRAIN_MODES, INFERENCE_ENGINES,
                                 SCHEMA_V1, SCHEMA_V2, CapacityEngine,
                                 EngineConfig, EngineStats, FeatureSchema,
                                 PredictionService, coloc_signature,
                                 get_schema)
from .predictor import (MODEL_ZOO, PerfPredictor, RandomForestRegressor,
                        build_features)
from .profiles import (BENCH_FUNCTIONS, FunctionSpec, ProfileStore,
                       arch_functions, synthetic_functions)
from .scheduler import (FAST_PATH_MS, REROUTE_MS, BaseScheduler,
                        GsightScheduler, JiaguScheduler, K8sScheduler,
                        OwlScheduler, SchedulerBuildContext,
                        SchedulerEntry, build_scheduler,
                        register_scheduler, registered_schedulers,
                        scheduler_entry)
from .scenarios import (LARGE_NODE, SCENARIO_KINDS, STANDARD_NODE,
                        NodeClass, Scenario, ScenarioWorld,
                        build_simulation, get_scenario_builder,
                        make_scenario, register_scenario,
                        registered_scenarios, scale_trace_to_nodes,
                        scenario_functions, scenario_simulation,
                        scenario_suite, scenario_world, zipf_weights)
from .simulator import (EqualSplitRouter, LocalityRouter, SimConfig,
                        SimResult, Simulation, generate_dataset)
from .traces import (Trace, azure_sparse_trace, burst_storm_trace,
                     coldstart_churn_trace, diurnal_shift_trace, flip_trace,
                     get_trace, realworld_suite, realworld_trace,
                     register_trace, registered_traces, replay_trace,
                     timer_trace)

__all__ = [
    "Autoscaler", "ScalingConfig", "ScalingMetrics", "QOS_MULT", "QoSStore",
    "SchedulerCapacityProvider", "EventHub", "Observer", "EqualSplitRouter",
    "SchedulerBuildContext", "SchedulerEntry", "build_scheduler",
    "register_scheduler", "registered_schedulers", "scheduler_entry",
    "get_scenario_builder", "register_scenario", "registered_scenarios",
    "get_trace", "register_trace", "registered_traces",
    "CapacityEngine", "EngineConfig", "EngineStats", "coloc_signature",
    "PredictionService", "FeatureSchema", "SCHEMA_V1", "SCHEMA_V2",
    "DRAIN_MODES", "INFERENCE_ENGINES",
    "get_schema", "Reservoir", "replay_trace",
    "capacity_of", "update_capacity_table", "CapEntry", "Cluster",
    "FuncState", "Node", "GroundTruth", "NodeResources", "MODEL_ZOO",
    "PerfPredictor", "RandomForestRegressor", "build_features",
    "BENCH_FUNCTIONS", "FunctionSpec", "ProfileStore", "arch_functions",
    "synthetic_functions", "FAST_PATH_MS", "REROUTE_MS", "BaseScheduler",
    "GsightScheduler", "JiaguScheduler", "K8sScheduler", "OwlScheduler",
    "Cell", "CellRouter", "CellSimulation", "CapacityExchange",
    "cell_scenario_simulation",
    "SimConfig", "SimResult", "Simulation", "generate_dataset", "Trace",
    "JsonlObserver", "LocalityRouter", "HarvestingScheduler",
    "CandidatePass", "DecisionContext", "DecisionTrace", "TraceBinding",
    "SchedulingPipeline", "PipelineHostMixin", "PipelineJiaguScheduler",
    "PipelineGsightScheduler", "PipelineK8sScheduler",
    "PipelineOwlScheduler",
    "flip_trace", "realworld_suite", "realworld_trace", "timer_trace",
    "burst_storm_trace", "diurnal_shift_trace", "coldstart_churn_trace",
    "azure_sparse_trace", "NodeClass", "Scenario", "ScenarioWorld",
    "STANDARD_NODE", "LARGE_NODE", "SCENARIO_KINDS", "build_simulation",
    "make_scenario", "scenario_functions", "scenario_simulation",
    "scenario_suite", "scenario_world", "scale_trace_to_nodes",
    "zipf_weights",
]
