"""Autoscaling: traditional keep-alive vs Jiagu's dual-staged scaling
(paper §5), plus on-demand migration of cached instances.

Dual-staged timeline for a load drop (paper Fig. 10, defaults §6):
    t=0       expected saturated count drops below current
    t=release_s   "release": re-route, excess instances become *cached*
    t=keepalive_s "real eviction": still-cached instances are destroyed
A load rise first consumes cached instances via *logical cold starts*
(re-route, <1 ms) and only then asks the scheduler for real cold starts.

The autoscaler consumes its scheduler only through the ``repro.platform``
capability protocols — ``ReleasePicker`` / ``LogicalStartPicker`` for
the dual-staged picks and ``CapacityProvider`` for migration targeting —
never through concrete class identity, so any scheduler that opts into
dual-staged scaling (the ``BaseScheduler`` greedy defaults, or its own
overrides) gets the full release / logical-cold-start / migration
machinery.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, Iterable, List, Optional, Tuple

from .cluster import Cluster, Node
from .events import EventHub
from .metrics import Reservoir
from .scheduler import REROUTE_MS, BaseScheduler

DEFAULT_KEEPALIVE_S = 60.0


@dataclass
class ScalingConfig:
    release_s: float = 45.0          # dual-staged release sensitivity
    keepalive_s: float = DEFAULT_KEEPALIVE_S
    init_ms: float = 8.4             # cfork container init; docker: 85.5
    dual_staged: bool = True
    migrate: bool = True             # on-demand migration of cached insts


@dataclass
class ScalingMetrics:
    real_cold_starts: int = 0
    logical_cold_starts: int = 0
    blocked_logical: int = 0         # cached present but node full ->
    #                                  would-be real cold start (paper
    #                                  Fig 14-b "migrations needed")
    migrations: int = 0
    releases: int = 0
    evictions: int = 0
    # bounded: long traces record one sample per (logical) cold start
    cold_start_ms: Reservoir = field(default_factory=lambda: Reservoir(512))

    @property
    def mean_cold_start_ms(self) -> float:
        return self.cold_start_ms.mean   # exact (running sum/count)

    @property
    def p99_cold_start_ms(self) -> float:
        return self.cold_start_ms.p99


class _CachedLedger:
    """FIFO of released (cached) instances per function, for keep-alive
    eviction accounting.  Entries: (release_time, node_id, count)."""

    def __init__(self):
        self.q: Dict[str, Deque[List]] = {}

    def push(self, fn: str, t: float, node_id: int, k: int):
        self.q.setdefault(fn, deque()).append([t, node_id, k])

    def pop_newest(self, fn: str, node_id: int, k: int) -> int:
        """Consume up to k cached instances of fn on node (newest first,
        so the oldest keep aging toward eviction)."""
        got = 0
        dq = self.q.get(fn)
        if not dq:
            return 0
        for entry in reversed(dq):
            if k <= 0:
                break
            if entry[1] != node_id:
                continue
            take = min(k, entry[2])
            entry[2] -= take
            got += take
            k -= take
        self.q[fn] = deque(e for e in dq if e[2] > 0)
        return got

    def expired(self, fn: str, now: float, ttl: float
                ) -> List[Tuple[int, int]]:
        """Pop all entries older than ttl; returns [(node_id, count)]."""
        dq = self.q.get(fn)
        out: List[Tuple[int, int]] = []
        if not dq:
            return out
        while dq and now - dq[0][0] >= ttl:
            _, node_id, k = dq.popleft()
            out.append((node_id, k))
        return out

    def move(self, fn: str, src: int, dst: int, k: int):
        dq = self.q.get(fn)
        if not dq:
            return
        splits = []
        for entry in dq:
            if k <= 0:
                break
            if entry[1] != src:
                continue
            take = min(k, entry[2])
            if take == entry[2]:
                entry[1] = dst
            else:
                entry[2] -= take
                splits.append([entry[0], dst, take])
            k -= take
        dq.extend(splits)


class SchedulerCapacityProvider:
    """Default ``platform.CapacityProvider``: best known capacity of fn
    on node is the capacity-table entry, else a zero-cost
    ``PredictionService`` cache hit (nodes that share a colocation
    signature — and, under schema v2, a node shape — with an
    already-solved node get an answer without any inference), else
    None.  Table-free schedulers simply report None everywhere."""

    def __init__(self, scheduler: BaseScheduler):
        self.scheduler = scheduler

    def node_capacity(self, node: Node, fn: str) -> Optional[int]:
        entry = node.table.get(fn)
        if entry is not None:
            return entry.capacity
        service = self.scheduler.prediction_service
        if service is None:
            return None
        return service.capacity_hint(service.node_coloc(node), fn,
                                     node_res=node.res)


class Autoscaler:
    """``release_picker`` / ``logical_start_picker`` / ``capacity``
    plug the scaling policies (defaults: the scheduler itself, which
    implements the picker protocols, and a table/cache-hint capacity
    provider); ``events`` receives ``on_schedule`` / ``on_scale``
    observer callbacks."""

    def __init__(self, cluster: Cluster, scheduler: BaseScheduler,
                 cfg: ScalingConfig, *,
                 release_picker=None, logical_start_picker=None,
                 capacity=None, events: Optional[EventHub] = None):
        self.cluster = cluster
        self.scheduler = scheduler
        self.cfg = cfg
        self.release_picker = release_picker or scheduler
        self.logical_start_picker = logical_start_picker or scheduler
        self.capacity = capacity or SchedulerCapacityProvider(scheduler)
        self.events = events or EventHub()
        self.metrics = ScalingMetrics()
        #: AdmissionController (repro.admission) — wired by
        #: ``build_simulation`` when the admission axis is enabled.
        #: Drives the end-of-tick vertical resize pass and stamps
        #: queue/SLO context onto DecisionTraces; None (default) keeps
        #: every pre-admission code path untouched.
        self.admission = None
        self._below_since: Dict[str, Optional[float]] = {}
        self._ledger = _CachedLedger()
        #: event-core hook — called with fn when an out-of-band mutation
        #: (a scheduler-initiated release) means fn needs a tick soon
        self.on_fn_dirty: Optional[Callable[[str], None]] = None

    # ------------------------------------------------------------------

    def note_release(self, fn: str, node: Node, k: int, now: float
                     ) -> bool:
        """Account a *scheduler-initiated* release (e.g. harvesting's
        QoS-breach give-back, performed via ``node.release``): the
        released instances enter the same keep-alive ledger as the
        autoscaler's own releases, so they are keep-alive-evicted,
        migrated, and counted (``metrics.releases`` / ``on_scale``)
        exactly like any other cached instance.

        Returns False without accounting when this autoscaler runs
        traditional keep-alive (``dual_staged=False``): its ledger
        sweep never fires there, so accepting the entry would park the
        instances as permanently-cached — the caller must keep-alive
        them itself."""
        if not self.cfg.dual_staged:
            return False
        if k <= 0:
            return True
        self._ledger.push(fn, now, node.id, k)
        self.metrics.releases += k
        self.events.on_scale(now, fn, "release", k)
        if self.on_fn_dirty is not None:
            self.on_fn_dirty(fn)
        return True

    def expected_instances(self, fn: str, rps: float) -> int:
        spec = self.cluster.specs[fn]
        if rps <= 1e-9:
            return 0
        return max(1, math.ceil(rps / spec.saturated_rps))

    def tick(self, now: float, rps: Dict[str, float],
             fns: Optional[Iterable[str]] = None):
        """One autoscaler pass.  ``fns=None`` (the legacy tick loop)
        visits every spec; the event-driven core passes just the *due*
        functions, already ordered like ``cluster.specs`` — skipped
        functions are exactly those whose ``_tick_fn`` would have been a
        no-op (no load, no timers armed, no ledger entries)."""
        for fn in (self.cluster.specs if fns is None else fns):
            self._tick_fn(now, fn, rps.get(fn, 0.0))
        if self.cfg.dual_staged and self.cfg.migrate:
            self._migrate(now)
        if self.admission is not None:
            # vertical resize rides the horizontal pass: shrink/grow
            # cpu reservations, re-solved against the capacity table
            self.admission.vertical_tick(now, self.cluster,
                                         self.scheduler, self.events)
        self.cluster.reap_empty()

    def next_wake(self, fn: str) -> Optional[float]:
        """Earliest future time fn needs autoscaler attention absent any
        load change: the armed scale-down timer and/or the keep-alive
        expiry of the oldest ledger entry.  None = nothing pending (the
        event core lets the function sleep until its load changes)."""
        t: Optional[float] = None
        if self.cfg.dual_staged:
            dq = self._ledger.q.get(fn)
            if dq:
                t = dq[0][0] + (self.cfg.keepalive_s - self.cfg.release_s)
        since = self._below_since.get(fn)
        if since is not None:
            delay = self.cfg.release_s if self.cfg.dual_staged \
                else self.cfg.keepalive_s
            t = since + delay if t is None else min(t, since + delay)
        return t

    # ------------------------------------------------------------------

    def _scale_up(self, now: float, fn: str, need: int):
        if self.cfg.dual_staged:
            picks = self.logical_start_picker.pick_logical_start_nodes(
                fn, need)
            for node, k in picks:
                got = node.logical_start(fn, k)
                self._ledger.pop_newest(fn, node.id, got)
                self.metrics.logical_cold_starts += got
                self.metrics.cold_start_ms.extend([REROUTE_MS] * got)
                need -= got
                self.scheduler.notify_change(node, now)
                if got:
                    self.events.on_scale(now, fn, "logical_start", got)
            if need > 0 and self.cluster.cached_count(fn) > 0:
                # cached instances exist but their nodes are full: these
                # conversions would have been real cold starts; migration
                # exists to prevent this state (paper Fig 14-b).
                self.metrics.blocked_logical += min(
                    need, self.cluster.cached_count(fn))
        if need > 0:
            placements = self.scheduler.schedule(fn, need, now)
            placed = sum(p.count for p in placements)
            self.metrics.real_cold_starts += placed
            for p in placements:
                self.metrics.cold_start_ms.extend(
                    [p.latency_ms + self.cfg.init_ms] * p.count)
            # pipeline schedulers attach a DecisionTrace explaining the
            # placement; legacy monolithic schedulers yield None
            trace = self.scheduler.take_trace()
            if trace is not None and self.admission is not None:
                # schema-v3 admission context: queue depth/age + class
                self.admission.stamp_trace(trace, fn, now)
            self.events.on_schedule(now, fn, placements, trace)
            if placed:
                self.events.on_scale(now, fn, "real_cold_start", placed)

    def _scale_down_dual(self, now: float, fn: str, expected: int,
                         n_sat: int):
        since = self._below_since.get(fn)
        if since is None:
            self._below_since[fn] = now
            return
        if now - since < self.cfg.release_s:
            return
        excess = n_sat - expected
        for node, k in self.release_picker.pick_release_nodes(fn, excess):
            got = node.release(fn, k)
            self._ledger.push(fn, now, node.id, got)
            self.metrics.releases += got
            self.scheduler.notify_change(node, now)
            if got:
                self.events.on_scale(now, fn, "release", got)
        self._below_since[fn] = now  # re-arm for further drops

    def _scale_down_traditional(self, now: float, fn: str, expected: int,
                                n_sat: int):
        since = self._below_since.get(fn)
        if since is None:
            self._below_since[fn] = now
            return
        if now - since < self.cfg.keepalive_s:
            return
        excess = n_sat - expected
        for node, k in self.release_picker.pick_release_nodes(fn, excess):
            got = node.evict_sat(fn, k)
            self.metrics.evictions += got
            self.scheduler.notify_change(node, now)
            if got:
                self.events.on_scale(now, fn, "evict", got)
        self._below_since[fn] = now

    def _tick_fn(self, now: float, fn: str, rps: float):
        expected = self.expected_instances(fn, rps)
        n_sat = self.cluster.sat_count(fn)

        if expected > n_sat:
            self._below_since[fn] = None
            self._scale_up(now, fn, expected - n_sat)
        elif expected < n_sat:
            if self.cfg.dual_staged:
                self._scale_down_dual(now, fn, expected, n_sat)
            else:
                self._scale_down_traditional(now, fn, expected, n_sat)
        else:
            self._below_since[fn] = None

        # keep-alive eviction of cached instances (dual-staged only)
        if self.cfg.dual_staged:
            ttl = self.cfg.keepalive_s - self.cfg.release_s
            for node_id, k in self._ledger.expired(fn, now, ttl):
                node = self.cluster.nodes.get(node_id)
                if node is None:
                    continue
                got = node.evict_cached(fn, k)
                self.metrics.evictions += got
                if got:
                    self.scheduler.notify_change(node, now)
                    self.events.on_scale(now, fn, "evict", got)

    # -- on-demand migration (paper §5) ---------------------------------

    def _node_capacity(self, node: Node, fn: str) -> Optional[int]:
        """Best known capacity of fn on node, via the pluggable
        ``CapacityProvider`` (default: capacity table, then zero-cost
        service cache hints)."""
        return self.capacity.node_capacity(node, fn)

    def _migrate(self, now: float):
        """Move cached instances off nodes where they could no longer be
        re-saturated (n_sat + n_cached > capacity), hiding the real cold
        start they would otherwise cost.  Additionally *consolidates*:
        a node left with only cached instances migrates them to busy
        nodes with headroom so the empty server can be returned (paper
        §6: "an empty server will be evicted to optimize costs" — cached
        instances must not pin otherwise-idle machines).

        Scans only nodes holding cached instances (the cluster's
        ``nodes_with_cached`` index, ascending id like the old full
        scan): zero-cached nodes are no-ops here, and a node that
        *gains* cached instances mid-pass as a migration target either
        was already in the snapshot or keeps ``n_sat > 0`` with
        post-move excess <= 0 (the target-fit condition), so the full
        scan would not have acted on it either."""
        for node in self.cluster.nodes_with_cached():
            all_cached = all(s.n_sat == 0 for s in node.funcs.values()) \
                and node.n_instances() > 0
            for fn, st in list(node.funcs.items()):
                if st.n_cached == 0:
                    continue
                cap = self._node_capacity(node, fn)
                if all_cached:
                    k = st.n_cached
                elif cap is not None:
                    excess = st.n_sat + st.n_cached - cap
                    if excess <= 0:
                        continue
                    k = min(excess, st.n_cached)
                else:
                    continue
                target = self._find_migration_target(fn, node, k)
                if target is None:
                    continue
                node.evict_cached(fn, k)
                target.add_cached(fn, k)
                self._ledger.move(fn, node.id, target.id, k)
                self.metrics.migrations += k
                self.scheduler.notify_change(node, now)
                self.scheduler.notify_change(target, now)
                self.events.on_scale(now, fn, "migrate", k)

    def _find_migration_target(self, fn: str, src: Node, k: int
                               ) -> Optional[Node]:
        for node in sorted(self.cluster.nodes_with(fn),
                           key=lambda n: -n.funcs[fn].n_sat):
            if node.id == src.id:
                continue
            cap = self._node_capacity(node, fn)
            if cap is None:
                continue
            st = node.funcs[fn]
            if (cap - st.n_sat - st.n_cached >= k
                    and self.cluster.mem_headroom(node, fn) >= k):
                return node
        return None
