"""Ground-truth performance model (the "real cluster").

The paper measures real P90 latencies on a 24-node OpenFaaS cluster; on
this CPU container the cluster is simulated, so *something* must decide
what latency a function experiences under colocation.  This module is that
ground truth.  It is intentionally:

  * nonlinear (convex queueing-style terms, saturating caps),
  * multi-resource (CPU oversubscription, memory-bandwidth contention,
    LLC cache pressure — the three classic interference channels),
  * heterogeneous (per-function sensitivities), and
  * hidden from the scheduler — the RFR predictor is trained on *samples*
    of (colocation -> latency) pairs and graded against fresh samples, so
    prediction error in the benchmarks is honest generalization error.

Latency model for function i on a node with saturated instance counts
{n_j} of functions {j}:

    lat_i = solo_i * (1 + s_i^cpu * g(rho_cpu) + s_i^bw * g(rho_bw)
                        + s_i^$ * cache_term) * load_term(u_i)

where rho_* are node-level demand/capacity ratios of *actual* usage
(cached instances contribute only a small residual footprint — the basis
of dual-staged scaling's win), g is a convex soft-queueing curve and
cache_term grows once combined working sets spill the LLC.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, Mapping, Tuple

import numpy as np

from .profiles import FunctionSpec


@dataclass(frozen=True)
class NodeResources:
    """One worker node (paper testbed: Xeon E5-2650, 48 HT cores, 128 GB).

    Calibration invariant (matches the paper's Fig 13 world): packing by
    *requested* resources (the K8s baseline) must be safe — interference
    multiplier ~1.0-1.1 — while ~1.5-2x that density pushes past the QoS
    headroom.  The capacity solver then lands between the two."""

    cpu_mcores: float = 48_000.0
    mem_mb: float = 131_072.0
    mem_bw_gbps: float = 68.0     # 4-channel DDR4-2133
    llc_mb: float = 60.0          # 2 sockets x 30 MB
    # residual footprint of a cached (drained) instance
    cached_residual: float = 0.06


def _queue(rho: float, knee: float = 0.55, cap: float = 6.0) -> float:
    """Convex soft-queueing curve: ~0 below the knee, grows like
    rho^2/(1-rho) above it, capped (the node never literally deadlocks)."""
    if rho <= knee:
        return 0.02 * rho
    x = min(rho, 0.98)
    val = 0.02 * knee + (x - knee) ** 2 / max(1.0 - x, 0.02)
    return min(val, cap)


class GroundTruth:
    """Oracle latencies.  Only the simulator may call this; the scheduler
    must go through the predictor."""

    def __init__(self, node: NodeResources | None = None, seed: int = 1234):
        self.node = node or NodeResources()
        self._rng = np.random.default_rng(seed)

    def reseed(self, seed: int = 1234) -> None:
        """Reset the measurement-noise stream.  A/B consumers that share
        one world across sequential runs (the platform smoke) call this
        so every arm faces the identical noise, instead of run-order-
        dependent draws."""
        self._rng = np.random.default_rng(seed)

    # -- node-level pressures ------------------------------------------

    def _pressures(self, colocation: Mapping[str, Tuple[FunctionSpec, float,
                                                        float]],
                   node_res: NodeResources | None = None):
        """colocation: name -> (spec, n_saturated, n_cached).

        ``node_res`` overrides the default node shape — the heterogeneous-
        fleet path, where pressures are relative to the *hosting* node's
        capacity (a 2x node halves every rho for the same colocation)."""
        nd = node_res or self.node
        cpu = bw = cache = mem = 0.0
        for spec, n_sat, n_cached in colocation.values():
            resid = nd.cached_residual * n_cached
            cpu += spec.cpu_req * spec.cpu_work * (n_sat + resid)
            bw += spec.bw_demand * (n_sat + resid)
            cache += spec.cache_mb * (n_sat + resid)
            mem += spec.mem_req * spec.mem_work * (n_sat + n_cached)
        return (cpu / nd.cpu_mcores, bw / nd.mem_bw_gbps,
                cache / nd.llc_mb, mem / nd.mem_mb)

    # -- latencies -------------------------------------------------------

    def solo_latency(self, fn: FunctionSpec) -> float:
        """P90 latency of a saturated, interference-free instance."""
        return fn.exec_ms * 1.30  # P90/mean ratio for a loaded server

    def latency(self, fn: FunctionSpec,
                colocation: Mapping[str, Tuple[FunctionSpec, float, float]],
                load_frac: float = 1.0,
                node_res: NodeResources | None = None) -> float:
        """P90 latency of `fn`'s instances on a node with `colocation`
        (which must include fn itself)."""
        rho_cpu, rho_bw, rho_cache, _ = self._pressures(colocation, node_res)
        cpu_term = fn.cpu_sens * _queue(rho_cpu)
        bw_term = fn.bw_sens * _queue(rho_bw, knee=0.55)
        # LLC only hurts once combined working sets actually spill it
        spill = max(0.0, rho_cache - 1.0)
        cache_term = fn.cache_sens * min(1.2 * spill * spill, 2.5)
        mult = 1.0 + cpu_term + bw_term + cache_term
        load_term = 0.75 + 0.25 * min(max(load_frac, 0.0), 1.2) ** 2
        return self.solo_latency(fn) * mult * load_term

    def measure(self, fn: FunctionSpec,
                colocation: Mapping[str, Tuple[FunctionSpec, float, float]],
                load_frac: float = 1.0, noise: float = 0.04,
                node_res: NodeResources | None = None) -> float:
        """A *measurement* of the latency — ground truth + measurement
        noise.  This is what training samples and QoS monitoring see."""
        lat = self.latency(fn, colocation, load_frac, node_res)
        return float(lat * (1.0 + self._rng.normal(0.0, noise)))

    def fits(self, colocation: Mapping[str, Tuple[FunctionSpec, float,
                                                  float]],
             node_res: NodeResources | None = None) -> bool:
        """Hard feasibility: memory is not overcommittable."""
        _, _, _, rho_mem = self._pressures(colocation, node_res)
        return rho_mem <= 1.0
