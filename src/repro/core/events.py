"""Observer hooks for the control plane (the ``repro.platform`` API).

Benchmarks and tests used to collect metrics by reaching into simulator
internals (``sim.scheduler.metrics``, ``sim.autoscaler.metrics``, the
service's stats object).  The observer API turns the interesting control
-plane transitions into events any number of observers can subscribe to
without the run loop knowing who is listening:

  * ``on_tick(now, sim)``        — once per simulated second, after
    autoscaling/routing/measurement for that second completed,
  * ``on_schedule(now, fn, placements, trace)`` — a scheduler decision
    placed real (cold-started) instances; ``trace`` is the pipeline's
    ``DecisionTrace`` explaining the placement (None for legacy
    monolithic schedulers),
  * ``on_scale(now, fn, event, count)``  — an autoscaler state
    transition: ``"logical_start"``, ``"real_cold_start"``,
    ``"release"``, ``"evict"``, or ``"migrate"``,
  * ``on_retrain(service)``      — the prediction service's online
    retraining policy fired (forest refit + epoch bump + cache clear),
  * ``on_result(result)``        — the run completed; ``result`` is the
    final ``SimResult`` (cumulative density/QoS counters), emitted once
    at the end of ``Simulation.run`` / ``CellSimulation.run`` so JSONL
    artifacts carry their own outcome record,
  * ``on_span(span)``            — a control-plane span closed
    (``repro.telemetry.spans``): wall-clock + counter deltas for
    ``schedule`` / ``retrain`` / ``capacity_solve`` sections, persisted
    alongside the ``DecisionTrace`` stream.

``EventHub`` fans one event out to every registered observer; the hub
with no observers is the default everywhere and costs one empty-list
iteration per event, so the instrumented and bare runs are the same
code path (parity gates depend on that).  ``JsonlObserver`` persists
the streams to ``artifacts/*.jsonl`` for cross-run dashboards.
"""
from __future__ import annotations

import json
import os
from typing import Iterable, List, Optional


class Observer:
    """Base observer: subclass and override the hooks you care about.

    Hooks must not mutate simulation state — they exist so benchmarks
    observe without perturbing (the A/B parity gates run with and
    without observers and assert identical results).
    """

    def on_tick(self, now: float, sim) -> None:
        pass

    def on_schedule(self, now: float, fn: str, placements,
                    trace=None) -> None:
        pass

    def on_scale(self, now: float, fn: str, event: str,
                 count: int) -> None:
        pass

    def on_retrain(self, service) -> None:
        pass

    def on_result(self, result) -> None:
        pass

    def on_span(self, span) -> None:
        pass


class EventHub(Observer):
    """Fan-out of control-plane events to registered observers.

    An ``EventHub`` is itself an ``Observer``, so hubs nest (a platform
    hub can subscribe to another platform's hub)."""

    __slots__ = ("observers",)

    def __init__(self, observers: Iterable[Observer] = ()):
        self.observers: List[Observer] = list(observers)

    def add(self, obs: Observer) -> Observer:
        self.observers.append(obs)
        return obs

    def remove(self, obs: Observer) -> None:
        self.observers.remove(obs)

    # -- fan-out ----------------------------------------------------------

    def on_tick(self, now: float, sim) -> None:
        for o in self.observers:
            o.on_tick(now, sim)

    def on_schedule(self, now: float, fn: str, placements,
                    trace=None) -> None:
        for o in self.observers:
            o.on_schedule(now, fn, placements, trace)

    def on_scale(self, now: float, fn: str, event: str,
                 count: int) -> None:
        for o in self.observers:
            o.on_scale(now, fn, event, count)

    def on_retrain(self, service) -> None:
        for o in self.observers:
            o.on_retrain(service)

    def on_result(self, result) -> None:
        for o in self.observers:
            o.on_result(result)

    def on_span(self, span) -> None:
        for o in self.observers:
            o.on_span(span)


class JsonlObserver(Observer):
    """Persist the observer streams to a JSONL artifact, one event per
    line, for cross-run dashboards:

      {"event": "tick", "now": ..., "nodes": ..., "instances": ...,
       "density": ...}
      {"event": "schedule", "fn": ..., "placed": ..., "trace": {...}}
      {"event": "scale", "fn": ..., "kind": "release", "count": ...}
      {"event": "retrain", "epoch": ..., "retrains": ...}

    ``tick_every`` subsamples the per-tick stream (schedule/scale/
    retrain events are always complete); ``trace.summary()`` — the
    compact ``DecisionTrace`` form — rides every schedule event, so a
    dashboard can reconstruct why each placement happened.  Usable as a
    context manager; the file is opened lazily on the first event.

    Durability: the handle is line-buffered and every event is flushed
    as it is written, so a crash mid-run (or an interpreter exit that
    never reached ``close()``) loses at most the event being formatted,
    never a buffered tail.  Nested parent directories are created on
    first write; writing after ``close()`` raises instead of silently
    truncating the artifact with a fresh ``open(.., "w")``."""

    def __init__(self, path: str, tick_every: int = 1,
                 meta: Optional[dict] = None):
        self.path = path
        self.tick_every = max(int(tick_every), 1)
        self.meta = meta
        self.events = 0
        self._fh = None
        self._closed = False

    # -- plumbing ---------------------------------------------------------

    @property
    def closed(self) -> bool:
        return self._closed

    def _write(self, record: dict) -> None:
        if self._closed:
            raise ValueError(
                f"JsonlObserver({self.path!r}) is closed; events after "
                f"close() would truncate the artifact")
        if self._fh is None:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            # line-buffered: one event == one line == one flush unit
            self._fh = open(self.path, "w", buffering=1)
            if self.meta:
                self._fh.write(json.dumps(
                    {"event": "meta", **self.meta}) + "\n")
        self._fh.write(json.dumps(record) + "\n")
        self._fh.flush()
        self.events += 1

    def flush(self) -> None:
        if self._fh is not None:
            self._fh.flush()

    def close(self) -> None:
        self._closed = True
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "JsonlObserver":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- hooks ------------------------------------------------------------

    def on_tick(self, now: float, sim) -> None:
        if int(now) % self.tick_every:
            return
        nodes = len(sim.cluster.nodes)
        inst = sim.cluster.total_instances()
        rec = {"event": "tick", "now": now, "nodes": nodes,
               "instances": inst,
               "density": inst / nodes if nodes else 0.0}
        # cumulative QoS counters so offline readers can label each
        # decision with "breach within horizon" by windowed deltas
        # instead of re-running the simulation
        live = getattr(sim, "live_result", None)
        if live is not None:
            rec["requests"] = round(live.requests, 3)
            rec["violated"] = round(live.violated_requests, 3)
        # pending-request backlog (repro.admission); absent — not 0 —
        # when the admission axis is off, so off-axis streams are
        # byte-identical to the pre-admission format
        depth = sim.queue_depth_total()
        if depth is not None:
            rec["queue_depth"] = round(depth, 3)
        self._write(rec)

    def on_schedule(self, now: float, fn: str, placements,
                    trace=None) -> None:
        rec = {"event": "schedule", "now": now, "fn": fn,
               "placed": sum(p.count for p in placements),
               "placements": [[p.node_id, p.count,
                               round(p.latency_ms, 4)]
                              for p in placements]}
        if trace is not None:
            rec["trace"] = trace.summary()
        self._write(rec)

    def on_scale(self, now: float, fn: str, event: str,
                 count: int) -> None:
        self._write({"event": "scale", "now": now, "fn": fn,
                     "kind": event, "count": count})

    def on_retrain(self, service) -> None:
        self._write({"event": "retrain", "epoch": service.epoch,
                     "retrains": service.stats.retrains,
                     "samples": service.predictor.n_samples})

    def on_result(self, result) -> None:
        self._write({
            "event": "summary",
            "scheduler": result.name,
            "ticks": result.ticks,
            "density": round(result.density, 4),
            "qos_violation_rate": round(result.qos_violation_rate, 6),
            "requests": round(result.requests, 3),
            "violated_requests": round(result.violated_requests, 3),
            "nodes_peak": result.nodes_peak,
            "per_fn_violation_rate": {
                fn: round(r, 6)
                for fn, r in sorted(result.per_fn_violation_rate().items())
            },
            # per-SLO-class accounting (repro.admission); keys absent
            # when the admission axis is off
            **({"class_violation_rate": {
                    c: round(r, 6) for c, r
                    in sorted(result.class_violation_rate().items())},
                "dropped_requests": round(result.dropped_requests, 3),
                "queue_delay_p99_s": round(result.queue_delay_s.p99, 4),
                "queue_depth_peak": round(result.queue_depth_peak, 3)}
               if result.class_requests else {}),
        })

    def on_span(self, span) -> None:
        self._write({"event": "span", **span.to_dict()})
