"""Observer hooks for the control plane (the ``repro.platform`` API).

Benchmarks and tests used to collect metrics by reaching into simulator
internals (``sim.scheduler.metrics``, ``sim.autoscaler.metrics``, the
service's stats object).  The observer API turns the interesting control
-plane transitions into events any number of observers can subscribe to
without the run loop knowing who is listening:

  * ``on_tick(now, sim)``        — once per simulated second, after
    autoscaling/routing/measurement for that second completed,
  * ``on_schedule(now, fn, placements)`` — a scheduler decision placed
    real (cold-started) instances,
  * ``on_scale(now, fn, event, count)``  — an autoscaler state
    transition: ``"logical_start"``, ``"real_cold_start"``,
    ``"release"``, ``"evict"``, or ``"migrate"``,
  * ``on_retrain(service)``      — the prediction service's online
    retraining policy fired (forest refit + epoch bump + cache clear).

``EventHub`` fans one event out to every registered observer; the hub
with no observers is the default everywhere and costs one empty-list
iteration per event, so the instrumented and bare runs are the same
code path (parity gates depend on that).
"""
from __future__ import annotations

from typing import Iterable, List


class Observer:
    """Base observer: subclass and override the hooks you care about.

    Hooks must not mutate simulation state — they exist so benchmarks
    observe without perturbing (the A/B parity gates run with and
    without observers and assert identical results).
    """

    def on_tick(self, now: float, sim) -> None:
        pass

    def on_schedule(self, now: float, fn: str, placements) -> None:
        pass

    def on_scale(self, now: float, fn: str, event: str,
                 count: int) -> None:
        pass

    def on_retrain(self, service) -> None:
        pass


class EventHub(Observer):
    """Fan-out of control-plane events to registered observers.

    An ``EventHub`` is itself an ``Observer``, so hubs nest (a platform
    hub can subscribe to another platform's hub)."""

    __slots__ = ("observers",)

    def __init__(self, observers: Iterable[Observer] = ()):
        self.observers: List[Observer] = list(observers)

    def add(self, obs: Observer) -> Observer:
        self.observers.append(obs)
        return obs

    def remove(self, obs: Observer) -> None:
        self.observers.remove(obs)

    # -- fan-out ----------------------------------------------------------

    def on_tick(self, now: float, sim) -> None:
        for o in self.observers:
            o.on_tick(now, sim)

    def on_schedule(self, now: float, fn: str, placements) -> None:
        for o in self.observers:
            o.on_schedule(now, fn, placements)

    def on_scale(self, now: float, fn: str, event: str,
                 count: int) -> None:
        for o in self.observers:
            o.on_scale(now, fn, event, count)

    def on_retrain(self, service) -> None:
        for o in self.observers:
            o.on_retrain(service)
