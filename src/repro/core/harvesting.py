"""Freyr-style harvesting scheduler, built entirely from the pipeline
surface (PAPERS: "Accelerating Serverless Computing by Harvesting Idle
Resources").

The policy, decomposed into pipeline stages:

  * **Pre-decision** — the same capacity-table gate Jiagu uses: fresh
    table headroom absorbs co-arriving instances at lookup cost, vetoed
    on nodes currently in QoS cooldown (``QosCooldownFilter``).
  * **Score** — ``IdleHeadroomScorer``: candidates ranked by predicted
    *idle headroom* from the ``PredictionService`` (capacity-table
    entry, else a zero-cost service cache hint), falling back to
    requested-CPU slack where no prediction exists.  Harvesting fills
    the most under-used machines first — the opposite of Jiagu's
    most-packed spread — converting idle capacity into placements.
  * **Bind** — ``HarvestBinder``: a critical-path capacity solve (same
    accounting as Jiagu's slow path) bounds the harvest;
    ``harvest_headroom`` scales how much of the predicted capacity may
    be claimed (1.0 = exactly the predicted bound, <1 conservative,
    >1 deliberate overcommit for burst absorption).
  * **Release on QoS-margin breach** — a runtime QoS violation on a
    node (``observe``) puts it in cooldown and releases recently
    harvested instances through the ``ReleasePicker`` stage hook
    (``BreachAwareReleasePicker`` drains the breached node first);
    released instances become *cached* (dual-staged semantics: a later
    rise re-saturates them elsewhere in <1 ms) and are evicted by the
    scheduler's own keep-alive ledger if the load never returns.

Registered as ``"harvesting"`` — runnable from a pure
``PlatformConfig`` manifest dict and part of the ``repro.platform``
CI smoke, where its QoS-violation rate must not regress versus the
K8s no-overcommit baseline on the burst-storm scenario.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from .capacity import M_MAX_DEFAULT, QoSStore
from .cluster import Cluster, Node
from .pipeline import (BreachAwareReleasePicker, CandidatePass,
                       CapacityTableGate, DecisionContext, MemRoomFilter,
                       PipelineHostMixin, SchedulingPipeline,
                       TableBoundLogicalStartPicker)
from .prediction_service import PredictionService
from .predictor import PerfPredictor
from .profiles import ProfileStore
from .scheduler import JiaguScheduler, register_scheduler

#: fraction of a breached node's saturated instances released per breach
RELEASE_FRAC = 0.25
#: keep-alive of QoS-released (cached) instances before real eviction
RELEASED_KEEPALIVE_S = 60.0


class QosCooldownFilter:
    """Reject nodes still cooling down from a QoS-margin breach — the
    pipeline must not immediately re-harvest a machine it just
    relieved."""

    name = "qos-cooldown"

    def filter(self, ctx: DecisionContext, node: Node) -> Optional[str]:
        if ctx.sched.qos_cooldown_until(node) > ctx.now:
            return "qos-cooldown"
        return None


class IdleHeadroomScorer:
    """Predicted idle headroom of a node for fn, highest first.

    Prefers prediction-backed estimates (fresh-or-stale table entry,
    else a zero-cost ``PredictionService`` cache hint) over the
    requested-CPU fallback: ``(known, headroom)`` tuples sort
    prediction-known nodes ahead, so harvesting chases *predicted*
    idle capacity and only falls back to requested-resource slack on
    never-solved nodes."""

    name = "idle-headroom"

    def score(self, ctx: DecisionContext, node: Node
              ) -> Tuple[int, float]:
        sched = ctx.sched
        cap: Optional[int] = None
        entry = node.table.get(ctx.fn)
        if entry is not None:
            cap = entry.capacity
        elif sched.engine is not None:
            cap = sched.engine.capacity_hint(
                sched._coloc_counts(node), ctx.fn, node_res=node.res)
        if cap is not None:
            st = node.funcs.get(ctx.fn)
            used = st.total if st is not None else 0
            return (1, float(cap - used))
        free = node.res.cpu_mcores \
            - node.cpu_requested(ctx.cluster.specs)
        return (0, free / max(ctx.spec.cpu_req, 1e-9))


class HarvestBinder:
    """Solve the node's capacity on the critical path (Jiagu slow-path
    accounting) and harvest up to ``harvest_headroom`` of it."""

    name = "harvest"

    def bind(self, ctx: DecisionContext, node: Node) -> int:
        sched = ctx.sched
        cap, ms = sched._slow_capacity(node, ctx.fn, ctx.remaining)
        ctx.add_ms(ms)
        st = node.state(ctx.fn)
        bound = int(cap * sched.harvest_bound(ctx.fn))
        room = min(bound - st.n_sat - st.n_cached, ctx.mem_room(node))
        if room <= 0:
            ctx.reject(node, "no-idle-headroom")
            return 0
        k = min(ctx.remaining, room)
        ctx.place(node, k, self.name, capacity=cap, room_before=room)
        ctx.metrics.slow += 1
        return k


class CooldownLogicalStartPicker(TableBoundLogicalStartPicker):
    """Table-bound logical starts that skip nodes in QoS cooldown: a
    just-breached machine must not be re-saturated the next tick (its
    cached instances re-route elsewhere or the pipeline places fresh
    capacity instead)."""

    name = "cooldown-table-bound"

    def eligible(self, node: Node) -> bool:
        # harvesting tracks the tick clock in _now
        now = getattr(self.sched, "_now", 0.0)
        return self.sched.qos_cooldown_until(node) <= now


class HarvestScaleOutBinder:
    """Scale-out under the harvest bound: a fresh node's capacity is
    all idle headroom, and only ``harvest_headroom`` of it may be
    claimed (minimum one instance, so scale-out always progresses)."""

    name = "harvest-scale-out"

    def bind(self, ctx: DecisionContext, node: Node) -> int:
        sched = ctx.sched
        cap, ms = sched._slow_capacity(node, ctx.fn, ctx.remaining)
        ctx.add_ms(ms)
        ctx.metrics.slow += 1
        bound = max(int(cap * sched.harvest_bound(ctx.fn)), 1)
        room = min(bound, ctx.mem_room(node))
        if room <= 0:
            ctx.reject(node, "scale-out-infeasible")
            return 0
        k = min(ctx.remaining, room)
        ctx.place(node, k, self.name, capacity=cap, room_before=room)
        return k


class HarvestingScheduler(PipelineHostMixin, JiaguScheduler):
    """Idle-resource harvesting over the decision pipeline; shares
    Jiagu's prediction machinery (async table updates, batched service
    solving, dual-staged pickers) but places by idle headroom and
    gives harvested capacity back on QoS-margin breach."""

    name = "harvesting"

    def __init__(self, cluster: Cluster, store: ProfileStore,
                 qos: QoSStore, predictor: PerfPredictor,
                 m_max: int = M_MAX_DEFAULT,
                 engine: Optional[PredictionService] = None,
                 harvest_headroom: float = 0.85,
                 qos_release_cooldown_s: float = 30.0):
        super().__init__(cluster, store, qos, predictor, m_max=m_max,
                         engine=engine)
        self.harvest_headroom = harvest_headroom
        #: per-function harvest bounds, maintained by the vertical
        #: resizer (``repro.admission``): a best-effort function running
        #: at a shrunken cpu share frees real headroom, so its bound may
        #: exceed the global scalar (up to the capacity-table solve).
        #: Empty == every function uses ``harvest_headroom``, which is
        #: the admission-off parity configuration.
        self.harvest_bounds: Dict[str, float] = {}
        self.cooldown_s = qos_release_cooldown_s
        self.release_stage = BreachAwareReleasePicker(self)
        self.logical_start_stage = CooldownLogicalStartPicker(self)
        self._cooldown_until: Dict[int, float] = {}
        self._now = 0.0
        # standalone fallback only: QoS-released cached instances
        # awaiting keep-alive eviction as (due_time, node_id, fn,
        # count).  With an assembled control plane the releases go
        # through ``release_ledger.note_release`` (the autoscaler's own
        # keep-alive ledger) instead, so eviction accounting, on_scale
        # events, and migration all treat them like any other cached
        # instance — this deque is used only when no autoscaler exists.
        self._released: Deque[List] = deque()
        self.qos_released = 0        # instances released on breach
        self.qos_breaches = 0        # distinct breach events handled

    def harvest_bound(self, fn: str) -> float:
        """Harvest headroom for ``fn``: its vertical-resize bound when
        one exists, the global scalar otherwise."""
        return self.harvest_bounds.get(fn, self.harvest_headroom)

    # -- the stack --------------------------------------------------------

    def build_pipeline(self) -> SchedulingPipeline:
        cooldown = QosCooldownFilter()
        return SchedulingPipeline(
            pre_decision=CapacityTableGate(filters=(cooldown,)),
            passes=[CandidatePass(
                "harvest", HarvestBinder(),
                filters=(cooldown, MemRoomFilter()),
                scorer=IdleHeadroomScorer())],
            scale_out=HarvestScaleOutBinder())

    def on_place(self, node: Node, k: int, now: float,
                 latency_ms: float) -> None:
        self._queue_update(node, now + latency_ms / 1e3)

    # -- QoS-margin breach: release through the ReleasePicker stage ------

    def qos_cooldown_until(self, node: Node) -> float:
        return self._cooldown_until.get(node.id, -math.inf)

    def observe(self, node: Node, ok: bool, now: float):
        if ok:
            return
        already_cooling = now < self.qos_cooldown_until(node)
        self._cooldown_until[node.id] = now + self.cooldown_s
        if already_cooling:
            return   # one release per breach event, not per tick
        sat_fns = [(s.n_sat, g) for g, s in node.funcs.items()
                   if s.n_sat > 0]
        if not sat_fns:
            return
        _, fn = max(sat_fns)
        k = max(1, int(round(node.funcs[fn].n_sat * RELEASE_FRAC)))
        self.qos_breaches += 1
        for target, take in self.release_stage.pick_release_nodes(fn, k):
            got = target.release(fn, take)
            if got <= 0:
                continue
            self.qos_released += got
            # the autoscaler declines when it runs traditional keep-
            # alive (its ledger sweep would never evict the entry)
            if self.release_ledger is None or \
                    not self.release_ledger.note_release(fn, target,
                                                         got, now):
                self._released.append(
                    [now + RELEASED_KEEPALIVE_S, target.id, fn, got])
            # released capacity can only have grown: queue a background
            # table refresh (Jiagu §5 semantics)
            self.notify_change(target, now)

    def has_pending_work(self) -> bool:
        return bool(self._released) or super().has_pending_work()

    def on_tick(self, now: float):
        self._now = now
        super().on_tick(now)
        # standalone fallback: keep-alive eviction of QoS-released
        # instances the load never re-claimed (empty whenever the
        # autoscaler's ledger is wired in)
        while self._released and self._released[0][0] <= now:
            _, node_id, fn, k = self._released.popleft()
            node = self.cluster.nodes.get(node_id)
            if node is None:
                continue
            got = node.evict_cached(fn, k)
            if got:
                self.notify_change(node, now)


register_scheduler(
    "harvesting",
    lambda ctx: HarvestingScheduler(
        ctx.cluster, ctx.store, ctx.qos, ctx.predictor, m_max=ctx.m_max,
        harvest_headroom=ctx.harvest_headroom,
        qos_release_cooldown_s=ctx.qos_release_cooldown_s),
    needs_predictor=True, dual_staged_default=True)


__all__ = ["HarvestingScheduler", "QosCooldownFilter",
           "IdleHeadroomScorer", "HarvestBinder",
           "HarvestScaleOutBinder", "CooldownLogicalStartPicker"]
