"""Jiagu's prediction model (paper §4.1): Random Forest Regression,
from scratch.

    P_{A | {B, C, ...}} = RFR{P_A, R_A, C_A, R_B, C_B, R_C, C_C, ...}

Function-granularity inputs (the paper's dimensionality-reduction insight):
instances of one function are homogeneous, so neighbor features are merged
into concurrency-weighted aggregates instead of per-instance columns —
input size is O(1) in the number of colocated instances:

    x = [ P_A, R_A (13), C_A^sat, C_A^cached,
          sum_B C_B^sat * R_B (13), sum_B C_B^sat, sum_B C_B^cached ]   (31,)

Training is plain numpy CART (variance-reduction splits, bootstrap rows,
sqrt-feature bagging) — profiling/training nodes are offline, so training
cost is off the scheduling path.  Inference has three engines:

  * ``numpy``  — vectorized level-synchronous descent (simulator default),
  * ``jax``    — jnp gathers (jit),
  * ``pallas`` — the VMEM-resident forest kernel
                 (``repro.kernels.rfr_inference``), the TPU hot path.

The forest is flattened to *complete* depth-D arrays so all engines share
one layout.  Also ships the Fig-16 comparison zoo (linear/ridge/ESP-style
quadratic ridge/GBT/MLP-2,3,4).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .profiles import N_PROFILE

N_FEATURES = 1 + N_PROFILE + 2 + N_PROFILE + 2  # 31


def build_features(solo_lat: float, profile: np.ndarray, n_sat: float,
                   n_cached: float,
                   neighbors: Sequence[Tuple[np.ndarray, float, float]]
                   ) -> np.ndarray:
    """Feature vector for one (target function, colocation) scenario.
    neighbors: [(profile, ns, nc), ...] NOT including the target.

    The aggregate block is the *node-level* concurrency-weighted profile
    sum INCLUDING the target's own instances: trees split on thresholds
    and cannot form the product n_sat x profile themselves, so giving
    them pre-multiplied total pressure is what makes capacity sweeps
    (m = 1..m_max with everything else fixed) resolvable."""
    agg = profile * n_sat
    tot_sat, tot_cached = float(n_sat), float(n_cached)
    for prof, ns, nc in neighbors:
        agg += prof * ns
        tot_sat += ns
        tot_cached += nc
    return np.concatenate([
        [solo_lat], profile, [n_sat, n_cached], agg, [tot_sat, tot_cached],
    ]).astype(np.float32)


# ---------------------------------------------------------------------------
# CART regression tree
# ---------------------------------------------------------------------------


class _CART:
    """Greedy variance-reduction regression tree, flattened on build to
    complete-tree arrays (feat, thr over 2^D-1 internal nodes; 2^D leaves).
    Unsplit subtrees are filled with always-go-left sentinels."""

    def __init__(self, max_depth: int = 8, min_samples_leaf: int = 2,
                 max_features: Optional[int] = None, rng=None):
        self.D = max_depth
        self.min_leaf = min_samples_leaf
        self.max_features = max_features
        self.rng = rng or np.random.default_rng()
        NN = (1 << self.D) - 1
        self.feat = np.zeros(NN, np.int32)
        self.thr = np.full(NN, np.inf, np.float32)
        self.leaf = np.zeros(1 << self.D, np.float32)

    def fit(self, X: np.ndarray, y: np.ndarray):
        self._X, self._y = X, y.astype(np.float64)
        self._build(np.arange(len(y)), 0, 0)
        del self._X, self._y
        return self

    def _best_split(self, idx):
        X, y = self._X[idx], self._y[idx]
        n, F = X.shape
        # sklearn's RandomForestRegressor default is max_features=1.0 (all
        # features) for regression — bootstrap rows provide the ensemble
        # diversity.  sqrt-bagging here measurably breaks the uncontended
        # corner (solo-run rows average into interference-heavy leaves).
        k = self.max_features or F
        feats = self.rng.choice(F, size=min(k, F), replace=False)
        total = y.sum()
        sq = (y ** 2).sum()
        best = (None, 0.0, 0.0)  # (feature, threshold, gain)
        base = sq - total * total / n
        for f in feats:
            order = np.argsort(X[:, f], kind="stable")
            xs, ys = X[order, f], y[order]
            cl = np.cumsum(ys)[:-1]
            cl2 = np.cumsum(ys ** 2)[:-1]
            nl = np.arange(1, n)
            nr = n - nl
            ok = (xs[1:] > xs[:-1]) & (nl >= self.min_leaf) & \
                 (nr >= self.min_leaf)
            if not ok.any():
                continue
            sse = (cl2 - cl ** 2 / nl) + \
                  ((sq - cl2) - (total - cl) ** 2 / nr)
            sse = np.where(ok, sse, np.inf)
            j = int(np.argmin(sse))
            gain = base - sse[j]
            if gain > best[2] + 1e-12:
                best = (int(f), float((xs[j] + xs[j + 1]) / 2), float(gain))
        return best

    def _fill_leaf(self, node: int, depth: int, value: float):
        """Make the whole subtree under (node, depth) return `value`."""
        NN = (1 << self.D) - 1
        if node >= NN:
            self.leaf[node - NN] = value
            return
        self.feat[node] = 0
        self.thr[node] = np.inf  # x[0] >= inf is False -> always left
        # all leaves reachable from here get the value (right side too, for
        # safety against NaNs)
        lo, hi = node, node
        for _ in range(self.D - depth):
            lo = 2 * lo + 1
            hi = 2 * hi + 2
        self.leaf[lo - NN: hi - NN + 1] = value

    def _build(self, idx, node: int, depth: int):
        y = self._y[idx]
        if depth == self.D or len(idx) < 2 * self.min_leaf or \
                np.ptp(y) < 1e-12:
            self._fill_leaf(node, depth, float(y.mean()))
            return
        f, t, gain = self._best_split(idx)
        if f is None:
            self._fill_leaf(node, depth, float(y.mean()))
            return
        self.feat[node] = f
        self.thr[node] = t
        mask = self._X[idx, f] < t
        self._build(idx[mask], 2 * node + 1, depth + 1)
        self._build(idx[~mask], 2 * node + 2, depth + 1)


# ---------------------------------------------------------------------------
# Random forest
# ---------------------------------------------------------------------------


@dataclass
class ForestArrays:
    feat: np.ndarray   # (T, 2^D - 1) int32
    thr: np.ndarray    # (T, 2^D - 1) float32
    leaf: np.ndarray   # (T, 2^D) float32


class RandomForestRegressor:
    def __init__(self, n_trees: int = 32, max_depth: int = 8,
                 min_samples_leaf: int = 2, seed: int = 0):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.seed = seed
        self.arrays: Optional[ForestArrays] = None
        self.train_time_s = 0.0
        self._device_arrays = None   # jnp copies, uploaded once per fit

    def fit(self, X: np.ndarray, y: np.ndarray):
        t0 = time.perf_counter()
        rng = np.random.default_rng(self.seed)
        feats, thrs, leaves = [], [], []
        n = len(y)
        for _ in range(self.n_trees):
            bidx = rng.integers(0, n, n)
            tree = _CART(self.max_depth, self.min_samples_leaf, rng=rng)
            tree.fit(X[bidx], y[bidx])
            feats.append(tree.feat)
            thrs.append(tree.thr)
            leaves.append(tree.leaf)
        self.arrays = ForestArrays(np.stack(feats), np.stack(thrs),
                                   np.stack(leaves))
        self._device_arrays = None   # stale after a refit
        self.train_time_s = time.perf_counter() - t0
        return self

    # -- inference engines ------------------------------------------------

    def device_arrays(self):
        """The flattened forest as device-resident jnp arrays
        (feat, thr, leaf), uploaded once per fit and shared by every
        jax/pallas inference and the fused capacity sweep — repeat
        drains re-read the VMEM-sized model without re-transfer."""
        assert self.arrays is not None, "fit first"
        if self._device_arrays is None:
            import jax.numpy as jnp
            a = self.arrays
            self._device_arrays = (jnp.asarray(a.feat), jnp.asarray(a.thr),
                                   jnp.asarray(a.leaf))
        return self._device_arrays

    def predict(self, X: np.ndarray, engine: str = "numpy") -> np.ndarray:
        assert self.arrays is not None, "fit first"
        X = np.atleast_2d(np.asarray(X, np.float32))
        if engine == "numpy":
            return self._predict_numpy(X)
        import jax.numpy as jnp
        from ..kernels import ops
        feat, thr, leaf = self.device_arrays()
        out = ops.rfr_op(jnp.asarray(X), feat, thr, leaf,
                         use_pallas=(engine == "pallas"))
        return np.asarray(out)

    def _predict_numpy(self, X: np.ndarray) -> np.ndarray:
        a = self.arrays
        N = X.shape[0]
        T, NN = a.feat.shape
        idx = np.zeros((N, T), np.int64)
        t_ids = np.arange(T)[None, :]
        for _ in range(self.max_depth):
            f = a.feat[t_ids, idx]
            t = a.thr[t_ids, idx]
            go_right = X[np.arange(N)[:, None], f] >= t
            idx = 2 * idx + 1 + go_right
        vals = a.leaf[t_ids, idx - NN]
        return vals.mean(axis=1).astype(np.float32)


# ---------------------------------------------------------------------------
# Online predictor with incremental retraining (paper §6)
# ---------------------------------------------------------------------------


class PerfPredictor:
    """Wraps the forest with the paper's operational loop: a growing
    training set, periodic retraining, per-function convergence tracking,
    and inference accounting (count + wall time) for the scheduling-cost
    benchmarks."""

    def __init__(self, n_trees: int = 32, max_depth: int = 8,
                 retrain_every: int = 64, seed: int = 0,
                 engine: str = "numpy", log_target: bool = True):
        self.model = RandomForestRegressor(n_trees, max_depth, seed=seed)
        self.engine = engine
        # Queueing-shaped latency labels are heavy-tailed; regressing
        # log-latency makes leaf averages multiplicative and roughly
        # halves the relative error near the QoS boundary.
        self.log_target = log_target
        self.retrain_every = retrain_every
        self._X: List[np.ndarray] = []
        self._y: List[float] = []
        self._since_retrain = 0
        self.inference_count = 0
        self.inference_calls = 0
        self.inference_time_s = 0.0
        self.retrain_count = 0
        self.fitted = False

    @property
    def n_samples(self) -> int:
        return len(self._y)

    def dataset(self) -> Tuple[np.ndarray, np.ndarray]:
        """The accumulated training set as (X, y) arrays (empty arrays
        before any sample) — validation consumers (the service's
        learned shape margins) read it without touching internals."""
        if not self._y:
            return (np.empty((0, 0), np.float32),
                    np.empty(0, np.float64))
        return np.stack(self._X), np.asarray(self._y, np.float64)

    def add_sample(self, x: np.ndarray, y: float, retrain: bool = True):
        self._X.append(np.asarray(x, np.float32))
        self._y.append(float(y))
        self._since_retrain += 1
        if retrain and (not self.fitted
                        or self._since_retrain >= self.retrain_every):
            self.retrain()

    def add_dataset(self, X: np.ndarray, y: np.ndarray,
                    retrain: bool = True):
        for xi, yi in zip(X, y):
            self._X.append(np.asarray(xi, np.float32))
            self._y.append(float(yi))
        if retrain:
            self.retrain()

    def retrain(self):
        if not self._y:
            return
        y = np.asarray(self._y)
        if self.log_target:
            y = np.log(np.maximum(y, 1e-6))
        self.model.fit(np.stack(self._X), y)
        self._since_retrain = 0
        self.retrain_count += 1
        self.fitted = True

    def predict(self, X: np.ndarray) -> np.ndarray:
        """One *batched* inference ("once" cost in the paper's terms)."""
        X = np.atleast_2d(X)
        t0 = time.perf_counter()
        out = self.model.predict(X, engine=self.engine)
        if self.log_target:
            out = np.exp(out)
        self.inference_time_s += time.perf_counter() - t0
        self.inference_calls += 1
        self.inference_count += len(X)
        return out

    def record_inference(self, rows: int, seconds: float) -> None:
        """Bill inference performed outside ``predict`` — the
        device-resident capacity sweep scores rows in its own fused
        kernel — into the same accounting the scheduling-cost
        benchmarks read."""
        self.inference_calls += 1
        self.inference_count += int(rows)
        self.inference_time_s += seconds

    def predict_many(self, Xs: Sequence[np.ndarray]) -> List[np.ndarray]:
        """Score several feature matrices in ONE batched inference call
        (the multi-query API behind the cluster-scale capacity engine:
        every unresolved scenario of a drain rides the same forest pass).
        Returns per-matrix prediction arrays."""
        mats = [np.atleast_2d(x) for x in Xs]
        if not mats:
            return []
        out = self.predict(np.concatenate(mats, axis=0))
        splits = np.cumsum([len(m) for m in mats])[:-1]
        return np.split(out, splits)

    @property
    def mean_inference_ms(self) -> float:
        return 1e3 * self.inference_time_s / max(self.inference_calls, 1)


# ---------------------------------------------------------------------------
# Fig-16 comparison zoo (from-scratch baselines)
# ---------------------------------------------------------------------------


class LinearModel:
    def __init__(self, l2: float = 0.0, quadratic: bool = False):
        self.l2 = l2
        self.quadratic = quadratic
        self.w = None
        self.train_time_s = 0.0

    def _phi(self, X):
        X = np.atleast_2d(X)
        if self.quadratic:  # ESP-style quadratic expansion (diagonal)
            X = np.concatenate([X, X ** 2], axis=1)
        return np.concatenate([X, np.ones((len(X), 1))], axis=1)

    def fit(self, X, y):
        t0 = time.perf_counter()
        P = self._phi(X)
        A = P.T @ P + self.l2 * np.eye(P.shape[1])
        self.w = np.linalg.solve(A, P.T @ y)
        self.train_time_s = time.perf_counter() - t0
        return self

    def predict(self, X, engine=None):
        return self._phi(X) @ self.w


class GradientBoostedTrees:
    """XGBoost-style: sequential depth-limited CARTs on residuals."""

    def __init__(self, n_rounds: int = 40, max_depth: int = 4,
                 lr: float = 0.15, seed: int = 0):
        self.n_rounds, self.max_depth, self.lr = n_rounds, max_depth, lr
        self.seed = seed
        self.trees: List[_CART] = []
        self.base = 0.0
        self.train_time_s = 0.0

    def fit(self, X, y):
        t0 = time.perf_counter()
        rng = np.random.default_rng(self.seed)
        self.base = float(np.mean(y))
        resid = y - self.base
        self.trees = []
        for _ in range(self.n_rounds):
            tr = _CART(self.max_depth, min_samples_leaf=3,
                       max_features=X.shape[1], rng=rng)
            tr.fit(X, resid)
            pred = _tree_predict(tr, X)
            resid = resid - self.lr * pred
            self.trees.append(tr)
        self.train_time_s = time.perf_counter() - t0
        return self

    def predict(self, X, engine=None):
        X = np.atleast_2d(X)
        out = np.full(len(X), self.base, np.float64)
        for tr in self.trees:
            out += self.lr * _tree_predict(tr, X)
        return out.astype(np.float32)


def _tree_predict(tree: _CART, X: np.ndarray) -> np.ndarray:
    N = len(X)
    NN = (1 << tree.D) - 1
    idx = np.zeros(N, np.int64)
    rows = np.arange(N)
    for _ in range(tree.D):
        f = tree.feat[idx]
        t = tree.thr[idx]
        idx = 2 * idx + 1 + (X[rows, f] >= t)
    return tree.leaf[idx - NN]


class MLPRegressor:
    """Small fully-connected net, numpy Adam, for the Fig-16 comparison."""

    def __init__(self, n_layers: int = 2, width: int = 64,
                 epochs: int = 300, lr: float = 1e-3, seed: int = 0):
        self.n_layers, self.width = n_layers, width
        self.epochs, self.lr, self.seed = epochs, lr, seed
        self.params = None
        self.train_time_s = 0.0
        self._norm = None

    def _init(self, F):
        rng = np.random.default_rng(self.seed)
        dims = [F] + [self.width] * (self.n_layers - 1) + [1]
        return [(rng.normal(0, np.sqrt(2.0 / d_in), (d_in, d_out)),
                 np.zeros(d_out))
                for d_in, d_out in zip(dims[:-1], dims[1:])]

    def _fwd(self, X, params):
        acts = [X]
        h = X
        for i, (W, b) in enumerate(params):
            h = h @ W + b
            if i < len(params) - 1:
                h = np.maximum(h, 0)
            acts.append(h)
        return h[:, 0], acts

    def fit(self, X, y):
        t0 = time.perf_counter()
        X = np.asarray(X, np.float64)
        mu, sd = X.mean(0), X.std(0) + 1e-8
        ymu, ysd = float(np.mean(y)), float(np.std(y) + 1e-8)
        self._norm = (mu, sd, ymu, ysd)
        Xn = (X - mu) / sd
        yn = (np.asarray(y, np.float64) - ymu) / ysd
        params = self._init(X.shape[1])
        m = [(np.zeros_like(W), np.zeros_like(b)) for W, b in params]
        v = [(np.zeros_like(W), np.zeros_like(b)) for W, b in params]
        b1, b2, eps = 0.9, 0.999, 1e-8
        step = 0
        for _ in range(self.epochs):
            pred, acts = self._fwd(Xn, params)
            err = (pred - yn)[:, None] / len(yn) * 2
            grads = []
            delta = err
            for i in reversed(range(len(params))):
                W, b = params[i]
                a_in = acts[i]
                gW = a_in.T @ delta
                gb = delta.sum(0)
                grads.append((gW, gb))
                if i > 0:
                    delta = (delta @ W.T) * (acts[i] > 0)
            grads.reverse()
            step += 1
            new_params = []
            for i, ((W, b), (gW, gb)) in enumerate(zip(params, grads)):
                mW, mb = m[i]
                vW, vb = v[i]
                mW = b1 * mW + (1 - b1) * gW
                mb = b1 * mb + (1 - b1) * gb
                vW = b2 * vW + (1 - b2) * gW ** 2
                vb = b2 * vb + (1 - b2) * gb ** 2
                m[i], v[i] = (mW, mb), (vW, vb)
                mhW = mW / (1 - b1 ** step)
                mhb = mb / (1 - b1 ** step)
                vhW = vW / (1 - b2 ** step)
                vhb = vb / (1 - b2 ** step)
                new_params.append((W - self.lr * mhW / (np.sqrt(vhW) + eps),
                                   b - self.lr * mhb / (np.sqrt(vhb) + eps)))
            params = new_params
        self.params = params
        self.train_time_s = time.perf_counter() - t0
        return self

    def predict(self, X, engine=None):
        mu, sd, ymu, ysd = self._norm
        Xn = (np.atleast_2d(np.asarray(X, np.float64)) - mu) / sd
        pred, _ = self._fwd(Xn, self.params)
        return (pred * ysd + ymu).astype(np.float32)


MODEL_ZOO = {
    "RFR (Jiagu)": lambda: RandomForestRegressor(32, 8),
    "Linear": lambda: LinearModel(0.0),
    "Ridge": lambda: LinearModel(1.0),
    "ESP (quad. ridge)": lambda: LinearModel(1.0, quadratic=True),
    "XGBoost-style GBT": lambda: GradientBoostedTrees(),
    "MLP-2": lambda: MLPRegressor(2),
    "MLP-3": lambda: MLPRegressor(3),
    "MLP-4": lambda: MLPRegressor(4),
}
