"""Schedulers: Jiagu pre-decision scheduling + the three baselines
(Kubernetes, Gsight-style, Owl-style) from the paper's evaluation.

Scheduling-cost accounting is *measured*, not assumed: every slow-path /
per-schedule inference is a real call into the RFR predictor and its wall
time is what lands in the metrics.  Fast-path decisions cost a table
lookup (FAST_PATH_MS).  Asynchronous capacity-table updates run real
inference too, but their time is billed to background work, never to the
scheduling critical path — the paper's core claim.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .capacity import M_MAX_DEFAULT, QoSStore, capacity_of, \
    update_capacity_table
from .cluster import CapEntry, Cluster, Node
from .metrics import Reservoir
from .predictor import PerfPredictor
from .prediction_service import EngineConfig, PredictionService
from .profiles import FunctionSpec, ProfileStore
from .registry import Registry

FAST_PATH_MS = 0.05     # capacity-table lookup + comparison
REROUTE_MS = 0.5        # logical cold start: K8s Service label flip


@dataclass
class SchedMetrics:
    decisions: int = 0
    instances_placed: int = 0
    fast: int = 0
    slow: int = 0
    failed: int = 0
    sched_time_ms: float = 0.0
    # bounded: 512-node full-trace runs record one sample per decision
    sched_latencies: Reservoir = field(
        default_factory=lambda: Reservoir(512))
    critical_inference_rows: int = 0
    critical_inference_calls: int = 0
    async_inference_rows: int = 0
    async_updates: int = 0

    @property
    def mean_latency_ms(self) -> float:
        return self.sched_latencies.mean   # exact (running sum/count)

    @property
    def p50_latency_ms(self) -> float:
        return self.sched_latencies.p50

    @property
    def p99_latency_ms(self) -> float:
        return self.sched_latencies.p99


@dataclass
class Placement:
    node_id: int
    count: int
    latency_ms: float      # scheduling latency experienced by this decision


class BaseScheduler:
    name = "base"
    #: table-driven schedulers (Jiagu) accept an attached
    #: ``PredictionService`` for batched/cached capacity solving
    accepts_service = False
    #: True for schedulers whose ``observe`` learns from *healthy* nodes
    #: too (Owl's safe-set promotion): the measurement pass must then
    #: visit every hosting node, not just those with live traffic — the
    #: dirty-set scan in ``simulator.measure_cluster`` keys off this
    needs_idle_observe = False
    #: pipeline hosts record a ``pipeline.DecisionTrace`` per decision
    #: when True (legacy monolithic schedulers never produce one).
    #: Off by default — traces exist to be consumed through the
    #: ``on_schedule`` observer hook, so ``Platform.build`` turns
    #: recording on when observers are attached (or when the manifest's
    #: ``pipeline.decision_traces`` forces it); standalone consumers
    #: set the attribute directly.
    trace_decisions = False
    #: additionally snapshot every node's raw candidate feature vector
    #: (``pipeline.candidate_feature_row``) and the chosen node into
    #: each ``DecisionTrace`` — the ``repro.policy`` training input.
    #: Off by default: the capture costs O(nodes) per decision, so only
    #: dataset-collection runs opt in (``PlatformConfig
    #: pipeline.trace_features``).  Implies nothing unless
    #: ``trace_decisions`` is also on.
    trace_features = False

    def __init__(self, cluster: Cluster, store: ProfileStore,
                 qos: QoSStore):
        self.cluster = cluster
        self.store = store
        self.qos = qos
        self.metrics = SchedMetrics()
        #: the most recent decision's trace (pipeline schedulers only);
        #: consumed by the autoscaler via ``take_trace`` and forwarded
        #: through the ``on_schedule`` observer hook
        self.last_trace = None
        # dual-staged scaling picks are pipeline stages (swappable via
        # platform.register_stage / PlatformConfig.pipeline)
        from .pipeline import (GreedyLogicalStartPicker,
                               GreedyReleasePicker)
        self.release_stage = GreedyReleasePicker(self)
        self.logical_start_stage = GreedyLogicalStartPicker(self)
        #: keep-alive accountant for scheduler-initiated releases (the
        #: assembled autoscaler, wired by build_simulation; None when
        #: the scheduler runs standalone)
        self.release_ledger = None

    # -- interface ---------------------------------------------------------

    def schedule(self, fn: str, count: int, now: float) -> List[Placement]:
        raise NotImplementedError

    def on_tick(self, now: float):
        pass

    def has_pending_work(self) -> bool:
        """True when ``on_tick`` has queued work whose *timing* matters
        (async capacity-table updates, deferred releases).  The
        event-driven core calls ``on_tick`` every tick while this holds
        even if no function in the cell is due, so deferred work drains
        on the same tick it would under the legacy loop."""
        return False

    def notify_change(self, node: Node, now: float):
        """Called when counts change outside scheduling (release/evict)."""
        pass

    def observe(self, node: Node, ok: bool, now: float):
        """Runtime QoS observation feedback (used by Owl)."""
        pass

    @property
    def prediction_service(self) -> Optional[PredictionService]:
        """The scheduler's ``PredictionService``, if it uses one — the
        ``platform.CapacityProvider`` hint source and the simulator's
        sample-collection client.  None for table-free baselines."""
        return None

    def attach_service(self, service: PredictionService) -> None:
        """Attach a ``PredictionService`` (only meaningful when
        ``accepts_service``)."""
        raise TypeError(f"{type(self).__name__} does not accept a "
                        f"PredictionService")

    # -- decision traces (pipeline schedulers) ----------------------------

    def take_trace(self):
        """Pop the most recent decision's ``DecisionTrace`` (None for
        legacy monolithic schedulers or when tracing is disabled)."""
        trace, self.last_trace = self.last_trace, None
        return trace

    def on_place(self, node: Node, k: int, now: float,
                 latency_ms: float) -> None:
        """Post-placement hook the pipeline's ``DecisionContext`` fires
        for every binding (Jiagu queues its async capacity update
        here)."""

    def qos_cooldown_until(self, node: Node) -> float:
        """Until when the scheduler considers ``node`` QoS-breached
        (harvesting-style policies override; -inf = never breached).
        Consumed by breach-aware release/logical-start stages."""
        return float("-inf")

    # -- dual-staged scaling capabilities (platform.ReleasePicker /
    # -- platform.LogicalStartPicker; the autoscaler consumes these).
    # -- The policies themselves are pipeline stages held in
    # -- ``release_stage`` / ``logical_start_stage`` (greedy defaults;
    # -- Jiagu installs the table-bound logical-start stage) -------------

    def pick_release_nodes(self, fn: str, k: int) -> List[Tuple[Node, int]]:
        return self.release_stage.pick_release_nodes(fn, k)

    def pick_logical_start_nodes(self, fn: str, k: int
                                 ) -> List[Tuple[Node, int]]:
        return self.logical_start_stage.pick_logical_start_nodes(fn, k)

    # -- shared helpers ------------------------------------------------

    def _new_node(self) -> Node:
        return self.cluster.add_node()

    def _mem_room(self, node: Node, fn: str) -> int:
        return self.cluster.mem_headroom(node, fn)


# ---------------------------------------------------------------------------
# Kubernetes baseline: requested-resource bin packing, no overcommitment
# ---------------------------------------------------------------------------


class K8sScheduler(BaseScheduler):
    name = "k8s"

    def _fits(self, node: Node, spec: FunctionSpec) -> bool:
        return (node.cpu_requested(self.cluster.specs) + spec.cpu_req
                <= node.res.cpu_mcores
                and node.mem_used(self.cluster.specs) + spec.mem_req
                <= node.res.mem_mb)

    def schedule(self, fn: str, count: int, now: float) -> List[Placement]:
        spec = self.cluster.specs[fn]
        out: List[Placement] = []
        for _ in range(count):
            target = None
            # most-allocated first (default kube-scheduler bin-packing-ish)
            for node in sorted(self.cluster.nodes.values(),
                               key=lambda n: -n.cpu_requested(
                                   self.cluster.specs)):
                if self._fits(node, spec):
                    target = node
                    break
            if target is None:
                target = self._new_node()
            target.deploy(fn, 1)
            out.append(Placement(target.id, 1, FAST_PATH_MS))
            self.metrics.decisions += 1
            self.metrics.instances_placed += 1
            self.metrics.fast += 1
            self.metrics.sched_latencies.append(FAST_PATH_MS)
            self.metrics.sched_time_ms += FAST_PATH_MS
        return out


# ---------------------------------------------------------------------------
# Jiagu: pre-decision scheduling (fast/slow path + async update + batching)
# ---------------------------------------------------------------------------


class JiaguScheduler(BaseScheduler):
    name = "jiagu"
    accepts_service = True

    def __init__(self, cluster: Cluster, store: ProfileStore, qos: QoSStore,
                 predictor: PerfPredictor, m_max: int = M_MAX_DEFAULT,
                 engine: Optional[PredictionService] = None):
        super().__init__(cluster, store, qos)
        self.predictor = predictor
        self.m_max = m_max
        # optional PredictionService (batched/cached solving; None keeps
        # the legacy per-node reference path)
        self.engine = engine
        self._pending: Dict[int, float] = {}  # node id -> due time
        # logical starts absorb only up to the capacity table's bound
        from .pipeline import TableBoundLogicalStartPicker
        self.logical_start_stage = TableBoundLogicalStartPicker(self)

    @property
    def prediction_service(self) -> Optional[PredictionService]:
        return self.engine

    def attach_service(self, service: PredictionService) -> None:
        self.engine = service

    # -- async update machinery -----------------------------------------

    def _queue_update(self, node: Node, now: float):
        est = max(self.predictor.mean_inference_ms, 0.5) / 1e3
        due = now + est
        self._pending[node.id] = max(self._pending.get(node.id, 0.0), due)
        node.update_pending_until = self._pending[node.id]

    def has_pending_work(self) -> bool:
        return bool(self._pending)

    def on_tick(self, now: float):
        due = [nid for nid, t in self._pending.items() if t <= now]
        if self.engine is not None:
            nodes = []
            for nid in due:
                self._pending.pop(nid)
                node = self.cluster.nodes.get(nid)
                if node is not None:
                    nodes.append(node)
            if nodes:
                # one coalesced drain: every due node's scenarios share
                # the same batched predictor passes and the engine cache
                rows = self.engine.update_nodes(nodes, self.m_max)
                for node in nodes:
                    node.update_pending_until = -1.0
                self.metrics.async_inference_rows += rows
                self.metrics.async_updates += len(nodes)
            return
        for nid in due:
            self._pending.pop(nid)
            node = self.cluster.nodes.get(nid)
            if node is None:
                continue
            rows = update_capacity_table(self.predictor, self.store,
                                         self.qos, self.cluster.specs, node,
                                         self.m_max)
            node.update_pending_until = -1.0
            self.metrics.async_inference_rows += rows
            self.metrics.async_updates += 1

    def notify_change(self, node: Node, now: float):
        # releases/evictions only increase capacities; queue a background
        # refresh so the scheduler can reuse the space (paper §5).
        self._queue_update(node, now)

    # -- scheduling -------------------------------------------------------

    def _coloc_counts(self, node: Node) -> Dict[str, Tuple[float, float]]:
        return {g: (float(s.n_sat), float(s.n_cached))
                for g, s in node.funcs.items() if s.total > 0}

    def _slow_capacity(self, node: Node, fn: str,
                       need: int) -> Tuple[int, float]:
        """Compute capacity on the critical path; returns (cap, ms).

        The sweep is capped at what THIS decision needs (current + need):
        the decision only requires knowing whether `need` more instances
        fit, and the asynchronous update queued by the deployment rebuilds
        the full-depth entry off the critical path — so the slow path
        pays O(need) inference rows, not O(m_max)."""
        t0 = time.perf_counter()
        st = node.funcs.get(fn)
        have = st.total if st is not None else 0
        m_cap = min(self.m_max, have + need + 1)
        if self.engine is not None:
            cap, rows = self.engine.capacity(self._coloc_counts(node), fn,
                                             m_cap, node_res=node.res)
        else:
            cap, rows = capacity_of(self.predictor, self.store, self.qos,
                                    self.cluster.specs,
                                    self._coloc_counts(node), fn, m_cap)
        ms = (time.perf_counter() - t0) * 1e3
        node.table[fn] = CapEntry(capacity=cap, fresh=cap < m_cap)
        self.metrics.critical_inference_rows += rows
        self.metrics.critical_inference_calls += 1
        return cap, ms

    def schedule(self, fn: str, count: int, now: float) -> List[Placement]:
        """Concurrency-aware: `count` co-arriving instances of one function
        are one batched decision wherever capacity allows."""
        out: List[Placement] = []
        remaining = count
        decision_ms = 0.0
        used_slow = False

        def place(node: Node, k: int, ms: float):
            nonlocal remaining
            node.deploy(fn, k)
            out.append(Placement(node.id, k, ms))
            remaining -= k
            self.metrics.instances_placed += k
            self._queue_update(node, now + ms / 1e3)

        # 1) fast path: nodes already running fn with a fresh entry
        for node in sorted(self.cluster.nodes_with(fn),
                           key=lambda n: -n.funcs[fn].n_sat):
            if remaining <= 0:
                break
            entry = node.table.get(fn)
            if entry is None or not entry.fresh:
                continue
            st = node.funcs[fn]
            room = min(entry.capacity - st.n_sat - st.n_cached,
                       self._mem_room(node, fn))
            if room <= 0:
                continue
            k = min(remaining, room)
            decision_ms += FAST_PATH_MS
            place(node, k, decision_ms)
            self.metrics.fast += 1

        # 2) slow path: stale entries on fn's nodes, then other nodes
        if remaining > 0:
            cands = [n for n in self.cluster.nodes_with(fn)
                     if n.table.get(fn) is None or not n.table[fn].fresh]
            others = sorted(
                (n for n in self.cluster.nodes.values()
                 if fn not in n.funcs or n.funcs[fn].total == 0),
                key=lambda n: -n.n_instances())
            for node in cands + others:
                if remaining <= 0:
                    break
                if self._mem_room(node, fn) <= 0:
                    continue
                cap, ms = self._slow_capacity(node, fn, remaining)
                decision_ms += ms
                used_slow = True
                st = node.state(fn)
                room = min(cap - st.n_sat - st.n_cached,
                           self._mem_room(node, fn))
                if room <= 0:
                    continue
                k = min(remaining, room)
                place(node, k, decision_ms)
                self.metrics.slow += 1

        # 3) cluster scale-out: fresh empty node
        while remaining > 0:
            node = self._new_node()
            cap, ms = self._slow_capacity(node, fn, remaining)
            decision_ms += ms
            used_slow = True
            self.metrics.slow += 1
            room = min(max(cap, 1), self._mem_room(node, fn))
            if room <= 0:
                self.metrics.failed += remaining
                break
            place(node, min(remaining, room), decision_ms)

        self.metrics.decisions += 1
        self.metrics.sched_latencies.append(decision_ms)
        self.metrics.sched_time_ms += decision_ms
        return out

    # -- dual-staged scaling hooks: the base class's greedy release
    # -- stage drains least-loaded-first; __init__ installed the
    # -- table-bound logical-start stage (pipeline stages both) ----------


# ---------------------------------------------------------------------------
# Gsight-style: accurate model, inference on every scheduling decision
# ---------------------------------------------------------------------------


class GsightScheduler(BaseScheduler):
    """Same predictor quality as Jiagu but coupled prediction/decision:
    every instance triggers per-candidate-node inference on the critical
    path, with per-instance-granularity inputs (higher row counts).

    Feature assembly and inference go through the shared
    ``PredictionService`` (one self-constructed with the legacy v1
    schema when none is supplied), so Gsight sees the same schema /
    inference-engine selection as Jiagu."""

    name = "gsight"

    def __init__(self, cluster: Cluster, store: ProfileStore, qos: QoSStore,
                 predictor: PerfPredictor, max_candidates: int = 4,
                 service: Optional[PredictionService] = None):
        super().__init__(cluster, store, qos)
        self.predictor = predictor
        self.max_candidates = max_candidates
        self.service = service or PredictionService(
            predictor, store, qos, cluster.specs)

    @property
    def prediction_service(self) -> Optional[PredictionService]:
        return self.service

    def _check_node(self, node: Node, fn: str) -> Tuple[bool, float]:
        """Predict everyone's latency with one more fn instance; per-
        instance granularity: one row per *instance* (not per function)."""
        coloc = {g: (float(s.n_sat), float(s.n_cached))
                 for g, s in node.funcs.items() if s.total > 0}
        coloc[fn] = (coloc.get(fn, (0.0, 0.0))[0] + 1,
                     coloc.get(fn, (0.0, 0.0))[1])
        names, fn_rows, fn_bounds = self.service.rows_for_coloc(coloc,
                                                                node.res)
        rows, bounds = [], []
        for g, row, bound in zip(names, fn_rows, fn_bounds):
            for _ in range(int(coloc[g][0]) or 1):  # instance granularity
                rows.append(row)
                bounds.append(bound)
        t0 = time.perf_counter()
        pred = self.service.predict(np.stack(rows))
        ms = (time.perf_counter() - t0) * 1e3
        self.metrics.critical_inference_rows += len(rows)
        self.metrics.critical_inference_calls += 1
        return bool((pred <= np.asarray(bounds)).all()), ms

    def schedule(self, fn: str, count: int, now: float) -> List[Placement]:
        out: List[Placement] = []
        for _ in range(count):
            decision_ms = 0.0
            placed = False
            cands = sorted(self.cluster.nodes.values(),
                           key=lambda n: (fn not in n.funcs,
                                          -n.n_instances()))
            for node in cands[: self.max_candidates]:
                if self._mem_room(node, fn) <= 0:
                    continue
                ok, ms = self._check_node(node, fn)
                decision_ms += ms
                self.metrics.slow += 1
                if ok:
                    node.deploy(fn, 1)
                    out.append(Placement(node.id, 1, decision_ms))
                    placed = True
                    break
            if not placed:
                node = self._new_node()
                ok, ms = self._check_node(node, fn)
                decision_ms += ms
                self.metrics.slow += 1
                node.deploy(fn, 1)
                out.append(Placement(node.id, 1, decision_ms))
            self.metrics.decisions += 1
            self.metrics.instances_placed += 1
            self.metrics.sched_latencies.append(decision_ms)
            self.metrics.sched_time_ms += decision_ms
        return out


# ---------------------------------------------------------------------------
# Owl-style: historical colocation table, at most two functions per node
# ---------------------------------------------------------------------------


class OwlScheduler(BaseScheduler):
    """Historical-information scheduler: colocation combos it has *seen*
    behave well are reused; unknown combos fall back to requested-resource
    packing.  Only two distinct functions may share a node (the paper's
    stated limitation -> lower density)."""

    name = "owl"
    needs_idle_observe = True   # safe-set promotion learns from ok nodes

    def __init__(self, cluster: Cluster, store: ProfileStore, qos: QoSStore):
        super().__init__(cluster, store, qos)
        self.safe: set = set()     # {(fa, na, fb, nb)} observed-safe
        self.unsafe: set = set()
        self.profiled_combos = 0   # O(n^2 k) profiling-cost counter

    @staticmethod
    def _key(coloc: Dict[str, int]) -> tuple:
        items = sorted(coloc.items())
        return tuple(x for kv in items for x in kv)

    def _combo_after(self, node: Node, fn: str) -> Dict[str, int]:
        c = {g: s.total for g, s in node.funcs.items() if s.total > 0}
        c[fn] = c.get(fn, 0) + 1
        return c

    def _fits_requested(self, node: Node, spec: FunctionSpec) -> bool:
        return (node.cpu_requested(self.cluster.specs) + spec.cpu_req
                <= node.res.cpu_mcores
                and node.mem_used(self.cluster.specs) + spec.mem_req
                <= node.res.mem_mb)

    def schedule(self, fn: str, count: int, now: float) -> List[Placement]:
        spec = self.cluster.specs[fn]
        out: List[Placement] = []
        for _ in range(count):
            target = None
            # 1) known-safe overcommitted combos
            for node in sorted(self.cluster.nodes.values(),
                               key=lambda n: -n.n_instances()):
                combo = self._combo_after(node, fn)
                if len(combo) > 2 or self._mem_room(node, fn) <= 0:
                    continue
                key = self._key(combo)
                if key in self.safe and key not in self.unsafe:
                    target = node
                    break
            # 2) exploration within requested resources
            if target is None:
                for node in sorted(self.cluster.nodes.values(),
                                   key=lambda n: -n.n_instances()):
                    combo = self._combo_after(node, fn)
                    if len(combo) > 2:
                        continue
                    if self._key(combo) in self.unsafe:
                        continue
                    if self._fits_requested(node, spec):
                        target = node
                        break
            if target is None:
                target = self._new_node()
            target.deploy(fn, 1)
            out.append(Placement(target.id, 1, FAST_PATH_MS))
            self.metrics.decisions += 1
            self.metrics.instances_placed += 1
            self.metrics.fast += 1
            self.metrics.sched_latencies.append(FAST_PATH_MS)
            self.metrics.sched_time_ms += FAST_PATH_MS
        return out

    def observe(self, node: Node, ok: bool, now: float):
        combo = {g: s.total for g, s in node.funcs.items() if s.total > 0}
        if not combo or len(combo) > 2:
            return
        key = self._key(combo)
        if key not in self.safe and key not in self.unsafe:
            self.profiled_combos += 1
        if ok:
            self.safe.add(key)
        else:
            self.unsafe.add(key)
            self.safe.discard(key)


# ---------------------------------------------------------------------------
# Scheduler registry (the repro.platform name-based component selection)
# ---------------------------------------------------------------------------


@dataclass
class SchedulerBuildContext:
    """Everything a scheduler factory may need.  Factories take what
    they use and ignore the rest, so one registry signature serves
    table-driven, per-schedule-inference, and model-free schedulers."""

    cluster: Cluster
    store: ProfileStore
    qos: QoSStore
    specs: Dict[str, FunctionSpec]
    predictor: Optional[PerfPredictor] = None
    m_max: int = M_MAX_DEFAULT
    max_candidates: int = 4
    schema_version: int = 1
    retrain_every: Optional[int] = None
    #: schema-v2 services learn per-shape QoS margins from validation
    #: error instead of the fixed shape_margin (PlatformConfig
    #: prediction.learned_shape_margin)
    learned_shape_margin: bool = False
    #: harvesting-scheduler knobs (PlatformConfig scheduler section)
    harvest_headroom: float = 0.85
    qos_release_cooldown_s: float = 30.0


@dataclass(frozen=True)
class SchedulerEntry:
    """One registered scheduler: its factory plus the capability facts
    the platform needs at assembly time (instead of `isinstance` checks
    against concrete classes)."""

    name: str
    factory: Callable[[SchedulerBuildContext], BaseScheduler]
    needs_predictor: bool = False     # gets the world's trained forest
    dual_staged_default: bool = False  # opts into dual-staged scaling


_SCHEDULERS = Registry("scheduler")


def register_scheduler(name: str,
                       factory: Callable[[SchedulerBuildContext],
                                         BaseScheduler], *,
                       needs_predictor: bool = False,
                       dual_staged_default: bool = False,
                       overwrite: bool = False) -> SchedulerEntry:
    """Register a scheduler under ``name`` so benchmarks, examples and
    ``PlatformConfig`` manifests can select it by string."""
    return _SCHEDULERS.register(
        name, SchedulerEntry(name, factory, needs_predictor,
                             dual_staged_default), overwrite=overwrite)


def scheduler_entry(name: str) -> SchedulerEntry:
    return _SCHEDULERS.get(name)


def registered_schedulers() -> List[str]:
    return _SCHEDULERS.names()


def build_scheduler(name: str, ctx: SchedulerBuildContext) -> BaseScheduler:
    return scheduler_entry(name).factory(ctx)


def make_gsight_scheduler(ctx: SchedulerBuildContext,
                          cls: Optional[type] = None) -> GsightScheduler:
    """The one Gsight assembly (legacy class and pipeline stack both):
    a single place builds the PredictionService, so the two variants
    can never drift apart in service configuration — the placement-
    parity gate depends on that."""
    cls = cls or GsightScheduler
    return cls(
        ctx.cluster, ctx.store, ctx.qos, ctx.predictor,
        max_candidates=ctx.max_candidates,
        service=PredictionService(
            ctx.predictor, ctx.store, ctx.qos, ctx.specs,
            EngineConfig(m_max=ctx.m_max,
                         retrain_every=ctx.retrain_every,
                         learned_shape_margin=ctx.learned_shape_margin),
            schema=ctx.schema_version))


register_scheduler(
    "jiagu",
    lambda ctx: JiaguScheduler(ctx.cluster, ctx.store, ctx.qos,
                               ctx.predictor, m_max=ctx.m_max),
    needs_predictor=True, dual_staged_default=True)
register_scheduler("gsight", make_gsight_scheduler, needs_predictor=True)
register_scheduler(
    "k8s", lambda ctx: K8sScheduler(ctx.cluster, ctx.store, ctx.qos))
register_scheduler(
    "owl", lambda ctx: OwlScheduler(ctx.cluster, ctx.store, ctx.qos))
