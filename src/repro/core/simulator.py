"""Tick-driven cluster simulator — the "24-node OpenFaaS testbed" of §7.

Each 1-second tick: read trace RPS -> autoscale (dual-staged or
traditional) -> process async capacity updates -> route load (the
pluggable ``Router`` policy; default: equal split over saturated
instances, the paper's load-balancing router) -> measure ground-truth
latencies per (node, function) -> account QoS violations weighted by
requests -> sample density.  Training samples for the predictor's
incremental learning are collected on the fly (the paper's runtime
dataset maintenance).

``Simulation`` is the run loop the ``repro.platform`` facade owns;
construct it through ``Platform.build`` (or the ``build_simulation`` /
``scenario_simulation`` shims) to get validated configuration, registry
-selected components, and observer hooks.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .autoscaler import Autoscaler, ScalingConfig, ScalingMetrics
from .capacity import QoSStore
from .cluster import Cluster, Node
from .events import EventHub
from .interference import GroundTruth, NodeResources
from .metrics import Reservoir
from .predictor import PerfPredictor, build_features
from .prediction_service import get_schema
from .profiles import FunctionSpec, ProfileStore
from .scheduler import BaseScheduler, SchedMetrics
from .traces import Trace
from ..telemetry.spans import NULL_TRACER


class EqualSplitRouter:
    """The paper's load-balancing router: every saturated instance of a
    function receives an equal share of its traffic, so a node hosting
    ``n_sat`` of ``total_sat`` instances serves that fraction of the
    requests.  The default ``platform.Router`` policy.

    Routers may additionally implement the optional ``begin_tick``
    hook — the simulator calls it once per tick with the whole cluster
    before routing, so stateful policies (``LocalityRouter``) can plan
    cluster-wide shares; routers without the hook stay purely
    per-node."""

    name = "equal-split"

    def begin_tick(self, now: float, cluster: Cluster,
                   rps: Dict[str, float],
                   sat_totals: Dict[str, int],
                   specs: Dict[str, FunctionSpec]) -> None:
        pass

    def route(self, spec: FunctionSpec, fn_rps: float, node: Node,
              n_sat: float, total_sat: int) -> Tuple[float, float]:
        """Returns (per_instance_rps, requests_routed_to_node)."""
        return fn_rps / total_sat, fn_rps * (n_sat / total_sat)


class LocalityRouter:
    """Locality/affinity routing: a function's traffic prefers its
    *warm*, least-contended placements and spills the rest by score.

    Per tick (``begin_tick``) the router plans cluster-wide shares per
    function: nodes hosting its saturated instances are scored by
    contention (foreign instances per own instance — a node mostly
    dedicated to the function is its warmest, least-interfered home),
    and traffic waterfills the score order, loading each node's
    instances up to ``load_cap`` of their saturated throughput before
    spilling to the next.  Load beyond the capped cluster capacity is
    distributed proportionally to instance counts (the equal-split
    overload behaviour).  Totals are conserved: the requests routed
    across nodes sum to the function's RPS exactly as equal split does.

    Registered as ``"locality"`` in the router registry; A/B'd against
    ``EqualSplitRouter`` by ``benchmarks/large_cluster.py``."""

    name = "locality"

    def __init__(self, load_cap: float = 0.85):
        self.load_cap = load_cap
        self._share: Dict[Tuple[str, int], float] = {}

    def begin_tick(self, now: float, cluster: Cluster,
                   rps: Dict[str, float],
                   sat_totals: Dict[str, int],
                   specs: Dict[str, FunctionSpec]) -> None:
        self._share.clear()
        # per-node instance totals are shared across every function
        # planned this tick: contention inputs are identical between
        # functions, so one sum per hosting node replaces a re-scan per
        # (function, node) pair — same integers, bit-identical plans
        n_inst: Dict[int, int] = {}
        for fn, total_sat in sat_totals.items():
            fn_rps = rps.get(fn, 0.0)
            if total_sat <= 0 or fn_rps <= 1e-9:
                continue
            spec = specs[fn]
            nodes = [n for n in cluster.nodes_with(fn)
                     if n.funcs[fn].n_sat > 0]

            def contention(n: Node) -> float:
                own = n.funcs[fn]
                ni = n_inst.get(n.id)
                if ni is None:
                    ni = n_inst[n.id] = n.n_instances()
                return (ni - own.total) / max(own.n_sat, 1)

            order = sorted(nodes, key=lambda n: (contention(n), n.id))
            remaining = fn_rps
            for n in order:
                take = min(remaining, n.funcs[fn].n_sat
                           * spec.saturated_rps * self.load_cap)
                self._share[(fn, n.id)] = take
                remaining -= take
            if remaining > 1e-9:
                for n in order:
                    self._share[(fn, n.id)] += \
                        remaining * n.funcs[fn].n_sat / total_sat

    def route(self, spec: FunctionSpec, fn_rps: float, node: Node,
              n_sat: float, total_sat: int) -> Tuple[float, float]:
        reqs = self._share.get((spec.name, node.id))
        if reqs is None:
            # no begin_tick plan (direct use outside the simulator):
            # degrade to the equal split
            return fn_rps / total_sat, fn_rps * (n_sat / total_sat)
        return reqs / max(n_sat, 1e-9), reqs


@dataclass
class SimConfig:
    collect_samples: bool = True
    sample_every_s: int = 20
    seed: int = 0
    # capacity-solve path: True (default since the full-trace A/B parity
    # gate, tests/test_engine_parity.py) attaches a PredictionService to a
    # Jiagu scheduler (coalesced/cached/vectorized cluster-scale solving);
    # False keeps the legacy per-node path as the reference oracle.
    use_capacity_engine: bool = True
    # feature-schema version for the attached service: 1 = legacy
    # node-shape-blind vector (the parity oracle), 2 = node-shape-aware
    # (requires a predictor trained on v2 rows and the engine path)
    schema_version: int = 1
    # online incremental retraining: route runtime samples through
    # PredictionService.on_samples (retrain + epoch-invalidate + refresh
    # capacity tables during the run, all off the critical path)
    online_retrain: bool = False
    # samples between online retrains (None -> the predictor's own
    # retrain_every)
    retrain_every: Optional[int] = None
    # schema-v2 only: learn the per-shape QoS margin from per-shape
    # validation error instead of the fixed shape_margin formula
    learned_shape_margin: bool = False


@dataclass
class SimResult:
    name: str
    ticks: int
    requests: float = 0.0
    violated_requests: float = 0.0
    instance_seconds: float = 0.0
    node_seconds: float = 0.0
    nodes_peak: int = 0
    # bounded uniform sample of the per-tick density series (512-node
    # full traces would otherwise grow this without limit)
    density_series: Reservoir = field(default_factory=lambda: Reservoir(512))
    per_fn_violations: Dict[str, float] = field(default_factory=dict)
    per_fn_requests: Dict[str, float] = field(default_factory=dict)
    sched: Optional[SchedMetrics] = None
    scaling: Optional[ScalingMetrics] = None
    inference_rows: int = 0
    inference_calls: int = 0
    mean_inference_ms: float = 0.0
    # online-retraining accounting (deltas over this run's service stats;
    # background work, reported separately from the critical path)
    retrains: int = 0
    retrain_time_s: float = 0.0
    refresh_rows: int = 0
    refresh_time_s: float = 0.0
    stale_epoch_hits: int = 0
    # admission accounting (repro.admission; all-zero/empty when the
    # admission axis is off — the default)
    class_requests: Dict[str, float] = field(default_factory=dict)
    class_violations: Dict[str, float] = field(default_factory=dict)
    dropped_requests: float = 0.0
    queue_delay_s: Reservoir = field(default_factory=lambda: Reservoir(512))
    queue_depth_peak: float = 0.0
    vertical_grows: int = 0
    vertical_shrinks: int = 0

    @property
    def qos_violation_rate(self) -> float:
        return self.violated_requests / max(self.requests, 1e-9)

    def class_violation_rate(self) -> Dict[str, float]:
        """Per-SLO-class QoS violation rate (empty without admission)."""
        return {c: self.class_violations.get(c, 0.0)
                / max(self.class_requests.get(c, 0.0), 1e-9)
                for c in self.class_requests}

    @property
    def density(self) -> float:
        """Duration-weighted mean instances per active node."""
        return self.instance_seconds / max(self.node_seconds, 1e-9)

    def per_fn_violation_rate(self) -> Dict[str, float]:
        return {fn: self.per_fn_violations.get(fn, 0.0)
                / max(self.per_fn_requests.get(fn, 0.0), 1e-9)
                for fn in self.per_fn_requests}


class Simulation:
    def __init__(self, specs: Dict[str, FunctionSpec], trace: Trace,
                 scheduler: BaseScheduler, autoscaler: Autoscaler,
                 ground_truth: GroundTruth, store: ProfileStore,
                 qos: QoSStore, predictor: Optional[PerfPredictor] = None,
                 cfg: Optional[SimConfig] = None, *,
                 router=None, events: Optional[EventHub] = None):
        self.specs = specs
        self.trace = trace
        self.scheduler = scheduler
        self.autoscaler = autoscaler
        self.gt = ground_truth
        self.store = store
        self.qos = qos
        self.predictor = predictor
        self.cfg = cfg or SimConfig()
        self.router = router or EqualSplitRouter()
        self.events = events or EventHub()
        #: AdmissionController (repro.admission) wired by
        #: ``build_simulation`` when the admission axis is enabled;
        #: None (the default) keeps the run loop structurally identical
        #: to the pre-admission control plane.
        self.admission = None
        #: span tracer for the per-tick scheduling section; the no-op
        #: default keeps uninstrumented runs on the identical code path
        #: (spans only read state — see the observer-parity test)
        self.tracer = NULL_TRACER
        self.cluster = scheduler.cluster
        self._rng = np.random.default_rng(self.cfg.seed)
        if (self.cfg.use_capacity_engine and predictor is not None
                and scheduler.accepts_service
                and scheduler.prediction_service is None):
            from .prediction_service import EngineConfig, PredictionService
            scheduler.attach_service(PredictionService(
                predictor, store, qos, specs,
                EngineConfig(m_max=scheduler.m_max,
                             retrain_every=self.cfg.retrain_every,
                             learned_shape_margin=self.cfg
                             .learned_shape_margin),
                schema=self.cfg.schema_version))
        # the shared service (Jiagu's solver or Gsight's feature/predict
        # client); the legacy per-node path has none
        self._service = scheduler.prediction_service
        if self._service is None and predictor is not None:
            if self.cfg.schema_version != 1:
                raise ValueError(
                    "schema v2 requires the PredictionService path "
                    "(use_capacity_engine=True); the legacy per-node "
                    "solver only speaks the v1 feature layout")
            if self.cfg.online_retrain:
                raise ValueError(
                    "online_retrain requires a PredictionService "
                    "(use_capacity_engine=True); the legacy path has no "
                    "on_samples retraining loop")
        if (self._service is not None
                and self._service.schema.version != self.cfg.schema_version):
            raise ValueError(
                f"scheduler's service speaks schema "
                f"v{self._service.schema.version} but SimConfig requests "
                f"v{self.cfg.schema_version}; pass a matching "
                f"schema_version")

    # ------------------------------------------------------------------

    def run(self, duration_s: Optional[int] = None) -> SimResult:
        T = duration_s or self.trace.duration_s
        res = SimResult(name=self.scheduler.name, ticks=T)
        #: observers read the accumulating result mid-run (tick records
        #: carry cumulative QoS counters for offline outcome labelling)
        self.live_result = res
        svc0 = self._service.stats.snapshot() if self._service else {}
        for t in range(T):
            now = float(t)
            rps = {fn: self.trace.at(fn, t) for fn in self.trace.rps}
            # admission phase 1: arrivals enter the bounded queues and
            # the autoscaler's signal is derived from backlog state
            # (queue depth/age) instead of instantaneous rps
            if self.admission is not None:
                with self.tracer.span("admission") as sp:
                    signal = self.admission.enqueue(now, rps,
                                                    self.cluster)
                    if sp is not None:
                        sp.attrs["now"] = now
                        sp.attrs["queue_depth"] = round(
                            self.admission.queue_depth(), 3)
            else:
                signal = rps
            # async capacity updates flush BEFORE this tick's scheduling:
            # they were queued sub-millisecond work during the previous
            # (idle) second — the paper's "table always up-to-date when
            # scheduling" property (§4.3).
            with self.tracer.span("schedule") as sp:
                if sp is not None:
                    sm = self.scheduler.metrics
                    d0, p0 = sm.decisions, sm.instances_placed
                self.scheduler.on_tick(now)
                self.autoscaler.tick(now, signal)
                if sp is not None:
                    sp.attrs["now"] = now
                    sp.attrs["decisions"] = sm.decisions - d0
                    sp.attrs["placed"] = sm.instances_placed - p0
            # admission phase 2: backlog drains into the (possibly just
            # scaled) fleet; the measurement pass routes served traffic
            if self.admission is not None:
                rps = self.admission.drain(now, self.cluster, res)
            self._measure(now, rps, res)
            if (self.cfg.collect_samples and self.predictor is not None
                    and t % self.cfg.sample_every_s == 0):
                self._collect_sample()
            inst = self.cluster.total_instances()
            nodes = len(self.cluster.nodes)
            res.instance_seconds += inst
            res.node_seconds += nodes
            res.nodes_peak = max(res.nodes_peak, nodes)
            res.density_series.append(inst / nodes if nodes else 0.0)
            self.events.on_tick(now, self)
        res.sched = self.scheduler.metrics
        res.scaling = self.autoscaler.metrics
        if self.predictor is not None:
            res.inference_rows = self.predictor.inference_count
            res.inference_calls = self.predictor.inference_calls
            res.mean_inference_ms = self.predictor.mean_inference_ms
        if self._service is not None:
            # deltas over this run (services may be shared across sims)
            st = self._service.stats.snapshot()
            res.retrains = int(st["retrains"] - svc0.get("retrains", 0))
            res.retrain_time_s = \
                st["retrain_time_s"] - svc0.get("retrain_time_s", 0.0)
            res.refresh_rows = \
                int(st["refresh_rows"] - svc0.get("refresh_rows", 0))
            res.refresh_time_s = \
                st["refresh_time_s"] - svc0.get("refresh_time_s", 0.0)
            res.stale_epoch_hits = int(
                st["stale_epoch_hits"] - svc0.get("stale_epoch_hits", 0))
        if self.admission is not None:
            self.admission.finalize(res)
        self.events.on_result(res)
        return res

    def queue_depth_total(self) -> Optional[float]:
        """Fleet pending-request backlog, or None when the admission
        axis is off (observers use this to decorate tick records)."""
        return None if self.admission is None \
            else self.admission.queue_depth()

    # ------------------------------------------------------------------

    def _measure(self, now: float, rps: Dict[str, float], res: SimResult):
        # O(1) reads off the cluster's incremental per-function totals
        sat_totals = {fn: self.cluster.sat_count(fn) for fn in self.specs}
        measure_cluster(now, self.cluster, self.specs, rps, sat_totals,
                        self.router, self.scheduler, self.gt, self.qos,
                        res,
                        slo=None if self.admission is None
                        else self.admission.slo)

    def _collect_sample(self):
        """Runtime training-sample collection (training nodes, §3/§6):
        measure one random busy node's functions at saturated load and add
        (features, label) pairs to the predictor's dataset.

        Under schema v1 only standard-shape nodes (matching the ground
        truth's profiling node) are sampled: on a heterogeneous fleet,
        labels from larger nodes would mix a different pressure scale
        into a feature space that cannot express node size.  Schema v2
        encodes the node shape, so every busy node is sampleable and the
        rows are measured against the *hosting* node's capacity.

        With ``cfg.online_retrain`` the rows go through the service's
        ``on_samples`` hook — the online retraining policy fires during
        the run, bumping the forest epoch and refreshing all capacity
        tables off the critical path."""
        svc = self._service
        v2 = svc is not None and svc.schema.version >= 2
        busy = [n for n in self.cluster.nodes.values()
                if any(s.n_sat > 0 for s in n.funcs.values())
                and (v2 or n.res == self.gt.node)]
        if not busy:
            return
        node = busy[self._rng.integers(len(busy))]
        coloc = node.colocation(self.specs)
        counts = {g: (float(s[1]), float(s[2])) for g, s in coloc.items()}
        node_res = node.res if v2 else None
        Xs, ys = [], []
        for fn, (spec, n_sat, n_cached) in coloc.items():
            if n_sat <= 0:
                continue
            if svc is not None:
                x = svc.feature_row(fn, n_sat, n_cached, counts, node_res)
            else:
                neigh = [(self.store.profile(self.specs[g]), ns, nc)
                         for g, (ns, nc) in counts.items() if g != fn]
                x = build_features(self.qos.solo(spec),
                                   self.store.profile(spec), n_sat,
                                   n_cached, neigh)
            y = self.gt.measure(spec, coloc, load_frac=1.0,
                                node_res=node_res)
            Xs.append(x)
            ys.append(y)
        if not Xs:
            return
        if svc is not None and self.cfg.online_retrain:
            if svc.on_samples(Xs, ys) and self.scheduler.accepts_service:
                # retrain fired: every table entry in the cluster was
                # computed by the old forest — refresh them all in one
                # coalesced drain, billed to the service's refresh
                # counters (background work, not the critical path).
                # Only table-driven schedulers (Jiagu) need this; Gsight
                # predicts per-schedule and never reads node.table.
                svc.refresh_tables(list(self.cluster.nodes.values()),
                                   self.scheduler.m_max)
        else:
            for x, yv in zip(Xs, ys):
                self.predictor.add_sample(x, yv, retrain=False)


def measure_cluster(now: float, cluster: Cluster,
                    specs: Dict[str, FunctionSpec],
                    rps: Dict[str, float], sat_totals: Dict[str, int],
                    router, scheduler: BaseScheduler, gt: GroundTruth,
                    qos: QoSStore, res: SimResult,
                    slo: Optional[Dict[str, str]] = None) -> None:
    """One cluster's measurement pass, shared by ``Simulation._measure``
    and the cell-sharded event core (per cell, with cell-local routers
    and traffic shares).

    Dirty-set scan: only nodes hosting a function with live traffic can
    produce a measurement (a ground-truth latency draw needs
    ``n_sat > 0`` *and* ``fn_rps > 1e-9``), so the loop walks the union
    of the cluster's hosting indexes over active functions, ascending
    node id — the exact node order (and therefore the exact ground-truth
    RNG call sequence) the legacy full scan produced, minus nodes whose
    iteration would have been a complete no-op.  Skipped nodes would
    only have received ``observe(node, ok=True)``, a no-op for every
    scheduler except those that *learn from idleness* — they set
    ``needs_idle_observe`` (Owl's safe-set promotion) and keep the full
    scan."""
    # stateful routers (LocalityRouter) plan cluster-wide shares
    # once per tick; the hook is optional so purely per-node
    # policies stay three-line classes
    begin_tick = getattr(router, "begin_tick", None)
    if begin_tick is not None:
        begin_tick(now, cluster, rps, sat_totals, specs)
    if scheduler.needs_idle_observe:
        nodes = list(cluster.nodes.values())
    else:
        active: set = set()
        for fn, fn_rps in rps.items():
            if fn_rps > 1e-9:
                active.update(cluster.hosting_ids(fn))
        nodes = [cluster.nodes[nid] for nid in sorted(active)]
    for node in nodes:
        coloc = node.colocation(specs)
        if not coloc:
            continue
        node_ok = True
        for fn, (spec, n_sat, _nc) in coloc.items():
            if n_sat <= 0:
                continue
            total_sat = max(sat_totals.get(fn, 0), 1)
            fn_rps = rps.get(fn, 0.0)
            if fn_rps <= 1e-9:
                continue
            # routing policy: how much of fn's traffic this node's
            # instances serve (default: the paper's equal split)
            per_inst_rps, reqs = router.route(
                spec, fn_rps, node, n_sat, total_sat)
            load_frac = per_inst_rps / spec.saturated_rps
            lat = gt.measure(spec, coloc, load_frac, node_res=node.res)
            res.requests += reqs
            res.per_fn_requests[fn] = \
                res.per_fn_requests.get(fn, 0.0) + reqs
            violated = lat > qos.qos(spec)
            if violated:
                res.violated_requests += reqs
                res.per_fn_violations[fn] = \
                    res.per_fn_violations.get(fn, 0.0) + reqs
                node_ok = False
            if slo is not None:
                # per-SLO-class accounting (admission axis only)
                cls = slo.get(fn)
                if cls is not None:
                    res.class_requests[cls] = \
                        res.class_requests.get(cls, 0.0) + reqs
                    if violated:
                        res.class_violations[cls] = \
                            res.class_violations.get(cls, 0.0) + reqs
        scheduler.observe(node, node_ok, now)


# ---------------------------------------------------------------------------
# Offline dataset generation (profiling/training nodes, pre-deployment)
# ---------------------------------------------------------------------------


def generate_dataset(specs: Dict[str, FunctionSpec], gt: GroundTruth,
                     store: ProfileStore, qos: QoSStore, n_samples: int,
                     seed: int = 0, max_kinds: int = 4, max_count: int = 24,
                     include_solo: bool = True,
                     budget_range: Tuple[float, float] = (0.25, 1.6),
                     schema=None,
                     node_shapes: Optional[Sequence[NodeResources]] = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Random colocation scenarios measured against the ground truth —
    what the training nodes accumulate before the model converges.

    ``include_solo`` additionally sweeps each function alone at
    m = 1..6 — the profiling-node measurements the paper's solo-run
    methodology produces; without them the forest extrapolates poorly at
    the uncontended corner and under-reports capacities.

    ``budget_range`` bounds the sampled requested-CPU packing (in units
    of node capacity).  The default spans under-packed to ~1.6x
    overcommitted — the capacity solver's decision region for the paper's
    six-function world.  Large Zipf-populated scenarios pack small-slot
    functions deeper, so their worlds train with a wider range (the
    forest extrapolates *flat* past its training ceiling and would
    otherwise under-predict exactly where overcommitting gets risky).

    ``schema``/``node_shapes`` select the feature-schema version and,
    for schema v2, the fleet's node shapes: every sampled colocation is
    hosted on one of the shapes (first = the standard profiling shape),
    its rows carry the normalized shape block, and its labels are
    measured against the *hosting* shape's capacity — the per-node-shape
    training rows that stop big nodes inheriting small-node capacities.
    The v1 default path is bit-identical to the pre-schema dataset."""
    sch = get_schema(schema)
    if sch.version >= 2:
        return _generate_dataset_shaped(
            sch, specs, gt, store, qos, n_samples, seed, max_kinds,
            max_count, include_solo, budget_range, node_shapes)
    rng = np.random.default_rng(seed)
    names = sorted(specs)
    X, y = [], []
    max_kinds = min(max_kinds, len(names))
    node = gt.node
    if include_solo:
        for fn in names:
            spec = specs[fn]
            m_hi = max(2, int(1.3 * node.cpu_mcores / spec.cpu_req))
            for m in range(1, m_hi + 1):
                coloc = {fn: (spec, float(m), 0.0)}
                if not gt.fits(coloc):
                    break
                X.append(build_features(qos.solo(spec), store.profile(spec),
                                        float(m), 0.0, []))
                y.append(gt.measure(spec, coloc, load_frac=1.0))
    while len(y) < n_samples:
        # Sample colocations the way real nodes are packed: a total
        # requested-CPU budget spanning under-packed to ~1.6x overcommitted
        # (the capacity solver's decision region), split across kinds.
        # Uniform per-function counts would put most training mass on
        # absurd densities and starve the boundary.
        kinds = rng.choice(names, size=rng.integers(1, max_kinds + 1),
                           replace=False)
        budget = rng.uniform(*budget_range) * node.cpu_mcores
        shares = rng.dirichlet(np.ones(len(kinds)))
        coloc = {}
        for k, share in zip(kinds, shares):
            n_sat = int(round(share * budget / specs[k].cpu_req))
            n_sat = min(max(n_sat, 1), max_count)
            n_cached = int(rng.integers(0, 3))
            coloc[k] = (specs[k], float(n_sat), float(n_cached))
        if not gt.fits(coloc):
            continue
        counts = {g: (c[1], c[2]) for g, c in coloc.items()}
        for fn in kinds:
            spec = specs[fn]
            neigh = [(store.profile(specs[g]), ns, nc)
                     for g, (ns, nc) in counts.items() if g != fn]
            X.append(build_features(qos.solo(spec), store.profile(spec),
                                    counts[fn][0], counts[fn][1], neigh))
            y.append(gt.measure(spec, coloc, load_frac=1.0))
            if len(y) >= n_samples:
                break
    return np.stack(X), np.asarray(y, np.float64)


def _generate_dataset_shaped(sch, specs: Dict[str, FunctionSpec],
                             gt: GroundTruth, store: ProfileStore,
                             qos: QoSStore, n_samples: int, seed: int,
                             max_kinds: int, max_count: int,
                             include_solo: bool,
                             budget_range: Tuple[float, float],
                             node_shapes: Optional[Sequence[NodeResources]]
                             ) -> Tuple[np.ndarray, np.ndarray]:
    """Schema-v2 dataset: per-node-shape training rows.

    Counts and packing budgets scale with the hosting shape's CPU
    relative to the standard shape (``shapes[0]``), so a 2x node trains
    on colocations twice as deep — exactly the region where its v2
    capacities must exceed the standard node's."""
    rng = np.random.default_rng(seed)
    names = sorted(specs)
    shapes: List[NodeResources] = list(node_shapes or [gt.node])
    ref_cpu = shapes[0].cpu_mcores
    X, y = [], []
    max_kinds = min(max_kinds, len(names))
    if include_solo:
        for shape in shapes:
            for fn in names:
                spec = specs[fn]
                m_hi = max(2, int(1.3 * shape.cpu_mcores / spec.cpu_req))
                m_hi = min(m_hi, 2 * max(
                    1, int(round(max_count * shape.cpu_mcores / ref_cpu))))
                # subsample deep sweeps: big shapes would otherwise
                # contribute O(100) interference-free rows per function
                # and drown the colocation samples the capacity
                # boundary is learned from
                ms = range(1, m_hi + 1) if m_hi <= 16 else sorted(
                    set(np.linspace(1, m_hi, 16).round().astype(int)))
                for m in ms:
                    coloc = {fn: (spec, float(m), 0.0)}
                    if not gt.fits(coloc, node_res=shape):
                        break
                    X.append(sch.build_row(
                        qos.solo(spec), store.profile(spec), float(m), 0.0,
                        [], node_res=shape))
                    y.append(gt.measure(spec, coloc, load_frac=1.0,
                                        node_res=shape))
    while len(y) < n_samples:
        shape = shapes[rng.integers(len(shapes))]
        cap_count = max(1, int(round(max_count * shape.cpu_mcores
                                     / ref_cpu)))
        kinds = rng.choice(names, size=rng.integers(1, max_kinds + 1),
                           replace=False)
        budget = rng.uniform(*budget_range) * shape.cpu_mcores
        shares = rng.dirichlet(np.ones(len(kinds)))
        coloc = {}
        for k, share in zip(kinds, shares):
            n_sat = int(round(share * budget / specs[k].cpu_req))
            n_sat = min(max(n_sat, 1), cap_count)
            n_cached = int(rng.integers(0, 3))
            coloc[k] = (specs[k], float(n_sat), float(n_cached))
        if not gt.fits(coloc, node_res=shape):
            continue
        counts = {g: (c[1], c[2]) for g, c in coloc.items()}
        for fn in kinds:
            spec = specs[fn]
            neigh = [(store.profile(specs[g]), ns, nc)
                     for g, (ns, nc) in counts.items() if g != fn]
            X.append(sch.build_row(qos.solo(spec), store.profile(spec),
                                   counts[fn][0], counts[fn][1], neigh,
                                   node_res=shape))
            y.append(gt.measure(spec, coloc, load_frac=1.0,
                                node_res=shape))
            if len(y) >= n_samples:
                break
    return np.stack(X), np.asarray(y, np.float64)
