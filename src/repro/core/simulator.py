"""Tick-driven cluster simulator — the "24-node OpenFaaS testbed" of §7.

Each 1-second tick: read trace RPS -> autoscale (dual-staged or
traditional) -> process async capacity updates -> route load (equal split
over saturated instances, the paper's load-balancing router) -> measure
ground-truth latencies per (node, function) -> account QoS violations
weighted by requests -> sample density.  Training samples for the
predictor's incremental learning are collected on the fly (the paper's
runtime dataset maintenance).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .autoscaler import Autoscaler, ScalingConfig, ScalingMetrics
from .capacity import QoSStore
from .cluster import Cluster
from .interference import GroundTruth
from .predictor import PerfPredictor, build_features
from .profiles import FunctionSpec, ProfileStore
from .scheduler import BaseScheduler, SchedMetrics
from .traces import Trace


@dataclass
class SimConfig:
    collect_samples: bool = True
    sample_every_s: int = 20
    seed: int = 0
    # capacity-solve path: True (default since the full-trace A/B parity
    # gate, tests/test_engine_parity.py) attaches a CapacityEngine to a
    # Jiagu scheduler (coalesced/cached/vectorized cluster-scale solving);
    # False keeps the legacy per-node path as the reference oracle.
    use_capacity_engine: bool = True


@dataclass
class SimResult:
    name: str
    ticks: int
    requests: float = 0.0
    violated_requests: float = 0.0
    instance_seconds: float = 0.0
    node_seconds: float = 0.0
    nodes_peak: int = 0
    density_series: List[float] = field(default_factory=list)
    per_fn_violations: Dict[str, float] = field(default_factory=dict)
    per_fn_requests: Dict[str, float] = field(default_factory=dict)
    sched: Optional[SchedMetrics] = None
    scaling: Optional[ScalingMetrics] = None
    inference_rows: int = 0
    inference_calls: int = 0
    mean_inference_ms: float = 0.0

    @property
    def qos_violation_rate(self) -> float:
        return self.violated_requests / max(self.requests, 1e-9)

    @property
    def density(self) -> float:
        """Duration-weighted mean instances per active node."""
        return self.instance_seconds / max(self.node_seconds, 1e-9)

    def per_fn_violation_rate(self) -> Dict[str, float]:
        return {fn: self.per_fn_violations.get(fn, 0.0)
                / max(self.per_fn_requests.get(fn, 0.0), 1e-9)
                for fn in self.per_fn_requests}


class Simulation:
    def __init__(self, specs: Dict[str, FunctionSpec], trace: Trace,
                 scheduler: BaseScheduler, autoscaler: Autoscaler,
                 ground_truth: GroundTruth, store: ProfileStore,
                 qos: QoSStore, predictor: Optional[PerfPredictor] = None,
                 cfg: Optional[SimConfig] = None):
        self.specs = specs
        self.trace = trace
        self.scheduler = scheduler
        self.autoscaler = autoscaler
        self.gt = ground_truth
        self.store = store
        self.qos = qos
        self.predictor = predictor
        self.cfg = cfg or SimConfig()
        self.cluster = scheduler.cluster
        self._rng = np.random.default_rng(self.cfg.seed)
        if (self.cfg.use_capacity_engine and predictor is not None
                and getattr(scheduler, "engine", None) is None
                and hasattr(scheduler, "m_max")):
            from .capacity_engine import CapacityEngine, EngineConfig
            scheduler.engine = CapacityEngine(
                predictor, store, qos, specs,
                EngineConfig(m_max=scheduler.m_max))

    # ------------------------------------------------------------------

    def run(self, duration_s: Optional[int] = None) -> SimResult:
        T = duration_s or self.trace.duration_s
        res = SimResult(name=self.scheduler.name, ticks=T)
        for t in range(T):
            now = float(t)
            rps = {fn: self.trace.at(fn, t) for fn in self.trace.rps}
            # async capacity updates flush BEFORE this tick's scheduling:
            # they were queued sub-millisecond work during the previous
            # (idle) second — the paper's "table always up-to-date when
            # scheduling" property (§4.3).
            self.scheduler.on_tick(now)
            self.autoscaler.tick(now, rps)
            self._measure(now, rps, res)
            if (self.cfg.collect_samples and self.predictor is not None
                    and t % self.cfg.sample_every_s == 0):
                self._collect_sample()
            inst = self.cluster.total_instances()
            nodes = len(self.cluster.nodes)
            res.instance_seconds += inst
            res.node_seconds += nodes
            res.nodes_peak = max(res.nodes_peak, nodes)
            res.density_series.append(inst / nodes if nodes else 0.0)
        res.sched = self.scheduler.metrics
        res.scaling = self.autoscaler.metrics
        if self.predictor is not None:
            res.inference_rows = self.predictor.inference_count
            res.inference_calls = self.predictor.inference_calls
            res.mean_inference_ms = self.predictor.mean_inference_ms
        return res

    # ------------------------------------------------------------------

    def _measure(self, now: float, rps: Dict[str, float], res: SimResult):
        sat_totals = {fn: self.cluster.sat_count(fn) for fn in self.specs}
        for node in self.cluster.nodes.values():
            coloc = node.colocation(self.specs)
            if not coloc:
                continue
            node_ok = True
            for fn, (spec, n_sat, _nc) in coloc.items():
                if n_sat <= 0:
                    continue
                total_sat = max(sat_totals.get(fn, 0), 1)
                fn_rps = rps.get(fn, 0.0)
                if fn_rps <= 1e-9:
                    continue
                per_inst_rps = fn_rps / total_sat
                load_frac = per_inst_rps / spec.saturated_rps
                lat = self.gt.measure(spec, coloc, load_frac,
                                      node_res=node.res)
                reqs = fn_rps * (n_sat / total_sat)  # routed to this node
                res.requests += reqs
                res.per_fn_requests[fn] = \
                    res.per_fn_requests.get(fn, 0.0) + reqs
                if lat > self.qos.qos(spec):
                    res.violated_requests += reqs
                    res.per_fn_violations[fn] = \
                        res.per_fn_violations.get(fn, 0.0) + reqs
                    node_ok = False
            self.scheduler.observe(node, node_ok, now)

    def _collect_sample(self):
        """Runtime training-sample collection (training nodes, §3/§6):
        measure one random busy node's functions at saturated load and add
        (features, label) pairs to the predictor's dataset.

        Only standard-shape nodes (matching the ground truth's profiling
        node) are sampled: on a heterogeneous fleet, labels from larger
        nodes would mix a different pressure scale into a feature space
        that cannot express node size."""
        busy = [n for n in self.cluster.nodes.values()
                if any(s.n_sat > 0 for s in n.funcs.values())
                and n.res == self.gt.node]
        if not busy:
            return
        node = busy[self._rng.integers(len(busy))]
        coloc = node.colocation(self.specs)
        counts = {g: (float(s[1]), float(s[2])) for g, s in coloc.items()}
        for fn, (spec, n_sat, n_cached) in coloc.items():
            if n_sat <= 0:
                continue
            neigh = [(self.store.profile(self.specs[g]), ns, nc)
                     for g, (ns, nc) in counts.items() if g != fn]
            x = build_features(self.qos.solo(spec), self.store.profile(spec),
                               n_sat, n_cached, neigh)
            y = self.gt.measure(spec, coloc, load_frac=1.0)
            self.predictor.add_sample(x, y, retrain=False)


# ---------------------------------------------------------------------------
# Offline dataset generation (profiling/training nodes, pre-deployment)
# ---------------------------------------------------------------------------


def generate_dataset(specs: Dict[str, FunctionSpec], gt: GroundTruth,
                     store: ProfileStore, qos: QoSStore, n_samples: int,
                     seed: int = 0, max_kinds: int = 4, max_count: int = 24,
                     include_solo: bool = True,
                     budget_range: Tuple[float, float] = (0.25, 1.6)
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Random colocation scenarios measured against the ground truth —
    what the training nodes accumulate before the model converges.

    ``include_solo`` additionally sweeps each function alone at
    m = 1..6 — the profiling-node measurements the paper's solo-run
    methodology produces; without them the forest extrapolates poorly at
    the uncontended corner and under-reports capacities.

    ``budget_range`` bounds the sampled requested-CPU packing (in units
    of node capacity).  The default spans under-packed to ~1.6x
    overcommitted — the capacity solver's decision region for the paper's
    six-function world.  Large Zipf-populated scenarios pack small-slot
    functions deeper, so their worlds train with a wider range (the
    forest extrapolates *flat* past its training ceiling and would
    otherwise under-predict exactly where overcommitting gets risky)."""
    rng = np.random.default_rng(seed)
    names = sorted(specs)
    X, y = [], []
    max_kinds = min(max_kinds, len(names))
    node = gt.node
    if include_solo:
        for fn in names:
            spec = specs[fn]
            m_hi = max(2, int(1.3 * node.cpu_mcores / spec.cpu_req))
            for m in range(1, m_hi + 1):
                coloc = {fn: (spec, float(m), 0.0)}
                if not gt.fits(coloc):
                    break
                X.append(build_features(qos.solo(spec), store.profile(spec),
                                        float(m), 0.0, []))
                y.append(gt.measure(spec, coloc, load_frac=1.0))
    while len(y) < n_samples:
        # Sample colocations the way real nodes are packed: a total
        # requested-CPU budget spanning under-packed to ~1.6x overcommitted
        # (the capacity solver's decision region), split across kinds.
        # Uniform per-function counts would put most training mass on
        # absurd densities and starve the boundary.
        kinds = rng.choice(names, size=rng.integers(1, max_kinds + 1),
                           replace=False)
        budget = rng.uniform(*budget_range) * node.cpu_mcores
        shares = rng.dirichlet(np.ones(len(kinds)))
        coloc = {}
        for k, share in zip(kinds, shares):
            n_sat = int(round(share * budget / specs[k].cpu_req))
            n_sat = min(max(n_sat, 1), max_count)
            n_cached = int(rng.integers(0, 3))
            coloc[k] = (specs[k], float(n_sat), float(n_cached))
        if not gt.fits(coloc):
            continue
        counts = {g: (c[1], c[2]) for g, c in coloc.items()}
        for fn in kinds:
            spec = specs[fn]
            neigh = [(store.profile(specs[g]), ns, nc)
                     for g, (ns, nc) in counts.items() if g != fn]
            X.append(build_features(qos.solo(spec), store.profile(spec),
                                    counts[fn][0], counts[fn][1], neigh))
            y.append(gt.measure(spec, coloc, load_frac=1.0))
            if len(y) >= n_samples:
                break
    return np.stack(X), np.asarray(y, np.float64)
