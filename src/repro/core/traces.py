"""Invocation traces (paper §7.1) and the large-cluster scenario programs.

Four generated "real-world-like" trace sets with the statistical shape of
the Huawei Cloud production traces described in the paper and in SHEPHERD/
Azure analyses: diurnal base + random-walk drift + Poisson bursts + quiet
valleys; per-minute CV is high (short-interval unpredictability) while the
long-horizon pattern is moderate — exactly the regime where prewarming
prediction fails and dual-staged scaling wins.

Also the two extreme traces of §7.2: ``timer`` (fixed-frequency single
function — best case, all fast path) and ``flip`` (concurrency oscillates
0 <-> 1 — worst case, every schedule is a slow path).

Beyond the paper's four same-shaped sets, the large-cluster scenario suite
(``repro.core.scenarios``) draws on four additional regimes:

  * ``burst_storm_trace``   — correlated cross-function spikes: global
    storm events hit a random coherent subset of functions at once, the
    flash-crowd case where per-function prewarming prediction is blind.
  * ``diurnal_shift_trace`` — regional peak migration: functions belong
    to regions whose diurnal peaks drift across the trace, so yesterday's
    placement is always stale.
  * ``coldstart_churn_trace`` — heavy-tailed on/off churn (Pareto gaps):
    functions sit idle past any keep-alive horizon, then burst — the
    cold-start-dominated long tail.
  * ``azure_sparse_trace``  — Azure-Functions-like population: a few hot
    functions carry most load while a Zipf long tail is invoked sparsely
    (most functions see well under one request per minute).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

from .registry import Registry


@dataclass
class Trace:
    """Per-function RPS time series at 1 s resolution."""

    name: str
    rps: Dict[str, np.ndarray]   # function name -> (T,) float array
    duration_s: int

    def at(self, fn: str, t: int) -> float:
        """RPS of `fn` at second `t`; out-of-range `t` clamps to the
        trace's first/last second, unknown functions raise KeyError."""
        if fn not in self.rps:
            raise KeyError(
                f"function {fn!r} not in trace {self.name!r} "
                f"(has {sorted(self.rps)})")
        return float(self.rps[fn][min(max(t, 0), self.duration_s - 1)])


def realworld_trace(fn_names: List[str], duration_s: int = 3600,
                    seed: int = 0, scale_rps: Dict[str, float] | None = None,
                    name: str | None = None) -> Trace:
    """One trace set: each function gets an independent pattern whose mean
    concurrency varies between ~1 and ~20 instances."""
    rng = np.random.default_rng(seed)
    t = np.arange(duration_s, dtype=np.float64)
    out = {}
    for i, fn in enumerate(fn_names):
        base = rng.uniform(0.3, 1.0)
        period = rng.uniform(900, 2400)
        phase = rng.uniform(0, 2 * math.pi)
        diurnal = 0.5 * (1 + np.sin(2 * math.pi * t / period + phase))
        # random-walk drift, smoothed
        steps = rng.normal(0, 0.04, duration_s)
        walk = np.cumsum(steps)
        walk = (walk - walk.min()) / max(float(np.ptp(walk)), 1e-9)
        # bursts: Poisson arrivals of 30-120 s spikes, 2-6x amplitude
        burst = np.zeros(duration_s)
        n_bursts = rng.poisson(duration_s / 600)
        for _ in range(n_bursts):
            s = rng.integers(0, duration_s)
            w = int(rng.uniform(30, 120))
            amp = rng.uniform(1.5, 5.0)
            e = min(s + w, duration_s)
            ramp = np.linspace(1, 0, e - s) ** 0.5
            burst[s:e] = np.maximum(burst[s:e], amp * ramp)
        # quiet valleys (load -> near zero)
        quiet = np.ones(duration_s)
        for _ in range(rng.poisson(duration_s / 1200)):
            s = rng.integers(0, duration_s)
            w = int(rng.uniform(60, 240))
            quiet[s:min(s + w, duration_s)] = rng.uniform(0.02, 0.15)
        shape = (0.35 * diurnal + 0.35 * walk + 0.3 * base) * (1 + burst)
        shape = shape * quiet
        # per-second jitter (high short-interval CV)
        shape = shape * rng.lognormal(0, 0.25, duration_s)
        peak = (scale_rps or {}).get(fn, rng.uniform(40, 400))
        out[fn] = np.clip(shape * peak, 0.0, None)
    return Trace(name or f"trace-seed{seed}", out, duration_s)


def realworld_suite(fn_names: List[str], duration_s: int = 3600,
                    n_traces: int = 4) -> List[Trace]:
    """The paper's four real-world trace sets (different regions/seeds)."""
    return [realworld_trace(fn_names, duration_s, seed=100 + 7 * i,
                            name=f"Trace {chr(65 + i)}")
            for i in range(n_traces)]


def timer_trace(fn: str, duration_s: int = 600, period_s: int = 60,
                rps_per_inst: float = 20.0, n_inst: int = 4) -> Trace:
    """Best case (§7.2): one function scaled at a fixed frequency —
    alternates between n_inst and n_inst+2 instances every period."""
    rps = np.zeros(duration_s)
    for t in range(duration_s):
        k = (t // period_s) % 2
        rps[t] = rps_per_inst * (n_inst + 2 * k) * 0.95
    return Trace("timer", {fn: rps}, duration_s)


def burst_storm_trace(fn_names: List[str], duration_s: int = 3600,
                      seed: int = 0, scale_rps: Dict[str, float] | None = None,
                      storms_per_hour: float = 10.0, coherence: float = 0.6,
                      name: str | None = None) -> Trace:
    """Correlated cross-function spike storms.

    A quiet per-function base load is punctured by cluster-wide storm
    events at Poisson times; each storm recruits a random ``coherence``
    fraction of the population simultaneously with a 3-8x spike.  Unlike
    ``realworld_trace`` (independent per-function bursts), the spikes are
    *correlated*, so the scheduler faces synchronized scale-up demand —
    the flash-crowd regime where short-interval prediction fails.
    """
    rng = np.random.default_rng(seed)
    t = np.arange(duration_s, dtype=np.float64)
    base = {}
    for fn in fn_names:
        level = rng.uniform(0.15, 0.45)
        period = rng.uniform(1200, 3000)
        phase = rng.uniform(0, 2 * math.pi)
        base[fn] = level * (0.8 + 0.2 * np.sin(2 * math.pi * t / period
                                               + phase))
    n_storms = max(1, int(rng.poisson(storms_per_hour * duration_s / 3600)))
    storm = {fn: np.zeros(duration_s) for fn in fn_names}
    for _ in range(n_storms):
        s = int(rng.integers(0, duration_s))
        w = int(rng.uniform(20, 90))
        e = min(s + w, duration_s)
        amp = rng.uniform(3.0, 8.0)
        envelope = amp * np.linspace(1, 0, e - s) ** 0.7
        hit = rng.random(len(fn_names)) < coherence
        if not hit.any():
            hit[rng.integers(len(fn_names))] = True
        for fn, h in zip(fn_names, hit):
            if h:
                storm[fn][s:e] = np.maximum(storm[fn][s:e], envelope)
    out = {}
    for fn in fn_names:
        shape = base[fn] * (1 + storm[fn])
        shape = shape * rng.lognormal(0, 0.2, duration_s)
        peak = (scale_rps or {}).get(fn, rng.uniform(40, 400))
        out[fn] = np.clip(shape * peak, 0.0, None)
    return Trace(name or f"burst-storm-seed{seed}", out, duration_s)


def diurnal_shift_trace(fn_names: List[str], duration_s: int = 3600,
                        seed: int = 0,
                        scale_rps: Dict[str, float] | None = None,
                        n_regions: int = 3, period_s: float = 1800.0,
                        shift_frac: float = 1.0,
                        name: str | None = None) -> Trace:
    """Regional peak migration.

    Functions are assigned round-robin to ``n_regions`` regions whose
    diurnal peaks start out of phase and *drift* by ``shift_frac`` full
    periods over the trace (peak time migrating between regions, the
    follow-the-sun load pattern).  Placement tuned for one region's peak
    is systematically wrong an hour later.
    """
    rng = np.random.default_rng(seed)
    t = np.arange(duration_s, dtype=np.float64)
    n_regions = max(1, min(n_regions, len(fn_names)))
    # drifting phase: peak center moves shift_frac periods over the trace
    drift = 2 * math.pi * shift_frac * t / max(duration_s, 1)
    regional = []
    for r in range(n_regions):
        phase0 = 2 * math.pi * r / n_regions
        act = np.sin(2 * math.pi * t / period_s + phase0 + drift)
        # sharpen into a peaked activity bump, floor at a quiet baseline
        regional.append(0.08 + np.maximum(act, 0.0) ** 2)
    out = {}
    for i, fn in enumerate(fn_names):
        shape = regional[i % n_regions] * rng.uniform(0.8, 1.2)
        shape = shape * rng.lognormal(0, 0.15, duration_s)
        peak = (scale_rps or {}).get(fn, rng.uniform(40, 400))
        out[fn] = np.clip(shape * peak, 0.0, None)
    return Trace(name or f"diurnal-shift-seed{seed}", out, duration_s)


def coldstart_churn_trace(fn_names: List[str], duration_s: int = 3600,
                          seed: int = 0,
                          scale_rps: Dict[str, float] | None = None,
                          pareto_shape: float = 1.1, off_min_s: float = 30.0,
                          on_s: Tuple[float, float] = (5.0, 30.0),
                          name: str | None = None) -> Trace:
    """Heavy-tailed on/off churn — the cold-start-dominated regime.

    Each function alternates OFF gaps drawn from a Pareto distribution
    (shape ~1.1: infinite-variance heavy tail, so many gaps outlast any
    keep-alive window) and short ON bursts at a one-to-few-instance load.
    Capacity-table entries and cached instances are constantly evicted
    before the next arrival — sustained slow-path and cold-start pressure.
    """
    rng = np.random.default_rng(seed)
    out = {}
    for fn in fn_names:
        series = np.zeros(duration_s)
        level = rng.uniform(0.6, 1.4)
        t = float(rng.uniform(0, off_min_s))   # staggered first burst
        while t < duration_s:
            w = rng.uniform(*on_s)
            s, e = int(t), min(int(t + w), duration_s)
            series[s:e] = level * rng.uniform(0.7, 1.3)
            t += w
            t += off_min_s * float(rng.pareto(pareto_shape) + 1.0)
        peak = (scale_rps or {}).get(fn, rng.uniform(10, 60))
        out[fn] = np.clip(series * peak, 0.0, None)
    return Trace(name or f"coldstart-churn-seed{seed}", out, duration_s)


def azure_sparse_trace(fn_names: List[str], duration_s: int = 3600,
                       seed: int = 0,
                       scale_rps: Dict[str, float] | None = None,
                       hot_frac: float = 0.1, zipf_s: float = 1.5,
                       name: str | None = None) -> Trace:
    """Azure-Functions-like sparse-invocation long tail.

    A ``hot_frac`` head of the population carries smooth diurnal load;
    the remaining tail is invoked sparsely — isolated few-second episodes
    at Poisson times whose rates follow a Zipf law over the tail ranks,
    so most tail functions see well under one invocation per minute and
    their per-second series is almost entirely zero.
    """
    rng = np.random.default_rng(seed)
    t = np.arange(duration_s, dtype=np.float64)
    n_hot = max(1, int(round(hot_frac * len(fn_names))))
    out = {}
    for i, fn in enumerate(fn_names):
        if i < n_hot:
            period = rng.uniform(1500, 3600)
            phase = rng.uniform(0, 2 * math.pi)
            shape = (0.45 + 0.35 * np.sin(2 * math.pi * t / period + phase)
                     ) * rng.lognormal(0, 0.2, duration_s)
            peak = (scale_rps or {}).get(fn, rng.uniform(80, 400))
            out[fn] = np.clip(shape * peak, 0.0, None)
            continue
        rank = i - n_hot + 1
        # mean invocation episodes per hour, Zipf-decaying down the tail
        rate_per_hour = 30.0 / rank ** zipf_s + 0.2
        series = np.zeros(duration_s)
        n_events = rng.poisson(rate_per_hour * duration_s / 3600)
        peak = (scale_rps or {}).get(fn, rng.uniform(3, 15))
        for _ in range(n_events):
            s = int(rng.integers(0, duration_s))
            e = min(s + int(rng.uniform(2, 8)), duration_s)
            series[s:e] = peak * rng.uniform(0.5, 1.0)
        out[fn] = series
    return Trace(name or f"azure-sparse-seed{seed}", out, duration_s)


def replay_trace(path, name: str | None = None,
                 duration_s: int | None = None) -> Trace:
    """Replay a real invocation dump behind the same ``Trace`` interface.

    Reads an Azure/Huawei-style CSV with ``fn,timestamp,rps`` rows
    (timestamp in seconds, absolute or relative; a header line and
    ``#`` comments are skipped).  Timestamps are normalized to the
    earliest entry and bucketed at 1 s resolution; multiple records of
    one function landing in the same second accumulate.  Functions keep
    zero RPS outside their recorded entries, exactly like the sparse
    generated traces.
    """
    import os
    entries: List[Tuple[str, float, float]] = []
    first_data_line = True
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            parts = [p.strip() for p in line.split(",")]
            if len(parts) < 3:
                raise ValueError(
                    f"{path}:{i + 1}: expected 'fn,timestamp,rps', "
                    f"got {line!r}")
            try:
                ts, rps = float(parts[1]), float(parts[2])
            except ValueError:
                if first_data_line:      # tolerated header line (only)
                    first_data_line = False
                    continue
                raise ValueError(
                    f"{path}:{i + 1}: non-numeric timestamp/rps "
                    f"in {line!r}")
            first_data_line = False
            if not (math.isfinite(ts) and math.isfinite(rps)):
                raise ValueError(
                    f"{path}:{i + 1}: non-finite timestamp/rps "
                    f"in {line!r}")
            if rps < 0:
                raise ValueError(f"{path}:{i + 1}: negative rps {rps}")
            entries.append((parts[0], ts, rps))
    if not entries:
        raise ValueError(f"{path}: no trace entries")
    t0 = math.floor(min(ts for _, ts, _r in entries))
    T = duration_s or int(math.floor(max(ts for _, ts, _r in entries)
                                     - t0)) + 1
    out: Dict[str, np.ndarray] = {}
    for fn, ts, rps in entries:
        series = out.setdefault(fn, np.zeros(T))
        sec = int(ts - t0)
        if 0 <= sec < T:
            series[sec] += rps
    return Trace(name or os.path.splitext(os.path.basename(str(path)))[0],
                 out, T)


# ---------------------------------------------------------------------------
# Trace registry (the repro.platform name-based component selection)
# ---------------------------------------------------------------------------

_TRACES = Registry("trace")


def register_trace(name: str, builder=None, *, overwrite: bool = False):
    """Register a trace generator under ``name`` so benchmarks and
    examples select it by string.  Usable as a decorator:
    ``@register_trace("my-trace")``."""
    return _TRACES.register(name, builder, overwrite=overwrite)


def get_trace(name: str):
    return _TRACES.get(name)


def registered_traces() -> List[str]:
    return _TRACES.names()


def flip_trace(fns: List[str], duration_s: int = 600,
               period_s: int = 30, rps: float = 5.0) -> Trace:
    """Worst case (§7.2): each function's concurrency flips 0 <-> 1 so the
    capacity-table entry is evicted before every arrival -> all slow path.
    Functions flip out of phase so every arrival lands on a node whose
    table no longer has the entry."""
    out = {}
    for i, fn in enumerate(fns):
        series = np.zeros(duration_s)
        for t in range(duration_s):
            on = ((t + i * period_s // max(len(fns), 1)) // period_s) % 2
            series[t] = rps * on
        out[fn] = series
    return Trace("flip", out, duration_s)


for _name, _builder in (("realworld", realworld_trace),
                        ("burst-storm", burst_storm_trace),
                        ("diurnal-shift", diurnal_shift_trace),
                        ("coldstart-churn", coldstart_churn_trace),
                        ("azure-sparse", azure_sparse_trace),
                        ("timer", timer_trace),
                        ("flip", flip_trace),
                        ("replay", replay_trace)):
    register_trace(_name, _builder)
del _name, _builder
