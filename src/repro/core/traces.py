"""Invocation traces (paper §7.1).

Four generated "real-world-like" trace sets with the statistical shape of
the Huawei Cloud production traces described in the paper and in SHEPHERD/
Azure analyses: diurnal base + random-walk drift + Poisson bursts + quiet
valleys; per-minute CV is high (short-interval unpredictability) while the
long-horizon pattern is moderate — exactly the regime where prewarming
prediction fails and dual-staged scaling wins.

Also the two extreme traces of §7.2: ``timer`` (fixed-frequency single
function — best case, all fast path) and ``flip`` (concurrency oscillates
0 <-> 1 — worst case, every schedule is a slow path).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List

import numpy as np


@dataclass
class Trace:
    """Per-function RPS time series at 1 s resolution."""

    name: str
    rps: Dict[str, np.ndarray]   # function name -> (T,) float array
    duration_s: int

    def at(self, fn: str, t: int) -> float:
        return float(self.rps[fn][min(t, self.duration_s - 1)])


def realworld_trace(fn_names: List[str], duration_s: int = 3600,
                    seed: int = 0, scale_rps: Dict[str, float] | None = None,
                    name: str | None = None) -> Trace:
    """One trace set: each function gets an independent pattern whose mean
    concurrency varies between ~1 and ~20 instances."""
    rng = np.random.default_rng(seed)
    t = np.arange(duration_s, dtype=np.float64)
    out = {}
    for i, fn in enumerate(fn_names):
        base = rng.uniform(0.3, 1.0)
        period = rng.uniform(900, 2400)
        phase = rng.uniform(0, 2 * math.pi)
        diurnal = 0.5 * (1 + np.sin(2 * math.pi * t / period + phase))
        # random-walk drift, smoothed
        steps = rng.normal(0, 0.04, duration_s)
        walk = np.cumsum(steps)
        walk = (walk - walk.min()) / max(float(np.ptp(walk)), 1e-9)
        # bursts: Poisson arrivals of 30-120 s spikes, 2-6x amplitude
        burst = np.zeros(duration_s)
        n_bursts = rng.poisson(duration_s / 600)
        for _ in range(n_bursts):
            s = rng.integers(0, duration_s)
            w = int(rng.uniform(30, 120))
            amp = rng.uniform(1.5, 5.0)
            e = min(s + w, duration_s)
            ramp = np.linspace(1, 0, e - s) ** 0.5
            burst[s:e] = np.maximum(burst[s:e], amp * ramp)
        # quiet valleys (load -> near zero)
        quiet = np.ones(duration_s)
        for _ in range(rng.poisson(duration_s / 1200)):
            s = rng.integers(0, duration_s)
            w = int(rng.uniform(60, 240))
            quiet[s:min(s + w, duration_s)] = rng.uniform(0.02, 0.15)
        shape = (0.35 * diurnal + 0.35 * walk + 0.3 * base) * (1 + burst)
        shape = shape * quiet
        # per-second jitter (high short-interval CV)
        shape = shape * rng.lognormal(0, 0.25, duration_s)
        peak = (scale_rps or {}).get(fn, rng.uniform(40, 400))
        out[fn] = np.clip(shape * peak, 0.0, None)
    return Trace(name or f"trace-seed{seed}", out, duration_s)


def realworld_suite(fn_names: List[str], duration_s: int = 3600,
                    n_traces: int = 4) -> List[Trace]:
    """The paper's four real-world trace sets (different regions/seeds)."""
    return [realworld_trace(fn_names, duration_s, seed=100 + 7 * i,
                            name=f"Trace {chr(65 + i)}")
            for i in range(n_traces)]


def timer_trace(fn: str, duration_s: int = 600, period_s: int = 60,
                rps_per_inst: float = 20.0, n_inst: int = 4) -> Trace:
    """Best case (§7.2): one function scaled at a fixed frequency —
    alternates between n_inst and n_inst+2 instances every period."""
    rps = np.zeros(duration_s)
    for t in range(duration_s):
        k = (t // period_s) % 2
        rps[t] = rps_per_inst * (n_inst + 2 * k) * 0.95
    return Trace("timer", {fn: rps}, duration_s)


def flip_trace(fns: List[str], duration_s: int = 600,
               period_s: int = 30, rps: float = 5.0) -> Trace:
    """Worst case (§7.2): each function's concurrency flips 0 <-> 1 so the
    capacity-table entry is evicted before every arrival -> all slow path.
    Functions flip out of phase so every arrival lands on a node whose
    table no longer has the entry."""
    out = {}
    for i, fn in enumerate(fns):
        series = np.zeros(duration_s)
        for t in range(duration_s):
            on = ((t + i * period_s // max(len(fns), 1)) // period_s) % 2
            series[t] = rps * on
        out[fn] = series
    return Trace("flip", out, duration_s)
