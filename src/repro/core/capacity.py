"""Capacity calculation (paper Fig. 7) and the QoS store.

The capacity of function f on a node is the largest m such that, with m
saturated instances of f and the current saturated counts of every
neighbor, *every* colocated function's predicted latency still meets its
QoS.  All (m, colocated-function) scenarios are assembled into one feature
matrix and scored in a single batched inference — the paper's "once"
inference-cost accounting (its Fig. 17-b shows batching 100 inputs costs
~2 ms extra).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cluster import Node
from .predictor import PerfPredictor, build_features
from .profiles import FunctionSpec, ProfileStore

QOS_MULT = 1.2         # QoS = 120% of interference-free saturated tail lat.
M_MAX_DEFAULT = 24     # capacity search bound per (node, function)


@dataclass
class QoSStore:
    """Provider-established QoS targets (paper §3): multiple of the
    monitored solo saturated tail latency."""

    store: ProfileStore
    ground_truth: object
    mult: float = QOS_MULT

    def solo(self, spec: FunctionSpec) -> float:
        return self.store.solo_latency(spec, self.ground_truth)

    def qos(self, spec: FunctionSpec) -> float:
        return self.mult * self.solo(spec)


def _neighbor_feats(store: ProfileStore,
                    specs: Dict[str, FunctionSpec],
                    coloc: Dict[str, Tuple[float, float]],
                    exclude: str) -> List[Tuple[np.ndarray, float, float]]:
    return [(store.profile(specs[g]), ns, nc)
            for g, (ns, nc) in coloc.items() if g != exclude and ns + nc > 0]


def capacity_of(predictor: PerfPredictor, store: ProfileStore,
                qos: QoSStore, specs: Dict[str, FunctionSpec],
                coloc: Dict[str, Tuple[float, float]], fn: str,
                m_max: int = M_MAX_DEFAULT) -> Tuple[int, int]:
    """Capacity of `fn` under colocation `coloc` ({name: (n_sat, n_cached)};
    fn's own current counts, if present, are ignored — m replaces them).

    Returns (capacity, n_feature_rows) — the row count feeds the
    inference-cost accounting.  One predictor.predict call total.
    """
    spec = specs[fn]
    prof_f = store.profile(spec)
    solo_f = qos.solo(spec)
    others = {g: v for g, v in coloc.items() if g != fn}

    rows: List[np.ndarray] = []
    qos_bounds: List[float] = []
    for m in range(1, m_max + 1):
        # target fn itself at concurrency m
        neigh = _neighbor_feats(store, specs, others, exclude=fn)
        rows.append(build_features(solo_f, prof_f, m, 0.0, neigh))
        qos_bounds.append(qos.qos(spec))
        # every neighbor under fn@m
        for g, (ns, nc) in others.items():
            if ns + nc <= 0:
                continue
            gspec = specs[g]
            neigh_g = _neighbor_feats(store, specs, {**others, fn: (m, 0.0)},
                                      exclude=g)
            rows.append(build_features(qos.solo(gspec), store.profile(gspec),
                                       ns, nc, neigh_g))
            qos_bounds.append(qos.qos(gspec))

    X = np.stack(rows)
    pred = predictor.predict(X)
    ok = pred <= np.asarray(qos_bounds)

    per_m = len(ok) // m_max
    capacity = 0
    for m in range(1, m_max + 1):
        sl = ok[(m - 1) * per_m: m * per_m]
        if sl.all():
            capacity = m
        else:
            break
    return capacity, len(rows)


def update_capacity_table(predictor: PerfPredictor, store: ProfileStore,
                          qos: QoSStore, specs: Dict[str, FunctionSpec],
                          node: Node, m_max: int = M_MAX_DEFAULT,
                          engine=None) -> int:
    """Recompute every entry of a node's capacity table (the asynchronous
    update).  Returns the number of inference rows used.

    When a ``PredictionService`` is supplied via ``engine`` the solve is
    delegated to it (cached + coalesced + vectorized + node-shape-aware
    under schema v2); the per-function loop below is the schema-v1
    reference oracle the service's parity gates are tested against."""
    if engine is not None:
        return engine.update_node(node, m_max)
    from .cluster import CapEntry
    coloc = {g: (float(s.n_sat), float(s.n_cached))
             for g, s in node.funcs.items() if s.total > 0}
    total_rows = 0
    for fn in list(coloc):
        cap, rows = capacity_of(predictor, store, qos, specs, coloc, fn,
                                m_max)
        node.table[fn] = CapEntry(capacity=cap, fresh=True)
        total_rows += rows
    return total_rows
