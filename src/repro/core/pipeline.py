"""Composable scheduling-decision pipeline: filter -> score -> bind.

Jiagu's pre-decision scheduling works because prediction is decoupled
from placement (§4) — but until this module the *decision logic itself*
was monolithic: each scheduler hard-coded candidate enumeration,
admission rules, ordering, and deployment inside one ``schedule()``
body, so the platform registry could swap whole schedulers but nothing
inside one.  This module decomposes a placement decision into typed
stages that any policy can recombine:

  * ``PreDecision`` — a gate that consults capacity tables *before any
    per-request work* (the paper's pre-decision scheduling: Jiagu's
    fast path is one such gate, reusable by any table-driven policy),
  * ``NodeFilter``  — rejects a candidate with a *reason* (recorded in
    the decision trace),
  * ``NodeScorer``  — orders surviving candidates (higher is better;
    stable, so enumeration order breaks ties exactly like the legacy
    ``sorted(key=-x)`` loops),
  * ``Binder``      — commits instances to one node (and is the only
    stage allowed to run critical-path inference or mutate state),

composed by a ``SchedulingPipeline`` (a ``PreDecision`` gate, ordered
``CandidatePass``es, and a scale-out binder for fresh nodes).  Every
decision produces a ``DecisionTrace`` explaining the placement: which
candidates were filtered and why, the score terms, and the capacity
margin each binding consumed — emitted through the platform's
``on_schedule`` observer hook.

The four legacy schedulers are re-expressed as named stacks over the
same stages (``jiagu-pipeline``, ``gsight-pipeline``, ``k8s-pipeline``,
``owl-pipeline`` in the scheduler registry), gated by placement-parity
tests: stack and legacy ``schedule()`` must produce bit-identical
placements, density, QoS, and scheduling counters.  The dual-staged
scaling picks are stages too (``GreedyReleasePicker``,
``GreedyLogicalStartPicker``, ``TableBoundLogicalStartPicker``) —
``BaseScheduler`` delegates its ``ReleasePicker`` /
``LogicalStartPicker`` capabilities to swappable stage objects, so the
autoscaler's policies plug through the same surface
(``platform.register_stage`` / ``PlatformConfig.pipeline``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterable, List, Optional,
                    Protocol, Sequence, Tuple, runtime_checkable)

from .cluster import Node
from .prediction_service import REFERENCE_NODE
from .scheduler import (FAST_PATH_MS, BaseScheduler, GsightScheduler,
                        JiaguScheduler, K8sScheduler, OwlScheduler,
                        Placement, make_gsight_scheduler,
                        register_scheduler)

#: bound on per-decision trace detail (reason *counts* are always
#: complete; per-node samples and score terms are capped so 512-node
#: per-instance schedulers don't allocate O(nodes x instances) records)
TRACE_SAMPLES = 8
TRACE_SCORES = 16
TRACE_TOP_SCORES = 4

#: DecisionTrace serialization schema.  v1 records (no
#: ``schema_version`` key) carried score terms only; v2 adds the
#: per-candidate raw feature vectors + chosen node that make JSONL
#: streams a reusable offline training dataset (``repro.policy``); v3
#: adds the admission context (pending-queue depth/age, SLO class) the
#: ``repro.admission`` controller stamps on every scale-up decision.
#: Readers must keep accepting versionless (v1) and v2 records — the
#: v3 fields default to zero/None, so old streams load unchanged.
TRACE_SCHEMA_VERSION = 3


# ---------------------------------------------------------------------------
# Decision traces
# ---------------------------------------------------------------------------


@dataclass
class TraceBinding:
    """One committed placement inside a decision: which stage bound how
    many instances where, at what cumulative latency, and — for
    capacity-driven stages — the predicted capacity and the headroom
    (capacity margin) available before this binding consumed it."""

    stage: str
    node_id: int
    count: int
    latency_ms: float
    capacity: Optional[int] = None
    room_before: Optional[int] = None


def _jsonable(v):
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return str(v)


@dataclass
class DecisionTrace:
    """Why one scheduling decision placed what it placed.

    ``filtered`` aggregates rejection reasons (reason -> count, complete)
    while ``filtered_samples`` keeps the first few (node_id, reason)
    pairs; ``scored`` records the top-scored candidates per pass (capped
    at ``TRACE_SCORES`` entries).  ``to_dict`` is JSON-able, so traces
    round-trip through ``JsonlObserver`` artifacts."""

    scheduler: str
    fn: str
    now: float
    requested: int
    mode: str = "batched"          # or "per-instance"
    placed: int = 0
    failed: int = 0
    latency_ms: float = 0.0
    schema_version: int = TRACE_SCHEMA_VERSION
    pre_decision: List[TraceBinding] = field(default_factory=list)
    bindings: List[TraceBinding] = field(default_factory=list)
    filtered: Dict[str, int] = field(default_factory=dict)
    filtered_samples: List[Tuple[int, str]] = field(default_factory=list)
    scored: List[Tuple[str, int, Any]] = field(default_factory=list)
    #: per-candidate raw feature vectors captured at decision start
    #: (before any binding mutated the cluster): [(node_id, row), ...].
    #: Empty unless the scheduler opts into ``trace_features`` — the
    #: capture costs O(nodes) per decision and exists to feed
    #: ``repro.policy`` training, not routine observability.
    candidates: List[Tuple[int, List[float]]] = field(default_factory=list)
    #: node that received this decision's first binding (-1 when the
    #: decision failed outright) — the imitation-learning label
    chosen_node: int = -1
    #: admission context at decision time (schema v3) — pending-queue
    #: depth and oldest-request age for ``fn``, and its SLO class.
    #: Stamped by ``AdmissionController.stamp_trace``; zero/None when
    #: admission is off, so v2 consumers see only inert defaults.
    queue_depth: float = 0.0
    queue_age_s: float = 0.0
    slo_class: Optional[str] = None
    #: every node id any stage rejected during the decision (filters
    #: AND binder refusals — capacity solves, mem room).  Only
    #: populated under ``trace_features``: offline training masks these
    #: out, because a pointwise scorer cannot see binder feasibility
    #: and must not be penalized for ranking an infeasible node first
    #: (serving re-applies the same binder checks anyway).
    rejected: List[int] = field(default_factory=list)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["filtered_samples"] = [list(s) for s in self.filtered_samples]
        d["scored"] = [[p, n, _jsonable(s)] for p, n, s in self.scored]
        d["candidates"] = [[nid, list(row)] for nid, row in self.candidates]
        return d

    def summary(self) -> Dict[str, Any]:
        """Compact form for event streams: totals + reasons, no
        per-candidate detail — except the feature rows, which ride along
        when captured (they ARE the payload of a feature-tracing run)."""
        out = {
            "scheduler": self.scheduler, "fn": self.fn, "now": self.now,
            "requested": self.requested, "placed": self.placed,
            "failed": self.failed, "mode": self.mode,
            "latency_ms": round(self.latency_ms, 4),
            "schema_version": self.schema_version,
            "fast_bindings": len(self.pre_decision),
            "bindings": [[b.stage, b.node_id, b.count]
                         for b in self.bindings],
            "filtered": dict(self.filtered),
        }
        if self.slo_class is not None:
            out["queue_depth"] = round(self.queue_depth, 4)
            out["queue_age_s"] = round(self.queue_age_s, 4)
            out["slo_class"] = self.slo_class
        if self.candidates:
            out["candidates"] = [
                [nid, [round(float(v), 5) for v in row]]
                for nid, row in self.candidates]
            out["chosen_node"] = self.chosen_node
            out["rejected"] = sorted(set(self.rejected))
            out["scale_out"] = any(
                "scale-out" in b.stage for b in self.bindings)
        return out


# ---------------------------------------------------------------------------
# Stage protocols
# ---------------------------------------------------------------------------


@runtime_checkable
class NodeFilter(Protocol):
    """Rejects candidate nodes.  Returns a short reason string (recorded
    in the decision trace) or None to keep the node.  Must not mutate
    node or scheduler state."""

    def filter(self, ctx: "DecisionContext", node: Node) -> Optional[str]:
        ...


@runtime_checkable
class NodeScorer(Protocol):
    """Orders candidates: higher score binds first.  Scores may be any
    mutually comparable value (floats, tuples); sorting is stable, so
    ties keep cluster enumeration order — exactly the legacy
    ``sorted(key=-x)`` semantics."""

    def score(self, ctx: "DecisionContext", node: Node) -> Any:
        ...


@runtime_checkable
class Binder(Protocol):
    """Commits instances to one node; returns how many were placed
    (0 = rejected, with a traced reason).  The only stage allowed to
    run critical-path inference, bill scheduling time, or mutate
    cluster state."""

    def bind(self, ctx: "DecisionContext", node: Node) -> int:
        ...


@runtime_checkable
class PreDecision(Protocol):
    """Pre-decision gate: consume as much of the request as possible
    from already-computed capacity tables before any per-request work
    runs (the paper's pre-decision scheduling)."""

    def gate(self, ctx: "DecisionContext") -> None:
        ...


# ---------------------------------------------------------------------------
# Decision context
# ---------------------------------------------------------------------------


class DecisionContext:
    """Mutable state of one ``schedule(fn, count, now)`` decision as it
    flows through the pipeline.  Stages read candidates and commit
    placements through it; it keeps the latency/metrics accounting
    bit-identical to the legacy schedulers."""

    __slots__ = ("sched", "cluster", "metrics", "fn", "count", "now",
                 "remaining", "decision_ms", "placements", "trace",
                 "_mem_used")

    def __init__(self, sched: BaseScheduler, fn: str, count: int,
                 now: float, trace: Optional[DecisionTrace]):
        self.sched = sched
        self.cluster = sched.cluster
        self.metrics = sched.metrics
        self.fn = fn
        self.count = count
        self.now = now
        self.remaining = count
        self.decision_ms = 0.0
        self.placements: List[Placement] = []
        self.trace = trace
        # per-decision memo of node.mem_used: filters/scorers/binders
        # re-ask for the same node's headroom many times per decision
        # (every pass, every per-instance re-run) while its counts only
        # change through place(), which invalidates the entry
        self._mem_used: Dict[int, float] = {}

    @property
    def spec(self):
        return self.cluster.specs[self.fn]

    def mem_room(self, node: Node) -> int:
        used = self._mem_used.get(node.id)
        if used is None:
            used = self._mem_used[node.id] = \
                node.mem_used(self.cluster.specs)
        spec = self.cluster.specs[self.fn]
        return max(0, int((node.res.mem_mb - used) // spec.mem_req))

    def add_ms(self, ms: float) -> None:
        self.decision_ms += ms

    def reject(self, node: Node, reason: str) -> None:
        t = self.trace
        if t is None:
            return
        t.filtered[reason] = t.filtered.get(reason, 0) + 1
        if len(t.filtered_samples) < TRACE_SAMPLES:
            t.filtered_samples.append((node.id, reason))
        if self.sched.trace_features:
            t.rejected.append(node.id)

    def place(self, node: Node, k: int, stage: str, *,
              capacity: Optional[int] = None,
              room_before: Optional[int] = None,
              pre: bool = False) -> None:
        """Commit ``k`` instances of ``fn`` to ``node`` at the current
        cumulative decision latency (the legacy ``place()`` closure)."""
        node.deploy(self.fn, k)
        self._mem_used.pop(node.id, None)   # memoized headroom is stale
        self.placements.append(Placement(node.id, k, self.decision_ms))
        self.remaining -= k
        self.metrics.instances_placed += k
        self.sched.on_place(node, k, self.now, self.decision_ms)
        t = self.trace
        if t is not None:
            t.placed += k
            rec = TraceBinding(stage, node.id, k, self.decision_ms,
                               capacity, room_before)
            (t.pre_decision if pre else t.bindings).append(rec)


# ---------------------------------------------------------------------------
# Candidate features (the repro.policy training input schema)
# ---------------------------------------------------------------------------

#: fixed-width per-candidate feature layout, version-locked to
#: ``TRACE_SCHEMA_VERSION``: training datasets, stored policies and the
#: serving-time scorer all key off this tuple, so a layout change must
#: bump the trace schema
CANDIDATE_FEATURES = (
    "has_fn",             # node already hosts the function
    "fn_n_sat",           # its saturated instances of fn
    "fn_n_cached",        # its cached instances of fn
    "n_instances",        # total instances on the node
    "n_functions",        # distinct live functions on the node
    "mem_room",           # instances of fn the free memory still fits
    "cpu_requested_frac",  # requested CPU / node CPU (overcommit depth)
    "mem_used_frac",      # used memory / node memory
    "table_capacity",     # capacity-table entry for fn (-1 = absent)
    "table_fresh",        # 1 when that entry is fresh
    "table_room",         # entry.capacity - n_sat - n_cached (-1 absent)
    "cpu_norm",           # node CPU vs the reference profiling shape
    "mem_norm",           # node memory vs the reference shape
    "requested",          # instances this decision is placing
)


def candidate_feature_row(ctx: DecisionContext,
                          node: Node) -> List[float]:
    """One candidate node's raw feature vector for the decision in
    ``ctx`` — the row DecisionTrace JSONL records carry (schema v2) and
    the learned scorer consumes at serving time.  Read-only: the same
    cluster state the filters/scorers see, captured before any binding
    mutates it."""
    specs = ctx.cluster.specs
    st = node.funcs.get(ctx.fn)
    n_sat = float(st.n_sat) if st is not None else 0.0
    n_cached = float(st.n_cached) if st is not None else 0.0
    entry = node.table.get(ctx.fn)
    cap = float(entry.capacity) if entry is not None else -1.0
    fresh = 1.0 if entry is not None and entry.fresh else 0.0
    room = (entry.capacity - n_sat - n_cached) \
        if entry is not None else -1.0
    return [
        1.0 if st is not None and st.total > 0 else 0.0,
        n_sat,
        n_cached,
        float(node.n_instances()),
        float(sum(1 for s in node.funcs.values() if s.total > 0)),
        float(ctx.mem_room(node)),
        node.cpu_requested(specs) / node.res.cpu_mcores,
        node.mem_used(specs) / node.res.mem_mb,
        cap,
        fresh,
        float(room),
        node.res.cpu_mcores / REFERENCE_NODE.cpu_mcores,
        node.res.mem_mb / REFERENCE_NODE.mem_mb,
        float(ctx.count),
    ]


# ---------------------------------------------------------------------------
# Candidate passes + the pipeline
# ---------------------------------------------------------------------------


def all_nodes(ctx: DecisionContext) -> Iterable[Node]:
    return ctx.cluster.nodes.values()


def nodes_with_fn(ctx: DecisionContext) -> Iterable[Node]:
    return ctx.cluster.nodes_with(ctx.fn)


@dataclass
class CandidatePass:
    """One filter -> score -> bind sweep over a candidate source.

    ``max_candidates`` truncates *after* scoring (Gsight's top-k
    fan-out); binders applied in score order until the pass places (per
    -instance mode) or the request drains (batched mode)."""

    name: str
    binder: Binder
    filters: Sequence[NodeFilter] = ()
    scorer: Optional[NodeScorer] = None
    source: Callable[[DecisionContext], Iterable[Node]] = all_nodes
    max_candidates: Optional[int] = None

    def candidates(self, ctx: DecisionContext) -> List[Node]:
        keep: List[Node] = []
        for node in self.source(ctx):
            reason = None
            for f in self.filters:
                reason = f.filter(ctx, node)
                if reason is not None:
                    break
            if reason is not None:
                ctx.reject(node, reason)
                continue
            keep.append(node)
        if self.scorer is not None:
            scorer = self.scorer
            # batching scorers (the learned policy's jitted forward)
            # score the whole surviving candidate set in one call;
            # plain scorers stay one-node functions
            batch = getattr(scorer, "score_batch", None)
            scores = batch(ctx, keep) if batch is not None \
                else [scorer.score(ctx, n) for n in keep]
            # stable descending order: ties keep enumeration order,
            # exactly the legacy sorted(key=-x) semantics
            order = sorted(range(len(keep)), key=scores.__getitem__,
                           reverse=True)
            keep = [keep[i] for i in order]
            t = ctx.trace
            if t is not None and len(t.scored) < TRACE_SCORES:
                for rank, i in enumerate(order[:TRACE_TOP_SCORES]):
                    if len(t.scored) >= TRACE_SCORES:
                        break
                    t.scored.append((self.name, keep[rank].id,
                                     scores[i]))
        if self.max_candidates is not None:
            keep = keep[: self.max_candidates]
        return keep


@dataclass
class SchedulingPipeline:
    """A complete decision policy: optional pre-decision gate, ordered
    candidate passes, and a scale-out binder for fresh nodes.

    ``per_instance=False`` (batched, Jiagu-style) drains the whole
    request through each pass in turn and accounts one decision;
    ``per_instance=True`` (K8s/Owl/Gsight-style) re-runs the passes for
    every instance, re-enumerating and re-scoring candidates each time,
    and accounts one decision per instance — both reproduce the legacy
    schedulers' metric granularity exactly."""

    passes: List[CandidatePass]
    scale_out: Binder
    pre_decision: Optional[PreDecision] = None
    per_instance: bool = False

    def run(self, sched: BaseScheduler, fn: str, count: int,
            now: float) -> List[Placement]:
        trace = DecisionTrace(
            sched.name, fn, now, count,
            mode="per-instance" if self.per_instance else "batched") \
            if sched.trace_decisions else None
        ctx = DecisionContext(sched, fn, count, now, trace)
        if trace is not None and sched.trace_features:
            # snapshot every node's raw feature row BEFORE any stage
            # mutates the cluster: this is the training input the
            # decision was actually made against (repro.policy)
            trace.candidates = [
                (node.id, candidate_feature_row(ctx, node))
                for node in ctx.cluster.nodes.values()]
        if self.per_instance:
            self._run_per_instance(ctx)
        else:
            self._run_batched(ctx)
        if trace is not None:
            first = trace.pre_decision[0] if trace.pre_decision else \
                trace.bindings[0] if trace.bindings else None
            trace.chosen_node = first.node_id if first is not None else -1
            sched.last_trace = trace
        return ctx.placements

    # -- batched (Jiagu-style): one decision for the whole request -------

    def _run_batched(self, ctx: DecisionContext) -> None:
        m = ctx.metrics
        if self.pre_decision is not None and ctx.remaining > 0:
            self.pre_decision.gate(ctx)
        for p in self.passes:
            if ctx.remaining <= 0:
                break
            for node in p.candidates(ctx):
                if ctx.remaining <= 0:
                    break
                p.binder.bind(ctx, node)
        while ctx.remaining > 0:
            node = ctx.sched._new_node()
            if self.scale_out.bind(ctx, node) <= 0:
                m.failed += ctx.remaining
                if ctx.trace is not None:
                    ctx.trace.failed = ctx.remaining
                break
        m.decisions += 1
        m.sched_latencies.append(ctx.decision_ms)
        m.sched_time_ms += ctx.decision_ms
        if ctx.trace is not None:
            ctx.trace.latency_ms += ctx.decision_ms

    # -- per-instance (K8s/Owl/Gsight-style) -----------------------------

    def _run_per_instance(self, ctx: DecisionContext) -> None:
        m = ctx.metrics
        total_ms = 0.0
        while ctx.remaining > 0:
            ctx.decision_ms = 0.0
            bound = False
            for p in self.passes:
                for node in p.candidates(ctx):
                    if p.binder.bind(ctx, node) > 0:
                        bound = True
                        break
                if bound:
                    break
            if not bound:
                # legacy semantics: a fresh node always absorbs the
                # instance (no capacity refusal on the per-instance
                # baselines)
                self.scale_out.bind(ctx, ctx.sched._new_node())
            m.decisions += 1
            m.sched_latencies.append(ctx.decision_ms)
            m.sched_time_ms += ctx.decision_ms
            total_ms += ctx.decision_ms
        if ctx.trace is not None:
            ctx.trace.latency_ms += total_ms


class PipelineHostMixin:
    """Turns any ``BaseScheduler`` subclass into a pipeline host:
    ``schedule()`` runs the composed ``SchedulingPipeline`` instead of
    a monolithic body.  Subclasses implement ``build_pipeline()`` (and
    may override ``on_place`` for post-placement bookkeeping, e.g.
    Jiagu's async capacity-update queueing)."""

    _pipeline: Optional[SchedulingPipeline] = None

    @property
    def pipeline(self) -> SchedulingPipeline:
        if self._pipeline is None:
            self._pipeline = self.build_pipeline()
        return self._pipeline

    def build_pipeline(self) -> SchedulingPipeline:
        raise NotImplementedError

    def schedule(self, fn: str, count: int, now: float) -> List[Placement]:
        return self.pipeline.run(self, fn, count, now)


# ---------------------------------------------------------------------------
# Reusable stages: Jiagu's capacity-table lookup
# ---------------------------------------------------------------------------


class CapacityTableGate:
    """Jiagu's fast path as a ``PreDecision`` gate: place co-arriving
    instances on nodes whose *fresh* capacity-table entries still show
    headroom, at table-lookup cost (``FAST_PATH_MS``), before any
    critical-path inference.  Optional ``filters`` let derived policies
    (harvesting's QoS cooldown) veto gate candidates."""

    name = "capacity-table"

    def __init__(self, filters: Sequence[NodeFilter] = ()):
        self.filters = tuple(filters)

    def gate(self, ctx: DecisionContext) -> None:
        fn = ctx.fn
        for node in sorted(ctx.cluster.nodes_with(fn),
                           key=lambda n: -n.funcs[fn].n_sat):
            if ctx.remaining <= 0:
                break
            vetoed = False
            for f in self.filters:
                reason = f.filter(ctx, node)
                if reason is not None:
                    ctx.reject(node, reason)
                    vetoed = True
                    break
            if vetoed:
                continue
            entry = node.table.get(fn)
            if entry is None or not entry.fresh:
                ctx.reject(node, "stale-table")
                continue
            st = node.funcs[fn]
            room = min(entry.capacity - st.n_sat - st.n_cached,
                       ctx.mem_room(node))
            if room <= 0:
                ctx.reject(node, "no-table-headroom")
                continue
            k = min(ctx.remaining, room)
            ctx.add_ms(FAST_PATH_MS)
            ctx.place(node, k, self.name, capacity=entry.capacity,
                      room_before=room, pre=True)
            ctx.metrics.fast += 1


class StaleTableFilter:
    """Keep only nodes whose capacity entry for fn is absent or stale
    (fresh entries were already drained by the pre-decision gate)."""

    name = "stale-table"

    def filter(self, ctx: DecisionContext, node: Node) -> Optional[str]:
        entry = node.table.get(ctx.fn)
        if entry is not None and entry.fresh:
            return "fresh-table"
        return None


class NotRunningFilter:
    """Keep only nodes not currently running fn (the slow path's
    spread-to-other-nodes sweep)."""

    name = "not-running"

    def filter(self, ctx: DecisionContext, node: Node) -> Optional[str]:
        st = node.funcs.get(ctx.fn)
        if st is not None and st.total > 0:
            return "already-running"
        return None


class MemRoomFilter:
    """Reject nodes with no (non-overcommitted) memory headroom."""

    name = "mem-room"

    def filter(self, ctx: DecisionContext, node: Node) -> Optional[str]:
        if ctx.mem_room(node) <= 0:
            return "no-mem-room"
        return None


class InstanceCountScorer:
    """Most-packed first (the legacy ``-n_instances()`` orderings)."""

    name = "instance-count"

    def score(self, ctx: DecisionContext, node: Node) -> float:
        return node.n_instances()


class JiaguSlowBinder:
    """Jiagu's slow path for one node: critical-path capacity solve
    (billed), place up to the predicted headroom."""

    name = "jiagu-slow"

    def bind(self, ctx: DecisionContext, node: Node) -> int:
        if ctx.mem_room(node) <= 0:
            ctx.reject(node, "no-mem-room")
            return 0
        cap, ms = ctx.sched._slow_capacity(node, ctx.fn, ctx.remaining)
        ctx.add_ms(ms)
        st = node.state(ctx.fn)
        room = min(cap - st.n_sat - st.n_cached, ctx.mem_room(node))
        if room <= 0:
            ctx.reject(node, "capacity-exhausted")
            return 0
        k = min(ctx.remaining, room)
        ctx.place(node, k, self.name, capacity=cap, room_before=room)
        ctx.metrics.slow += 1
        return k


class JiaguScaleOutBinder:
    """Jiagu's cluster scale-out: solve the fresh node's capacity
    (billed to the slow path), refuse only when even an empty node
    cannot host the function."""

    name = "jiagu-scale-out"

    def bind(self, ctx: DecisionContext, node: Node) -> int:
        cap, ms = ctx.sched._slow_capacity(node, ctx.fn, ctx.remaining)
        ctx.add_ms(ms)
        ctx.metrics.slow += 1
        room = min(max(cap, 1), ctx.mem_room(node))
        if room <= 0:
            ctx.reject(node, "scale-out-infeasible")
            return 0
        k = min(ctx.remaining, room)
        ctx.place(node, k, self.name, capacity=cap, room_before=room)
        return k


# ---------------------------------------------------------------------------
# Reusable stages: Gsight's per-request prediction
# ---------------------------------------------------------------------------


class WarmAffinityScorer:
    """Nodes already running fn first, most-packed first within each
    group (Gsight's candidate ordering)."""

    name = "warm-affinity"

    def score(self, ctx: DecisionContext, node: Node) -> Tuple[bool, int]:
        return (ctx.fn in node.funcs, node.n_instances())


class GsightAdmitBinder:
    """Per-request prediction on the critical path: one inference pass
    over the node's whole colocation (per-instance granularity) admits
    or rejects the placement."""

    name = "gsight-admit"

    def bind(self, ctx: DecisionContext, node: Node) -> int:
        if ctx.mem_room(node) <= 0:
            ctx.reject(node, "no-mem-room")
            return 0
        ok, ms = ctx.sched._check_node(node, ctx.fn)
        ctx.add_ms(ms)
        ctx.metrics.slow += 1
        if not ok:
            ctx.reject(node, "predicted-qos-violation")
            return 0
        ctx.place(node, 1, self.name)
        return 1


class GsightScaleOutBinder:
    """Fresh-node fallback: still pays the prediction (the legacy
    accounting), then deploys regardless — an empty node is the best
    available option."""

    name = "gsight-scale-out"

    def bind(self, ctx: DecisionContext, node: Node) -> int:
        _ok, ms = ctx.sched._check_node(node, ctx.fn)
        ctx.add_ms(ms)
        ctx.metrics.slow += 1
        ctx.place(node, 1, self.name)
        return 1


# ---------------------------------------------------------------------------
# Reusable stages: requested-resource packing (K8s) + Owl's grouping
# ---------------------------------------------------------------------------


class RequestedFitFilter:
    """Kubernetes admission: requested CPU and memory must fit without
    overcommitment."""

    name = "requested-fit"

    def filter(self, ctx: DecisionContext, node: Node) -> Optional[str]:
        if not ctx.sched._fits(node, ctx.spec):
            return "requested-overcommit"
        return None


class RequestedCpuScorer:
    """Most-allocated first (default kube-scheduler bin-packing-ish)."""

    name = "requested-cpu"

    def score(self, ctx: DecisionContext, node: Node) -> float:
        return node.cpu_requested(ctx.cluster.specs)


class DeployOneBinder:
    """Model-free deployment of a single instance at table-lookup cost
    (K8s and Owl placements)."""

    name = "deploy-one"

    def bind(self, ctx: DecisionContext, node: Node) -> int:
        ctx.add_ms(FAST_PATH_MS)
        ctx.place(node, 1, self.name)
        ctx.metrics.fast += 1
        return 1


class OwlSafeComboFilter:
    """Owl pass 1: only colocation combos *observed* safe (and at most
    two functions per node — the paper's stated limitation)."""

    name = "owl-safe-combo"

    def filter(self, ctx: DecisionContext, node: Node) -> Optional[str]:
        sched = ctx.sched
        combo = sched._combo_after(node, ctx.fn)
        if len(combo) > 2:
            return "combo-limit"
        if ctx.mem_room(node) <= 0:
            return "no-mem-room"
        key = sched._key(combo)
        if key in sched.safe and key not in sched.unsafe:
            return None
        return "unproven-combo"


class OwlExploreFilter:
    """Owl pass 2: explore unknown combos within requested resources
    (never combos observed unsafe)."""

    name = "owl-explore"

    def filter(self, ctx: DecisionContext, node: Node) -> Optional[str]:
        sched = ctx.sched
        combo = sched._combo_after(node, ctx.fn)
        if len(combo) > 2:
            return "combo-limit"
        if sched._key(combo) in sched.unsafe:
            return "observed-unsafe"
        if not sched._fits_requested(node, ctx.spec):
            return "requested-overcommit"
        return None


# ---------------------------------------------------------------------------
# Dual-staged scaling picks as stages (platform.ReleasePicker /
# platform.LogicalStartPicker implementations)
# ---------------------------------------------------------------------------


class GreedyReleasePicker:
    """Default ``ReleasePicker`` stage: drain least-loaded nodes first
    so released capacity concentrates (and empty servers can be
    returned).  Subclasses reorder candidacy via ``sort_key`` without
    re-implementing the drain."""

    name = "greedy"

    def __init__(self, scheduler: BaseScheduler):
        self.sched = scheduler

    def sort_key(self, node: Node):
        return node.n_instances()

    def pick_release_nodes(self, fn: str, k: int) -> List[Tuple[Node, int]]:
        picks = []
        for node in sorted(self.sched.cluster.nodes_with(fn),
                           key=self.sort_key):
            if k <= 0:
                break
            take = min(k, node.funcs[fn].n_sat)
            if take > 0:
                picks.append((node, take))
                k -= take
        return picks


class BreachAwareReleasePicker(GreedyReleasePicker):
    """Release stage that drains QoS-breached (cooling-down) nodes
    first — most recent breach first — then falls back to the greedy
    least-loaded order.  The harvesting scheduler's QoS-margin release
    goes through this stage."""

    name = "breach-aware"

    def sort_key(self, node: Node):
        return (-self.sched.qos_cooldown_until(node),
                node.n_instances())


class GreedyLogicalStartPicker:
    """Default ``LogicalStartPicker`` stage: re-saturate cached
    instances most-cached-first (<1 ms re-routes instead of real cold
    starts for any scheduler that opts into dual-staged scaling)."""

    name = "greedy"

    def __init__(self, scheduler: BaseScheduler):
        self.sched = scheduler

    def pick_logical_start_nodes(self, fn: str, k: int
                                 ) -> List[Tuple[Node, int]]:
        picks = []
        nodes = sorted((n for n in self.sched.cluster.nodes_with(fn)
                        if n.funcs[fn].n_cached > 0),
                       key=lambda n: -n.funcs[fn].n_cached)
        for node in nodes:
            if k <= 0:
                break
            take = min(k, node.funcs[fn].n_cached)
            picks.append((node, take))
            k -= take
        return picks


class TableBoundLogicalStartPicker:
    """Capacity-table-bound logical starts (Jiagu): re-saturate cached
    instances only where the table says the node can absorb them.
    Subclasses narrow candidacy via ``eligible`` (harvesting skips
    nodes in QoS cooldown) without re-implementing the pick."""

    name = "table-bound"

    def __init__(self, scheduler: BaseScheduler):
        self.sched = scheduler

    def eligible(self, node: Node) -> bool:
        return True

    def pick_logical_start_nodes(self, fn: str, k: int
                                 ) -> List[Tuple[Node, int]]:
        picks = []
        nodes = sorted((n for n in self.sched.cluster.nodes_with(fn)
                        if n.funcs[fn].n_cached > 0),
                       key=lambda n: -n.funcs[fn].n_cached)
        for node in nodes:
            if k <= 0:
                break
            if not self.eligible(node):
                continue
            st = node.funcs[fn]
            entry = node.table.get(fn)
            cap = entry.capacity if entry else st.n_sat + st.n_cached
            absorb = min(st.n_cached, max(cap - st.n_sat, 0))
            if absorb <= 0:
                continue
            take = min(k, absorb)
            picks.append((node, take))
            k -= take
        return picks


# ---------------------------------------------------------------------------
# The four legacy schedulers, re-expressed as pipeline stacks
# ---------------------------------------------------------------------------


class PipelineJiaguScheduler(PipelineHostMixin, JiaguScheduler):
    """Jiagu as a stack: capacity-table ``PreDecision`` gate, a
    stale-table sweep over the function's nodes, a most-packed-first
    spread over nodes not yet running it, and capacity-checked
    scale-out.  Placement-parity-gated against ``JiaguScheduler``."""

    name = "jiagu-pipeline"

    def build_pipeline(self) -> SchedulingPipeline:
        slow = JiaguSlowBinder()
        return SchedulingPipeline(
            pre_decision=CapacityTableGate(),
            passes=[
                CandidatePass("slow-stale", slow,
                              filters=(StaleTableFilter(),),
                              source=nodes_with_fn),
                CandidatePass("slow-spread", slow,
                              filters=(NotRunningFilter(),),
                              scorer=InstanceCountScorer()),
            ],
            scale_out=JiaguScaleOutBinder())

    def on_place(self, node: Node, k: int, now: float,
                 latency_ms: float) -> None:
        self._queue_update(node, now + latency_ms / 1e3)


class PipelineGsightScheduler(PipelineHostMixin, GsightScheduler):
    """Gsight as a stack: warm-affinity top-k candidates, per-request
    prediction as the admission binder."""

    name = "gsight-pipeline"

    def build_pipeline(self) -> SchedulingPipeline:
        return SchedulingPipeline(
            passes=[CandidatePass("admit", GsightAdmitBinder(),
                                  scorer=WarmAffinityScorer(),
                                  max_candidates=self.max_candidates)],
            scale_out=GsightScaleOutBinder(),
            per_instance=True)


class PipelineK8sScheduler(PipelineHostMixin, K8sScheduler):
    """Kubernetes as a stack: requested-fit filter, most-allocated
    scorer, model-free binder."""

    name = "k8s-pipeline"

    def build_pipeline(self) -> SchedulingPipeline:
        return SchedulingPipeline(
            passes=[CandidatePass("binpack", DeployOneBinder(),
                                  filters=(RequestedFitFilter(),),
                                  scorer=RequestedCpuScorer())],
            scale_out=DeployOneBinder(),
            per_instance=True)


class PipelineOwlScheduler(PipelineHostMixin, OwlScheduler):
    """Owl as a stack: known-safe historical combos first, then
    exploration within requested resources."""

    name = "owl-pipeline"

    def build_pipeline(self) -> SchedulingPipeline:
        deploy = DeployOneBinder()
        return SchedulingPipeline(
            passes=[
                CandidatePass("known-safe", deploy,
                              filters=(OwlSafeComboFilter(),),
                              scorer=InstanceCountScorer()),
                CandidatePass("explore", deploy,
                              filters=(OwlExploreFilter(),),
                              scorer=InstanceCountScorer()),
            ],
            scale_out=deploy,
            per_instance=True)


register_scheduler(
    "jiagu-pipeline",
    lambda ctx: PipelineJiaguScheduler(ctx.cluster, ctx.store, ctx.qos,
                                       ctx.predictor, m_max=ctx.m_max),
    needs_predictor=True, dual_staged_default=True)
register_scheduler(
    "gsight-pipeline",
    lambda ctx: make_gsight_scheduler(ctx, PipelineGsightScheduler),
    needs_predictor=True)
register_scheduler(
    "k8s-pipeline",
    lambda ctx: PipelineK8sScheduler(ctx.cluster, ctx.store, ctx.qos))
register_scheduler(
    "owl-pipeline",
    lambda ctx: PipelineOwlScheduler(ctx.cluster, ctx.store, ctx.qos))


__all__ = [
    "TRACE_SCHEMA_VERSION", "CANDIDATE_FEATURES",
    "candidate_feature_row",
    "DecisionTrace", "TraceBinding", "DecisionContext",
    "NodeFilter", "NodeScorer", "Binder", "PreDecision",
    "CandidatePass", "SchedulingPipeline", "PipelineHostMixin",
    "all_nodes", "nodes_with_fn",
    "CapacityTableGate", "StaleTableFilter", "NotRunningFilter",
    "MemRoomFilter", "InstanceCountScorer", "JiaguSlowBinder",
    "JiaguScaleOutBinder", "WarmAffinityScorer", "GsightAdmitBinder",
    "GsightScaleOutBinder", "RequestedFitFilter", "RequestedCpuScorer",
    "DeployOneBinder", "OwlSafeComboFilter", "OwlExploreFilter",
    "GreedyReleasePicker", "BreachAwareReleasePicker",
    "GreedyLogicalStartPicker", "TableBoundLogicalStartPicker",
    "PipelineJiaguScheduler", "PipelineGsightScheduler",
    "PipelineK8sScheduler", "PipelineOwlScheduler",
]
