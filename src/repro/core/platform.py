"""``repro.platform`` — the unified control-plane API.

Jiagu's core claim is architectural: prediction, scheduling, and scaling
are decoupled stages cooperating through narrow interfaces (pre-decision
capacity tables §4, dual-staged scaling §5).  This module is that
architecture as an API:

  * **Capability protocols** — the autoscaler and simulator consume
    their collaborators through typed capabilities (``CapacityProvider``,
    ``ReleasePicker``, ``LogicalStartPicker``, ``Router``), never
    through concrete class identity, so an RL scheduler, a harvesting
    scaler, or a locality-aware router plugs in without touching the
    run loop.
  * **One validated config tree** — ``PlatformConfig`` (cluster /
    scenario / scheduler / scaling / prediction / simulation sections)
    with a strict ``to_dict``/``from_dict`` round trip, so benchmark
    manifests are plain JSON-able dicts and every schema/engine
    consistency rule fires at construction, not mid-run.
  * **Name-based registries** — schedulers, scenario kinds, trace
    programs and routers are selected by string
    (``register_scheduler`` / ``register_scenario`` / ``register_trace``
    / ``register_router``), so benchmarks, examples and manifests never
    import concrete classes.
  * **The facade** — ``Platform.build(scenario=..., config=...)``
    assembles the world (ground truth, profiles, trained forest),
    cluster, scheduler, autoscaler and simulation, wires the observer
    hub (``on_tick`` / ``on_schedule`` / ``on_scale`` / ``on_retrain``)
    and returns a runnable ``Platform``; ``run()`` drives the tick loop.

``Simulation``, ``build_simulation`` and ``scenario_simulation`` remain
as thin shims over the same machinery, so the legacy/engine/service
parity gates run unchanged.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterable, List, Optional,
                    Protocol, Tuple, Union, runtime_checkable)

from .capacity import M_MAX_DEFAULT
from .cluster import Cluster, Node
from .events import EventHub, JsonlObserver, Observer
from .interference import NodeResources
from .prediction_service import INFERENCE_ENGINES, get_schema
from .profiles import FunctionSpec
from .registry import Registry
from .scheduler import (BaseScheduler, SchedulerBuildContext,
                        SchedulerEntry, build_scheduler,
                        register_scheduler, registered_schedulers,
                        scheduler_entry)
from .scenarios import (NodeClass, Scenario, ScenarioWorld,
                        get_scenario_builder, make_scenario,
                        register_scenario, registered_scenarios,
                        scenario_simulation, scenario_world)
from .cells import (CapacityExchange, Cell, CellRouter, CellSimulation,
                    cell_scenario_simulation)
from .simulator import (EqualSplitRouter, LocalityRouter, SimResult,
                        Simulation)
from .traces import get_trace, register_trace, registered_traces
# importing these modules registers the pipeline-stacked scheduler
# variants and the harvesting scheduler with the scheduler registry
from .pipeline import (Binder, CandidatePass, DecisionContext,
                       DecisionTrace, GreedyLogicalStartPicker,
                       GreedyReleasePicker, NodeFilter, NodeScorer,
                       PipelineHostMixin, PreDecision,
                       SchedulingPipeline, TableBoundLogicalStartPicker,
                       TraceBinding)
from .pipeline import BreachAwareReleasePicker
from .harvesting import CooldownLogicalStartPicker, HarvestingScheduler
# importing the policy stage registers the "learned" scheduler stack
# (JAX stays un-imported until real weights swap in)
from ..policy.stage import LearnedScheduler, LearnedScorer
from ..admission import ADMIT_STAGES, RELEASE_STAGES
from ..telemetry import Telemetry, publish_result


class PlatformConfigError(ValueError):
    """A ``PlatformConfig`` failed construction-time validation."""


# ---------------------------------------------------------------------------
# Capability protocols
# ---------------------------------------------------------------------------


@runtime_checkable
class CapacityProvider(Protocol):
    """Best-known capacity of a function on a node — what the
    autoscaler's migration targeting and consolidation consume.  The
    default (``autoscaler.SchedulerCapacityProvider``) reads the node's
    capacity table, then falls back to a zero-cost prediction-service
    cache hint; None means "unknown", and callers must never run
    inference to find out (migration is not a critical path)."""

    def node_capacity(self, node: Node, fn: str) -> Optional[int]:
        ...


@runtime_checkable
class ReleasePicker(Protocol):
    """Which (node, count) pairs to drain when dual-staged scaling
    releases excess instances (or traditional keep-alive evicts them).
    ``BaseScheduler`` provides the greedy least-loaded default."""

    def pick_release_nodes(self, fn: str, k: int) -> List[Tuple[Node, int]]:
        ...


@runtime_checkable
class LogicalStartPicker(Protocol):
    """Which cached instances to re-saturate (<1 ms logical cold
    starts) when load rises.  ``BaseScheduler`` provides a greedy
    most-cached-first default so *any* scheduler that opts into
    dual-staged scaling benefits; ``JiaguScheduler`` overrides it to
    absorb only up to the capacity table's bound."""

    def pick_logical_start_nodes(self, fn: str, k: int
                                 ) -> List[Tuple[Node, int]]:
        ...


@runtime_checkable
class Router(Protocol):
    """Per-tick load routing policy: how much of a function's traffic a
    node's saturated instances serve.  Returns
    ``(per_instance_rps, requests_routed_to_node)``; the default is the
    paper's equal split (``simulator.EqualSplitRouter``)."""

    def route(self, spec: FunctionSpec, fn_rps: float, node: Node,
              n_sat: float, total_sat: int) -> Tuple[float, float]:
        ...


# ---------------------------------------------------------------------------
# Router registry
# ---------------------------------------------------------------------------

_ROUTERS = Registry("router")


def register_router(name: str, factory: Optional[Callable[[], Router]]
                    = None, *, overwrite: bool = False):
    """Register a ``Router`` factory under ``name`` (usable as a class
    decorator)."""
    return _ROUTERS.register(name, factory, overwrite=overwrite)


def get_router(name: str) -> Callable[[], Router]:
    return _ROUTERS.get(name)


def registered_routers() -> List[str]:
    return _ROUTERS.names()


register_router("equal-split", EqualSplitRouter)
register_router("locality", LocalityRouter)


# ---------------------------------------------------------------------------
# Pipeline-stage registry (release / logical-start picker policies and
# any custom filter/scorer/binder a plugin wants selectable by name)
# ---------------------------------------------------------------------------

_STAGES = Registry("pipeline stage")


def _stage_key(kind: str, name: str) -> str:
    return f"{kind}:{name}"


def register_stage(kind: str, name: str, factory=None, *,
                   overwrite: bool = False):
    """Register a pipeline-stage factory under ``(kind, name)``.

    ``kind`` groups stages by protocol ("release", "logical-start",
    "filter", "scorer", "binder", ...); factories take the owning
    scheduler and return the stage object, so config manifests can
    select picker policies by string (``PlatformConfig.pipeline``)."""
    return _STAGES.register(_stage_key(kind, name), factory,
                            overwrite=overwrite)


def get_stage(kind: str, name: str):
    return _STAGES.get(_stage_key(kind, name))


def registered_stages(kind: Optional[str] = None) -> List[str]:
    names = _STAGES.names()
    if kind is None:
        return names
    prefix = f"{kind}:"
    return [n[len(prefix):] for n in names if n.startswith(prefix)]


register_stage("release", "greedy", GreedyReleasePicker)
register_stage("release", "breach-aware", BreachAwareReleasePicker)
register_stage("logical-start", "greedy", GreedyLogicalStartPicker)
register_stage("logical-start", "table-bound",
               TableBoundLogicalStartPicker)
register_stage("logical-start", "cooldown-table-bound",
               CooldownLogicalStartPicker)
register_stage("scorer", "learned", lambda sched: LearnedScorer())

# admission-pipeline stages (``repro.admission``): the controller owns
# the authoritative name -> class dicts; re-registering them here makes
# them discoverable/validatable through the same registry as picker
# stages (``registered_stages("admit")`` etc.)
for _name, _cls in ADMIT_STAGES.items():
    register_stage("admit", _name, _cls)
for _name, _cls in RELEASE_STAGES.items():
    register_stage("queue-release", _name, _cls)


# ---------------------------------------------------------------------------
# The config tree
# ---------------------------------------------------------------------------


@dataclass
class NodeClassConfig:
    """One server shape of the fleet mix, in manifest form."""

    name: str = "std"
    cpu_mcores: float = 48_000.0
    mem_mb: float = 131_072.0
    mem_bw_gbps: float = 68.0
    llc_mb: float = 60.0
    weight: int = 1

    def to_node_class(self) -> NodeClass:
        return NodeClass(self.name, NodeResources(
            cpu_mcores=self.cpu_mcores, mem_mb=self.mem_mb,
            mem_bw_gbps=self.mem_bw_gbps, llc_mb=self.llc_mb),
            weight=self.weight)


@dataclass
class ClusterSection:
    """Fleet topology.  ``node_classes=None`` uses the scenario default
    (heterogeneous std+large mix, or std-only with
    ``heterogeneous=False``); an explicit list overrides it."""

    node_classes: Optional[List[NodeClassConfig]] = None
    heterogeneous: bool = True
    max_nodes: Optional[int] = None

    def to_node_classes(self) -> Optional[List[NodeClass]]:
        if self.node_classes is None:
            return None
        return [nc.to_node_class() for nc in self.node_classes]


@dataclass
class ScenarioSection:
    """World description: population + trace program + scale."""

    kind: str = "burst-storm"
    n_functions: int = 24
    duration_s: int = 600
    target_nodes: int = 64
    seed: int = 0
    #: population seed, decoupled from the trace seed (None -> ``seed``)
    spec_seed: Optional[int] = None
    zipf_s: float = 1.2
    utilization: float = 0.8
    #: passthrough to the registered trace builder (``coherence=`` for
    #: burst storms, ``path=`` for replayed CSV dumps, ...)
    trace_kw: Dict[str, Any] = field(default_factory=dict)


@dataclass
class SchedulerSection:
    name: str = "jiagu"
    m_max: int = M_MAX_DEFAULT
    max_candidates: int = 4      # gsight-style candidate fan-out
    #: harvesting: fraction of predicted capacity claimable (1.0 =
    #: exactly the predicted bound; >1 deliberate overcommit)
    harvest_headroom: float = 0.85
    #: harvesting: seconds a QoS-breached node is exempt from
    #: harvesting / re-saturation after its release
    qos_release_cooldown_s: float = 30.0


@dataclass
class ScalingSection:
    release_s: float = 45.0
    keepalive_s: float = 60.0
    init_ms: float = 8.4         # cfork container init; docker: 85.5
    #: None -> the scheduler registry's per-scheduler default (dual for
    #: Jiagu, traditional keep-alive for baselines); an explicit bool
    #: forces the mode for any scheduler
    dual_staged: Optional[bool] = None
    migrate: bool = True


@dataclass
class PredictionSection:
    schema_version: int = 1
    n_train: int = 2000
    n_trees: int = 24
    max_depth: int = 8
    #: RFR inference engine override (numpy / jax / pallas); None keeps
    #: the predictor's default
    engine: Optional[str] = None
    online_retrain: bool = False
    retrain_every: Optional[int] = None
    #: schema v2: learn the per-shape QoS margin from per-shape
    #: validation error instead of the fixed shape_margin formula
    learned_shape_margin: bool = False


@dataclass
class PipelineSection:
    """Decision-pipeline knobs: trace recording and named stage
    overrides for the dual-staged scaling picks (resolved through the
    ``register_stage`` registry, applied to whatever scheduler the
    manifest selects).

    ``decision_traces=None`` (default) records traces only when the
    platform is built with observers — traces exist to be consumed
    through ``on_schedule``, and observer-less runs shouldn't pay the
    bookkeeping; an explicit bool forces recording on or off."""

    decision_traces: Optional[bool] = None
    release_picker: Optional[str] = None       # stage registry name
    logical_start_picker: Optional[str] = None  # stage registry name
    #: additionally snapshot per-candidate raw feature vectors + the
    #: chosen node into every trace (``repro.policy`` dataset
    #: collection; implies ``decision_traces``).  O(nodes) per
    #: decision, so off by default.
    trace_features: bool = False


@dataclass
class PolicySection:
    """Learned-scorer serving (``repro.policy``): where to load trained
    weights from and how they track retrains.

    ``store=None`` (default) leaves the ``"learned"`` stack on its
    built-in heuristic — buildable with no artifact on disk; ``epoch``
    pins a stored epoch (None loads the latest); ``hot_swap`` wires a
    PredictionService retrain listener that reloads/re-tags the scorer
    synchronously with every epoch bump, keeping stale-epoch serves at
    zero."""

    store: Optional[str] = None
    epoch: Optional[int] = None
    hot_swap: bool = True


@dataclass
class TelemetrySection:
    """Unified metrics/trace layer (``repro.telemetry``).

    ``metrics=None`` (default) attaches the ``MetricsObserver`` +
    registry only when the platform is built with observers — like
    decision traces, telemetry exists to be consumed, and bare runs
    shouldn't pay for it; an explicit bool forces it either way.
    ``spans=None`` follows the resolved metrics setting; when on, a
    ``SpanTracer`` is handed to the simulator and prediction service
    and every closed span fans out through ``EventHub.on_span``.
    ``histogram_bins`` sizes the bucketed export in
    ``Platform.metrics_snapshot()`` (0 = summary stats only)."""

    metrics: Optional[bool] = None
    spans: Optional[bool] = None
    histogram_bins: int = 0


@dataclass
class SimulationSection:
    #: None -> the SimConfig default (the PredictionService path);
    #: False forces the legacy per-node reference oracle
    use_capacity_engine: Optional[bool] = None
    collect_samples: bool = False
    sample_every_s: Optional[int] = None
    seed: int = 0
    router: str = "equal-split"


@dataclass
class CellsSection:
    """Sharded control plane (``core/cells.py``): ``count > 1``
    partitions the fleet into that many cells, each with its own
    cluster slice, scheduler, autoscaler and PredictionService, driven
    by the event-driven per-cell loop with cross-cell traffic shares
    (``CellRouter``).  ``count = 1`` (default) keeps the legacy
    single-loop assembly — bit-identical results, gated in tier-1."""

    count: int = 1
    #: cross-cell waterfill cap: fraction of a cell's saturated
    #: throughput loaded before traffic spills to the next cell
    load_cap: float = 0.85
    #: capacity gossip between cell services (solved capacities are
    #: published to sibling caches, epoch-checked)
    exchange: bool = True


@dataclass
class AdmissionSection:
    """Queue-backed admission, SLO classes and vertical scaling
    (``repro.admission``).  Default-off: ``enabled=False`` builds the
    exact pre-admission control plane (no controller object exists),
    which the admission-off bit-parity gates pin down.  Field names
    mirror ``admission.AdmissionConfig`` one-to-one."""

    enabled: bool = False
    #: per-function cpu-reservation resize driving the harvesting
    #: scheduler's per-function harvest bounds
    vertical: bool = False
    #: autoscaler input: "queue" = backlog-derived (depth + drain
    #: target, KEDA-style), "rps" = instantaneous arrivals (the
    #: horizontal-only benchmark arm)
    signal: str = "queue"
    #: fraction of the population tagged best-effort (deterministic
    #: hash tag, no RNG stream consumed)
    best_effort_frac: float = 0.5
    slo_seed: int = 0
    #: queue bound, in seconds of peak-held arrival rate
    queue_cap_s: float = 8.0
    #: backlog catch-up horizon the "queue" signal targets
    target_drain_s: float = 2.0
    #: per-class queue-delay budgets (delay beyond = violation)
    lc_delay_budget_s: float = 0.25
    be_delay_budget_s: float = 8.0
    #: backlog catch-up provisioning cap, in multiples of the
    #: peak-held arrival rate
    catch_up_mult: float = 1.5
    #: admit/release stage names (``registered_stages("admit")`` /
    #: ``registered_stages("queue-release")``)
    admit: str = "bounded-fifo"
    queue_release: str = "greedy"
    #: vertical-resize floor for a best-effort function's cpu share
    min_share: float = 0.5
    resize_every_s: float = 15.0


_SECTIONS = {
    "cluster": ClusterSection,
    "scenario": ScenarioSection,
    "scheduler": SchedulerSection,
    "scaling": ScalingSection,
    "prediction": PredictionSection,
    "pipeline": PipelineSection,
    "policy": PolicySection,
    "simulation": SimulationSection,
    "telemetry": TelemetrySection,
    "cells": CellsSection,
    "admission": AdmissionSection,
}


def _load_section(cls, data, where: str):
    if data is None:
        return cls()
    if isinstance(data, cls):
        return data
    if not isinstance(data, dict):
        raise PlatformConfigError(
            f"{where}: expected a dict, got {type(data).__name__}")
    known = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(data) - known)
    if unknown:
        raise PlatformConfigError(
            f"{where}: unknown keys {unknown} (known: {sorted(known)})")
    kw = dict(data)
    if cls is ClusterSection and kw.get("node_classes") is not None:
        kw["node_classes"] = [
            nc if isinstance(nc, NodeClassConfig)
            else _load_section(NodeClassConfig, nc,
                               f"{where}.node_classes[{i}]")
            for i, nc in enumerate(kw["node_classes"])]
    if cls is ScenarioSection and kw.get("trace_kw") is not None:
        kw["trace_kw"] = dict(kw["trace_kw"])
    return cls(**kw)


@dataclass
class PlatformConfig:
    """The whole control plane as one validated, serializable tree.

    ``from_dict`` is strict (unknown sections/keys raise
    ``PlatformConfigError``) and ``from_dict(to_dict(cfg)) == cfg``, so
    benchmark manifests round-trip losslessly through JSON."""

    cluster: ClusterSection = field(default_factory=ClusterSection)
    scenario: ScenarioSection = field(default_factory=ScenarioSection)
    scheduler: SchedulerSection = field(default_factory=SchedulerSection)
    scaling: ScalingSection = field(default_factory=ScalingSection)
    prediction: PredictionSection = field(default_factory=PredictionSection)
    pipeline: PipelineSection = field(default_factory=PipelineSection)
    policy: PolicySection = field(default_factory=PolicySection)
    simulation: SimulationSection = field(default_factory=SimulationSection)
    telemetry: TelemetrySection = field(default_factory=TelemetrySection)
    cells: CellsSection = field(default_factory=CellsSection)
    admission: AdmissionSection = field(default_factory=AdmissionSection)

    # -- (de)serialization ------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        """Plain nested dicts (JSON-able; ``from_dict`` inverts it)."""
        return {name: dataclasses.asdict(getattr(self, name))
                for name in _SECTIONS}

    @classmethod
    def from_dict(cls, data: Dict[str, Any]) -> "PlatformConfig":
        if not isinstance(data, dict):
            raise PlatformConfigError(
                f"manifest: expected a dict, got {type(data).__name__}")
        unknown = sorted(set(data) - set(_SECTIONS))
        if unknown:
            raise PlatformConfigError(
                f"manifest: unknown sections {unknown} "
                f"(known: {sorted(_SECTIONS)})")
        return cls(**{name: _load_section(scls, data.get(name), name)
                      for name, scls in _SECTIONS.items()})

    @classmethod
    def coerce(cls, config: Union["PlatformConfig", Dict[str, Any], None]
               ) -> "PlatformConfig":
        if config is None:
            return cls()
        if isinstance(config, cls):
            return config
        return cls.from_dict(config)

    # -- construction-time validation -------------------------------------

    def validate(self) -> "PlatformConfig":
        """Every schema/engine/scheduler consistency rule, checked before
        anything is built (these used to surface as scattered
        ``Simulation.__init__`` raises mid-assembly)."""
        sc, p, sim = self.scenario, self.prediction, self.simulation
        entry = scheduler_entry(self.scheduler.name)   # unknown -> raises
        get_scenario_builder(sc.kind)                  # unknown -> raises
        get_router(sim.router)                         # unknown -> raises
        get_schema(p.schema_version)                   # unknown -> raises
        if self.pipeline.release_picker is not None:
            get_stage("release", self.pipeline.release_picker)
        if self.pipeline.logical_start_picker is not None:
            get_stage("logical-start", self.pipeline.logical_start_picker)
        if self.policy.epoch is not None and self.policy.store is None:
            raise PlatformConfigError(
                "policy.epoch pins a stored policy but policy.store is "
                "unset; point it at a PolicyStore directory")
        if self.pipeline.decision_traces is False \
                and self.pipeline.trace_features:
            raise PlatformConfigError(
                "pipeline.trace_features captures per-candidate rows "
                "into decision traces; it cannot be combined with "
                "decision_traces=False")
        if p.learned_shape_margin and p.schema_version == 1:
            raise PlatformConfigError(
                "prediction.learned_shape_margin needs the node-shape-"
                "aware feature schema (schema_version >= 2); v1 rows "
                "carry no shape block to learn margins from")
        if self.scheduler.harvest_headroom <= 0:
            raise PlatformConfigError(
                "scheduler.harvest_headroom must be positive (fraction "
                "of predicted capacity claimable; 1.0 = the full bound)")
        if self.scheduler.qos_release_cooldown_s < 0:
            raise PlatformConfigError(
                "scheduler.qos_release_cooldown_s must be >= 0")
        if sc.n_functions <= 0 or sc.duration_s <= 0 \
                or sc.target_nodes <= 0:
            raise PlatformConfigError(
                "scenario: n_functions, duration_s and target_nodes must "
                "be positive")
        if p.engine is not None and p.engine not in INFERENCE_ENGINES:
            raise PlatformConfigError(
                f"prediction.engine {p.engine!r} unknown "
                f"(have {INFERENCE_ENGINES})")
        if p.schema_version != 1 and sim.use_capacity_engine is False:
            raise PlatformConfigError(
                "prediction.schema_version >= 2 requires the "
                "PredictionService path; the legacy per-node solver "
                "(simulation.use_capacity_engine=False) only speaks the "
                "v1 feature layout")
        if p.online_retrain and sim.use_capacity_engine is False:
            raise PlatformConfigError(
                "prediction.online_retrain requires a PredictionService "
                "(simulation.use_capacity_engine=False selects the "
                "legacy path, which has no on_samples retraining loop)")
        if p.online_retrain and not sim.collect_samples:
            raise PlatformConfigError(
                "prediction.online_retrain needs runtime samples: set "
                "simulation.collect_samples=True")
        if not entry.needs_predictor and (p.schema_version != 1
                                          or p.online_retrain):
            backed = [n for n in registered_schedulers()
                      if scheduler_entry(n).needs_predictor]
            raise PlatformConfigError(
                f"scheduler {entry.name!r} runs without a predictor; "
                f"schema v2 / online retraining need a prediction-backed "
                f"scheduler ({backed})")
        if self.cells.count < 1:
            raise PlatformConfigError(
                f"cells.count must be >= 1, got {self.cells.count}")
        if not 0 < self.cells.load_cap <= 1:
            raise PlatformConfigError(
                f"cells.load_cap must be in (0, 1], got "
                f"{self.cells.load_cap}")
        adm = self.admission
        if adm.vertical and not adm.enabled:
            raise PlatformConfigError(
                "admission.vertical needs the admission controller; "
                "set admission.enabled=True")
        if adm.signal not in ("queue", "rps"):
            raise PlatformConfigError(
                f"admission.signal must be 'queue' or 'rps', got "
                f"{adm.signal!r}")
        if not 0 <= adm.best_effort_frac <= 1:
            raise PlatformConfigError(
                f"admission.best_effort_frac must be in [0, 1], got "
                f"{adm.best_effort_frac}")
        if adm.queue_cap_s <= 0 or adm.target_drain_s <= 0 \
                or adm.lc_delay_budget_s <= 0 \
                or adm.be_delay_budget_s <= 0 or adm.resize_every_s <= 0:
            raise PlatformConfigError(
                "admission: queue_cap_s, target_drain_s, the delay "
                "budgets and resize_every_s must all be positive")
        if not 0 < adm.min_share <= 1:
            raise PlatformConfigError(
                f"admission.min_share must be in (0, 1], got "
                f"{adm.min_share}")
        get_stage("admit", adm.admit)                  # unknown -> raises
        get_stage("queue-release", adm.queue_release)  # unknown -> raises
        return self


def scenario_from_config(cfg: PlatformConfig) -> Scenario:
    """Build just the ``Scenario`` a config describes (the same call
    ``Platform.build`` makes) — lets benchmarks stage scenario/world
    construction outside their timers while still driving everything
    from one manifest."""
    sc = cfg.scenario
    return make_scenario(
        sc.kind, n_functions=sc.n_functions, duration_s=sc.duration_s,
        target_nodes=sc.target_nodes, seed=sc.seed,
        spec_seed=sc.spec_seed, zipf_s=sc.zipf_s,
        heterogeneous=cfg.cluster.heterogeneous,
        node_classes=cfg.cluster.to_node_classes(),
        utilization=sc.utilization, **sc.trace_kw)


# ---------------------------------------------------------------------------
# The facade
# ---------------------------------------------------------------------------


class Platform:
    """A fully assembled control plane: config + scenario + world +
    simulation + observer hub.  Construct with ``Platform.build``."""

    def __init__(self, config: PlatformConfig, scenario: Scenario,
                 world: ScenarioWorld,
                 simulation: Union[Simulation, CellSimulation],
                 hub: EventHub, telemetry: Optional[Telemetry] = None):
        self.config = config
        self.scenario = scenario
        self.world = world
        self.simulation = simulation
        self.hub = hub
        self.telemetry = telemetry
        self.result: Optional[SimResult] = None

    # -- component access --------------------------------------------------

    @property
    def scheduler(self) -> BaseScheduler:
        return self.simulation.scheduler

    @property
    def autoscaler(self):
        return self.simulation.autoscaler

    @property
    def cluster(self) -> Cluster:
        return self.simulation.cluster

    @property
    def service(self):
        """The scheduler's PredictionService (None on the legacy path)."""
        return self.scheduler.prediction_service

    @property
    def router(self) -> Router:
        return self.simulation.router

    # -- observers ----------------------------------------------------------

    def add_observer(self, obs: Observer) -> Observer:
        return self.hub.add(obs)

    def remove_observer(self, obs: Observer) -> None:
        self.hub.remove(obs)

    # -- run ----------------------------------------------------------------

    def run(self, duration_s: Optional[int] = None) -> SimResult:
        self.result = self.simulation.run(duration_s)
        if self.telemetry is not None:
            publish_result(
                self.telemetry.registry, self.result,
                engine_stats=self.service.stats.snapshot()
                if self.service is not None else None)
        return self.result

    def metrics_snapshot(self) -> Dict[str, Dict[str, Any]]:
        """The telemetry registry's JSON-able snapshot ({} when the
        platform was built without telemetry)."""
        if self.telemetry is None:
            return {}
        return self.telemetry.snapshot(self.config.telemetry.histogram_bins)

    def span_summary(self) -> List[Dict[str, Any]]:
        """Per-span-name aggregate wall-clock rows ([] without spans)."""
        if self.telemetry is None:
            return []
        return self.telemetry.span_summary()

    def to_manifest(self) -> Dict[str, Any]:
        """The config tree as a plain dict (``PlatformConfig.to_dict``)."""
        return self.config.to_dict()

    # -- construction --------------------------------------------------------

    @classmethod
    def build(cls, scenario: Union[Scenario, str, None] = None,
              config: Union[PlatformConfig, Dict[str, Any], None] = None,
              *, world: Optional[ScenarioWorld] = None,
              router: Optional[Router] = None,
              observers: Iterable[Observer] = ()) -> "Platform":
        """Assemble a runnable platform.

        ``config`` may be a ``PlatformConfig`` or a plain manifest dict
        (validated strictly); ``scenario`` overrides the config's
        scenario section with a prebuilt ``Scenario`` (or a kind
        string).  ``world`` reuses a prebuilt ``ScenarioWorld`` (its
        feature schema must match the config's); ``router``/
        ``observers`` plug the routing policy and observer hooks.  All
        schema/engine consistency validation happens here, before any
        component exists."""
        cfg = PlatformConfig.coerce(config)
        if isinstance(scenario, str):
            cfg = dataclasses.replace(
                cfg, scenario=dataclasses.replace(cfg.scenario,
                                                  kind=scenario))
            scenario = None
        cfg.validate()
        sc, p, sim_cfg = cfg.scenario, cfg.prediction, cfg.simulation
        hub = EventHub(observers)
        if scenario is None:
            scenario = scenario_from_config(cfg)
        if world is None:
            world = scenario_world(
                scenario, n_train=p.n_train, n_trees=p.n_trees,
                max_depth=p.max_depth, schema_version=p.schema_version)
        elif world.schema_version != p.schema_version:
            raise PlatformConfigError(
                f"mismatched service schema: the prebuilt world speaks "
                f"schema v{world.schema_version} but the config requests "
                f"v{p.schema_version}; rebuild the world or align "
                f"prediction.schema_version")
        build_kw = dict(
            release_s=cfg.scaling.release_s,
            keepalive_s=cfg.scaling.keepalive_s,
            init_ms=cfg.scaling.init_ms, migrate=cfg.scaling.migrate,
            m_max=cfg.scheduler.m_max,
            max_candidates=cfg.scheduler.max_candidates,
            use_engine=sim_cfg.use_capacity_engine,
            collect_samples=sim_cfg.collect_samples,
            online_retrain=p.online_retrain,
            retrain_every=p.retrain_every,
            sample_every_s=sim_cfg.sample_every_s,
            sim_seed=sim_cfg.seed,
            dual_staged=cfg.scaling.dual_staged,
            learned_shape_margin=p.learned_shape_margin,
            harvest_headroom=cfg.scheduler.harvest_headroom,
            qos_release_cooldown_s=cfg.scheduler.qos_release_cooldown_s,
            admission=cfg.admission if cfg.admission.enabled else None)
        if cfg.cells.count > 1:
            if router is not None:
                raise PlatformConfigError(
                    "cells.count > 1 builds one router per cell; select "
                    "the policy by name via simulation.router instead of "
                    "passing a router instance")
            simulation: Union[Simulation, CellSimulation] = \
                cell_scenario_simulation(
                    scenario, cfg.scheduler.name,
                    n_cells=cfg.cells.count, world=world,
                    router_factory=get_router(sim_cfg.router),
                    cell_load_cap=cfg.cells.load_cap,
                    exchange=cfg.cells.exchange,
                    max_nodes=cfg.cluster.max_nodes, events=hub,
                    **build_kw)
        else:
            simulation = scenario_simulation(
                scenario, cfg.scheduler.name, world=world,
                max_nodes=cfg.cluster.max_nodes,
                router=router or get_router(sim_cfg.router)(),
                events=hub, **build_kw)
        services = simulation.services() \
            if isinstance(simulation, CellSimulation) else \
            [s for s in (simulation.scheduler.prediction_service,)
             if s is not None]
        for service in services:
            if p.engine is not None:
                service.set_engine(p.engine)
            service.add_retrain_listener(hub.on_retrain)
        # telemetry section: registry + observer + span tracer.  The
        # None default resolves against the *external* observers, so a
        # bare build stays uninstrumented and the parity gates hold.
        tel = cfg.telemetry
        want_metrics = tel.metrics if tel.metrics is not None \
            else bool(hub.observers)
        want_spans = tel.spans if tel.spans is not None else want_metrics
        telemetry: Optional[Telemetry] = None
        if want_metrics or want_spans:
            telemetry = Telemetry.create(
                metrics=want_metrics, spans=want_spans,
                emit=hub.on_span if want_spans else None)
            if telemetry.observer is not None:
                hub.add(telemetry.observer)
            if want_spans:
                simulation.tracer = telemetry.tracer
                for service in services:
                    service.tracer = telemetry.tracer
        # pipeline section: trace toggle + named picker-stage overrides
        # (applied to every cell's scheduler on the sharded path)
        scheds = simulation.schedulers() \
            if isinstance(simulation, CellSimulation) \
            else [simulation.scheduler]
        pl = cfg.pipeline
        for sched in scheds:
            sched.trace_decisions = pl.decision_traces \
                if pl.decision_traces is not None else bool(hub.observers)
            if pl.trace_features:
                # dataset collection: feature capture needs the traces
                # it annotates
                sched.trace_decisions = True
                sched.trace_features = True
            if pl.release_picker is not None:
                sched.release_stage = \
                    get_stage("release", pl.release_picker)(sched)
            if pl.logical_start_picker is not None:
                sched.logical_start_stage = \
                    get_stage("logical-start", pl.logical_start_picker)(sched)
        # policy section: install stored weights into any learned
        # scorer and keep its epoch tag in lockstep with the service's
        # (the listener runs inside the same synchronous retrain call
        # that bumps the epoch — zero stale-epoch serves)
        pol = cfg.policy
        learned = [s for s in scheds
                   if getattr(s, "learned_scorer", None) is not None]
        if learned:
            params = None
            if pol.store is not None:
                from ..policy.store import PolicyStore
                params, _meta = PolicyStore(pol.store).load(
                    epoch=pol.epoch)
            for s in learned:
                svc = s.prediction_service
                epoch0 = svc.epoch if svc is not None else 0
                if params is not None:
                    s.learned_scorer.swap(params, epoch0)
                else:
                    s.learned_scorer.expect(epoch0)
                if pol.hot_swap and svc is not None:
                    def _resync(service, scorer=s.learned_scorer,
                                store=pol.store, pin=pol.epoch):
                        p = scorer.policy
                        if store is not None and pin is None:
                            from ..policy.store import PolicyStore
                            try:
                                p, _ = PolicyStore(store).load()
                            except FileNotFoundError:
                                p = scorer.policy
                        if p is not None:
                            scorer.swap(p, service.epoch)
                        else:
                            scorer.expect(service.epoch)
                    svc.add_retrain_listener(_resync)
        return cls(cfg, scenario, world, simulation, hub,
                   telemetry=telemetry)


# ---------------------------------------------------------------------------
# CI smoke: every registered scheduler from pure config dicts
# ---------------------------------------------------------------------------


def smoke(duration_s: int = 30, verbose: bool = True
          ) -> Dict[str, SimResult]:
    """Build every registered scheduler against one scenario from pure
    manifest dicts and run ``duration_s`` ticks — the
    ``scripts/verify.sh`` platform smoke step.  Raises if any build or
    run fails or runs short.  The scenario and trained world come from
    the first manifest and are shared across schedulers (they differ
    only in the scheduler section; retraining the forest per scheduler
    would quadruple the smoke's cost for nothing)."""
    results: Dict[str, SimResult] = {}
    scenario = world = None
    for name in registered_schedulers():
        manifest = {
            "scenario": {"kind": "burst-storm", "n_functions": 4,
                         "duration_s": duration_s, "target_nodes": 8,
                         "seed": 0},
            "scheduler": {"name": name},
            "prediction": {"n_train": 300, "n_trees": 8},
        }
        plat = Platform.build(scenario=scenario, config=manifest,
                              world=world)
        scenario, world = plat.scenario, plat.world
        # every scheduler faces the identical measurement-noise stream
        # (the shared world's ground truth draws from a stateful RNG;
        # without the reset, results would depend on run order and the
        # harvesting-vs-k8s QoS gate below would compare different
        # noise)
        world.gt.reseed()
        res = plat.run()
        if res.ticks != duration_s:
            raise RuntimeError(
                f"platform smoke: {name} ran {res.ticks}/{duration_s} "
                f"ticks")
        results[name] = res
        if verbose:
            print(f"# platform-smoke {name}: density={res.density:.2f} "
                  f"qos={res.qos_violation_rate:.4f} "
                  f"peak_nodes={res.nodes_peak}", flush=True)
    # harvesting gate: claiming idle headroom must not regress QoS
    # versus the no-overcommit K8s baseline on the burst-storm scenario
    harv, k8s = results.get("harvesting"), results.get("k8s")
    if harv is not None and k8s is not None \
            and harv.qos_violation_rate > k8s.qos_violation_rate + 1e-9:
        raise RuntimeError(
            f"platform smoke: harvesting QoS violation rate "
            f"{harv.qos_violation_rate:.4f} regressed versus the K8s "
            f"baseline's {k8s.qos_violation_rate:.4f}")
    if verbose:
        print(f"# platform-smoke: {len(results)} schedulers x 1 scenario "
              f"x {duration_s} ticks => PASS")
    return results


__all__ = [
    # facade + config
    "Platform", "PlatformConfig", "PlatformConfigError",
    "ClusterSection", "ScenarioSection", "SchedulerSection",
    "ScalingSection", "PredictionSection", "PipelineSection",
    "PolicySection", "SimulationSection", "TelemetrySection",
    "NodeClassConfig", "CellsSection", "AdmissionSection",
    # sharded control plane
    "Cell", "CellRouter", "CellSimulation", "CapacityExchange",
    "cell_scenario_simulation",
    # telemetry
    "Telemetry", "publish_result",
    # capability protocols
    "CapacityProvider", "ReleasePicker", "LogicalStartPicker", "Router",
    # decision pipeline
    "NodeFilter", "NodeScorer", "Binder", "PreDecision",
    "DecisionContext", "DecisionTrace", "TraceBinding",
    "CandidatePass", "SchedulingPipeline", "PipelineHostMixin",
    "HarvestingScheduler", "LearnedScheduler", "LearnedScorer",
    # observers
    "Observer", "EventHub", "JsonlObserver",
    # registries
    "register_scheduler", "registered_schedulers", "scheduler_entry",
    "build_scheduler", "SchedulerEntry", "SchedulerBuildContext",
    "register_scenario", "registered_scenarios", "get_scenario_builder",
    "register_trace", "registered_traces", "get_trace",
    "register_router", "registered_routers", "get_router",
    "register_stage", "registered_stages", "get_stage",
    # defaults + helpers
    "EqualSplitRouter", "LocalityRouter", "scenario_from_config",
    "GreedyReleasePicker", "GreedyLogicalStartPicker",
    "TableBoundLogicalStartPicker", "BreachAwareReleasePicker",
    "CooldownLogicalStartPicker",
    # smoke
    "smoke",
]
