from .pipeline import ByteCorpus, TokenPipeline

__all__ = ["ByteCorpus", "TokenPipeline"]
