"""Deterministic data pipeline.

Batches are a pure function of (seed, step) via counter-based Philox
bit-generators, so the pipeline is *stateless*: resuming from a checkpoint
needs only the step number (no iterator state to snapshot), and every
data-parallel host can materialize exactly its shard.  Two sources:

  * ``TokenPipeline`` — synthetic LM tokens with a Zipfian unigram mixture
    plus short Markov motifs (so a model can actually reduce loss on it).
  * ``ByteCorpus``   — byte-level LM over a real text file (the repo's own
    sources by default): overlapping windows, deterministic shuffling.
"""
from __future__ import annotations

import os
from typing import Dict, Optional

import numpy as np

from ..configs.base import InputShape, ModelConfig


def _rng(seed: int, step: int, salt: int = 0) -> np.random.Generator:
    # counter-based: batches are a pure function of (seed, step, salt)
    return np.random.Generator(
        np.random.Philox(key=(seed << 32) ^ (salt & 0xFFFFFFFF),
                         counter=step))


class TokenPipeline:
    """Synthetic-but-learnable token stream."""

    def __init__(self, cfg: ModelConfig, shape: InputShape, seed: int = 0,
                 n_motifs: int = 64, motif_len: int = 8):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        v = cfg.vocab_size
        motif_rng = _rng(seed, 0, salt=999)
        self.motifs = motif_rng.integers(0, v, (n_motifs, motif_len))
        # Zipf-ish unigram distribution over a capped head of the vocab
        head = min(v, 4096)
        w = 1.0 / np.arange(1, head + 1) ** 1.1
        self.head = head
        self.p = w / w.sum()

    def _tokens(self, rng, B: int, S: int) -> np.ndarray:
        toks = rng.choice(self.head, p=self.p, size=(B, S + 1))
        # paste motifs at random offsets (repeatable structure => learnable)
        n_paste = max(1, (S // 64))
        for b in range(B):
            idx = rng.integers(0, len(self.motifs), n_paste)
            offs = rng.integers(0, S + 1 - self.motifs.shape[1], n_paste)
            for i, o in zip(idx, offs):
                toks[b, o: o + self.motifs.shape[1]] = self.motifs[i]
        return toks.astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Global batch for `step` (numpy, host-resident)."""
        cfg, shape = self.cfg, self.shape
        B, S = shape.global_batch, shape.seq_len
        rng = _rng(self.seed, step)
        out: Dict[str, np.ndarray] = {}
        if cfg.frontend == "audio":
            out["frames"] = rng.standard_normal(
                (B, S, cfg.frontend_dim)).astype(np.float32)
            out["targets"] = rng.integers(0, cfg.vocab_size,
                                          (B, S)).astype(np.int32)
            return out
        if cfg.frontend == "vision":
            nf = cfg.n_frontend_tokens
            toks = self._tokens(rng, B, S - nf)
            out["patch_embeds"] = rng.standard_normal(
                (B, nf, cfg.frontend_dim)).astype(np.float32)
            out["tokens"] = toks[:, :-1]
            out["targets"] = toks[:, 1:]
            return out
        toks = self._tokens(rng, B, S)
        out["tokens"] = toks[:, :-1]
        out["targets"] = toks[:, 1:]
        return out

    def shard_batch(self, step: int, lo: int, hi: int):
        """Rows [lo, hi) of the global batch — what one DP host loads.
        Deterministic: materializes the global batch row-block only."""
        full = self.batch(step)
        return {k: v[lo:hi] for k, v in full.items()}


class ByteCorpus:
    """Byte-level LM windows over a text file tree."""

    def __init__(self, root: str = ".", exts=(".py", ".md"),
                 max_bytes: int = 8 << 20, seed: int = 0):
        bufs = []
        total = 0
        for dirpath, _dirs, files in sorted(os.walk(root)):
            if any(part.startswith(".") or part == "__pycache__"
                   for part in dirpath.split(os.sep)):
                continue
            for fn in sorted(files):
                if not fn.endswith(exts):
                    continue
                try:
                    with open(os.path.join(dirpath, fn), "rb") as f:
                        bufs.append(f.read())
                except OSError:
                    continue
                total += len(bufs[-1])
                if total >= max_bytes:
                    break
            if total >= max_bytes:
                break
        data = b"\n".join(bufs) if bufs else b"empty corpus"
        self.data = np.frombuffer(data, np.uint8)
        self.seed = seed

    def batch(self, step: int, B: int, S: int) -> Dict[str, np.ndarray]:
        rng = _rng(self.seed, step, salt=7)
        n = len(self.data) - (S + 1)
        starts = rng.integers(0, max(n, 1), B)
        rows = np.stack([self.data[s: s + S + 1] for s in starts])
        rows = rows.astype(np.int32)
        return {"tokens": rows[:, :-1], "targets": rows[:, 1:]}
