"""Model/config system.

Every assigned architecture is described by a :class:`ModelConfig` — a frozen
dataclass that fully determines parameter shapes, the per-layer block pattern,
sharding-relevant dimensions and serving behaviour.  Configs are registered in
``REGISTRY`` and selectable by ``--arch <id>`` everywhere (launchers, dryrun,
benchmarks, tests).

Layer kinds
-----------
``global``     full (causal or bidirectional) attention
``local``      sliding-window attention (``window`` tokens)
``chunked``    chunked-local attention (llama4 iRoPE style: attention within
               aligned chunks of ``window`` tokens)
``recurrent``  RG-LRU block (RecurrentGemma / Griffin)
``ssm``        Mamba-2 SSD block

The per-layer pattern is expressed as a repeating ``pattern`` tuple plus an
optional ``pattern_tail`` for architectures whose depth is not a multiple of
the period (e.g. recurrentgemma-2b: 26 = 8x(rec,rec,local) + (rec,rec)).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0            # dimension of the shared expert MLP
    first_dense_layers: int = 0     # leading layers that use a dense MLP
    d_ff_dense: int = 0             # d_ff of dense (non-MoE) layers
    moe_period: int = 1             # MoE every `period` layers (llama4: 2)
    capacity_factor: float = 1.25
    router_softcap: float = 0.0
    dispatch: str = "sort"          # "sort" (scalable) | "einsum" (GShard)


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSDConfig:
    """Mamba-2 SSD block dimensions."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU block dimensions."""

    lru_width: int = 0              # 0 -> d_model
    conv_width: int = 4
    block_width_multiplier: float = 1.0


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    pattern: Tuple[str, ...] = ("global",)
    pattern_tail: Tuple[str, ...] = ()
    window: int = 4096              # local / chunked attention window
    activation: str = "swiglu"      # swiglu | geglu
    qkv_bias: bool = False
    qk_norm: bool = False
    post_norms: bool = False        # gemma2-style post-attn/post-ffn norms
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    rope_theta: float = 10000.0
    rope_theta_global: float = 0.0  # gemma3: different theta for global layers
    nope_global: bool = False       # llama4 iRoPE: no rope on global layers
    tie_embeddings: bool = True
    encoder_only: bool = False
    frontend: Optional[str] = None  # None | "vision" | "audio"
    frontend_dim: int = 0           # embedding dim provided by the stub frontend
    n_frontend_tokens: int = 0      # number of prepended frontend tokens (vlm)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssd: Optional[SSDConfig] = None
    rglru: Optional[RGLRUConfig] = None
    emb_scale: bool = True          # gemma-style sqrt(d_model) embedding scale
    norm_eps: float = 1e-6
    max_seq_len: int = 1 << 20      # positional-encoding safety bound
    dtype: str = "bfloat16"
    # [source; verified-tier] provenance string from the assignment table
    source: str = ""

    # -- derived -----------------------------------------------------------
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expanded per-layer kind list of length n_layers."""
        kinds: list[str] = []
        period = len(self.pattern)
        n_body = self.n_layers - len(self.pattern_tail)
        assert n_body % period == 0, (
            f"{self.name}: {n_body} body layers not a multiple of period "
            f"{period}; use pattern_tail"
        )
        for i in range(n_body):
            kinds.append(self.pattern[i % period])
        kinds.extend(self.pattern_tail)
        assert len(kinds) == self.n_layers
        return tuple(kinds)

    def n_periods(self) -> int:
        return (self.n_layers - len(self.pattern_tail)) // len(self.pattern)

    def is_subquadratic(self) -> bool:
        """True if no layer does unbounded full attention over the sequence,
        or the arch mixes bounded-window layers with a sparse set of global
        layers (gemma2/gemma3/llama4 style) — the assignment's criterion for
        running long_500k."""
        kinds = set(self.layer_kinds())
        if kinds <= {"ssm", "recurrent", "local", "chunked"}:
            return True
        # mixed local/global archs qualify (>=half the layers bounded)
        n_global = sum(1 for k in self.layer_kinds() if k == "global")
        return ("local" in kinds or "chunked" in kinds) and (
            n_global * 2 <= self.n_layers
        )

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, hd = self.d_model, self.resolved_head_dim()
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings and not self.encoder_only:
            total += self.vocab_size * d
        if self.frontend is not None and self.frontend_dim:
            total += self.frontend_dim * d
        for i, kind in enumerate(self.layer_kinds()):
            total += 2 * d  # pre-norms (attn+ffn); close enough for post-norm
            if kind in ("global", "local", "chunked"):
                if self.mla is not None:
                    m = self.mla
                    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
                    total += d * m.q_lora_rank + m.q_lora_rank  # q down + norm
                    total += m.q_lora_rank * self.n_heads * qk_hd  # q up
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    total += m.kv_lora_rank  # kv norm
                    total += m.kv_lora_rank * self.n_heads * (
                        m.qk_nope_head_dim + m.v_head_dim
                    )
                    total += self.n_heads * m.v_head_dim * d  # o proj
                else:
                    total += d * self.n_heads * hd  # q
                    total += 2 * d * self.n_kv_heads * hd  # k, v
                    total += self.n_heads * hd * d  # o
                    if self.qkv_bias:
                        total += (self.n_heads + 2 * self.n_kv_heads) * hd
            elif kind == "recurrent":
                r = self.rglru or RGLRUConfig()
                w = r.lru_width or d
                nb = max(self.n_heads, 1)  # block-diagonal gate blocks
                total += 2 * d * w  # in-proj (x branch, gate branch)
                total += r.conv_width * w  # temporal conv
                total += 2 * w * (w // nb)  # block-diagonal r,i gates
                total += w  # a (recurrence decay) param
                total += w * d  # out proj
            elif kind == "ssm":
                s = self.ssd or SSDConfig()
                di = s.d_inner(d)
                nh = s.n_heads(d)
                total += d * (2 * di + 2 * s.n_groups * s.d_state + nh)
                total += s.conv_width * (di + 2 * s.n_groups * s.d_state)
                total += 3 * nh  # A, D, dt_bias
                total += di  # gate norm
                total += di * d  # out proj
            # FFN
            if kind == "ssm":
                continue  # mamba2 blocks have no separate FFN
            if kind == "recurrent":
                total += 3 * d * self.d_ff
                continue
            if self.moe is not None and self.is_moe_layer(i):
                m = self.moe
                total += d * m.n_experts  # router
                total += m.n_experts * 3 * d * m.d_ff_expert
                if m.n_shared_experts:
                    total += 3 * d * (
                        m.d_ff_shared or m.d_ff_expert * m.n_shared_experts
                    )
            elif self.moe is not None:
                total += 3 * d * (m_dff := (self.moe.d_ff_dense or self.d_ff))
            else:
                total += 3 * d * self.d_ff
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        per_layer_all = m.n_experts * 3 * self.d_model * m.d_ff_expert
        per_layer_active = m.top_k * 3 * self.d_model * m.d_ff_expert
        n_moe_layers = sum(
            1 for i in range(self.n_layers) if self.is_moe_layer(i)
        )
        return self.param_count() - n_moe_layers * (
            per_layer_all - per_layer_active
        )

    def is_moe_layer(self, i: int) -> bool:
        """Whether layer ``i`` uses the MoE FFN (vs a dense MLP)."""
        if self.moe is None:
            return False
        m = self.moe
        if i < m.first_dense_layers:
            return False
        return i % m.moe_period == m.moe_period - 1

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assignment: 4 per arch)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4096, 256, "train"),
    InputShape("prefill_32k", 32768, 32, "prefill"),
    InputShape("decode_32k", 32768, 128, "decode"),
    InputShape("long_500k", 524288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def cell_is_runnable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch x shape) cell."""
    if cfg.encoder_only and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.is_subquadratic():
        return False, "pure full-attention arch; long_500k needs sub-quadratic attention"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
SMOKE_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(fn: Callable[[], ModelConfig]) -> Callable[[], ModelConfig]:
    cfg = fn()
    REGISTRY[cfg.name] = fn
    return fn


def register_smoke(name: str):
    def deco(fn: Callable[[], ModelConfig]) -> Callable[[], ModelConfig]:
        SMOKE_REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]()


def get_smoke_config(name: str) -> ModelConfig:
    if name not in SMOKE_REGISTRY:
        raise KeyError(f"no smoke config for {name!r}")
    return SMOKE_REGISTRY[name]()


def list_archs() -> list[str]:
    return sorted(REGISTRY)
