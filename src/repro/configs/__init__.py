from .base import (
    SHAPES,
    SHAPE_BY_NAME,
    InputShape,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    RGLRUConfig,
    SSDConfig,
    cell_is_runnable,
    get_config,
    get_smoke_config,
    list_archs,
)
from . import archs as _archs  # noqa: F401  (populates the registry)

__all__ = [
    "SHAPES",
    "SHAPE_BY_NAME",
    "InputShape",
    "MLAConfig",
    "MoEConfig",
    "ModelConfig",
    "RGLRUConfig",
    "SSDConfig",
    "cell_is_runnable",
    "get_config",
    "get_smoke_config",
    "list_archs",
]
