"""The 10 assigned architectures (+ reduced smoke variants).

Every full config matches the assignment table exactly; provenance is recorded
in ``source``.  Smoke variants keep the *family shape* (same layer pattern,
same block kinds, same ratios) at laptop scale.
"""
from __future__ import annotations

from .base import (
    MLAConfig,
    MoEConfig,
    ModelConfig,
    RGLRUConfig,
    SSDConfig,
    register,
    register_smoke,
)

# ---------------------------------------------------------------------------
# MoE family
# ---------------------------------------------------------------------------


@register
def llama4_maverick() -> ModelConfig:
    # 48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1,
    # iRoPE: 3 chunked-local layers (rope) : 1 global layer (nope).
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        pattern=("chunked", "chunked", "chunked", "global"),
        window=8192,
        nope_global=True,
        activation="swiglu",
        tie_embeddings=False,
        moe=MoEConfig(
            n_experts=128,
            top_k=1,
            d_ff_expert=8192,
            n_shared_experts=1,
            d_ff_shared=8192,
            moe_period=2,       # interleave_moe_layer_step=2 (odd layers MoE)
            d_ff_dense=16384,   # dense layers between MoE layers
        ),
        rope_theta=500000.0,
        source="[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]",
    )


@register
def deepseek_v2() -> ModelConfig:
    # 60L d_model=5120 128H d_ff=1536/expert vocab=102400,
    # MLA kv_lora=512, 2 shared + 160 routed top-6, first layer dense.
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=192,  # qk head dim = nope(128) + rope(64)
        d_ff=12288,
        vocab_size=102400,
        pattern=("global",),
        activation="swiglu",
        tie_embeddings=False,
        mla=MLAConfig(
            q_lora_rank=1536,
            kv_lora_rank=512,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            n_experts=160,
            top_k=6,
            d_ff_expert=1536,
            n_shared_experts=2,
            d_ff_shared=2 * 1536,
            first_dense_layers=1,
            d_ff_dense=12288,
        ),
        source="[arXiv:2405.04434; hf]",
    )


# ---------------------------------------------------------------------------
# Hybrid / SSM family
# ---------------------------------------------------------------------------


@register
def recurrentgemma_2b() -> ModelConfig:
    # 26L d_model=2560 10H (MQA kv=1, head_dim=256) d_ff=7680 vocab=256000,
    # RG-LRU + local attn, pattern (rec, rec, local); 26 = 8*3 + 2.
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        pattern=("recurrent", "recurrent", "local"),
        pattern_tail=("recurrent", "recurrent"),
        window=2048,
        activation="geglu",
        rglru=RGLRUConfig(lru_width=2560, conv_width=4),
        source="[arXiv:2402.19427; hf]",
    )


@register
def mamba2_2p7b() -> ModelConfig:
    # 64L d_model=2560 attn-free, ssm_state=128, vocab=50280.
    return ModelConfig(
        name="mamba2-2.7b",
        family="ssm",
        n_layers=64,
        d_model=2560,
        n_heads=80,  # d_inner(5120) / head_dim(64)
        n_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        pattern=("ssm",),
        ssd=SSDConfig(d_state=128, head_dim=64, expand=2, n_groups=1),
        source="[arXiv:2405.21060; unverified]",
    )


# ---------------------------------------------------------------------------
# Dense family
# ---------------------------------------------------------------------------


@register
def gemma_7b() -> ModelConfig:
    # 28L d_model=3072 16H (MHA kv=16, head_dim=256) d_ff=24576 vocab=256000.
    return ModelConfig(
        name="gemma-7b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=16,
        n_kv_heads=16,
        head_dim=256,
        d_ff=24576,
        vocab_size=256000,
        pattern=("global",),
        activation="geglu",
        source="[arXiv:2403.08295; hf]",
    )


@register
def qwen15_110b() -> ModelConfig:
    # 80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064, QKV bias.
    return ModelConfig(
        name="qwen1.5-110b",
        family="dense",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=49152,
        vocab_size=152064,
        pattern=("global",),
        qkv_bias=True,
        activation="swiglu",
        tie_embeddings=False,
        emb_scale=False,
        rope_theta=1000000.0,
        source="[hf:Qwen/Qwen1.5-0.5B; hf]",
    )


@register
def gemma3_12b() -> ModelConfig:
    # 48L d_model=3840 16H (GQA kv=8, head_dim=256) d_ff=15360 vocab=262144,
    # 5 local : 1 global, 128k context, qk-norm, dual rope theta.
    return ModelConfig(
        name="gemma3-12b",
        family="dense",
        n_layers=48,
        d_model=3840,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=15360,
        vocab_size=262144,
        pattern=("local", "local", "local", "local", "local", "global"),
        window=1024,
        qk_norm=True,
        post_norms=True,
        activation="geglu",
        rope_theta=10000.0,
        rope_theta_global=1000000.0,
        source="[hf:google/gemma-3-1b-pt; unverified]",
    )


@register
def gemma2_2b() -> ModelConfig:
    # 26L d_model=2304 8H (GQA kv=4, head_dim=256) d_ff=9216 vocab=256000,
    # alternating local/global, logit softcap.
    return ModelConfig(
        name="gemma2-2b",
        family="dense",
        n_layers=26,
        d_model=2304,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab_size=256000,
        pattern=("local", "global"),
        window=4096,
        post_norms=True,
        logit_softcap=30.0,
        attn_softcap=50.0,
        activation="geglu",
        source="[arXiv:2408.00118; hf]",
    )


# ---------------------------------------------------------------------------
# Multimodal backbones (frontends are stubs per the assignment)
# ---------------------------------------------------------------------------


@register
def internvl2_2b() -> ModelConfig:
    # InternLM2-1.8B backbone: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
    # vocab=92553; InternViT frontend stub supplies patch embeddings.
    return ModelConfig(
        name="internvl2-2b",
        family="vlm",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=92553,
        pattern=("global",),
        activation="swiglu",
        frontend="vision",
        frontend_dim=1024,       # InternViT-300M width, projected to d_model
        n_frontend_tokens=256,   # pixel-unshuffled 448x448 tile
        rope_theta=1000000.0,
        source="[arXiv:2404.16821; hf]",
    )


@register
def hubert_xlarge() -> ModelConfig:
    # Encoder-only: 48L d_model=1280 16H (MHA) d_ff=5120 vocab=504 (k-means
    # targets); conv waveform frontend stub supplies frame embeddings.
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        pattern=("global",),
        activation="geglu",
        encoder_only=True,
        frontend="audio",
        frontend_dim=512,  # conv feature extractor output width
        emb_scale=False,
        source="[arXiv:2106.07447; unverified]",
    )


# ---------------------------------------------------------------------------
# Smoke variants — same family/pattern, laptop scale.
# ---------------------------------------------------------------------------


def _smoke(cfg: ModelConfig, **kw) -> ModelConfig:
    base = dict(
        n_layers=len(cfg.pattern) + len(cfg.pattern_tail),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        window=16,
        max_seq_len=4096,
        dtype="float32",
    )
    base.update(kw)
    return cfg.replace(**base)


@register_smoke("llama4-maverick-400b-a17b")
def smoke_llama4() -> ModelConfig:
    return _smoke(
        llama4_maverick(),
        moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=128,
                      n_shared_experts=1, d_ff_shared=128),
    )


@register_smoke("deepseek-v2-236b")
def smoke_deepseek() -> ModelConfig:
    return _smoke(
        deepseek_v2(),
        n_layers=2,
        head_dim=24,  # nope16 + rope8
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                      n_shared_experts=2, d_ff_shared=128,
                      first_dense_layers=1, d_ff_dense=128),
    )


@register_smoke("recurrentgemma-2b")
def smoke_recurrentgemma() -> ModelConfig:
    return _smoke(
        recurrentgemma_2b(),
        n_layers=5,  # one (rec, rec, local) period + (rec, rec) tail
        n_kv_heads=1,
        rglru=RGLRUConfig(lru_width=64, conv_width=4),
    )


@register_smoke("mamba2-2.7b")
def smoke_mamba2() -> ModelConfig:
    return _smoke(
        mamba2_2p7b(),
        n_heads=8,  # d_inner(128) / head_dim(16)
        n_kv_heads=0,
        ssd=SSDConfig(d_state=16, head_dim=16, expand=2, n_groups=1, chunk=8),
    )


@register_smoke("gemma-7b")
def smoke_gemma7b() -> ModelConfig:
    return _smoke(gemma_7b(), n_layers=2, n_kv_heads=4)


@register_smoke("qwen1.5-110b")
def smoke_qwen() -> ModelConfig:
    return _smoke(qwen15_110b(), n_layers=2)


@register_smoke("gemma3-12b")
def smoke_gemma3() -> ModelConfig:
    return _smoke(gemma3_12b())


@register_smoke("gemma2-2b")
def smoke_gemma2() -> ModelConfig:
    return _smoke(gemma2_2b())


@register_smoke("internvl2-2b")
def smoke_internvl() -> ModelConfig:
    return _smoke(internvl2_2b(), n_layers=2, frontend_dim=32,
                  n_frontend_tokens=8)


@register_smoke("hubert-xlarge")
def smoke_hubert() -> ModelConfig:
    return _smoke(hubert_xlarge(), n_layers=2, frontend_dim=32)
