"""Step-atomic checkpoints with reshard-on-restore.

Layout:  <dir>/step_000123/  arrays.npz  meta.json
Writes go to ``<dir>/.tmp_<step>`` and are *renamed* into place — a crash
mid-write never corrupts the latest checkpoint (fault tolerance).  Keep-K
GC deletes the oldest checkpoints after a successful save.

Restore takes the *abstract* state tree plus target shardings and
``jax.device_put``s each leaf — the saved mesh shape is irrelevant, so a
run can resume on a *different* mesh (elastic re-scale after node loss).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flat(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        out[key] = leaf
    return out


def save(ckpt_dir: str, step: int, state, keep: int = 3,
         extra_meta: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_{step}")
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flat(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"step": step, "n_arrays": len(arrays),
            **(extra_meta or {})}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and os.path.exists(
                       os.path.join(ckpt_dir, d, "meta.json")))
    return int(steps[-1].split("_")[1]) if steps else None


def restore(ckpt_dir: str, abstract_state, shardings=None,
            step: Optional[int] = None):
    """-> (state, meta).  `shardings` may target ANY mesh (reshard on
    load); None restores host-local arrays."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    npz = np.load(os.path.join(d, "arrays.npz"))
    flat_abs = _flat(abstract_state)
    flat_sh = _flat(shardings) if shardings is not None else None

    def build(path_key, leaf_abs):
        arr = npz[path_key]
        assert tuple(arr.shape) == tuple(leaf_abs.shape), (
            path_key, arr.shape, leaf_abs.shape)
        arr = arr.astype(leaf_abs.dtype)
        if flat_sh is not None:
            return jax.device_put(arr, flat_sh[path_key])
        return jax.device_put(arr)

    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(abstract_state)
    rebuilt = []
    for path, leaf in leaves_p:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        rebuilt.append(build(key, leaf))
    return jax.tree_util.tree_unflatten(treedef, rebuilt), meta
