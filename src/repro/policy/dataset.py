"""DecisionTrace JSONL streams -> supervised training matrices.

The scheduler's decision pipeline already emits a ``DecisionTrace`` per
placement; with ``trace_features`` on (``PlatformConfig
pipeline.trace_features``), each trace carries every node's raw feature
row captured *before* the decision mutated the cluster
(``pipeline.candidate_feature_row``), plus the chosen node.  A
``JsonlObserver`` artifact of such a run is therefore a complete offline
dataset of (cluster state, candidate features, decision, outcome) —
this module parses it back:

  * schedule records (schema v2) become ``DecisionRecord``s: a
    ``[n_candidates, n_features]`` float32 matrix, the chosen-candidate
    index (the imitation label), and outcome annotations,
  * tick records carry cumulative request/violation counters, so each
    decision is labelled ``qos_breach`` by the *windowed* violation
    rate over ``qos_horizon_s`` after it — no re-simulation needed,
  * the trailing summary record supplies run-level fallbacks.

Versionless (v1) records predate the feature capture and are counted
and skipped, never errors: old artifacts stay readable, they just
contribute no training rows.  Everything here is numpy-only — JAX
enters in ``repro.policy.train``.
"""
from __future__ import annotations

import hashlib
import json
import os
from bisect import bisect_right
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Iterable, List, Optional, Tuple, Union

import numpy as np

from ..core.pipeline import CANDIDATE_FEATURES, TRACE_SCHEMA_VERSION

#: decisions whose within-horizon violation rate exceeds this are
#: labelled ``qos_breach`` (matches the benchmarks' "materially
#: violating" threshold, not any single violated request)
BREACH_THRESHOLD = 0.01


@dataclass
class DecisionRecord:
    """One scheduling decision as a training example."""

    now: float
    fn: str
    node_ids: List[int]
    features: np.ndarray          # [n_candidates, n_features] float32
    chosen: int                   # index into node_ids (the label)
    requested: int
    cold_start: bool = False      # decision scaled out a fresh node
    qos_breach: bool = False      # QoS violations within the horizon


@dataclass
class PolicyDataset:
    """Parsed decisions plus the bookkeeping a trainer needs."""

    decisions: List[DecisionRecord] = field(default_factory=list)
    feature_names: Tuple[str, ...] = CANDIDATE_FEATURES
    schema_version: int = TRACE_SCHEMA_VERSION
    #: v1 records seen (no ``schema_version`` key) — readable, skipped
    skipped_versionless: int = 0
    #: v2 records without feature capture (``trace_features`` off) or
    #: without a usable label (failed decision, unknown chosen node)
    skipped_unlabelled: int = 0
    #: the trailing run-summary record, when the stream carried one
    summary: Optional[Dict[str, Any]] = None

    def __len__(self) -> int:
        return len(self.decisions)

    @property
    def n_features(self) -> int:
        return len(self.feature_names)

    @property
    def max_candidates(self) -> int:
        return max((len(d.node_ids) for d in self.decisions), default=0)


def _iter_records(source) -> Iterable[dict]:
    """Yield JSON records from a path, an open iterable of lines, or an
    iterable of already-parsed dicts."""
    if isinstance(source, (str, os.PathLike)):
        with open(source) as fh:
            for line in fh:
                line = line.strip()
                if line:
                    yield json.loads(line)
        return
    for item in source:
        if isinstance(item, dict):
            yield item
        else:
            line = item.strip()
            if line:
                yield json.loads(line)


def load_traces(source, *, qos_horizon_s: float = 30.0,
                breach_threshold: float = BREACH_THRESHOLD
                ) -> PolicyDataset:
    """Parse one JSONL event stream into a ``PolicyDataset``.

    ``qos_breach`` labelling: the stream's tick records carry cumulative
    request/violation counters; a decision at time ``t`` is breached
    when the violation rate over ``(t, t + qos_horizon_s]`` exceeds
    ``breach_threshold``.  Streams without the counters (pre-summary
    artifacts) fall back to the run summary's per-function rate, then
    to False."""
    ds = PolicyDataset()
    schedules: List[dict] = []
    tick_t: List[float] = []
    tick_req: List[float] = []
    tick_viol: List[float] = []
    for rec in _iter_records(source):
        ev = rec.get("event")
        if ev == "tick" and "requests" in rec:
            tick_t.append(float(rec["now"]))
            tick_req.append(float(rec["requests"]))
            tick_viol.append(float(rec["violated"]))
        elif ev == "schedule" and "trace" in rec:
            schedules.append(rec["trace"])
        elif ev == "summary":
            ds.summary = rec

    def _window_breach(now: float) -> Optional[bool]:
        if len(tick_t) < 2:
            return None
        i0 = bisect_right(tick_t, now) - 1
        i1 = bisect_right(tick_t, now + qos_horizon_s) - 1
        if i0 < 0:
            i0 = 0
        if i1 <= i0:
            i1 = min(i0 + 1, len(tick_t) - 1)
        dreq = tick_req[i1] - tick_req[i0]
        dviol = tick_viol[i1] - tick_viol[i0]
        return (dviol / max(dreq, 1e-9)) > breach_threshold

    summary_rates = (ds.summary or {}).get("per_fn_violation_rate", {})

    for trace in schedules:
        if "schema_version" not in trace:
            ds.skipped_versionless += 1
            continue
        cands = trace.get("candidates")
        chosen_node = trace.get("chosen_node", -1)
        if not cands or chosen_node < 0:
            ds.skipped_unlabelled += 1
            continue
        # binder/filter rejections are feasibility, not preference: a
        # pointwise scorer cannot see them, and serving re-applies them
        # — so rejected nodes leave the training candidate set (never
        # the chosen node itself, which some stage rejected before
        # another bound it)
        rejected = set(trace.get("rejected", ())) - {chosen_node}
        kept = [(int(nid), row) for nid, row in cands
                if int(nid) not in rejected]
        node_ids = [nid for nid, _row in kept]
        if chosen_node not in node_ids:
            ds.skipped_unlabelled += 1
            continue
        feats = np.asarray([row for _nid, row in kept],
                           dtype=np.float32)
        if feats.shape[1] != len(ds.feature_names):
            ds.skipped_unlabelled += 1
            continue
        now = float(trace["now"])
        breach = _window_breach(now)
        if breach is None:
            breach = summary_rates.get(
                trace.get("fn", ""), 0.0) > breach_threshold
        ds.decisions.append(DecisionRecord(
            now=now, fn=trace.get("fn", ""), node_ids=node_ids,
            features=feats, chosen=node_ids.index(chosen_node),
            requested=int(trace.get("requested", 1)),
            cold_start=bool(trace.get("scale_out", False)),
            qos_breach=bool(breach)))
    return ds


# ---------------------------------------------------------------------------
# Splitting / batching
# ---------------------------------------------------------------------------


def merge(datasets: Iterable[PolicyDataset]) -> PolicyDataset:
    """Concatenate datasets from several collection runs (e.g. one per
    scenario seed) — skip counters add, the last summary wins."""
    out = PolicyDataset()
    for ds in datasets:
        out.decisions.extend(ds.decisions)
        out.skipped_versionless += ds.skipped_versionless
        out.skipped_unlabelled += ds.skipped_unlabelled
        if ds.summary is not None:
            out.summary = ds.summary
    return out


def _holdout_hash(rec: DecisionRecord) -> int:
    """Deterministic per-decision bucket in [0, 1000) — stable across
    runs, machines and record order (md5, not ``hash()``)."""
    key = f"{rec.fn}:{rec.now:.3f}".encode()
    return int.from_bytes(hashlib.md5(key).digest()[:4], "big") % 1000


def split(ds: PolicyDataset, holdout_frac: float = 0.2
          ) -> Tuple[PolicyDataset, PolicyDataset]:
    """Deterministic train/holdout split keyed on (fn, time) — the same
    artifact always splits the same way, independent of parse order."""
    cut = int(holdout_frac * 1000)
    train = [d for d in ds.decisions if _holdout_hash(d) >= cut]
    hold = [d for d in ds.decisions if _holdout_hash(d) < cut]
    return (replace(ds, decisions=train), replace(ds, decisions=hold))


def matrices(ds: PolicyDataset, n_candidates: Optional[int] = None
             ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Fixed-width batch form: ``(X [N, C, F], mask [N, C], y [N])``.

    Decisions with fewer candidates are zero-padded and masked;
    decisions with more than ``n_candidates`` keep their first
    ``n_candidates`` rows (the chosen row is always kept — decisions
    whose label falls outside the cap are dropped, which cannot happen
    when ``n_candidates >= ds.max_candidates``, the default)."""
    C = n_candidates or max(ds.max_candidates, 1)
    F = ds.n_features
    keep = [d for d in ds.decisions if d.chosen < C]
    N = len(keep)
    X = np.zeros((N, C, F), dtype=np.float32)
    mask = np.zeros((N, C), dtype=np.float32)
    y = np.zeros((N,), dtype=np.int32)
    for i, d in enumerate(keep):
        c = min(len(d.node_ids), C)
        X[i, :c] = d.features[:c]
        mask[i, :c] = 1.0
        y[i] = d.chosen
    return X, mask, y


def normalization(X: np.ndarray, mask: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Masked per-feature mean / std over all real candidate rows —
    stored inside the policy (not trained, not weight-decayed) so
    serving applies the identical transform."""
    m = mask.reshape(-1).astype(bool)
    rows = X.reshape(-1, X.shape[-1])[m]
    if rows.size == 0:
        F = X.shape[-1]
        return (np.zeros(F, np.float32), np.ones(F, np.float32))
    mu = rows.mean(axis=0)
    sd = rows.std(axis=0)
    sd = np.where(sd < 1e-6, 1.0, sd)
    return mu.astype(np.float32), sd.astype(np.float32)


def reward_weights(ds: PolicyDataset, *, qos_penalty: float = 3.0,
                   cold_penalty: float = 0.5) -> np.ndarray:
    """Offline-RL per-decision weights: advantage-weighted imitation.

    Every logged decision starts at weight 1 (the behaviour policy is
    already strong); decisions followed by a QoS breach within the
    horizon are down-weighted by ``1 + qos_penalty`` and cold-start
    scale-outs by ``1 + cold_penalty``, so the learner imitates the
    trace's *good* outcomes preferentially.  Normalized to mean 1 so
    the loss scale (and learning-rate transfer) matches imitation."""
    w = np.ones(len(ds.decisions), dtype=np.float32)
    for i, d in enumerate(ds.decisions):
        if d.qos_breach:
            w[i] /= (1.0 + qos_penalty)
        if d.cold_start:
            w[i] /= (1.0 + cold_penalty)
    if len(w):
        w /= max(w.mean(), 1e-9)
    return w


__all__ = [
    "BREACH_THRESHOLD", "DecisionRecord", "PolicyDataset",
    "load_traces", "merge", "split", "matrices", "normalization",
    "reward_weights",
]
