"""Serve a trained policy as a pipeline ``NodeScorer`` stage.

``LearnedScorer`` implements the batched scorer protocol
(``score_batch``): one jitted MLP forward over the whole surviving
candidate set per pass, instead of O(candidates) Python ``score``
calls.  Candidate batches are padded to the next power of two so JIT
recompilation is bounded (log2(max_nodes) shapes, not one per cluster
size).

Hot-swap contract: ``swap(policy, epoch)`` atomically installs new
weights tagged with the serving epoch they were trained for; the
platform wires a PredictionService retrain listener that re-loads /
re-tags the scorer *inside* the same synchronous callback that bumps
the service epoch, so by the time any post-retrain decision runs the
scorer already matches.  ``ScorerStats.stale_serves`` counts scored
batches whose policy epoch lagged the expected epoch — the analogue of
the service's ``stale_epoch_hits``, and like it, it must stay 0 (the
policy tests assert it across a live retrain).

Until a policy is installed the scorer falls back to a jiagu-like
heuristic (warm nodes first, most-packed first), so the ``"learned"``
stack is runnable straight from a config dict — the platform smoke
builds it alongside the other registered schedulers with no artifact
on disk.  JAX is imported lazily, only when real weights swap in.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

from ..core.cluster import Node
from ..core.harvesting import (HarvestBinder, HarvestScaleOutBinder,
                               HarvestingScheduler, QosCooldownFilter)
from ..core.pipeline import (CandidatePass, CapacityTableGate,
                             DecisionContext, MemRoomFilter,
                             SchedulingPipeline, candidate_feature_row)
from ..core.scheduler import register_scheduler


class ScorerStats:
    """Serving counters (reset on construction, never on swap)."""

    __slots__ = ("batches", "scored_nodes", "swaps", "stale_serves")

    def __init__(self):
        self.batches = 0        # score_batch invocations
        self.scored_nodes = 0   # candidate rows scored
        self.swaps = 0          # policies installed
        self.stale_serves = 0   # batches served at a lagging epoch

    def snapshot(self) -> Dict[str, int]:
        return {k: getattr(self, k) for k in self.__slots__}


def _pad_len(n: int) -> int:
    """Next power of two >= n (bounded set of jit shapes)."""
    p = 1
    while p < n:
        p <<= 1
    return p


class LearnedScorer:
    """Batched ``NodeScorer`` over a swappable trained policy."""

    name = "learned"

    def __init__(self, policy: Optional[Dict[str, np.ndarray]] = None,
                 epoch: int = 0):
        self.policy: Optional[Dict[str, np.ndarray]] = None
        self.epoch = -1
        #: the serving epoch the world is at (service forest epoch);
        #: kept in lockstep by the platform's retrain listener
        self.expected_epoch = epoch
        self.stats = ScorerStats()
        self._fwd = None
        if policy is not None:
            self.swap(policy, epoch)

    # -- hot swap ---------------------------------------------------------

    def swap(self, policy: Dict[str, np.ndarray], epoch: int) -> None:
        """Atomically install ``policy`` as the scorer for ``epoch``."""
        import jax

        from .train import forward
        jp = {k: jax.numpy.asarray(v) for k, v in policy.items()}
        fwd = jax.jit(lambda x: forward(jp, x))
        # single-assignment order matters: the forward must exist
        # before the epoch tag says it serves
        self._fwd = fwd
        self.policy = policy
        self.epoch = epoch
        self.expected_epoch = epoch
        self.stats.swaps += 1

    def expect(self, epoch: int) -> None:
        """Declare the epoch serving must match (the retrain listener
        calls ``swap`` instead; this exists so tests can simulate a
        missed swap and watch ``stale_serves`` fire)."""
        self.expected_epoch = epoch

    # -- scoring ----------------------------------------------------------

    def score_batch(self, ctx: DecisionContext,
                    nodes: List[Node]) -> List[float]:
        self.stats.batches += 1
        self.stats.scored_nodes += len(nodes)
        if self.policy is not None and self.epoch != self.expected_epoch:
            self.stats.stale_serves += 1
        if not nodes:
            return []
        if self._fwd is None:
            # no trained weights yet: jiagu-like heuristic (warm nodes
            # first, most-packed first) keeps the stack runnable from a
            # bare config dict
            fn = ctx.fn
            return [
                (1e6 if fn in n.funcs else 0.0)
                + 1e3 * (n.funcs[fn].n_sat if fn in n.funcs else 0.0)
                + n.n_instances()
                for n in nodes]
        rows = np.asarray(
            [candidate_feature_row(ctx, n) for n in nodes],
            dtype=np.float32)
        pad = _pad_len(len(nodes))
        if pad != len(nodes):
            rows = np.concatenate(
                [rows, np.zeros((pad - len(nodes), rows.shape[1]),
                                np.float32)])
        scores = np.asarray(self._fwd(rows))
        return [float(s) for s in scores[:len(nodes)]]

    def score(self, ctx: DecisionContext, node: Node) -> float:
        return self.score_batch(ctx, [node])[0]


class LearnedScheduler(HarvestingScheduler):
    """The ``"learned"`` stack: the capacity-table ``PreDecision`` gate
    and the harvesting binders/release machinery, with the hand-tuned
    candidate ordering replaced by the trained scorer.

    The split of responsibilities is deliberate: placement *safety*
    stays with existing stages — the binder's critical-path capacity
    solve bounds every placement at ``harvest_headroom`` of the
    predicted capacity, and QoS-margin breaches release instances and
    put nodes in cooldown — while the policy only chooses *among*
    feasible candidates.  That is the same decoupling the paper draws
    between prediction and decision, and it is what lets a learned
    ordering ship without being able to regress QoS below the
    no-overcommit baseline (the ``BENCH_policy.json`` hard gate)."""

    name = "learned"

    def __init__(self, *args, **kw):
        self.learned_scorer = LearnedScorer()
        super().__init__(*args, **kw)

    def build_pipeline(self) -> SchedulingPipeline:
        cooldown = QosCooldownFilter()
        return SchedulingPipeline(
            pre_decision=CapacityTableGate(filters=(cooldown,)),
            passes=[CandidatePass(
                "learned", HarvestBinder(),
                filters=(cooldown, MemRoomFilter()),
                scorer=self.learned_scorer)],
            scale_out=HarvestScaleOutBinder())


register_scheduler(
    "learned",
    lambda ctx: LearnedScheduler(
        ctx.cluster, ctx.store, ctx.qos, ctx.predictor, m_max=ctx.m_max,
        harvest_headroom=ctx.harvest_headroom,
        qos_release_cooldown_s=ctx.qos_release_cooldown_s),
    needs_predictor=True, dual_staged_default=True)


__all__ = ["ScorerStats", "LearnedScorer", "LearnedScheduler"]
