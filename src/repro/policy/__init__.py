"""``repro.policy`` — learned scheduling policy, trained from
DecisionTraces and served as a hot-swappable pipeline stage.

Production-style split:

  * ``dataset`` — DecisionTrace JSONL -> feature matrices + labels +
    outcome annotations, deterministic train/holdout split,
  * ``train``   — small JAX MLP scorer; imitation of jiagu traces,
    plus an offline-RL mode with QoS/cold-start-penalized weighting,
  * ``store``   — versioned, epoch-tagged ``.npz`` persistence,
  * ``stage``   — the ``LearnedScorer`` pipeline stage and the
    registered ``"learned"`` scheduler stack, hot-swapped through the
    PredictionService retrain-epoch machinery.

``train`` is re-exported lazily: importing the package (which the
platform registry does on every build) must not pull JAX in.
"""
from .dataset import (DecisionRecord, PolicyDataset, load_traces,
                      matrices, merge, normalization, reward_weights,
                      split)
from .stage import LearnedScheduler, LearnedScorer, ScorerStats
from .store import POLICY_SCHEMA, PolicyStore, PolicyStoreError

#: lazy re-exports from ``.train`` (maps public name -> attribute
#: there; ``train_policy`` avoids shadowing the submodule itself)
_LAZY = {"TrainConfig": "TrainConfig", "train_policy": "train",
         "top1_agreement": "top1_agreement", "np_scores": "np_scores",
         "forward": "forward", "init_params": "init_params"}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(".train", __name__)
        return getattr(mod, _LAZY[name])
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "DecisionRecord", "PolicyDataset", "load_traces", "matrices",
    "merge", "normalization", "reward_weights", "split",
    "LearnedScheduler", "LearnedScorer", "ScorerStats",
    "POLICY_SCHEMA", "PolicyStore", "PolicyStoreError",
    *sorted(_LAZY),
]
