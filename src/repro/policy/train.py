"""Train a learned ``NodeScorer`` from parsed DecisionTraces.

The model is deliberately small: a two-hidden-layer tanh MLP mapping
one candidate's 14 raw features (``pipeline.CANDIDATE_FEATURES``) to a
scalar score; a decision scores all candidates and a masked softmax
over the scores is the placement distribution.  Two training modes:

  * ``imitation``  — weighted cross-entropy against the logged
    (jiagu) chosen node, every decision weight 1.  This is the
    behaviour-cloning baseline the acceptance gate measures (top-1
    agreement on the deterministic holdout split).
  * ``offline-rl`` — the same loss under advantage-style reward
    weights (``dataset.reward_weights``): decisions followed by a QoS
    breach within the horizon, or which paid a cold-start scale-out,
    are down-weighted, so the policy prefers the trace's good outcomes
    (one-step weighted regression, the standard offline approach when
    the behaviour policy is near-expert — no bootstrapping, no
    off-distribution actions).

Optimization reuses ``repro.optim.adamw`` (warmup+cosine, global-norm
clip, decoupled decay — biases escape decay by name, and the ``mu`` /
``sd`` normalization stats live *outside* the trainable tree entirely
so they are neither updated nor decayed).  Training is deterministic
under a fixed config: numpy RNG for init/shuffling, single jitted step
with fixed batch shapes.  JAX is imported lazily so merely importing
``repro.policy`` (e.g. via the platform registry) stays cheap.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import numpy as np

from .dataset import PolicyDataset, matrices, normalization, reward_weights

#: parameter keys updated by the optimizer ("bias*" escapes weight
#: decay by adamw's name rule; ``mu`` / ``sd`` are excluded entirely)
TRAINABLE_KEYS = ("w1", "bias1", "w2", "bias2", "w3", "bias3")


@dataclass
class TrainConfig:
    hidden: int = 32
    epochs: int = 10
    batch_size: int = 128
    lr: float = 0.01
    weight_decay: float = 1e-4
    seed: int = 0
    mode: str = "imitation"          # or "offline-rl"
    qos_penalty: float = 3.0         # offline-rl breach down-weight
    cold_penalty: float = 0.5        # offline-rl cold-start down-weight


def init_params(n_features: int, hidden: int, seed: int
                ) -> Dict[str, np.ndarray]:
    """Deterministic fan-in-scaled init (numpy RNG, not JAX keys — the
    policy store round-trips plain float32 arrays)."""
    rng = np.random.default_rng(seed)
    def w(shape):
        return rng.normal(0.0, 1.0 / math.sqrt(shape[0]),
                          shape).astype(np.float32)
    return {
        "w1": w((n_features, hidden)),
        "bias1": np.zeros(hidden, np.float32),
        "w2": w((hidden, hidden)),
        "bias2": np.zeros(hidden, np.float32),
        "w3": w((hidden, 1)),
        "bias3": np.zeros(1, np.float32),
    }


def forward(policy: Dict[str, Any], x):
    """Per-candidate scores, jnp math (jit-safe; ``x`` is [..., F]).

    Normalization is part of the policy — serving applies exactly the
    transform training fit, no separate scaler artifact."""
    import jax.numpy as jnp
    z = (x - policy["mu"]) / policy["sd"]
    h = jnp.tanh(z @ policy["w1"] + policy["bias1"])
    h = jnp.tanh(h @ policy["w2"] + policy["bias2"])
    return (h @ policy["w3"] + policy["bias3"])[..., 0]


def np_scores(policy: Dict[str, np.ndarray], x: np.ndarray) -> np.ndarray:
    """The same forward in numpy — lets evaluation and tests run
    without touching JAX (argmax agreement is insensitive to the tiny
    tanh ULP differences between the two stacks)."""
    z = (x - policy["mu"]) / policy["sd"]
    h = np.tanh(z @ policy["w1"] + policy["bias1"])
    h = np.tanh(h @ policy["w2"] + policy["bias2"])
    return (h @ policy["w3"] + policy["bias3"])[..., 0]


def top1_agreement(policy: Dict[str, np.ndarray], X: np.ndarray,
                   mask: np.ndarray, y: np.ndarray) -> float:
    """Fraction of decisions whose argmax score picks the logged node."""
    if len(y) == 0:
        return 0.0
    s = np_scores(policy, X) - 1e9 * (1.0 - mask)
    return float((s.argmax(axis=-1) == y).mean())


def train(train_ds: PolicyDataset,
          holdout_ds: Optional[PolicyDataset] = None,
          cfg: Optional[TrainConfig] = None
          ) -> Tuple[Dict[str, np.ndarray], Dict[str, float]]:
    """Fit the scorer; returns ``(policy, metrics)``.

    ``policy`` is a plain dict of float32 numpy arrays (weights +
    ``mu``/``sd``) — exactly what ``PolicyStore.save`` persists and
    ``stage.LearnedScorer.swap`` serves."""
    import jax
    import jax.numpy as jnp
    from ..optim import adamw

    cfg = cfg or TrainConfig()
    if len(train_ds) == 0:
        raise ValueError("policy.train: empty training dataset")
    C = max(train_ds.max_candidates,
            holdout_ds.max_candidates if holdout_ds else 0, 1)
    X, mask, y = matrices(train_ds, n_candidates=C)
    if cfg.mode == "offline-rl":
        w = reward_weights(train_ds, qos_penalty=cfg.qos_penalty,
                           cold_penalty=cfg.cold_penalty)
    elif cfg.mode == "imitation":
        w = np.ones(len(X), np.float32)
    else:
        raise ValueError(f"policy.train: unknown mode {cfg.mode!r} "
                         f"(imitation | offline-rl)")
    mu, sd = normalization(X, mask)
    stats = {"mu": jnp.asarray(mu), "sd": jnp.asarray(sd)}
    params = {k: jnp.asarray(v) for k, v in
              init_params(train_ds.n_features, cfg.hidden,
                          cfg.seed).items()}

    N = len(X)
    B = min(cfg.batch_size, N)
    steps_per_epoch = (N + B - 1) // B
    n_steps = max(cfg.epochs * steps_per_epoch, 1)
    acfg = adamw.AdamWConfig(
        lr=cfg.lr, weight_decay=cfg.weight_decay, clip_norm=1.0,
        warmup_steps=min(20, max(n_steps // 10, 1)),
        total_steps=n_steps, min_lr_frac=0.1)
    opt = adamw.init(params, acfg)

    def loss_fn(p, xb, mb, yb, wb):
        logits = forward({**p, **stats}, xb) + (mb - 1.0) * 1e9
        logz = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logz, yb[:, None], axis=-1)[:, 0]
        return jnp.sum(nll * wb) / jnp.maximum(jnp.sum(wb), 1e-9)

    @jax.jit
    def step(p, o, xb, mb, yb, wb):
        loss, grads = jax.value_and_grad(loss_fn)(p, xb, mb, yb, wb)
        p, o, _ = adamw.update(p, grads, o, acfg)
        return p, o, loss

    rng = np.random.default_rng(cfg.seed)
    last_loss = float("nan")
    for _epoch in range(cfg.epochs):
        order = rng.permutation(N)
        for s0 in range(0, N, B):
            idx = order[s0:s0 + B]
            if len(idx) < B:           # fixed shapes: wrap the tail
                idx = np.concatenate([idx, order[:B - len(idx)]])
            params, opt, loss = step(
                params, opt, jnp.asarray(X[idx]), jnp.asarray(mask[idx]),
                jnp.asarray(y[idx]), jnp.asarray(w[idx]))
        last_loss = float(loss)

    policy = {k: np.asarray(v, np.float32) for k, v in params.items()}
    policy["mu"], policy["sd"] = mu, sd
    metrics = {
        "loss": last_loss,
        "mode_weight_mean": float(w.mean()),
        "n_train": float(N),
        "train_agreement": top1_agreement(policy, X, mask, y),
    }
    if holdout_ds is not None and len(holdout_ds):
        Xh, mh, yh = matrices(holdout_ds, n_candidates=C)
        metrics["n_holdout"] = float(len(yh))
        metrics["holdout_agreement"] = top1_agreement(policy, Xh, mh, yh)
    return policy, metrics


__all__ = ["TrainConfig", "TRAINABLE_KEYS", "init_params", "forward",
           "np_scores", "top1_agreement", "train"]
