"""Versioned on-disk policy storage: ``.npz`` weights + JSON header.

Layout under one root directory::

    policy_e000003.npz   # float32 arrays (w*/bias*/mu/sd) + __meta__
    latest.json          # {"epoch": 3, "file": "policy_e000003.npz", ...}

Every saved policy is tagged with the *serving epoch* it was trained
for — the PredictionService forest epoch at save time — which is what
makes hot-swap race-free: the platform's retrain listener reloads the
store and re-tags the scorer in the same synchronous callback that
bumped the service epoch, so a scorer can always check "am I serving
the epoch the world is at?" (``stage.ScorerStats.stale_serves``).

``POLICY_SCHEMA`` versions the file format itself (array names + meta
keys); loading a newer schema than this reader speaks raises instead
of mis-deserializing.  Numpy-only — no JAX at store time.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

#: .npz layout version (bump on array-name / meta-key changes)
POLICY_SCHEMA = 1

#: arrays every stored policy must carry
REQUIRED_KEYS = ("w1", "bias1", "w2", "bias2", "w3", "bias3",
                 "mu", "sd")


class PolicyStoreError(ValueError):
    """A policy artifact failed schema validation."""


class PolicyStore:
    """Epoch-tagged save/load of learned-scorer weights."""

    def __init__(self, root: str):
        self.root = root

    # -- paths ------------------------------------------------------------

    def _path(self, epoch: int) -> str:
        return os.path.join(self.root, f"policy_e{epoch:06d}.npz")

    def _latest_path(self) -> str:
        return os.path.join(self.root, "latest.json")

    def epochs(self) -> List[int]:
        """Stored epochs, ascending (empty when the root is missing)."""
        if not os.path.isdir(self.root):
            return []
        out = []
        for name in os.listdir(self.root):
            if name.startswith("policy_e") and name.endswith(".npz"):
                try:
                    out.append(int(name[len("policy_e"):-len(".npz")]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_epoch(self) -> Optional[int]:
        eps = self.epochs()
        return eps[-1] if eps else None

    # -- save / load ------------------------------------------------------

    def save(self, policy: Dict[str, np.ndarray], *, epoch: int,
             mode: str = "imitation",
             feature_names: Sequence[str] = (),
             metrics: Optional[Dict[str, float]] = None) -> str:
        """Persist one policy tagged with its serving ``epoch``."""
        missing = [k for k in REQUIRED_KEYS if k not in policy]
        if missing:
            raise PolicyStoreError(
                f"policy is missing arrays {missing} "
                f"(required: {list(REQUIRED_KEYS)})")
        os.makedirs(self.root, exist_ok=True)
        meta = {
            "schema": POLICY_SCHEMA,
            "epoch": int(epoch),
            "mode": mode,
            "feature_names": list(feature_names),
            "n_features": int(policy["w1"].shape[0]),
            "hidden": int(policy["w1"].shape[1]),
            "metrics": {k: float(v) for k, v in (metrics or {}).items()},
        }
        path = self._path(epoch)
        arrays = {k: np.asarray(v, np.float32) for k, v in policy.items()}
        np.savez(path, __meta__=np.asarray(json.dumps(meta)), **arrays)
        with open(self._latest_path(), "w") as fh:
            json.dump({"schema": POLICY_SCHEMA, "epoch": int(epoch),
                       "file": os.path.basename(path), "mode": mode},
                      fh, indent=1)
        return path

    def load(self, epoch: Optional[int] = None
             ) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
        """Load ``(policy, meta)`` — the latest epoch by default, or a
        pinned one.  Raises ``FileNotFoundError`` on an empty store and
        ``PolicyStoreError`` on schema/layout mismatches."""
        if epoch is None:
            epoch = self.latest_epoch()
            if epoch is None:
                raise FileNotFoundError(
                    f"policy store {self.root!r} holds no policies")
        path = self._path(epoch)
        if not os.path.exists(path):
            raise FileNotFoundError(
                f"policy store {self.root!r} has no epoch {epoch} "
                f"(stored: {self.epochs()})")
        with np.load(path) as npz:
            if "__meta__" not in npz:
                raise PolicyStoreError(f"{path}: missing __meta__ header")
            meta = json.loads(str(npz["__meta__"]))
            if meta.get("schema", 0) > POLICY_SCHEMA:
                raise PolicyStoreError(
                    f"{path}: schema v{meta.get('schema')} is newer than "
                    f"this reader (v{POLICY_SCHEMA})")
            policy = {k: np.asarray(npz[k]) for k in npz.files
                      if k != "__meta__"}
        missing = [k for k in REQUIRED_KEYS if k not in policy]
        if missing:
            raise PolicyStoreError(f"{path}: missing arrays {missing}")
        return policy, meta


__all__ = ["POLICY_SCHEMA", "REQUIRED_KEYS", "PolicyStore",
           "PolicyStoreError"]
