from .engine import Request, ServingInstance, ServingEngine

__all__ = ["Request", "ServingInstance", "ServingEngine"]
