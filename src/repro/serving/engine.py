"""Serving engine: real model replicas behind Jiagu's control plane.

A *function* in Jiagu's terms is a model architecture; an *instance* is a
:class:`ServingInstance` — a replica holding weights + a slotted KV/state
cache, running continuous batching: each engine tick prefills newly
admitted requests into free slots and advances every active slot by one
decode step.  The :class:`ServingEngine` is the per-node data plane the
control plane (core/) schedules; ``examples/serve_cluster.py`` wires both
together with real (smoke-scale) model compute.

The saturated-load semantics match the paper: an instance serves at most
``slots`` concurrent requests; the autoscaler's saturated_rps maps to
slots/expected-latency.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import model as model_lib


_STEP_CACHE: Dict[tuple, tuple] = {}


def _jitted_steps(cfg: ModelConfig, max_len: int):
    """Jitted decode/prefill shared across replicas of one function (a
    replica must not trigger its own compilation — that would be a cold
    start the paper's cfork constant already accounts for)."""
    key = (cfg.name, cfg.n_layers, cfg.d_model, max_len)
    if key not in _STEP_CACHE:
        decode = jax.jit(
            lambda p, t, pos, c: model_lib.decode_step(cfg, p, t, pos, c))
        prefill = jax.jit(
            lambda p, toks: model_lib.prefill(cfg, p, {"tokens": toks},
                                              max_len))
        _STEP_CACHE[key] = (decode, prefill)
    return _STEP_CACHE[key]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new: int = 16
    t_submit: float = 0.0
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    tokens: List[int] = field(default_factory=list)

    @property
    def latency_ms(self) -> float:
        return 1e3 * ((self.t_done or time.time()) - self.t_submit)


class ServingInstance:
    """One replica: weights + a fixed-slot batched KV cache."""

    _ids = 0

    def __init__(self, cfg: ModelConfig, params, slots: int = 4,
                 max_len: int = 512):
        ServingInstance._ids += 1
        self.iid = ServingInstance._ids
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = model_lib.init_cache(cfg, slots, max_len)
        self.pos = np.zeros(slots, np.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.last_token = np.zeros(slots, np.int32)
        self._decode, self._prefill = _jitted_steps(cfg, max_len)

    # -- slot management ---------------------------------------------------

    def free_slots(self) -> int:
        return sum(1 for r in self.active if r is None)

    def n_active(self) -> int:
        return self.slots - self.free_slots()

    def admit(self, req: Request) -> bool:
        """Prefill `req` into a free slot (one-request prefill, cache rows
        spliced into the batched cache)."""
        try:
            slot = self.active.index(None)
        except ValueError:
            return False
        toks = jnp.asarray(req.prompt[None, :])
        logits, cache1 = self._prefill(self.params, toks)
        tok0 = int(jnp.argmax(logits[0]))
        req.tokens.append(tok0)
        req.t_first_token = time.time()
        self.cache = _splice_cache(self.cache, cache1, slot)
        self.pos[slot] = len(req.prompt)
        self.last_token[slot] = tok0
        self.active[slot] = req
        return True

    def step(self) -> List[Request]:
        """One decode step over all slots; returns finished requests."""
        if self.n_active() == 0:
            return []
        toks = jnp.asarray(self.last_token)
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._decode(self.params, toks, pos,
                                          self.cache)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        done = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            req.tokens.append(int(nxt[s]))
            self.pos[s] += 1
            self.last_token[s] = nxt[s]
            if len(req.tokens) >= req.max_new or self.pos[s] >= \
                    self.max_len - 1:
                req.t_done = time.time()
                done.append(req)
                self.active[s] = None
        return done


def _splice_cache(full, one, slot: int):
    """Copy the single-request cache `one` (batch=1) into row `slot` of the
    batched cache, leaf by leaf.  Batch axis = 0 for plain leaves, 1 for
    body-stacked leaves (leading period axis)."""
    def leaf(f, o):
        if f.ndim == o.ndim and f.shape[1:] == o.shape[1:]:
            return f.at[slot: slot + 1].set(o)           # batch axis 0
        return f.at[:, slot: slot + 1].set(o)            # stacked: axis 1
    return jax.tree.map(leaf, full, one)


class ServingEngine:
    """Per-function pool of instances + router with saturated/cached
    split (dual-staged scaling's data plane): requests go only to
    *saturated* instances; cached instances retain state but receive no
    traffic until a logical cold start re-labels them."""

    def __init__(self, cfg: ModelConfig, params, slots: int = 4,
                 max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.instances: Dict[int, ServingInstance] = {}
        self.cached: set = set()          # iids drained by "release"
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self._rr = 0

    # -- control-plane hooks (called by the Jiagu autoscaler/scheduler) ----

    def scale_up(self, k: int = 1, init_delay_s: float = 0.0) -> List[int]:
        out = []
        for _ in range(k):
            inst = ServingInstance(self.cfg, self.params, self.slots,
                                   self.max_len)
            self.instances[inst.iid] = inst
            out.append(inst.iid)
        return out

    def release(self, k: int = 1) -> List[int]:
        """Drain k saturated instances (dual-staged stage 1)."""
        sat = [i for i in self.instances if i not in self.cached]
        picked = sat[:k]
        self.cached.update(picked)
        return picked

    def logical_start(self, k: int = 1) -> int:
        """Re-route to k cached instances (<1 ms; no init cost)."""
        revived = list(self.cached)[:k]
        for i in revived:
            self.cached.discard(i)
        return len(revived)

    def evict_cached(self, k: int = 1) -> int:
        victims = list(self.cached)[:k]
        for i in victims:
            self.cached.discard(i)
            self.instances.pop(i, None)
        return len(victims)

    def n_saturated(self) -> int:
        return len(self.instances) - len(self.cached)

    # -- data plane ---------------------------------------------------------

    def submit(self, req: Request):
        req.t_submit = time.time()
        self.queue.append(req)

    def tick(self):
        """Admit queued requests round-robin over saturated instances,
        then advance every instance one decode step."""
        sat = [inst for iid, inst in sorted(self.instances.items())
               if iid not in self.cached]
        if sat:
            while self.queue:
                order = sorted(sat, key=lambda i: -i.free_slots())
                if order[0].free_slots() == 0:
                    break
                order[0].admit(self.queue.pop(0))
        for inst in sat:
            self.done.extend(inst.step())

    def drain(self, max_ticks: int = 1000):
        for _ in range(max_ticks):
            if not self.queue and all(i.n_active() == 0
                                      for i in self.instances.values()):
                break
            self.tick()
        return self.done
