"""Public export surface for the unified control-plane API.

    from repro.platform import Platform, PlatformConfig

``Platform.build(scenario=..., config=...)`` assembles a validated
control plane (world, cluster, scheduler, autoscaler, simulation,
observer hub) from a ``PlatformConfig`` tree or a plain manifest dict;
schedulers / scenario kinds / trace programs / routers are selected
through name-based registries, and the autoscaler and simulator consume
their collaborators only through the capability protocols
(``CapacityProvider``, ``ReleasePicker``, ``LogicalStartPicker``,
``Router``) — see ``repro.core.platform``.

    python -m repro.platform        # CI smoke: every registered
                                    # scheduler x one scenario, built
                                    # from pure config dicts, 30 ticks
"""
from .core.platform import *            # noqa: F401,F403
from .core.platform import __all__      # noqa: F401

if __name__ == "__main__":
    from .core.platform import smoke
    smoke()
