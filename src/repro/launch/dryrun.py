import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh) cell this lowers and
compiles the cell's step (train / prefill / decode) against the production
mesh — 16x16 single-pod and 2x16x16 multi-pod — using ShapeDtypeStruct
stand-ins (no allocation), then records:

  * per-device memory analysis (proves the cell fits HBM),
  * cost analysis (FLOPs / bytes for the roofline),
  * the collective schedule (wire bytes per collective kind),
  * the three roofline terms + bottleneck (launch/roofline.py).

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k \
      --mesh single [--out benchmarks/artifacts/dryrun] [--opts ...]
  python -m repro.launch.dryrun --all --mesh both     # every cell, one proc
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp


def _mesh(kind: str):
    from .mesh import make_production_mesh
    return make_production_mesh(multi_pod=(kind == "multi"))


def _per_device_arg_bytes(args) -> int:
    total = 0
    for leaf in jax.tree.leaves(args):
        shard = leaf.sharding.shard_shape(leaf.shape)
        n = 1
        for d in shard:
            n *= d
        total += n * leaf.dtype.itemsize
    return total


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             opts: dict | None = None) -> dict:
    from ..configs.base import SHAPE_BY_NAME, cell_is_runnable, get_config
    from ..distributed.steps import make_step_bundle
    from ..optim.adamw import AdamWConfig
    from .roofline import collective_bytes, roofline_terms

    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "kind": shape.kind, "opts": opts or {}}
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    mesh = _mesh(mesh_kind)
    kw = dict(opts or {})
    # translate string/flag opts into builder kwargs (perf-iteration knobs)
    if kw.pop("act_seq_shard", None):
        # Megatron-style sequence parallelism for the residual stream
        from jax.sharding import NamedSharding, PartitionSpec as P
        from ..distributed.sharding import axis_size, dp_axes
        dp = dp_axes(mesh)
        dpx = (dp if len(dp) > 1 else dp[0]) if (
            dp and shape.global_batch % axis_size(mesh, dp) == 0) else None
        kw.setdefault("extra_hints", {})["activations"] = NamedSharding(
            mesh, P(dpx, "model", None))
    if kw.pop("moe_dshard", None):
        # decode: keep expert weights sharded; shard expert-buffer d dim on
        # "data" so the FFN contraction partial-sums + all-reduces
        # activations instead of all-gathering expert weights
        from jax.sharding import NamedSharding, PartitionSpec as P
        kw.setdefault("extra_hints", {})["moe_expert_in"] = NamedSharding(
            mesh, P("model", None, None, "data"))
    if kw.get("cache_l_model") is not None:
        kw["cache_l_model"] = bool(kw["cache_l_model"])
    if isinstance(kw.get("param_dtype"), str):
        kw["param_dtype"] = jnp.dtype(kw["param_dtype"])
    # big-model dry-runs default to bf16 Adam moments (DESIGN.md §5)
    if shape.kind == "train":
        kw.setdefault("opt_cfg", AdamWConfig(
            moment_dtype=kw.pop("moment_dtype", "bfloat16")))
    else:
        kw.pop("cast_params", None)
    if shape.kind != "decode":
        kw.pop("cache_l_model", None)
    t0 = time.time()
    bundle = make_step_bundle(cfg, mesh, shape, **kw)
    lowered = bundle.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    from .roofline import normalize_cost_analysis
    cost = normalize_cost_analysis(compiled.cost_analysis())
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_rec = {"error": str(e)}
    mem_rec["arg_bytes_analytic_per_device"] = _per_device_arg_bytes(
        bundle.args)

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    from .roofline import hlo_stats
    stats = hlo_stats(hlo)   # loop-corrected (cost_analysis counts loop
    #                          bodies once; see roofline.hlo_stats)
    terms = roofline_terms(stats, coll, mesh.size, cfg, shape)
    terms["xla_flops_unscaled"] = cost.get("flops")
    terms["xla_bytes_unscaled"] = cost.get("bytes accessed")

    rec.update({
        "status": "ok",
        "step": bundle.name,
        "dispatch": bundle.meta.get("dispatch"),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem_rec,
        "cost": {k: v for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "collectives": coll,
        "roofline": terms,
        "hlo_bytes": len(hlo),
    })
    return rec


def main() -> int:
    from ..configs.base import SHAPES, cell_is_runnable, get_config, \
        list_archs

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--remat", type=int, default=None)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--dispatch", default=None)
    ap.add_argument("--set", action="append", default=[],
                    help="extra builder opts, e.g. --set cast_params=1 "
                         "--set param_dtype=bfloat16 --set act_seq_shard=1")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s.name) for a in list_archs() for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    opts = {}
    if args.remat is not None:
        opts["remat"] = bool(args.remat)
    if args.microbatch is not None:
        opts["microbatch"] = args.microbatch
    if args.dispatch is not None:
        opts["dispatch"] = args.dispatch
    for kv in args.set:
        k, _, v = kv.partition("=")
        opts[k] = int(v) if v.isdigit() else v

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for mesh_kind in meshes:
            name = f"{args.tag}--{arch}--{shape}--{mesh_kind}.json"
            path = os.path.join(args.out, name)
            if os.path.exists(path) and not args.force:
                print(f"[skip-cached] {name}")
                continue
            t0 = time.time()
            try:
                # train-only opts must not leak into serve cells
                cell_kind = next(s.kind for s in SHAPES
                                 if s.name == shape)
                kw = dict(opts)
                if cell_kind != "train":
                    kw.pop("remat", None)
                    kw.pop("microbatch", None)
                rec = run_cell(arch, shape, mesh_kind, kw)
            except Exception:
                rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                       "status": "error",
                       "error": traceback.format_exc(limit=20)}
                failures += 1
            rec["wall_s"] = round(time.time() - t0, 2)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1, default=str)
            status = rec.get("status")
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (f" bottleneck={r['bottleneck']}"
                         f" comp={r['compute_s']:.3e}s"
                         f" mem={r['memory_s']:.3e}s"
                         f" coll={r['collective_s']:.3e}s"
                         f" compile={rec['compile_s']:.0f}s")
            elif status == "skipped":
                extra = f" ({rec['reason']})"
            print(f"[{status}] {arch} x {shape} x {mesh_kind}{extra}",
                  flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
