"""Serving driver: Jiagu control plane over the 10 architecture serving
functions (replica scheduling simulation at cluster scale; use
examples/serve_cluster.py for real model compute at smoke scale).

  PYTHONPATH=src python -m repro.launch.serve [--seconds 600] \
      [--scheduler jiagu|gsight|owl|k8s] [--release 45] [--no-dual]
"""
from __future__ import annotations

import argparse


def main():
    from ..core import (Autoscaler, Cluster, GroundTruth, GsightScheduler,
                        JiaguScheduler, K8sScheduler, OwlScheduler,
                        PerfPredictor, ProfileStore, QoSStore,
                        ScalingConfig, SimConfig, Simulation,
                        arch_functions, generate_dataset, realworld_trace)

    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=int, default=600)
    ap.add_argument("--scheduler", default="jiagu",
                    choices=["jiagu", "gsight", "owl", "k8s"])
    ap.add_argument("--release", type=float, default=45.0)
    ap.add_argument("--keepalive", type=float, default=60.0)
    ap.add_argument("--no-dual", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    specs = arch_functions()
    gt = GroundTruth(seed=args.seed)
    store = ProfileStore(seed=args.seed)
    qos = QoSStore(store, gt)
    pred = PerfPredictor(n_trees=24, max_depth=8, seed=args.seed)
    X, y = generate_dataset(specs, gt, store, qos, 1500, seed=args.seed + 1)
    pred.add_dataset(X, y)

    cluster = Cluster(specs)
    sched = {"jiagu": lambda: JiaguScheduler(cluster, store, qos, pred),
             "gsight": lambda: GsightScheduler(cluster, store, qos, pred),
             "owl": lambda: OwlScheduler(cluster, store, qos),
             "k8s": lambda: K8sScheduler(cluster, store, qos)}[
        args.scheduler]()
    aut = Autoscaler(cluster, sched, ScalingConfig(
        release_s=args.release, keepalive_s=args.keepalive,
        dual_staged=not args.no_dual and args.scheduler == "jiagu"))
    trace = realworld_trace(sorted(specs), duration_s=args.seconds,
                            seed=args.seed + 7)
    sim = Simulation(specs, trace, sched, aut, gt, store, qos,
                     predictor=pred, cfg=SimConfig(collect_samples=True))
    res = sim.run()

    s = res.sched
    print(f"scheduler={args.scheduler} dual={not args.no_dual}")
    print(f"density: {res.density:.2f} instances/node | QoS violations: "
          f"{100 * res.qos_violation_rate:.2f}%")
    print(f"scheduling: {s.decisions} decisions, fast={s.fast} "
          f"slow={s.slow}, mean latency {s.mean_latency_ms:.3f} ms")
    if res.scaling:
        sc = res.scaling
        print(f"scaling: {sc.real_cold_starts} real / "
              f"{sc.logical_cold_starts} logical cold starts, "
              f"{sc.releases} releases, {sc.migrations} migrations, "
              f"mean cold start {sc.mean_cold_start_ms:.2f} ms")


if __name__ == "__main__":
    main()
