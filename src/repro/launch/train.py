"""End-to-end training driver with fault tolerance.

Runs anywhere from 1 CPU device (smoke configs) to the production mesh:
deterministic data pipeline, step-atomic checkpoints (resume with
``--resume``), straggler logging, watchdog heartbeats, optional failure
injection (``--fail-at N`` kills the loop at step N; rerunning with
--resume restores from the latest checkpoint — the fault-tolerance drill
used by tests and examples).

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --smoke \
      --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt --save-every 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def build_state(cfg, bundle, opt_cfg, seed: int = 0):
    """Initialize a sharded train state directly into bundle shardings."""
    from ..models import model as model_lib
    from ..optim import adamw

    state_sh = bundle.meta["state_shardings"]

    def init():
        params = model_lib.init_params(cfg, jax.random.PRNGKey(seed))
        return {"params": params, "opt": adamw.init(params, opt_cfg)}

    return jax.jit(init, out_shardings=state_sh)()


def put_batch(batch, shardings):
    return {k: jax.device_put(v, shardings[k]) for k, v in batch.items()}


def train_loop(cfg, shape, mesh, steps: int, ckpt_dir=None, resume=False,
               save_every: int = 0, log_every: int = 10, fail_at: int = -1,
               microbatch: int = 1, remat: bool = True, seed: int = 0,
               data: str = "synthetic", opt_cfg=None, quiet=False):
    from .. import checkpoint as ckpt_lib
    from ..data.pipeline import ByteCorpus, TokenPipeline
    from ..distributed.fault_tolerance import (FailureInjector,
                                               StragglerDetector, Watchdog)
    from ..distributed.steps import abstract_train_state, make_train_step
    from ..optim import adamw

    opt_cfg = opt_cfg or adamw.AdamWConfig(total_steps=max(steps, 2),
                                           warmup_steps=max(steps // 20, 1))
    bundle = make_train_step(cfg, mesh, shape, opt_cfg=opt_cfg,
                             remat=remat, microbatch=microbatch)
    batch_sh = bundle.meta["batch_shardings"]

    start_step = 0
    if resume and ckpt_dir and ckpt_lib.latest_step(ckpt_dir) is not None:
        abs_state = abstract_train_state(cfg, opt_cfg)
        state, meta = ckpt_lib.restore(ckpt_dir, abs_state,
                                       bundle.meta["state_shardings"])
        start_step = meta["step"]
        if not quiet:
            print(f"[train] resumed from step {start_step}")
    else:
        state = build_state(cfg, bundle, opt_cfg, seed)

    if data == "bytes":
        corpus = ByteCorpus()
        def get_batch(i):
            return corpus.batch(i, shape.global_batch, shape.seq_len)
    else:
        pipe = TokenPipeline(cfg, shape, seed=seed)
        get_batch = pipe.batch

    wd = Watchdog(timeout_s=600)
    sd = StragglerDetector()
    inj = FailureInjector(fail_at_step=fail_at)
    history = []
    for i in range(start_step, steps):
        t0 = time.time()
        batch = put_batch(get_batch(i), batch_sh)
        state, metrics = bundle.fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        wd.beat(i)
        sd.record(0, dt)
        history.append(loss)
        if not quiet and (i % log_every == 0 or i == steps - 1):
            print(f"[train] step {i} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s",
                  flush=True)
        if ckpt_dir and save_every and (i + 1) % save_every == 0:
            ckpt_lib.save(ckpt_dir, i + 1, state,
                          extra_meta={"arch": cfg.name, "loss": loss})
        inj.maybe_fail(i)  # after ckpt: the drill resumes past this step
    if ckpt_dir and save_every:
        ckpt_lib.save(ckpt_dir, steps, state,
                      extra_meta={"arch": cfg.name,
                                  "loss": history[-1] if history else None})
    return state, history


def main():
    from ..configs.base import InputShape, get_config, get_smoke_config
    from .mesh import make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--save-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=-1)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--data", default="synthetic",
                    choices=["synthetic", "bytes"])
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (needs 256 devices)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    shape = InputShape("custom", args.seq, args.batch, "train")
    if args.production_mesh:
        mesh = make_production_mesh()
    else:
        n = len(jax.devices())
        mesh = jax.make_mesh((n, 1), ("data", "model"))
    t0 = time.time()
    _state, history = train_loop(
        cfg, shape, mesh, args.steps, ckpt_dir=args.ckpt_dir,
        resume=args.resume, save_every=args.save_every,
        log_every=args.log_every, fail_at=args.fail_at,
        microbatch=args.microbatch, data=args.data, seed=args.seed)
    print(f"[train] done: {len(history)} steps in {time.time()-t0:.1f}s; "
          f"loss {history[0]:.4f} -> {history[-1]:.4f}")


if __name__ == "__main__":
    main()
