"""Roofline terms from a compiled dry-run artifact.

Three terms per (arch x shape x mesh) cell, all in seconds-per-step on
TPU v5e constants (mesh.py):

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS_BF16
    memory     = HLO_bytes_per_device / HBM_BW
    collective = wire_bytes_per_device / ICI_BW

``cost_analysis()`` runs on the SPMD-partitioned module, so its FLOPs /
bytes are already per-device.  Collective bytes are NOT in cost_analysis:
we parse the optimized HLO text and sum operand/result sizes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
(all-reduce counts 2x for the reduce+broadcast halves of a ring).

``useful_ratio`` = MODEL_FLOPS / (HLO_FLOPs x chips) — how much of the
compiled compute is the 6·N·D (train) / 2·N·D (inference) model math;
remat recompute, GShard dispatch one-hots and padding all push it down.
"""
from __future__ import annotations

import re
from typing import Dict, Optional

from ..configs.base import InputShape, ModelConfig
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def normalize_cost_analysis(cost) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` returned a one-element list of dicts
    in older JAX and a plain dict in newer releases (and may be None for
    some backends).  Normalize every variant to a dict."""
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\]")
_DOT_OPERANDS_RE = re.compile(r"dot\(([^)]*)\)")
_NAME_RE = re.compile(r"%([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_SKIP_TRAFFIC = ("parameter(", "constant(", "tuple(", "get-tuple-element(",
                 "bitcast(", "after-all(", "partition-id(",
                 # loop-carried state is aliased, not moved, per iteration
                 "while(", "conditional(", "optimization-barrier(")
# ops whose large buffers are aliased in-place / read only a slice
_SLICE_FAMILY = ("dynamic-update-slice", "dynamic-slice", " gather(",
                 " scatter(", "wrapped_scatter", "wrapped_gather",
                 "_scatter", "_gather")


def _parse_computations(hlo_text: str):
    """-> {comp_name: [lines]}, entry_name."""
    comps: Dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        if line and not line.startswith(" ") and line.rstrip().endswith("{"):
            m = _COMP_RE.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
                continue
        if cur is not None and line.strip() and line.strip() != "}":
            comps[cur].append(line.strip())
    return comps, entry


def _trip_count(while_line: str, cond_lines) -> int:
    """Trip count of one while site: XLA annotates
    backend_config known_trip_count; fall back to the largest integer
    constant in the loop condition computation (scan bounds)."""
    m = _TRIP_RE.search(while_line)
    if m:
        return max(int(m.group(1)), 1)
    best = 1
    for line in cond_lines:
        for c in _CONST_RE.finditer(line):
            best = max(best, int(c.group(1)))
    return best


def _multipliers(comps: Dict[str, list], entry) -> Dict[str, float]:
    """Execution-count multiplier per computation: product of enclosing
    while-loop trip counts; fusion bodies / reducers inherit the caller's
    multiplier."""
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    if entry not in comps:
        return {c: 1.0 for c in comps}
    mult[entry] = 1.0
    for _ in range(16):  # fixpoint over (shallow) nesting
        changed = False
        for comp, lines in comps.items():
            m = mult.get(comp, 0.0)
            if m <= 0:
                continue
            for line in lines:
                wm = _WHILE_RE.search(line)
                if wm:
                    cond, body = wm.group(1), wm.group(2)
                    trips = _trip_count(line, comps.get(cond, []))
                    if m * trips > mult.get(body, 0.0):
                        mult[body] = m * trips
                        changed = True
                    if m > mult.get(cond, 0.0):
                        mult[cond] = m
                        changed = True
                for cm in _CALL_RE.finditer(line):
                    callee = cm.group(1)
                    if m > mult.get(callee, 0.0):
                        mult[callee] = m
                        changed = True
        if not changed:
            break
    return {c: (v if v > 0 else 1.0) for c, v in mult.items()}


def hlo_stats(hlo_text: str) -> Dict[str, float]:
    """Loop-corrected per-device FLOPs and HBM traffic from optimized HLO.

    XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE
    (verified empirically), which under-reports every scan-over-layers
    model by ~n_periods.  This walk multiplies each computation's cost by
    the product of enclosing loop trip counts.

    * FLOPs: every ``dot`` (2 x result elems x contracted elems), counted
      in all computations (incl. fusion bodies).
    * traffic: operand+result bytes of ops in non-fusion-body computations
      (fusion interiors never touch HBM; the fusion call site is counted).
    """
    comps, entry = _parse_computations(hlo_text)
    mult = _multipliers(comps, entry)
    interior = set()
    for lines in comps.values():
        for line in lines:
            for m in _CALL_RE.finditer(line):
                interior.add(m.group(1))
    flops = 0.0
    traffic = 0.0
    for comp, lines in comps.items():
        m = mult.get(comp, 1.0)
        # local symbol table: defined name -> (dtype, dims)
        sym = {}
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                sym[dm.group(1)] = (dm.group(2), dm.group(3))
        for line in lines:
            if " dot(" in line or line.startswith("dot("):
                dm = _DEF_RE.match(line)
                out_elems = 1
                if dm:
                    for d in dm.group(3).split(","):
                        if d:
                            out_elems *= int(d)
                contracted = 1
                om = _DOT_OPERANDS_RE.search(line)
                cm = _LHS_CONTRACT_RE.search(line)
                if om and cm:
                    names = _NAME_RE.findall(om.group(1))
                    if names and names[0] in sym:
                        dims = [int(x) for x in sym[names[0]][1].split(",")
                                if x]
                        for ci in cm.group(1).split(","):
                            if ci and int(ci) < len(dims):
                                contracted *= dims[int(ci)]
                flops += 2.0 * out_elems * contracted * m
            if comp in interior and comp != entry:
                continue
            s = line.lstrip("%")
            if any(s.startswith(k) or f" {k}" in s for k in _SKIP_TRAFFIC):
                continue
            sizes = [_shape_bytes(dt, dims)
                     for dt, dims in _SHAPE_RE.findall(line)]
            if not sizes:
                continue
            if any(t in line for t in _SLICE_FAMILY):
                # in-place update / slice ops touch only the slice bytes:
                # the full buffer appears as operand AND result (aliased),
                # so count 2x everything except the max-sized shapes
                mx = max(sizes)
                traffic += 2.0 * sum(x for x in sizes if x < mx) * m
            else:
                traffic += sum(sizes) * m
    return {"flops": flops, "bytes accessed": traffic}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective-kind wire bytes (per device) from optimized HLO.

    While-loop aware: a collective inside a scan body counts once per trip
    (matching how cost_analysis scales FLOPs)."""
    comps, entry = _parse_computations(hlo_text)
    mult = _multipliers(comps, entry)
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    top: list = []
    for comp, lines in comps.items():
        m = mult.get(comp, 1.0)
        for line in lines:
            for kind in _COLLECTIVES:
                if f" {kind}(" not in line and f" {kind}-start(" not in \
                        line and not line.startswith(f"{kind}("):
                    continue
                shapes = _SHAPE_RE.findall(line)
                if not shapes:
                    continue
                sizes = [_shape_bytes(dt, dims) for dt, dims in shapes]
                wire = max(sizes)
                if kind == "all-reduce":
                    wire *= 2
                # XLA:CPU promotes sub-f32 collectives to f32 (reducer
                # named *.clone_promoted, convert fusions around the op);
                # TPU moves the original 16-bit tensor — count that.
                if "promot" in line:
                    wire /= 2
                out[kind] += float(wire) * m
                counts[kind] += m
                top.append((float(wire) * m, kind, m,
                            line[:140]))
                break
    top.sort(reverse=True)
    out["_counts"] = counts           # type: ignore[assignment]
    out["_top"] = [                   # type: ignore[assignment]
        {"bytes": b, "kind": k, "mult": m, "op": op}
        for b, k, m, op in top[:12]]
    return out


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """6·N·D (train) / 2·N·D (inference); N = active params for MoE."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per batch element
    return 2.0 * n * shape.global_batch


def roofline_terms(cost: dict, coll: Dict[str, float], n_devices: int,
                   cfg: Optional[ModelConfig] = None,
                   shape: Optional[InputShape] = None) -> dict:
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    wire = float(sum(v for k, v in coll.items() if not k.startswith("_")))
    terms = {
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": bytes_hbm / HBM_BW,
        "collective_s": wire / ICI_BW,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_hbm,
        "wire_bytes_per_device": wire,
        "n_devices": n_devices,
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["bottleneck"] = dom.replace("_s", "")
    if cfg is not None and shape is not None:
        mf = model_flops(cfg, shape)
        terms["model_flops"] = mf
        terms["useful_ratio"] = mf / max(flops * n_devices, 1.0)
        # roofline fraction: useful model FLOPs per device-second achievable
        # given the *dominant* term as the step time.
        step_s = max(terms["compute_s"], terms["memory_s"],
                     terms["collective_s"])
        terms["roofline_frac"] = (mf / n_devices / max(step_s, 1e-30)
                                  / PEAK_FLOPS_BF16)
    return terms
