"""Production mesh definition (TPU v5e pods).

``make_production_mesh`` is a function (never a module-level constant) so
importing this module never touches jax device state.  The dry-run process
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
jax import to get placeholder devices; tests and benchmarks see 1 device.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link (~4 links usable per chip)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(n_data: int = 2, n_model: int = 2,
                    n_pod: int = 0) -> jax.sharding.Mesh:
    """Small mesh for CPU tests (requires host-device override to >=4)."""
    if n_pod:
        return jax.make_mesh((n_pod, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
