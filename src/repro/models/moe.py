"""Mixture-of-Experts FFN with two dispatch strategies.

``einsum``   GShard-style dense one-hot dispatch/combine tensors — the
             paper-faithful / textbook baseline.  O(N·E·C) dispatch tensors.
``sort``     scatter-based dispatch into fixed (E, C, d) buffers — the
             optimized variant (no N·E·C one-hots; a scatter + gather pair).

Both are capacity-based (tokens over capacity are dropped, standard for
fixed-shape TPU MoE) and numerically equivalent for kept tokens (tested).
Experts are stacked on a leading E axis so expert parallelism is a single
PartitionSpec on that axis.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from . import pctx
from .layers import _act, dense_init, softcap


def moe_init(key, d_model: int, moe, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    E, F = moe.n_experts, moe.d_ff_expert
    p = {
        "w_router": dense_init(ks[0], (d_model, E), d_model, jnp.float32),
        "w_gate": dense_init(ks[1], (E, d_model, F), d_model, dtype),
        "w_up": dense_init(ks[2], (E, d_model, F), d_model, dtype),
        "w_down": dense_init(ks[3], (E, F, d_model), F, dtype),
    }
    if moe.n_shared_experts:
        from .layers import mlp_init
        dff_sh = moe.d_ff_shared or moe.d_ff_expert * moe.n_shared_experts
        p["shared"] = mlp_init(ks[4], d_model, dff_sh, dtype)
    return p


def _router(params, x2d, moe):
    """x2d: (N, d) -> (weights (N, k), experts (N, k)) with fp32 routing."""
    logits = jnp.einsum("nd,de->ne", x2d.astype(jnp.float32),
                        params["w_router"].astype(jnp.float32))
    if moe.router_softcap:
        logits = softcap(logits, moe.router_softcap)
    gates = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(gates, moe.top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, idx, gates


def _capacity(n_tokens: int, moe) -> int:
    c = int(math.ceil(n_tokens * moe.top_k / moe.n_experts
                      * moe.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8


def _positions_in_expert(idx, n_experts: int):
    """idx: (N, k) expert ids; returns (N, k) arrival order within expert."""
    N, k = idx.shape
    flat = idx.reshape(-1)                      # (N*k,) row-major: token major
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1        # arrival index per expert
    pos = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    return pos.reshape(N, k)


def _expert_ffn(params, buf, activation: str):
    """buf: (E, C, d) -> (E, C, d) via per-expert gated MLP."""
    dtype = buf.dtype
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(dtype))
    h = _act(g, activation) * u
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dtype))


def _gshard_grouped(params, x2d, moe, activation: str, G: int):
    """GShard grouped dense dispatch (the multi-pod scalable formulation):
    tokens are split into G groups (G = number of data-parallel shards so
    each group is device-local), capacity is per-group, and the dispatch /
    combine one-hots carry an explicit group axis the partitioner shards.
    """
    N, d = x2d.shape
    assert N % G == 0, (N, G)
    n = N // G
    E, k = moe.n_experts, moe.top_k
    C = _capacity(n, moe)
    w, idx, _ = _router(params, x2d, moe)
    xg = x2d.reshape(G, n, d)
    wg, idxg = w.reshape(G, n, k), idx.reshape(G, n, k)
    # position-in-expert within each group
    oh_i = jax.nn.one_hot(idxg.reshape(G, n * k), E, dtype=jnp.int32)
    pos = jnp.cumsum(oh_i, axis=1) - 1                       # (G, n*k, E)
    pos = jnp.take_along_axis(pos.reshape(G, n, k, E),
                              idxg[..., None], axis=-1)[..., 0]
    keep = pos < C
    wg = jnp.where(keep, wg, 0.0).astype(x2d.dtype)
    oh_e = jax.nn.one_hot(idxg, E, dtype=x2d.dtype)          # (G, n, k, E)
    oh_c = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                          dtype=x2d.dtype)[..., :-1]         # (G, n, k, C)
    disp = pctx.constrain(jnp.einsum("gnke,gnkc->gnec", oh_e, oh_c),
                          "moe_dispatch")
    expert_in = pctx.constrain(jnp.einsum("gnec,gnd->egcd", disp, xg),
                               "moe_expert_in")
    eo = _expert_ffn(params, expert_in.reshape(E, G * C, d), activation)
    eo = pctx.constrain(eo.reshape(E, G, C, d), "moe_expert_in")
    comb = pctx.constrain(jnp.einsum("gnke,gnkc,gnk->gnec", oh_e, oh_c, wg),
                          "moe_dispatch")
    out = jnp.einsum("gnec,egcd->gnd", comb, eo)
    return out.reshape(N, d)


def _sort_grouped(params, x2d, moe, activation: str, G: int):
    """Grouped scatter dispatch — the all-to-all MoE formulation.

    Each data-parallel group scatters its tokens into a LOCAL (E, C, d)
    buffer (vmapped scatter over the group axis: no cross-device scatter),
    the (G, E, C, d) buffers are resharded group-major -> expert-major
    (one all-to-all-shaped collective, the only inter-device movement),
    experts compute, and the inverse reshard + local gather combine.
    Versus the GShard dense dispatch this removes the O(N·E·C) one-hot
    dispatch/combine matmuls entirely (they dominate compute at 1M-token
    batches) at the cost of one buffer-sized reshard each way.
    """
    N, d = x2d.shape
    assert N % G == 0, (N, G)
    n = N // G
    E, k = moe.n_experts, moe.top_k
    C = _capacity(n, moe)
    w, idx, _ = _router(params, x2d, moe)
    xg = x2d.reshape(G, n, d)
    wg, idxg = w.reshape(G, n, k), idx.reshape(G, n, k)
    oh_i = jax.nn.one_hot(idxg.reshape(G, n * k), E, dtype=jnp.int32)
    pos = jnp.cumsum(oh_i, axis=1) - 1
    pos = jnp.take_along_axis(pos.reshape(G, n, k, E),
                              idxg[..., None], axis=-1)[..., 0]
    keep = pos < C
    wg = jnp.where(keep, wg, 0.0).astype(x2d.dtype)
    pos_c = jnp.where(keep, pos, C)              # overflow row C: dropped

    def scatter_group(xg_i, idx_i, pos_i):
        buf = jnp.zeros((E, C + 1, d), x2d.dtype)
        return buf.at[idx_i.reshape(-1), pos_i.reshape(-1)].set(
            jnp.repeat(xg_i, k, axis=0), mode="drop")[:, :C]

    bufs = jax.vmap(scatter_group)(xg, idxg, pos_c)       # (G, E, C, d)
    bufs = pctx.constrain(bufs, "moe_group_buf")          # local scatter
    ein = pctx.constrain(bufs.transpose(1, 0, 2, 3),      # reshard: a2a
                         "moe_expert_in")
    eo = _expert_ffn(params, ein.reshape(E, G * C, d), activation)
    eo = pctx.constrain(eo.reshape(E, G, C, d), "moe_expert_in")
    eo_g = pctx.constrain(eo.transpose(1, 0, 2, 3),       # reshard back
                          "moe_group_buf")
    eo_g = jnp.concatenate(
        [eo_g, jnp.zeros((G, E, 1, d), x2d.dtype)], axis=2)

    def gather_group(eo_i, idx_i, pos_i, w_i):
        g = eo_i[idx_i.reshape(-1), pos_i.reshape(-1)]    # (n*k, d)
        return jnp.einsum("nkd,nk->nd", g.reshape(n, k, d), w_i)

    out = jax.vmap(gather_group)(eo_g, idxg, pos_c, wg)
    return out.reshape(N, d)


def moe_forward(params, x, moe, activation: str = "swiglu",
                dispatch: Optional[str] = None):
    """x: (B, S, d) -> (B, S, d).  Aux losses intentionally omitted from the
    return (load-balance loss available via ``moe_aux_loss``)."""
    B, S, d = x.shape
    N = B * S
    x2d = x.reshape(N, d)
    method = dispatch or moe.dispatch

    if method.startswith("gshard") or method.startswith("sortg"):
        groups = int(method.split(":")[1]) if ":" in method else 1
        fn = _sort_grouped if method.startswith("sortg") else \
            _gshard_grouped
        out = fn(params, x2d, moe, activation, groups)
        if "shared" in params:
            from .layers import mlp
            out = out + mlp(params["shared"], x2d, activation)
        return out.reshape(B, S, d)

    w, idx, _ = _router(params, x2d, moe)
    C = _capacity(N, moe)
    E = moe.n_experts

    pos = _positions_in_expert(idx, E)
    keep = pos < C
    w = jnp.where(keep, w, 0.0).astype(x.dtype)

    if method == "einsum":
        # GShard: dense one-hot dispatch (N, E, C) and combine tensors.
        disp = (jax.nn.one_hot(idx, E, dtype=x.dtype)[..., None]
                * jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                                 dtype=x.dtype)[..., None, :-1])
        disp = disp.sum(axis=1)                       # (N, E, C)
        expert_in = jnp.einsum("nec,nd->ecd", disp, x2d)
        expert_out = _expert_ffn(params, expert_in, activation)
        combine = disp * w.sum(axis=1)[:, None, None] if moe.top_k == 1 else \
            jnp.einsum("nkec,nk->nec", _per_k_disp(idx, pos, keep, E, C,
                                                   x.dtype), w)
        out = jnp.einsum("nec,ecd->nd", combine, expert_out)
    else:
        # sort/scatter: build (E, C, d) buffers with a scatter, gather back.
        pos_c = jnp.where(keep, pos, C)              # dropped -> overflow row
        buf = jnp.zeros((E, C + 1, d), x.dtype)
        buf = buf.at[idx.reshape(-1), pos_c.reshape(-1)].set(
            jnp.repeat(x2d, moe.top_k, axis=0), mode="drop")
        expert_out = _expert_ffn(params, buf[:, :C], activation)
        expert_out = jnp.concatenate(
            [expert_out, jnp.zeros((E, 1, d), x.dtype)], axis=1)
        gathered = expert_out[idx.reshape(-1), pos_c.reshape(-1)]
        out = jnp.einsum("nkd,nk->nd", gathered.reshape(N, moe.top_k, d), w)

    if "shared" in params:
        from .layers import mlp
        out = out + mlp(params["shared"], x2d, activation)
    return out.reshape(B, S, d)


def _per_k_disp(idx, pos, keep, E, C, dtype):
    """(N, k, E, C) per-assignment one-hot (einsum combine path, top_k>1)."""
    oh_e = jax.nn.one_hot(idx, E, dtype=dtype)       # (N, k, E)
    oh_c = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                          dtype=dtype)[..., :-1]     # (N, k, C)
    return oh_e[..., :, None] * oh_c[..., None, :]


def moe_aux_loss(params, x, moe):
    """GShard load-balance auxiliary loss (mean gate * mean assignment)."""
    B, S, d = x.shape
    x2d = x.reshape(-1, d)
    w, idx, gates = _router(params, x2d, moe)
    me = jnp.mean(gates, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(idx[:, 0], moe.n_experts, dtype=jnp.float32), axis=0)
    return moe.n_experts * jnp.sum(me * ce)
