from .model import (apply_blocks, block_structure, decode_step, final_hidden,
                    forward, init_cache, init_params, layer_specs,
                    logits_from_hidden, prefill)
from .steps import chunked_xent, loss_fn, make_train_batch

__all__ = [
    "apply_blocks", "block_structure", "decode_step", "final_hidden",
    "forward", "init_cache", "init_params", "layer_specs",
    "logits_from_hidden", "prefill", "chunked_xent", "loss_fn",
    "make_train_batch",
]
