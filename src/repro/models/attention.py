"""Attention blocks: MHA / GQA / MQA, global / local / chunked, and MLA.

Memory-aware by construction: prefill/train attention is computed with a
*q-block scan* ("XLA-flash") — a ``lax.scan`` over query blocks so the
materialized score tensor is O(q_block x kv_span) instead of O(S^2).  For
local / chunked layers the kv span is a static window slice, so long
sequences never touch a full-length score matrix.

Decode (single new token against a KV cache) uses direct attention; the MLA
path implements the *absorbed* decode (q absorbed into the kv_lora latent so
the cache stays compressed — the DeepSeek-V2 serving optimization).

Layout conventions:
    activations  (B, S, d_model)
    q/k/v        (B, S, H, D)
    caches       (B, L, H_kv, D)   (L = max_len for global, window for local)
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import pctx
from .layers import apply_rope, dense_init, rmsnorm, rmsnorm_init, softcap

_NEG_INF = -2.3819763e38  # bf16-safe large negative


class AttnSpec(NamedTuple):
    """Static per-layer attention behaviour."""

    kind: str               # "global" | "local" | "chunked"
    causal: bool
    window: int             # receptive window for local/chunked
    rope_theta: float       # 0.0 -> NoPE (llama4 global layers)
    softcap: float
    qk_norm: bool
    q_block: int = 512


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def attention_init(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, qkv_bias: bool = False,
                   qk_norm: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "w_q": dense_init(ks[0], (d_model, n_heads, head_dim), d_model, dtype),
        "w_k": dense_init(ks[1], (d_model, n_kv_heads, head_dim), d_model, dtype),
        "w_v": dense_init(ks[2], (d_model, n_kv_heads, head_dim), d_model, dtype),
        "w_o": dense_init(ks[3], (n_heads, head_dim, d_model),
                          n_heads * head_dim, dtype),
    }
    if qkv_bias:
        p["b_q"] = jnp.zeros((n_heads, head_dim), dtype)
        p["b_k"] = jnp.zeros((n_kv_heads, head_dim), dtype)
        p["b_v"] = jnp.zeros((n_kv_heads, head_dim), dtype)
    if qk_norm:
        p["q_norm"] = rmsnorm_init(head_dim, dtype)
        p["k_norm"] = rmsnorm_init(head_dim, dtype)
    return p


def mla_init(key, d_model: int, n_heads: int, mla, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    qk_hd = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    return {
        "w_dq": dense_init(ks[0], (d_model, mla.q_lora_rank), d_model, dtype),
        "q_norm": rmsnorm_init(mla.q_lora_rank, dtype),
        "w_uq": dense_init(ks[1], (mla.q_lora_rank, n_heads, qk_hd),
                           mla.q_lora_rank, dtype),
        "w_dkv": dense_init(
            ks[2], (d_model, mla.kv_lora_rank + mla.qk_rope_head_dim),
            d_model, dtype),
        "kv_norm": rmsnorm_init(mla.kv_lora_rank, dtype),
        "w_ukv": dense_init(
            ks[3], (mla.kv_lora_rank, n_heads,
                    mla.qk_nope_head_dim + mla.v_head_dim),
            mla.kv_lora_rank, dtype),
        "w_o": dense_init(ks[4], (n_heads, mla.v_head_dim, d_model),
                          n_heads * mla.v_head_dim, dtype),
    }


# ---------------------------------------------------------------------------
# q-block scanned attention (prefill / train)
# ---------------------------------------------------------------------------


def _qkv(params, x, spec: AttnSpec, positions, eps):
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["w_k"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["w_v"].astype(dtype))
    if "b_q" in params:
        q = q + params["b_q"].astype(dtype)
        k = k + params["b_k"].astype(dtype)
        v = v + params["b_v"].astype(dtype)
    if spec.qk_norm:
        q = rmsnorm(params["q_norm"], q, eps)
        k = rmsnorm(params["k_norm"], k, eps)
    if spec.rope_theta:
        q = apply_rope(q, positions, spec.rope_theta)
        k = apply_rope(k, positions, spec.rope_theta)
    q = pctx.constrain(q, "attn_q")
    k = pctx.constrain(k, "attn_kv")
    v = pctx.constrain(v, "attn_kv")
    return q, k, v


def blockwise_attention(q, k, v, spec: AttnSpec, q_offset: int = 0):
    """Scan over query blocks; kv span restricted statically per kind.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, Dk/Dv).  Returns (B, Sq, Hq, Dv).
    Assumes q positions are ``q_offset + arange(Sq)`` and kv positions are
    ``arange(Skv)`` (self-attention over one segment).
    """
    B, Sq, Hq, Dk = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(Dk)

    qb = min(spec.q_block, Sq)
    if spec.kind == "chunked" and Sq > spec.window:
        # a q block must lie within one aligned chunk: qb | window
        qb = min(qb, spec.window)
        while Sq % qb or spec.window % qb:
            qb -= 1
    else:
        while Sq % qb:
            qb -= 1
    n_blocks = Sq // qb

    # static kv span per block
    if spec.kind == "global":
        span = Skv
    elif spec.kind == "local":
        span = min(spec.window + qb, Skv)
    else:  # chunked: a q block lies within one aligned chunk
        span = min(spec.window, Skv)

    qg = q.reshape(B, n_blocks, qb, Hkv, G, Dk).transpose(1, 0, 2, 3, 4, 5)

    def body(carry, inp):
        blk_idx, q_blk = inp
        q_start = blk_idx * qb + 0  # positions are absolute already via rope
        if spec.kind == "global":
            kv_start = 0
        elif spec.kind == "local":
            kv_start = jnp.maximum(q_start + qb - span, 0)
        else:  # chunked
            kv_start = (q_start // spec.window) * spec.window
            kv_start = jnp.minimum(kv_start, Skv - span)
        k_blk = jax.lax.dynamic_slice_in_dim(k, kv_start, span, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(v, kv_start, span, axis=1)

        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk,
                       preferred_element_type=jnp.float32) * scale
        if spec.softcap:
            s = softcap(s, spec.softcap)

        q_pos = q_offset + q_start + jnp.arange(qb)
        k_pos = kv_start + jnp.arange(span)
        valid = jnp.ones((qb, span), bool)
        if spec.causal:
            valid &= q_pos[:, None] >= k_pos[None, :]
        if spec.kind == "local":
            valid &= q_pos[:, None] - k_pos[None, :] < spec.window
        elif spec.kind == "chunked":
            valid &= (q_pos[:, None] // spec.window) == (k_pos[None, :]
                                                         // spec.window)
        s = jnp.where(valid[None, None, None], s, _NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_blk)
        return carry, o

    _, outs = jax.lax.scan(body, None, (jnp.arange(n_blocks), qg))
    # outs: (n_blocks, B, qb, Hkv, G, Dv)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hq, Dv)
    return pctx.constrain(out, "attn_q")


def attention_forward(params, x, spec: AttnSpec, positions=None,
                      eps: float = 1e-6):
    """Full-sequence (train / prefill) attention.  x: (B, S, d_model)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _qkv(params, x, spec, positions, eps)
    out = blockwise_attention(q, k, v, spec)
    return jnp.einsum("bshd,hdm->bsm", out, params["w_o"].astype(x.dtype))


def attention_make_cache(params, x, spec: AttnSpec, cache_len: int,
                         positions=None, eps: float = 1e-6):
    """Prefill returning (output, cache) with cache sized for decode."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _qkv(params, x, spec, positions, eps)
    out = blockwise_attention(q, k, v, spec)
    out = jnp.einsum("bshd,hdm->bsm", out, params["w_o"].astype(x.dtype))
    L = cache_len if spec.kind == "global" else min(spec.window, cache_len)
    if S >= L:
        # ring layout: position p lives at slot p % L
        ck, cv = k[:, S - L:], v[:, S - L:]
        if spec.kind != "global" and S % L:
            ck = jnp.roll(ck, S % L, axis=1)
            cv = jnp.roll(cv, S % L, axis=1)
    else:
        pad = [(0, 0), (0, L - S), (0, 0), (0, 0)]
        ck, cv = jnp.pad(k, pad), jnp.pad(v, pad)
    return out, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# Decode (one token per sequence, against a cache)
# ---------------------------------------------------------------------------


def attention_decode(params, x, cache, spec: AttnSpec, pos,
                     eps: float = 1e-6):
    """x: (B, 1, d_model); pos: (B,) int32 position of the new token.
    cache: {"k": (B, L, Hkv, D), "v": ...}. Returns (out, new_cache)."""
    B = x.shape[0]
    q, k_new, v_new = _qkv(params, x, spec, pos[:, None], eps)

    L = cache["k"].shape[1]
    if spec.kind == "global":
        slot = jnp.minimum(pos, L - 1)
    else:
        slot = pos % L
    bidx = jnp.arange(B)
    k = cache["k"].at[bidx, slot].set(k_new[:, 0])
    v = cache["v"].at[bidx, slot].set(v_new[:, 0])

    Hq, Dk = q.shape[2], q.shape[3]
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(Dk)
    qg = q.reshape(B, 1, Hkv, G, Dk)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if spec.softcap:
        s = softcap(s, spec.softcap)

    slots = jnp.arange(L)
    if spec.kind == "global":
        valid = slots[None] <= pos[:, None]
    elif spec.kind == "local":
        valid = (slots[None] <= pos[:, None]) | (pos[:, None] + 1 >= L)
    else:  # chunked: visible slots are those written in the current chunk
        valid = slots[None] <= (pos[:, None] % L)
    s = jnp.where(valid[:, None, None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(B, 1, Hq, -1)
    out = jnp.einsum("bshd,hdm->bsm", o, params["w_o"].astype(x.dtype))
    return out, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def _mla_q(params, x, mla, spec: AttnSpec, positions, eps):
    dtype = x.dtype
    c_q = jnp.einsum("bsd,dl->bsl", x, params["w_dq"].astype(dtype))
    c_q = rmsnorm(params["q_norm"], c_q, eps)
    q = jnp.einsum("bsl,lhk->bshk", c_q, params["w_uq"].astype(dtype))
    q_nope = q[..., : mla.qk_nope_head_dim]
    q_rope = apply_rope(q[..., mla.qk_nope_head_dim:], positions,
                        spec.rope_theta)
    return q_nope, q_rope


def _mla_ckv(params, x, mla, spec: AttnSpec, positions, eps):
    dtype = x.dtype
    dkv = jnp.einsum("bsd,dl->bsl", x, params["w_dkv"].astype(dtype))
    c_kv = rmsnorm(params["kv_norm"], dkv[..., : mla.kv_lora_rank], eps)
    k_rope = apply_rope(dkv[..., mla.kv_lora_rank:][:, :, None, :],
                        positions, spec.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_forward(params, x, mla, spec: AttnSpec, positions=None,
                eps: float = 1e-6):
    """Prefill/train MLA: up-project then blockwise attention."""
    B, S, _ = x.shape
    dtype = x.dtype
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q_nope, q_rope = _mla_q(params, x, mla, spec, positions, eps)
    c_kv, k_rope = _mla_ckv(params, x, mla, spec, positions, eps)
    kv = jnp.einsum("bsl,lhk->bshk", c_kv, params["w_ukv"].astype(dtype))
    k_nope = kv[..., : mla.qk_nope_head_dim]
    v = kv[..., mla.qk_nope_head_dim:]
    H = k_nope.shape[2]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, mla.qk_rope_head_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = pctx.constrain(q, "attn_q")
    k = pctx.constrain(k, "attn_q")
    v = pctx.constrain(v, "attn_q")
    out = blockwise_attention(q, k, v, spec)
    return jnp.einsum("bshd,hdm->bsm", out, params["w_o"].astype(dtype))


def mla_make_cache(params, x, mla, spec: AttnSpec, cache_len: int,
                   positions=None, eps: float = 1e-6):
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    out = mla_forward(params, x, mla, spec, positions, eps)
    c_kv, k_rope = _mla_ckv(params, x, mla, spec, positions, eps)
    L = cache_len
    if S >= L:
        c_kv, k_rope = c_kv[:, S - L:], k_rope[:, S - L:]
    else:
        c_kv = jnp.pad(c_kv, [(0, 0), (0, L - S), (0, 0)])
        k_rope = jnp.pad(k_rope, [(0, 0), (0, L - S), (0, 0)])
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode(params, x, cache, mla, spec: AttnSpec, pos,
               eps: float = 1e-6):
    """Absorbed-q MLA decode: scores/context computed in the latent space so
    the cache stays (B, L, kv_lora_rank) — never re-expanded per step."""
    B = x.shape[0]
    dtype = x.dtype
    q_nope, q_rope = _mla_q(params, x, mla, spec, pos[:, None], eps)
    ckv_new, krope_new = _mla_ckv(params, x, mla, spec, pos[:, None], eps)

    L = cache["c_kv"].shape[1]
    slot = jnp.minimum(pos, L - 1)
    bidx = jnp.arange(B)
    c_kv = cache["c_kv"].at[bidx, slot].set(ckv_new[:, 0])
    k_rope = cache["k_rope"].at[bidx, slot].set(krope_new[:, 0])

    w_ukv = params["w_ukv"].astype(dtype)
    w_uk = w_ukv[..., : mla.qk_nope_head_dim]       # (lora, H, nope)
    w_uv = w_ukv[..., mla.qk_nope_head_dim:]         # (lora, H, v)
    q_abs = jnp.einsum("bthn,lhn->bthl", q_nope, w_uk)  # (B,1,H,lora)

    scale = 1.0 / math.sqrt(mla.qk_nope_head_dim + mla.qk_rope_head_dim)
    s = (jnp.einsum("bthl,bsl->bhts", q_abs, c_kv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bthr,bsr->bhts", q_rope, k_rope,
                      preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(L)[None] <= pos[:, None]
    s = jnp.where(valid[:, None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(dtype)
    ctx = jnp.einsum("bhts,bsl->bthl", p, c_kv)
    o = jnp.einsum("bthl,lhv->bthv", ctx, w_uv)
    out = jnp.einsum("bshd,hdm->bsm", o, params["w_o"].astype(dtype))
    return out, {"c_kv": c_kv, "k_rope": k_rope}
