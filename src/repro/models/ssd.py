"""Mamba-2 SSD (state-space duality) block — pure JAX reference path.

Implements the chunked SSD algorithm from the Mamba-2 paper: within-chunk
quadratic attention-like term + inter-chunk linear state recurrence.  The
Pallas TPU kernel for the hot loop lives in ``repro.kernels.ssd_scan``; this
module is the model-level block (projections, conv, gating) and the jnp
algorithm used on CPU and as the oracle.

Shapes: x (B, S, d_model); inner width di = expand*d_model; heads nh =
di/head_dim; state n = d_state; groups g (B/C shared across nh/g heads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm, rmsnorm_init


def ssd_init(key, d_model: int, ssd, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    di = ssd.d_inner(d_model)
    nh = ssd.n_heads(d_model)
    g = ssd.n_groups
    conv_ch = di + 2 * g * ssd.d_state
    return {
        # fused in-proj: [z(di), xBC(conv_ch), dt(nh)]
        "w_in": dense_init(ks[0], (d_model, 2 * di + 2 * g * ssd.d_state + nh),
                           d_model, dtype),
        "conv_w": dense_init(ks[1], (ssd.conv_width, conv_ch), ssd.conv_width,
                             dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (nh,), jnp.float32)
                    * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
        )).astype(jnp.float32),
        "gate_norm": rmsnorm_init(di, dtype),
        "w_out": dense_init(ks[3], (di, d_model), di, dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv, x: (B, S, C), w: (W, C) -> (B, S, C)."""
    W = w.shape[0]
    out = x * w[-1] + b
    for i in range(1, W):
        shifted = jnp.pad(x, [(0, 0), (i, 0), (0, 0)])[:, : x.shape[1]]
        out = out + shifted * w[-1 - i]
    return out


def _segsum(dA):
    """dA: (..., L) -> (..., L, L) lower-tri cumulative sums: sum dA[j+1..i]."""
    L = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(xh, dt, A, Bh, Ch, chunk: int, init_state=None):
    """Chunked SSD scan.

    xh: (B, S, nh, hd); dt: (B, S, nh) (post-softplus); A: (nh,) negative;
    Bh, Ch: (B, S, nh, n) (already broadcast from groups to heads).
    Returns y: (B, S, nh, hd), final_state: (B, nh, hd, n).
    """
    Bsz, S, nh, hd = xh.shape
    n = Bh.shape[-1]
    c = min(chunk, S)
    while S % c:
        c -= 1
    nc = S // c

    r = lambda t: t.reshape(Bsz, nc, c, *t.shape[2:])
    xh, dt, Bh, Ch = r(xh), r(dt), r(Bh), r(Ch)

    dA = dt * A  # (B, nc, c, nh)
    dA = jnp.moveaxis(dA, -1, 2)                  # (B, nc, nh, c)
    dA_cs = jnp.cumsum(dA, axis=-1)               # within-chunk cumsum

    # 1) within-chunk (quadratic) term
    L = jnp.exp(_segsum(dA))                      # (B, nc, nh, c, c)
    scores = jnp.einsum("bzlhn,bzshn->bzhls", Ch, Bh,
                        preferred_element_type=jnp.float32)
    M = scores * L
    y_diag = jnp.einsum("bzhls,bzshp,bzsh->bzlhp", M.astype(xh.dtype),
                        xh, dt.astype(xh.dtype))

    # 2) per-chunk output states (contribution to the carried state)
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)         # (B,nc,nh,c)
    states = jnp.einsum("bzshn,bzhs,bzsh,bzshp->bzhpn", Bh,
                        decay_states.astype(xh.dtype), dt.astype(xh.dtype),
                        xh)

    # 3) inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[..., -1])                   # (B,nc,nh)

    def step(h, inp):
        st, dec = inp
        h_new = h * dec[..., None, None].astype(h.dtype) + st
        return h_new, h  # emit state *entering* the chunk

    h0 = (jnp.zeros((Bsz, nh, hd, n), xh.dtype) if init_state is None
          else init_state.astype(xh.dtype))
    final, h_in = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)                          # (B,nc,nh,hd,n)

    # 4) inter-chunk contribution to outputs
    state_decay = jnp.exp(dA_cs)                             # (B,nc,nh,c)
    y_off = jnp.einsum("bzlhn,bzhpn,bzhl->bzlhp", Ch, h_in,
                       state_decay.astype(xh.dtype))
    y = (y_diag + y_off).reshape(Bsz, S, nh, hd)
    return y, final


def ssd_forward(params, x, ssd, eps: float = 1e-6, state=None,
                return_state: bool = False):
    """Full-sequence Mamba-2 block. x: (B, S, d_model)."""
    Bsz, S, d = x.shape
    dtype = x.dtype
    di = ssd.d_inner(d)
    nh = ssd.n_heads(d)
    g, n = ssd.n_groups, ssd.d_state

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(dtype))
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di: di + di + 2 * g * n]
    dt_raw = zxbcdt[..., -nh:]

    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"].astype(dtype),
                                   params["conv_b"].astype(dtype)))
    xs = xBC[..., :di].reshape(Bsz, S, nh, ssd.head_dim)
    Bh = xBC[..., di: di + g * n].reshape(Bsz, S, g, n)
    Ch = xBC[..., di + g * n:].reshape(Bsz, S, g, n)
    rep = nh // g
    Bh = jnp.repeat(Bh, rep, axis=2)
    Ch = jnp.repeat(Ch, rep, axis=2)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    init = None if state is None else state.get("h")
    y, h_final = ssd_chunked(xs, dt, A, Bh, Ch, ssd.chunk, init)
    y = y + xs * params["D"].astype(dtype)[None, None, :, None]
    y = y.reshape(Bsz, S, di)
    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z), eps)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(dtype))
    if return_state:
        conv_tail = xBC_raw_tail(zxbcdt, di, g, n, ssd.conv_width)
        return out, {"h": h_final, "conv": conv_tail}
    return out


def xBC_raw_tail(zxbcdt, di, g, n, conv_width):
    """Last (conv_width-1) pre-conv xBC inputs, for decode continuation."""
    xBC_raw = zxbcdt[..., di: di + di + 2 * g * n]
    W = conv_width - 1
    S = xBC_raw.shape[1]
    if S >= W:
        return xBC_raw[:, S - W:]
    return jnp.pad(xBC_raw, [(0, 0), (W - S, 0), (0, 0)])


def ssd_decode(params, x, state, ssd, eps: float = 1e-6):
    """Single-token step. x: (B, 1, d); state: {"h": (B,nh,hd,n),
    "conv": (B, conv_width-1, conv_ch)}."""
    Bsz, _, d = x.shape
    dtype = x.dtype
    di = ssd.d_inner(d)
    nh = ssd.n_heads(d)
    g, n = ssd.n_groups, ssd.d_state

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(dtype))
    z = zxbcdt[..., :di]
    xBC_new = zxbcdt[:, 0, di: di + di + 2 * g * n]
    dt_raw = zxbcdt[..., -nh:]

    conv_buf = jnp.concatenate([state["conv"], xBC_new[:, None]], axis=1)
    w = params["conv_w"].astype(dtype)
    xBC = jnp.einsum("bwc,wc->bc", conv_buf, w) + params["conv_b"].astype(dtype)
    xBC = jax.nn.silu(xBC)

    xs = xBC[:, :di].reshape(Bsz, nh, ssd.head_dim)
    Bh = jnp.repeat(xBC[:, di: di + g * n].reshape(Bsz, g, n), nh // g, axis=1)
    Ch = jnp.repeat(xBC[:, di + g * n:].reshape(Bsz, g, n), nh // g, axis=1)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + params["dt_bias"])                   # (B, nh)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                        # (B, nh)

    h = (state["h"].astype(jnp.float32) * dA[..., None, None]
         + jnp.einsum("bh,bhp,bhn->bhpn", dt, xs.astype(jnp.float32),
                      Bh.astype(jnp.float32)))
    y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), h)
    y = y.astype(dtype) + xs * params["D"].astype(dtype)[None, :, None]
    y = y.reshape(Bsz, 1, di)
    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z), eps)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(dtype))
    return out, {"h": h.astype(state["h"].dtype), "conv": conv_buf[:, 1:]}
