"""Partitioning context: activation sharding hints for model code.

The model layer is mesh-agnostic; the distributed step builders install a
dict of NamedShardings here (trace-time Python state) and model code
applies them via :func:`constrain`.  On a single device (tests, smoke) the
context is empty and ``constrain`` is the identity.

Keys used by the model layer:
    moe_dispatch   (G, n, E, C) dispatch/combine one-hots
    moe_expert_in  (E, G, C, d) expert input buffers
    attn_qkv       (B, S, H, D) post-projection activations
    activations    (B, S, d) residual-stream activations
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Dict, Optional

import jax

_SPECS: Dict[str, object] = {}


@contextmanager
def sharding_hints(specs: Optional[Dict[str, object]]):
    global _SPECS
    old = _SPECS
    _SPECS = dict(specs or {})
    try:
        yield
    finally:
        _SPECS = old


def constrain(x, key: str):
    s = _SPECS.get(key)
    if s is None:
        return x
    spec = getattr(s, "spec", None)
    if spec is not None and len(spec) > x.ndim:
        return x  # rank-mismatched call site (e.g. flattened tokens)
    return jax.lax.with_sharding_constraint(x, s)


def hint(key: str):
    return _SPECS.get(key)
