"""Loss and step functions shared by the launchers, dry-run and tests.

The LM cross-entropy is computed *chunked over the sequence*: the (B, S, V)
logit tensor is never materialized — each scan step computes one (B, c, V)
chunk in fp32, reduces it to a scalar, and discards it.  For 256k-vocab
models at 4k sequence this is the difference between ~0.5 TB of logits and
a few hundred MB.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import InputShape, ModelConfig
from . import pctx
from .model import final_hidden, logits_from_hidden

AUX_LOSS_WEIGHT = 0.01


def _pick_chunk(S: int, target: int = 512) -> int:
    c = min(target, S)
    while S % c:
        c -= 1
    return c


def chunked_xent(cfg: ModelConfig, params, h, targets, mask=None,
                 chunk: int = 512):
    """h: (B, S, d) final hidden; targets: (B, S) int32.
    Returns (total_loss, total_weight) as fp32 scalars."""
    B, S, _ = h.shape
    c = _pick_chunk(S, chunk)
    nc = S // c
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    hc = h.reshape(B, nc, c, -1).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, nc, c).transpose(1, 0, 2)
    mc = mask.reshape(B, nc, c).transpose(1, 0, 2)

    def body(carry, xs):
        loss, weight = carry
        h_i, t_i, m_i = xs
        logits = logits_from_hidden(cfg, params, h_i).astype(jnp.float32)
        logits = pctx.constrain(logits, "logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        # one-hot mask-reduce instead of take_along_axis: vocab-parallel
        # friendly (fuses to a masked local reduce + tiny all-reduce; a
        # gather over the sharded vocab dim would all-gather the logits)
        oh = jax.nn.one_hot(t_i, logits.shape[-1], dtype=logits.dtype)
        ll = jnp.sum(logits * oh, axis=-1)
        loss = loss + jnp.sum((lse - ll) * m_i)
        weight = weight + jnp.sum(m_i)
        return (loss, weight), None

    (loss, weight), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, tc, mc))
    return loss, weight


def loss_fn(cfg: ModelConfig, params, batch, remat: bool = False,
            dispatch: Optional[str] = None):
    """Mean next-token xent (+ MoE aux). Returns (loss, metrics)."""
    h, aux = final_hidden(cfg, params, batch, remat=remat, dispatch=dispatch)
    targets = batch["targets"]
    mask = batch.get("mask")
    if cfg.frontend == "vision":
        # frontend tokens carry no LM targets
        n_front = h.shape[1] - targets.shape[1]
        h = h[:, n_front:]
    loss, weight = chunked_xent(cfg, params, h, targets, mask)
    mean = loss / jnp.maximum(weight, 1.0)
    total = mean + AUX_LOSS_WEIGHT * aux
    return total, {"xent": mean, "aux": aux, "tokens": weight}


def make_train_batch(cfg: ModelConfig, shape: InputShape, rng=None):
    """Concrete random batch (for smoke tests / CPU training)."""
    import numpy as np
    rng = rng or np.random.default_rng(0)
    B, S = shape.global_batch, shape.seq_len
    batch = {}
    if cfg.frontend == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.frontend_dim), dtype=np.float32))
        batch["targets"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    elif cfg.frontend == "vision":
        n_front = cfg.n_frontend_tokens
        s_txt = S - n_front
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, n_front, cfg.frontend_dim),
                                dtype=np.float32))
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, s_txt)), jnp.int32)
        batch["targets"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, s_txt)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        batch["targets"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return batch
