"""Shared neural-net building blocks.

Everything is a pure function over explicit param pytrees (nested dicts of
jnp arrays) — no flax/haiku.  Initializers return numpy-seeded jax arrays via
``jax.random``; compute dtype and param dtype are decoupled (params may be
fp32 or bf16, activations run in ``cfg.dtype``).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from . import pctx

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis_size: Optional[int] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (LeCun-style)."""
    if in_axis_size is None:
        in_axis_size = shape[0]
    std = 1.0 / math.sqrt(max(in_axis_size, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32, zero_centered: bool = True):
    # gemma-style zero-centered scale: weight stored as (scale - 1)
    return {"scale": jnp.zeros((d,), dtype) if zero_centered
            else jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6, zero_centered: bool = True):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    scale = params["scale"].astype(jnp.float32)
    if zero_centered:
        scale = scale + 1.0
    return (x * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), d_model, dtype),
        "w_up": dense_init(k2, (d_model, d_ff), d_model, dtype),
        "w_down": dense_init(k3, (d_ff, d_model), d_ff, dtype),
    }


def _act(x, activation: str):
    if activation == "geglu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)  # swiglu


def mlp(params, x, activation: str = "swiglu"):
    dtype = x.dtype
    gate = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(dtype))
    up = jnp.einsum("...d,df->...f", x, params["w_up"].astype(dtype))
    h = _act(gate, activation) * up
    h = pctx.constrain(h, "ffn_hidden" if h.ndim == 3 else "ffn_hidden_2d")
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(dtype))


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,), fp32."""
    half = head_dim // 2
    exponents = jnp.arange(0, half, dtype=jnp.float32) / half
    return 1.0 / (theta ** exponents)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, n_heads, head_dim); positions: broadcastable to (..., seq)."""
    dtype = x.dtype
    half = x.shape[-1] // 2
    inv_freq = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # (..., S, half)
    angles = angles[..., None, :]  # (..., S, 1, half) broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Softcapping
# ---------------------------------------------------------------------------


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embedding_init(key, vocab: int, d_model: int, dtype=jnp.float32):
    return {"table": embed_init(key, (vocab, d_model), dtype)}


def embed(params, tokens: jnp.ndarray, scale: bool, d_model: int,
          dtype=jnp.bfloat16) -> jnp.ndarray:
    x = jnp.take(params["table"], tokens, axis=0).astype(dtype)
    if scale:
        x = x * jnp.asarray(math.sqrt(d_model), dtype)
    return x


def unembed(params, x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., d_model) -> logits (..., vocab)."""
    return jnp.einsum("...d,vd->...v", x, params["table"].astype(x.dtype))
