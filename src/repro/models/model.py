"""Model assembly: configs -> params -> forward / prefill / decode.

Depth is compiled as ``jax.lax.scan`` over *periods* of the layer pattern:
layer params are stacked per pattern-position with a leading ``n_periods``
axis, so the HLO size is O(pattern length), not O(n_layers) — an 80-layer
model lowers as fast as a 2-layer one.  Non-periodic prefix layers (e.g.
DeepSeek's first dense layer) and ``pattern_tail`` layers are unrolled.

Param tree layout::

    {"embed": {...}, "frontend_proj"?, "lm_head"?, "final_norm",
     "head": [layer0, ...],                  # unrolled prefix
     "body": {"p0": stacked, "p1": stacked}, # scanned periods
     "tail": [layerK, ...]}                  # unrolled suffix
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn
from . import moe as moe_mod
from . import pctx
from . import rglru as rglru_mod
from . import ssd as ssd_mod
from .layers import (dense_init, embed, embedding_init, mlp, mlp_init,
                     rmsnorm, rmsnorm_init, unembed)


class LayerSpec(NamedTuple):
    kind: str            # global | local | chunked | recurrent | ssm
    is_moe: bool
    d_ff: int            # dense-FFN width for this layer (0 -> no FFN)
    rope_theta: float    # 0.0 -> NoPE
    window: int
    causal: bool


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Structure
# ---------------------------------------------------------------------------


def layer_specs(cfg: ModelConfig) -> list[LayerSpec]:
    specs = []
    for i, kind in enumerate(cfg.layer_kinds()):
        is_moe = cfg.is_moe_layer(i)
        if kind == "ssm":
            d_ff = 0
        elif is_moe:
            d_ff = 0  # MoE layer: expert dims live in MoEConfig
        elif cfg.moe is not None:
            d_ff = cfg.moe.d_ff_dense or cfg.d_ff
        else:
            d_ff = cfg.d_ff
        if kind == "global":
            theta = (0.0 if cfg.nope_global
                     else (cfg.rope_theta_global or cfg.rope_theta))
        else:
            theta = cfg.rope_theta
        specs.append(LayerSpec(
            kind=kind, is_moe=is_moe, d_ff=d_ff, rope_theta=theta,
            window=cfg.window, causal=not cfg.encoder_only))
    return specs


def block_structure(cfg: ModelConfig):
    """-> (head_specs, period_specs, n_periods, tail_specs)."""
    specs = layer_specs(cfg)
    n_head = cfg.moe.first_dense_layers if cfg.moe else 0
    n_tail = len(cfg.pattern_tail)
    body = specs[n_head: len(specs) - n_tail] if n_tail else specs[n_head:]
    P = len(cfg.pattern)
    if cfg.moe is not None:
        P = math.lcm(P, cfg.moe.moe_period)
    assert len(body) % P == 0, (cfg.name, len(body), P)
    n_periods = len(body) // P
    period = body[:P]
    for j in range(n_periods):  # uniformity check (required for scan)
        assert tuple(body[j * P: (j + 1) * P]) == tuple(period), cfg.name
    tail = specs[len(specs) - n_tail:] if n_tail else []
    return specs[:n_head], period, n_periods, tail


def attn_spec(cfg: ModelConfig, spec: LayerSpec, q_block: int = 512):
    return attn.AttnSpec(
        kind=spec.kind, causal=spec.causal, window=spec.window,
        rope_theta=spec.rope_theta, softcap=cfg.attn_softcap,
        qk_norm=cfg.qk_norm, q_block=q_block)


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------


def init_layer(key, cfg: ModelConfig, spec: LayerSpec, param_dtype):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict[str, Any] = {"pre_norm": rmsnorm_init(d, param_dtype)}
    if spec.kind in ("global", "local", "chunked"):
        if cfg.mla is not None:
            p["mla"] = attn.mla_init(ks[0], d, cfg.n_heads, cfg.mla,
                                     param_dtype)
        else:
            p["attn"] = attn.attention_init(
                ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                cfg.resolved_head_dim(), cfg.qkv_bias, cfg.qk_norm,
                param_dtype)
        if cfg.post_norms:
            p["post_attn_norm"] = rmsnorm_init(d, param_dtype)
    elif spec.kind == "recurrent":
        p["rglru"] = rglru_mod.rglru_init(ks[0], d, cfg.n_heads,
                                          cfg.rglru, param_dtype)
    elif spec.kind == "ssm":
        p["ssd"] = ssd_mod.ssd_init(ks[0], d, cfg.ssd, param_dtype)
        return p  # mamba2 block has no separate FFN / second norm
    p["pre_ffn_norm"] = rmsnorm_init(d, param_dtype)
    if spec.is_moe:
        p["moe"] = moe_mod.moe_init(ks[1], d, cfg.moe, param_dtype)
    elif spec.d_ff:
        p["mlp"] = mlp_init(ks[1], d, spec.d_ff, param_dtype)
    if cfg.post_norms:
        p["post_ffn_norm"] = rmsnorm_init(d, param_dtype)
    return p


def init_params(cfg: ModelConfig, key, param_dtype=None):
    param_dtype = param_dtype or jnp.float32
    head_s, period_s, n_periods, tail_s = block_structure(cfg)
    n_keys = len(head_s) + len(period_s) * n_periods + len(tail_s) + 3
    keys = list(jax.random.split(key, n_keys))
    params: dict[str, Any] = {
        "embed": embedding_init(keys.pop(), cfg.vocab_size, cfg.d_model,
                                param_dtype),
        "final_norm": rmsnorm_init(cfg.d_model, param_dtype),
    }
    if not cfg.tie_embeddings and not cfg.encoder_only:
        params["lm_head"] = embedding_init(keys.pop(), cfg.vocab_size,
                                           cfg.d_model, param_dtype)
    if cfg.frontend is not None:
        params["frontend_proj"] = dense_init(
            keys.pop(), (cfg.frontend_dim, cfg.d_model), cfg.frontend_dim,
            param_dtype)
    params["head"] = [init_layer(keys.pop(), cfg, s, param_dtype)
                      for s in head_s]
    body = {}
    for pi, s in enumerate(period_s):
        per = [init_layer(keys.pop(), cfg, s, param_dtype)
               for _ in range(n_periods)]
        body[f"p{pi}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    params["body"] = body
    params["tail"] = [init_layer(keys.pop(), cfg, s, param_dtype)
                      for s in tail_s]
    return params


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int, dtype):
    d = cfg.d_model
    if spec.kind in ("global", "local", "chunked"):
        if cfg.mla is not None:
            m = cfg.mla
            return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
                    "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim),
                                        dtype)}
        L = max_len if spec.kind == "global" else min(spec.window, max_len)
        hd = cfg.resolved_head_dim()
        return {"k": jnp.zeros((batch, L, cfg.n_kv_heads, hd), dtype),
                "v": jnp.zeros((batch, L, cfg.n_kv_heads, hd), dtype)}
    if spec.kind == "recurrent":
        r = cfg.rglru
        w = r.lru_width or d
        return {"h": jnp.zeros((batch, w), jnp.float32),
                "conv": jnp.zeros((batch, r.conv_width - 1, w), dtype)}
    # ssm
    s = cfg.ssd
    di = s.d_inner(d)
    conv_ch = di + 2 * s.n_groups * s.d_state
    return {"h": jnp.zeros((batch, s.n_heads(d), s.head_dim, s.d_state),
                           dtype),
            "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dtype = _dtype(cfg)
    head_s, period_s, n_periods, tail_s = block_structure(cfg)
    cache: dict[str, Any] = {
        "head": [init_layer_cache(cfg, s, batch, max_len, dtype)
                 for s in head_s],
        "tail": [init_layer_cache(cfg, s, batch, max_len, dtype)
                 for s in tail_s],
    }
    body = {}
    for pi, s in enumerate(period_s):
        one = init_layer_cache(cfg, s, batch, max_len, dtype)
        body[f"p{pi}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_periods,) + x.shape), one)
    cache["body"] = body
    return cache


# ---------------------------------------------------------------------------
# Block apply
# ---------------------------------------------------------------------------


def _residual(cfg, params, key, y):
    if cfg.post_norms and key in params:
        y = rmsnorm(params[key], y, cfg.norm_eps)
    return y


def block_apply(cfg: ModelConfig, spec: LayerSpec, params, x, positions,
                mode: str, cache=None, pos=None, cache_len: int = 0,
                dispatch: Optional[str] = None):
    """One transformer block. Returns (x, new_cache, aux_loss)."""
    eps = cfg.norm_eps
    aux = jnp.zeros((), jnp.float32)
    x = pctx.constrain(x, "activations")
    h = rmsnorm(params["pre_norm"], x, eps)
    new_cache = cache
    if spec.kind in ("global", "local", "chunked"):
        aspec = attn_spec(cfg, spec)
        if cfg.mla is not None:
            if mode == "forward":
                y = attn.mla_forward(params["mla"], h, cfg.mla, aspec,
                                     positions, eps)
            elif mode == "prefill":
                y, new_cache = attn.mla_make_cache(
                    params["mla"], h, cfg.mla, aspec, cache_len, positions,
                    eps)
            else:
                y, new_cache = attn.mla_decode(params["mla"], h, cache,
                                               cfg.mla, aspec, pos, eps)
        else:
            if mode == "forward":
                y = attn.attention_forward(params["attn"], h, aspec,
                                           positions, eps)
            elif mode == "prefill":
                y, new_cache = attn.attention_make_cache(
                    params["attn"], h, aspec, cache_len, positions, eps)
            else:
                y, new_cache = attn.attention_decode(params["attn"], h,
                                                     cache, aspec, pos, eps)
        x = x + _residual(cfg, params, "post_attn_norm", y)
    elif spec.kind == "recurrent":
        if mode == "forward":
            y = rglru_mod.rglru_forward(params["rglru"], h, cfg.n_heads,
                                        cfg.rglru)
        elif mode == "prefill":
            y, new_cache = rglru_mod.rglru_forward(
                params["rglru"], h, cfg.n_heads, cfg.rglru,
                return_state=True)
        else:
            y, new_cache = rglru_mod.rglru_decode(params["rglru"], h, cache,
                                                  cfg.n_heads, cfg.rglru)
        x = x + y
    else:  # ssm
        if mode == "forward":
            y = ssd_mod.ssd_forward(params["ssd"], h, cfg.ssd, eps)
        elif mode == "prefill":
            y, new_cache = ssd_mod.ssd_forward(params["ssd"], h, cfg.ssd,
                                               eps, return_state=True)
        else:
            y, new_cache = ssd_mod.ssd_decode(params["ssd"], h, cache,
                                              cfg.ssd, eps)
        return x + y, new_cache, aux

    # FFN half
    h = rmsnorm(params["pre_ffn_norm"], x, eps)
    if spec.is_moe:
        y = moe_mod.moe_forward(params["moe"], h, cfg.moe, cfg.activation,
                                dispatch)
        if mode == "forward":
            aux = moe_mod.moe_aux_loss(params["moe"], h, cfg.moe)
    elif spec.d_ff:
        y = mlp(params["mlp"], h, cfg.activation)
    else:
        y = jnp.zeros_like(x)
    x = x + _residual(cfg, params, "post_ffn_norm", y)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def embed_inputs(cfg: ModelConfig, params, batch):
    """-> (x (B,S,d), positions (B,S))."""
    dtype = _dtype(cfg)
    if cfg.frontend == "audio":
        x = jnp.einsum("bsf,fd->bsd", batch["frames"].astype(dtype),
                       params["frontend_proj"].astype(dtype))
    elif cfg.frontend == "vision":
        img = jnp.einsum("bsf,fd->bsd", batch["patch_embeds"].astype(dtype),
                         params["frontend_proj"].astype(dtype))
        txt = embed(params["embed"], batch["tokens"], cfg.emb_scale,
                    cfg.d_model, dtype)
        x = jnp.concatenate([img, txt], axis=1)
    else:
        x = embed(params["embed"], batch["tokens"], cfg.emb_scale,
                  cfg.d_model, dtype)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    return x, positions


def apply_blocks(cfg: ModelConfig, params, x, positions, mode: str,
                 cache=None, pos=None, cache_len: int = 0,
                 remat: bool = False, dispatch: Optional[str] = None):
    """Run all layers. Returns (x, new_cache, aux_sum)."""
    head_s, period_s, n_periods, tail_s = block_structure(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    def run_unrolled(x, specs, plist, clist, aux_total):
        new_caches = []
        for i, s in enumerate(specs):
            c = clist[i] if clist is not None else None
            x, nc, aux = block_apply(cfg, s, plist[i], x, positions, mode,
                                     c, pos, cache_len, dispatch)
            new_caches.append(nc)
            aux_total = aux_total + aux
        return x, new_caches, aux_total

    x, head_cache, aux_total = run_unrolled(
        x, head_s, params["head"],
        cache["head"] if cache is not None else None, aux_total)

    def period_fn(x, pparams, pcache):
        new_c = {}
        aux = jnp.zeros((), jnp.float32)
        for pi, s in enumerate(period_s):
            c = pcache[f"p{pi}"] if pcache is not None else None
            x, nc, a = block_apply(cfg, s, pparams[f"p{pi}"], x, positions,
                                   mode, c, pos, cache_len, dispatch)
            new_c[f"p{pi}"] = nc
            aux = aux + a
        return x, new_c, aux

    if remat:
        period_fn = jax.checkpoint(period_fn)

    if n_periods:
        if cache is not None:
            def scan_body(carry, xs):
                x, aux = carry
                pparams, pcache = xs
                x, nc, a = period_fn(x, pparams, pcache)
                return (x, aux + a), nc
            (x, aux_total), body_cache = jax.lax.scan(
                scan_body, (x, aux_total), (params["body"], cache["body"]))
        else:
            def scan_body(carry, pparams):
                x, aux = carry
                x, _, a = period_fn(x, pparams, None)
                return (x, aux + a), None
            (x, aux_total), _ = jax.lax.scan(
                scan_body, (x, aux_total), params["body"])
            body_cache = None

    x, tail_cache, aux_total = run_unrolled(
        x, tail_s, params["tail"],
        cache["tail"] if cache is not None else None, aux_total)

    new_cache = None
    if cache is not None:
        new_cache = {"head": head_cache, "body": body_cache,
                     "tail": tail_cache}
    return x, new_cache, aux_total


def final_hidden(cfg: ModelConfig, params, batch, remat: bool = False,
                 dispatch: Optional[str] = None):
    """Train/scoring path: full sequence -> final hidden states + aux."""
    x, positions = embed_inputs(cfg, params, batch)
    x, _, aux = apply_blocks(cfg, params, x, positions, "forward",
                             remat=remat, dispatch=dispatch)
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def logits_from_hidden(cfg: ModelConfig, params, h):
    table = params.get("lm_head", params["embed"])
    out = unembed(table, h)
    if cfg.logit_softcap:
        out = jnp.tanh(out / cfg.logit_softcap) * cfg.logit_softcap
    return out


def forward(cfg: ModelConfig, params, batch, remat: bool = False,
            dispatch: Optional[str] = None):
    h, aux = final_hidden(cfg, params, batch, remat, dispatch)
    return logits_from_hidden(cfg, params, h)


def prefill(cfg: ModelConfig, params, batch, cache_len: int,
            dispatch: Optional[str] = None):
    """-> (last-position logits (B, V), cache)."""
    x, positions = embed_inputs(cfg, params, batch)
    cache = init_cache(cfg, x.shape[0], cache_len)
    x, cache, _ = apply_blocks(cfg, params, x, positions, "prefill",
                               cache=cache, cache_len=cache_len,
                               dispatch=dispatch)
    h = rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    return logits_from_hidden(cfg, params, h)[:, 0], cache


def decode_step(cfg: ModelConfig, params, tokens, pos, cache,
                dispatch: Optional[str] = None):
    """tokens: (B,) int32; pos: (B,) int32. -> (logits (B, V), cache)."""
    dtype = _dtype(cfg)
    x = embed(params["embed"], tokens[:, None], cfg.emb_scale, cfg.d_model,
              dtype)
    x, cache, _ = apply_blocks(cfg, params, x, pos[:, None], "decode",
                               cache=cache, pos=pos, dispatch=dispatch)
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_from_hidden(cfg, params, h)[:, 0], cache
