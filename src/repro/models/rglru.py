"""RG-LRU recurrent block (RecurrentGemma / Griffin).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)
a_t = exp(-c * softplus(Lambda) * r_t),  c = 8
r_t, i_t: block-diagonal linear gates over the conv'd input.

Train/prefill uses ``jax.lax.associative_scan`` over time (the recurrence
h = a*h + b is associative) — sequence-parallel, O(log S) depth.  The Pallas
TPU kernel for the scan lives in ``repro.kernels.rglru_scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init

_C = 8.0
_MAX_SQRT = 1e6


def rglru_init(key, d_model: int, n_heads: int, rglru, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    w = rglru.lru_width or d_model
    nb = n_heads
    bw = w // nb
    # Lambda init so that a^c in (0.9, 0.999) at r=1 (Griffin appendix)
    u = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    a_param = jnp.log(jnp.expm1(-(1.0 / _C) * jnp.log(u)))
    return {
        "w_x": dense_init(ks[0], (d_model, w), d_model, dtype),
        "w_gate_branch": dense_init(ks[1], (d_model, w), d_model, dtype),
        "conv_w": dense_init(ks[2], (rglru.conv_width, w), rglru.conv_width,
                             dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_r": dense_init(ks[3], (nb, bw, bw), bw, dtype),
        "b_r": jnp.zeros((w,), dtype),
        "w_i": dense_init(ks[5], (nb, bw, bw), bw, dtype),
        "b_i": jnp.zeros((w,), dtype),
        "a_param": a_param,
        "w_out": dense_init(ks[2], (w, d_model), w, dtype),
    }


def _block_diag(x, w, b, nb):
    """x: (..., W) with W = nb*bw; w: (nb, bw, bw)."""
    shape = x.shape
    xb = x.reshape(*shape[:-1], nb, -1)
    out = jnp.einsum("...nb,nbc->...nc", xb, w)
    return out.reshape(shape) + b


def _gates(params, u, nb):
    dtype = u.dtype
    r = jax.nn.sigmoid(_block_diag(u, params["w_r"].astype(dtype),
                                   params["b_r"].astype(dtype), nb)
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(u, params["w_i"].astype(dtype),
                                   params["b_i"].astype(dtype), nb)
                       .astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(params["a_param"].astype(jnp.float32))
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) in fp32, clipped for stability near a=1
    beta = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, None))
    gated_in = i * u.astype(jnp.float32)
    return a, beta * gated_in


def lru_scan(a, b, h0=None):
    """Associative scan of h_t = a_t h_{t-1} + b_t over axis 1 (time).
    a, b: (B, S, W) fp32.  Returns h: (B, S, W)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def _causal_conv(x, w, b):
    W = w.shape[0]
    out = x * w[-1] + b
    for i in range(1, W):
        shifted = jnp.pad(x, [(0, 0), (i, 0), (0, 0)])[:, : x.shape[1]]
        out = out + shifted * w[-1 - i]
    return out


def rglru_forward(params, x, n_heads: int, rglru, state=None,
                  return_state: bool = False):
    """x: (B, S, d_model) -> (B, S, d_model)."""
    dtype = x.dtype
    gate = jax.nn.gelu(jnp.einsum(
        "bsd,dw->bsw", x, params["w_gate_branch"].astype(dtype)))
    u_raw = jnp.einsum("bsd,dw->bsw", x, params["w_x"].astype(dtype))
    if state is not None:
        # continue the conv across the prefill boundary
        buf = jnp.concatenate([state["conv"].astype(dtype), u_raw], axis=1)
        u = _causal_conv(buf, params["conv_w"].astype(dtype),
                         params["conv_b"].astype(dtype))[:, state["conv"].shape[1]:]
    else:
        u = _causal_conv(u_raw, params["conv_w"].astype(dtype),
                         params["conv_b"].astype(dtype))
    a, b = _gates(params, u, n_heads)
    h0 = None if state is None else state["h"].astype(jnp.float32)
    h = lru_scan(a, b, h0).astype(dtype)
    out = jnp.einsum("bsw,wd->bsd", h * gate, params["w_out"].astype(dtype))
    if return_state:
        W = rglru.conv_width - 1
        S = u_raw.shape[1]
        tail = (u_raw[:, S - W:] if S >= W
                else jnp.pad(u_raw, [(0, 0), (W - S, 0), (0, 0)]))
        return out, {"h": h[:, -1].astype(jnp.float32), "conv": tail}
    return out


def rglru_decode(params, x, state, n_heads: int, rglru):
    """x: (B, 1, d); state: {"h": (B, W) fp32, "conv": (B, cw-1, W)}."""
    dtype = x.dtype
    gate = jax.nn.gelu(jnp.einsum(
        "bsd,dw->bsw", x, params["w_gate_branch"].astype(dtype)))
    u_new = jnp.einsum("bsd,dw->bsw", x, params["w_x"].astype(dtype))[:, 0]
    buf = jnp.concatenate([state["conv"].astype(dtype), u_new[:, None]],
                          axis=1)
    u = (jnp.einsum("bwc,wc->bc", buf, params["conv_w"].astype(dtype))
         + params["conv_b"].astype(dtype))
    a, b = _gates(params, u[:, None], n_heads)
    h = a[:, 0] * state["h"] + b[:, 0]
    out = jnp.einsum("bsw,wd->bsd", h[:, None].astype(dtype) * gate,
                     params["w_out"].astype(dtype))
    return out, {"h": h, "conv": buf[:, 1:]}
