"""AdamW from scratch (no optax): fp32 master weights, configurable
moment dtype (bf16 moments halve optimizer HBM for the 100B+ dry-runs),
decoupled weight decay, global-norm clipping, warmup+cosine schedule.

The optimizer state is a pytree congruent with the params tree, so the
sharding rules that shard a weight also shard its moments — no separate
optimizer partitioning logic (ZeRO falls out of FSDP'd params).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"   # "bfloat16" halves optimizer memory


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array       # i32 scalar


def init(params, cfg: AdamWConfig) -> OptState:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return OptState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def schedule(cfg: AdamWConfig, step) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(math.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def _decayable(path) -> bool:
    """No weight decay on norms / biases / scalars (standard practice)."""
    name = str(path[-1]) if path else ""
    return not any(t in name for t in ("scale", "bias", "b_", "a_param",
                                       "A_log", "dt_bias", "D"))


def update(params, grads, state: OptState, cfg: AdamWConfig):
    """-> (new_params, new_state, metrics). Everything fp32 math."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.ones(())
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        upd_ = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        p32 = p.astype(jnp.float32)
        if _decayable(path):
            upd_ = upd_ + cfg.weight_decay * p32
        new_p = p32 - lr * upd_
        return new_p.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    out = jax.tree_util.tree_map_with_path(upd, params, grads, state.m,
                                           state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(new_m, new_v, step), {
        "grad_norm": gnorm, "lr": lr}
